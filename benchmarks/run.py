"""Benchmark harness — one function per paper table/figure + kernel/system
micro-benchmarks.  Prints ``name,us_per_call,derived`` CSV rows (deliverable
d).  ``derived`` carries the benchmark's headline quantity (power reduction,
cluster count, rel-error, ...).

    PYTHONPATH=src python -m benchmarks.run [--only tableII] [--fast]
        [--out-dir DIR] [--json-out PATH] [--min-flow-speedup X]

JSON artifacts (``BENCH_serve.json``, ``BENCH_flow.json``,
``BENCH_hwloop.json``, ``BENCH_traffic.json``, ``BENCH_resilience.json``,
``BENCH_railscale.json``) land in ``--out-dir`` (default: CWD); ``--json-out`` overrides the exact path
when a single ``--only`` scenario is run.  ``--min-flow-speedup`` turns the
``flow`` scenario into a CI gate: exit non-zero unless the vectorized sweep
beats the loop-reference sweep by at least that factor.
``--resilience-gate`` does the same for the ``resilience`` scenario: exit
non-zero unless abft-guarded GEMMs show zero silent escapes and the chaos
campaign is all-green.  ``--obs-overhead-gate PCT`` gates the ``obs``
scenario (``BENCH_obs.json``): exit non-zero unless tracing overhead is
below PCT% and two identical virtual-time runs render bit-identical
metric snapshots.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

# single-core hosts deadlock the pure_callback serving path (see conftest);
# arm the XLA thread-pool workaround before any scenario builds a CPU client
from repro.backend import ensure_host_callback_capacity

ensure_host_callback_capacity()

#: Output routing for JSON artifacts, set by main() from --out-dir/--json-out.
_OUT: Dict[str, Optional[str]] = {"dir": ".", "json_out": None}

#: The shared BENCH_*.json envelope: every JSON-writing scenario emits these
#: top-level keys with identical semantics, and scenario-specific ``config``
#: blocks reuse the same key names for the same concepts (``arch``,
#: ``requests``, ``slots``, ``max_len``, ``array_n``, ``seed``, ...).
BENCH_SCHEMA_KEYS: Tuple[str, ...] = ("scenario", "elapsed_s", "config")


def validate_bench_payload(payload: Dict) -> None:
    """Assert the shared BENCH_*.json schema (tests/benchmarks pins this)."""
    for key in BENCH_SCHEMA_KEYS:
        if key not in payload:
            raise ValueError(f"BENCH payload missing {key!r}; has "
                             f"{sorted(payload)}")
    if not isinstance(payload["scenario"], str) or not payload["scenario"]:
        raise ValueError(f"scenario must be a non-empty string, got "
                         f"{payload['scenario']!r}")
    elapsed = payload["elapsed_s"]
    if not isinstance(elapsed, (int, float)) or not np.isfinite(elapsed) \
            or elapsed < 0:
        raise ValueError(f"elapsed_s must be a finite non-negative number, "
                         f"got {elapsed!r}")
    if not isinstance(payload["config"], dict):
        raise ValueError(f"config must be a dict, got "
                         f"{type(payload['config']).__name__}")


def bench_payload(scenario: str, elapsed_s: float, config: Dict,
                  **extra) -> Dict:
    """Build (and eagerly validate) a BENCH_*.json payload."""
    payload = {"scenario": scenario, "elapsed_s": float(elapsed_s),
               "config": dict(config), **extra}
    validate_bench_payload(payload)
    return payload


def _json_path(default_name: str) -> str:
    """Where a benchmark's JSON artifact goes (honours --out-dir/--json-out)."""
    if _OUT["json_out"]:
        parent = os.path.dirname(_OUT["json_out"])
        if parent:
            os.makedirs(parent, exist_ok=True)
        return _OUT["json_out"]
    out_dir = _OUT["dir"] or "."
    os.makedirs(out_dir, exist_ok=True)
    return os.path.join(out_dir, default_name)


def _time_us(fn: Callable, repeats: int = 3) -> Tuple[float, object]:
    out = fn()                     # warmup + result
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
    dt = (time.perf_counter() - t0) / repeats
    return dt * 1e6, out


def bench_tableII(fast: bool) -> List[Tuple[str, float, str]]:
    """Paper Table II: dynamic power, 3 array sizes x 4 techs, model vs paper."""
    from repro.core import validate_against_table2
    rows = []
    us, table = _time_us(lambda: validate_against_table2())
    worst = max(abs(r["delta_pp"]) for r in table)
    rows.append(("tableII/all15rows", us, f"max|delta|={worst:.2f}pp"))
    for r in table[:3]:
        rows.append((f"tableII/{r['tech']}_{r['array']}x{r['array']}", us / 15,
                     f"model={r['model_reduction_pct']:.2f}%"
                     f"_paper={r['paper_reduction_pct']:.2f}%"))
    return rows


def bench_fig15_16(fast: bool) -> List[Tuple[str, float, str]]:
    """Figs. 15/16: 64x64 variant sweep per tech.

    Variant voltage ranges follow the paper: 0.5-1.2 V for 22/45 nm,
    0.7-1.3 V for 130 nm (its threshold is 0.7 V).  The paper's minimum-power
    variants (2x(32x64){0.5,0.6} resp. {0.7,0.8}) must win.  NOTE: the
    paper's quoted 18/21/39% spreads are inconsistent with its own Table II
    reductions under any single P(V) law; our model is calibrated to Table II
    and reports the spread that calibration implies (EXPERIMENTS.md
    §Paper-validation)."""
    from repro.core import model_for
    v_2245 = {
        "2x(32x64){0.5,0.6}": ([0.5, 0.6], [0.5, 0.5]),
        "4x(32x32){0.5,0.6,0.7,0.8}": ([0.5, 0.6, 0.7, 0.8], None),
        "4x(32x32){0.8,1.0,1.2,1.2}": ([0.8, 1.0, 1.2, 1.2], None),
        "2x(32x64){1.0,1.2}": ([1.0, 1.2], [0.5, 0.5]),
    }
    v_130 = {
        "2x(32x64){0.7,0.8}": ([0.7, 0.8], [0.5, 0.5]),
        "4x(32x32){0.7,0.9,1.1,1.3}": ([0.7, 0.9, 1.1, 1.3], None),
        "4x(32x32){0.8,1.0,1.2,1.3}": ([0.8, 1.0, 1.2, 1.3], None),
        "2x(32x64){1.1,1.3}": ([1.1, 1.3], [0.5, 0.5]),
    }
    out = []
    for tech, variants, paper_best in (
            ("vtr-22nm", v_2245, "2x(32x64){0.5,0.6}"),
            ("vtr-45nm", v_2245, "2x(32x64){0.5,0.6}"),
            ("vtr-130nm", v_130, "2x(32x64){0.7,0.8}")):
        m = model_for(tech)

        def sweep():
            return {k: m.partitioned_mw(64, v, frac)
                    for k, (v, frac) in variants.items()}

        us, powers = _time_us(sweep)
        spread = (max(powers.values()) - min(powers.values())) \
            / max(powers.values())
        best = min(powers, key=powers.get)
        out.append((f"fig15_16/{tech}", us,
                    f"spread={spread:.1%}_best={best}"
                    f"_paperbest_match={best == paper_best}"))
    return out


def bench_clustering(fast: bool) -> List[Tuple[str, float, str]]:
    """Figs. 10-14: the four algorithms on 16x16..64x64 min-slack data."""
    from repro.core import (TimingModel, dbscan, hierarchical, kmeans,
                            meanshift)
    sizes = [16, 32] if fast else [16, 32, 64]
    out = []
    for n in sizes:
        slack = TimingModel(n=n, seed=2021).min_slack_flat()
        spread = slack.max() - slack.min()
        algos = {
            "kmeans": lambda: kmeans(slack, 4, seed=0),
            "hierarchical": lambda: hierarchical(slack, 4),
            "meanshift": lambda: meanshift(slack, bandwidth=0.17 * spread),
            "dbscan": lambda: dbscan(slack, eps=spread / 12,
                                     min_pts=max(4, len(slack) // 64)),
        }
        # hierarchical at 64x64 used to be excluded (the O(n^3) loop oracle
        # takes minutes at 4096 points); the nearest-neighbour-cached
        # vectorized rewrite runs it in ~2 s, so it stays in
        for name, fn in algos.items():
            us, labels = _time_us(fn, repeats=1)
            k = len(set(labels.tolist()) - {-1})
            out.append((f"clustering/{name}_{n}x{n}", us, f"clusters={k}"))
    return out


def bench_cadflow(fast: bool) -> List[Tuple[str, float, str]]:
    """End-to-end flow (Fig. 9) incl. Razor-runtime calibration, via the
    staged repro.flow pipeline."""
    from repro.flow import FlowConfig, run
    out = []
    for tech in ("vivado-28nm", "vtr-22nm"):
        us, rep = _time_us(
            lambda t=tech: run(FlowConfig(array_n=16, tech=t, algo="dbscan",
                                          seed=2021)), repeats=1)
        out.append((f"cadflow/16x16_{tech}", us,
                    f"static={rep.static_reduction_pct:.2f}%"
                    f"_runtime={rep.runtime_reduction_pct:.2f}%"))
    return out


def bench_flow_sweep(fast: bool) -> List[Tuple[str, float, str]]:
    """Multi-scenario sweep with shared artifact-prefix caching: the timing
    stage must run once per tech node regardless of how many clustering
    algorithms ride on it."""
    from repro.flow import FlowConfig, sweep
    techs = ["vivado-28nm", "vtr-22nm"] if fast else \
        ["vivado-28nm", "vtr-22nm", "vtr-45nm", "vtr-130nm"]
    algos = ["kmeans", "dbscan"] if fast else \
        ["kmeans", "hierarchical", "meanshift", "dbscan"]

    def go():
        return sweep({"tech": techs, "algo": algos},
                     FlowConfig(array_n=16, seed=2021))

    us, res = _time_us(go, repeats=1)
    return [("flow_sweep/%dtech_x_%dalgo" % (len(techs), len(algos)), us,
             f"configs={len(res.configs)}"
             f"_timing_runs={res.timing_stage_runs()}"
             f"_best={res.best()['runtime_reduction_pct']:.2f}%")]


def bench_flow(fast: bool) -> List[Tuple[str, float, str]]:
    """Vectorized vs loop-reference CAD-flow sweep (the PR's perf headline).

    Runs the full 4-tech x 4-algorithm 16x16 grid twice: once with the
    vectorized hot paths + content-addressed stage sharing, once with the
    bit-exact loop oracles and seed-era cache topology
    (``impl="reference"``, ``Pipeline(content_cache=False)``, per-run power
    fit).  Verifies the 16 FlowReports are bit-identical, then writes the
    timing comparison to BENCH_flow.json.
    """
    from repro.flow import FlowConfig, Pipeline, sweep
    grid = {"tech": ["vivado-28nm", "vtr-22nm", "vtr-45nm", "vtr-130nm"],
            "algo": ["kmeans", "hierarchical", "meanshift", "dbscan"]}
    base = dict(array_n=16, seed=2021)
    repeats = 1 if fast else 3
    sweep(grid, FlowConfig(**base))                    # warm numpy/caches

    runs: Dict[str, Dict] = {}
    for name, impl, cc in (("vectorized", "vectorized", True),
                           ("reference", "reference", False)):
        best_s, res = float("inf"), None
        for _ in range(repeats):
            t0 = time.perf_counter()
            r = sweep(grid, FlowConfig(impl=impl, **base),
                      pipeline=Pipeline(content_cache=cc))
            dt = time.perf_counter() - t0
            if dt < best_s:
                best_s, res = dt, r
        runs[name] = {
            "wall_s": best_s,
            "per_config_s": [round(s, 6) for s in res.elapsed_s],
            "timing_stage_runs": res.store.runs_of("timing"),
            "cluster_stage_runs": res.store.runs_of("cluster"),
            "result": res,
        }

    rv, rr = runs["vectorized"]["result"], runs["reference"]["result"]
    identical = all(
        np.array_equal(a.labels, b.labels)
        and np.array_equal(a.static_v, b.static_v)
        and np.array_equal(np.asarray(a.runtime_v), np.asarray(b.runtime_v))
        and a.n_partitions == b.n_partitions
        and a.baseline_mw == b.baseline_mw and a.static_mw == b.static_mw
        and a.runtime_mw == b.runtime_mw and a.razor_trials == b.razor_trials
        for a, b in zip(rv.reports, rr.reports))
    speedup = runs["reference"]["wall_s"] / runs["vectorized"]["wall_s"]

    payload = bench_payload(
        "flow",
        runs["vectorized"]["wall_s"] + runs["reference"]["wall_s"],
        {**grid, **base, "repeats": repeats},
        configs=len(rv.configs),
        vectorized={k: v for k, v in runs["vectorized"].items()
                    if k != "result"},
        reference={k: v for k, v in runs["reference"].items()
                   if k != "result"},
        speedup=speedup,
        bit_identical_reports=bool(identical),
        best_runtime_reduction_pct=rv.best()["runtime_reduction_pct"],
        notes="reference = loop clustering/simulator/power-fit oracles "
              "with prefix-only caching (seed behaviour); vectorized = "
              "array hot paths + content-addressed cluster/floorplan "
              "sharing. Reports are bit-identical across the two.",
    )
    with open(_json_path("BENCH_flow.json"), "w") as f:
        json.dump(payload, f, indent=2)
    return [
        ("flow/vectorized_4tech_x_4algo_16x16",
         runs["vectorized"]["wall_s"] * 1e6,
         f"cluster_runs={runs['vectorized']['cluster_stage_runs']}"),
        ("flow/reference_4tech_x_4algo_16x16",
         runs["reference"]["wall_s"] * 1e6,
         f"cluster_runs={runs['reference']['cluster_stage_runs']}"),
        ("flow/speedup", 0.0,
         f"x{speedup:.2f}_bit_identical={identical}"),
    ]


def bench_systolic_sim(fast: bool) -> List[Tuple[str, float, str]]:
    """Cycle-level fault-injection simulator throughput."""
    from repro.core import (RazorConfig, SystolicSim, TimingModel, TECH_NODES,
                            quadrant_floorplan)
    tm = TimingModel(n=16, tech=TECH_NODES["vtr-22nm"], seed=2021)
    fp = quadrant_floorplan(16).with_voltages([0.9, 0.9, 1.0, 1.0])
    sim = SystolicSim(tm, fp, RazorConfig())
    rng = np.random.default_rng(0)
    a, w = rng.normal(size=(64, 16)), rng.normal(size=(16, 16))
    us, (c, stats) = _time_us(lambda: sim.matmul(a, w), repeats=1)
    return [("systolic_sim/16x16_m64", us,
             f"rel_err={stats.rel_error:.2e}_replays={stats.replay_cycles}")]


def bench_kernels(fast: bool) -> List[Tuple[str, float, str]]:
    """Pallas kernels in interpret mode vs their oracles (correctness +
    wall time; interpret-mode numbers are NOT TPU performance)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.ops import (precision_mm, razor_mm, ssd_op,
                                   systolic_matmul, wkv6_op)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (256, 256), jnp.bfloat16)
    b = jax.random.normal(k2, (256, 256), jnp.bfloat16)
    vmap_ = jnp.full((2, 2), 0.9)
    vsafe = jnp.asarray([[0.8, 1.0], [0.8, 0.8]])
    out = []

    us, (c, flags) = _time_us(
        lambda: jax.block_until_ready(systolic_matmul(a, b, vmap_, vsafe)))
    c_ref, f_ref = ref.systolic_mac(a, b, vmap_, vsafe)
    out.append(("kernels/systolic_mac_256", us,
                f"flags_match={bool((np.array(flags) == np.array(f_ref)).all())}"))

    us, (c, fl, rel) = _time_us(
        lambda: jax.block_until_ready(razor_mm(a, b)))
    out.append(("kernels/razor_matmul_256", us,
                f"max_tile_rel={float(np.array(rel).max()):.3f}"))

    tiers = jnp.asarray([[0, 1], [2, 0]], jnp.int32)
    us, c = _time_us(lambda: jax.block_until_ready(precision_mm(a, b, tiers)))
    out.append(("kernels/precision_island_256", us, "tiers=int4/int8/f32"))

    bs, s, h, p = 1, 128, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r_, k_, v_ = (jax.random.normal(ks[i], (bs, s, h, p)) for i in range(3))
    w_log = -jnp.exp(jax.random.normal(ks[3], (bs, s, h, p)) * 0.5)
    u = jax.random.normal(ks[4], (h, p)) * 0.1
    s0 = jnp.zeros((bs, h, p, p))
    us, (y, _) = _time_us(
        lambda: jax.block_until_ready(wkv6_op(r_, k_, v_, w_log, u, s0,
                                              chunk=32)))
    y_ref, _ = ref.wkv6(r_, k_, v_, w_log, u, s0)
    err = float(jnp.abs(y - y_ref).max())
    out.append(("kernels/wkv6_b1s128", us, f"max_err_vs_ref={err:.2e}"))

    n = 8
    x = jax.random.normal(ks[0], (bs, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, s, h)))
    A_log = jax.random.normal(ks[2], (h,)) * 0.3
    B = jax.random.normal(ks[3], (bs, s, n))
    C = jax.random.normal(ks[4], (bs, s, n))
    D = jnp.ones((h,))
    st = jnp.zeros((bs, h, n, p))
    us, (y, _) = _time_us(
        lambda: jax.block_until_ready(ssd_op(x, dt, A_log, B, C, D, st,
                                             chunk=32)))
    y_ref, _ = ref.ssd(x, dt, A_log, B, C, D, st)
    err = float(jnp.abs(y - y_ref).max())
    out.append(("kernels/ssd_chunk_b1s128", us, f"max_err_vs_ref={err:.2e}"))
    return out


def bench_power_report(fast: bool) -> List[Tuple[str, float, str]]:
    """Paper power model applied to three dry-run cells' MAC counts."""
    from repro.roofline.power_report import power_row
    out = []
    cells = [("qwen1.5-110b", "train_4k"), ("rwkv6-1.6b", "decode_32k"),
             ("llama4-scout-17b-a16e", "prefill_32k")]
    for arch, shape in cells:
        us, row = _time_us(lambda a=arch, s=shape: power_row(a, s), repeats=1)
        out.append((f"power_report/{arch}_{shape}", us,
                    f"runtime_saving={row.runtime_saving_pct:.1f}%"
                    f"_precision={row.precision_saving_pct:.1f}%"))
    return out


def bench_serve(fast: bool) -> List[Tuple[str, float, str]]:
    """Continuous vs wave engine on one mixed smoke workload (CPU); writes
    the full telemetry comparison to BENCH_serve.json."""
    import jax
    from repro.configs import get_config
    from repro.models import model_api
    from repro.serve import Request, ServeEngine, WaveServeEngine
    cfg = get_config("starcoder2-3b", smoke=True)
    params = model_api(cfg).init_params(jax.random.PRNGKey(0))
    n_req = 4 if fast else 8

    def workload():
        return [Request(uid=uid,
                        prompt=rng.integers(3, cfg.vocab_size,
                                            int(rng.integers(1, 7))).tolist(),
                        max_new_tokens=int(rng.integers(2, 8)))
                for uid in range(n_req)]

    rows, engines = [], {}
    for name, engine_cls in (("continuous", ServeEngine),
                             ("wave", WaveServeEngine)):
        rng = np.random.default_rng(0)          # identical request sets

        def serve(engine_cls=engine_cls):
            eng = engine_cls(cfg, params, slots=2, max_len=48)
            for req in workload():
                eng.submit(req)
            return eng.run_until_drained()

        us, stats = _time_us(serve, repeats=1)
        engines[name] = {"us_per_call": us, **stats.to_dict()}
        rows.append((f"serve/{name}_{n_req}req", us,
                     f"model_steps={stats.model_steps}"
                     f"_tok_per_s={stats.tokens_generated / (us / 1e6):.1f}"))
    saved = 1 - engines["continuous"]["model_steps"] \
        / max(engines["wave"]["model_steps"], 1)
    payload = bench_payload(
        "serve",
        sum(e["us_per_call"] for e in engines.values()) / 1e6,
        {"arch": cfg.name, "requests": n_req, "slots": 2, "max_len": 48},
        **engines, model_steps_saved_frac=saved)
    with open(_json_path("BENCH_serve.json"), "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("serve/steps_saved", 0.0, f"saved_frac={saved:.2f}"))
    return rows


def bench_hwloop(fast: bool) -> List[Tuple[str, float, str]]:
    """Hardware-in-the-loop emulation (repro.hwloop): serving throughput with
    and without the emulated voltage-scaled accelerator attached, plus the
    energy/token vs replay-rate curve across rail operating points.  Writes
    BENCH_hwloop.json."""
    import jax
    from repro.configs import get_config
    from repro.flow import ArtifactStore, FlowConfig
    from repro.flow import run as flow_run
    from repro.hwloop import EmulatedAccelerator, HwLoopSession
    from repro.models import model_api
    from repro.serve import Request, ServeEngine

    mcfg = get_config("starcoder2-3b", smoke=True)
    params = model_api(mcfg).init_params(jax.random.PRNGKey(0))
    fcfg = FlowConfig(array_n=8, tech="vtr-22nm", max_trials=8, seed=2021)
    n_req = 3 if fast else 6
    rows: List[Tuple[str, float, str]] = []
    serve_payload: Dict = {}
    # one flow-artifact store shared by every session construction, so the
    # warmup and timed invocations both cache-hit the CAD-flow prefix
    store = ArtifactStore()

    for name in ("ideal", "hwloop"):

        def serve(name=name):
            # fresh session (and rng -> identical workload) per invocation:
            # _time_us calls serve() twice (warmup + timed), and the reported
            # telemetry must cover exactly the run the timing covers
            session = (HwLoopSession(fcfg, probe_rows=8, rail_margin=0.02,
                                     store=store)
                       if name == "hwloop" else None)
            rng = np.random.default_rng(0)
            eng = ServeEngine(mcfg, params, slots=2, max_len=48,
                              hwloop=session)
            for uid in range(n_req):
                eng.submit(Request(
                    uid=uid,
                    prompt=rng.integers(3, mcfg.vocab_size,
                                        int(rng.integers(1, 5))).tolist(),
                    max_new_tokens=int(rng.integers(2, 6))))
            return eng.run_until_drained()

        us, stats = _time_us(serve, repeats=1)
        tok_per_s = stats.tokens_generated / (us / 1e6)
        serve_payload[name] = {
            "us_per_call": us, "tok_per_s": tok_per_s,
            "model_steps": stats.model_steps,
            "telemetry": stats.hwloop,
            "step_flags_nonempty": bool(stats.hwloop_step_flags),
        }
        derived = f"tok_per_s={tok_per_s:.1f}"
        if stats.hwloop:
            derived += (f"_energy_per_tok="
                        f"{stats.hwloop['energy_per_token_j']:.3g}J")
        rows.append((f"hwloop/serve_{name}_{n_req}req", us, derived))
    overhead_pct = 100.0 * (
        serve_payload["ideal"]["tok_per_s"]
        / max(serve_payload["hwloop"]["tok_per_s"], 1e-9) - 1.0)

    # energy/token vs replay-rate across rail operating points: the same
    # calibrated design, rails scaled into (and past) the failure region
    rep = flow_run(fcfg)
    points = []
    for scale in (1.0, 0.97, 0.94, 0.9):
        accel = EmulatedAccelerator.from_flow(
            rep, fcfg, rails=np.asarray(rep.runtime_v) * scale)
        rng = np.random.default_rng(7)
        rel, steps = [], 8
        for _ in range(steps):
            _, tel = accel.matmul(rng.normal(size=(16, 8)),
                                  rng.normal(size=(8, 8)))
            rel.append(tel.rel_error)
        accel.ledger.add_tokens(steps)
        led = accel.ledger
        points.append({
            "rail_scale": scale,
            "rails_v": accel.rails.tolist(),
            "energy_per_token_j": led.energy_per_token_j,
            "replay_rate": led.replay_rate,
            "rel_error_mean": float(np.mean(rel)),
        })
        rows.append((f"hwloop/operating_point_x{scale}", 0.0,
                     f"energy_per_tok={led.energy_per_token_j:.3g}J"
                     f"_replay_rate={led.replay_rate:.2e}"
                     f"_rel_err={float(np.mean(rel)):.2e}"))
    payload = bench_payload(
        "hwloop",
        sum(e["us_per_call"] for e in serve_payload.values()) / 1e6,
        {"arch": mcfg.name, "requests": n_req, "slots": 2, "max_len": 48,
         "flow": fcfg.to_dict()},
        serve=serve_payload, emulation_overhead_pct=overhead_pct,
        operating_points=points)
    with open(_json_path("BENCH_hwloop.json"), "w") as f:
        json.dump(payload, f, indent=2)
    return rows


def bench_traffic(fast: bool) -> List[Tuple[str, float, str]]:
    """Traffic-trace overload envelope (repro.server): seeded Poisson /
    heavy-tailed workloads replayed deterministically in virtual time at
    1x/2x/4x the deployment's serving capacity, per execution backend.
    Reports p50/p99 TTFT, tokens/s, and shed rate; writes
    BENCH_traffic.json.  All latency numbers come from the injected
    VirtualClock, so they are bit-reproducible across machines."""
    import jax
    from repro.configs import get_config
    from repro.models import model_api
    from repro.serve import ServeEngine
    from repro.server import (LoadHarness, TrafficConfig, TrafficGenerator,
                              VirtualClock, overload_rate_rps)

    mcfg = get_config("starcoder2-3b", smoke=True)
    params = model_api(mcfg).init_params(jax.random.PRNGKey(0))
    slots, max_len, max_pending, step_cost_s, seed = 2, 32, 6, 0.02, 0
    duration_s = 1.5 if fast else 4.0
    backends = ("ideal",) if fast else ("ideal", "emulated")
    base = dict(duration_s=duration_s, seed=seed, max_prompt_len=8,
                max_gen_len=8, prompt_len_log_mean=0.8,
                prompt_len_log_sigma=0.5, gen_len_log_mean=1.0,
                gen_len_log_sigma=0.5, diurnal_amplitude=0.5,
                diurnal_period_s=duration_s, vocab_size=mcfg.vocab_size)

    def make_backend(name):
        if name == "ideal":
            return None
        from repro.backend import EmulatedBackend
        from repro.flow import FlowConfig
        from repro.flow import run as flow_run
        fcfg = FlowConfig(array_n=8, tech="vtr-22nm", max_trials=8,
                          seed=2021)
        return EmulatedBackend.from_flow(flow_run(fcfg), fcfg)

    rows: List[Tuple[str, float, str]] = []
    per_backend: Dict[str, Dict] = {}
    elapsed = 0.0
    for backend in backends:
        levels: Dict[str, Dict] = {}
        for factor in (1.0, 2.0, 4.0):
            rate = overload_rate_rps(factor, slots, step_cost_s,
                                     TrafficConfig(**base))
            events = TrafficGenerator(
                TrafficConfig(rate_rps=rate, **base)).events()
            clock = VirtualClock()
            eng = ServeEngine(mcfg, params, slots=slots, max_len=max_len,
                              clock=clock, policy="priority",
                              max_pending=max_pending,
                              backend=make_backend(backend))
            m = LoadHarness(eng, clock, step_cost_s=step_cost_s) \
                .replay(events)
            levels[f"{factor:g}x"] = m.to_dict()
            elapsed += m.wall_s
            p99 = "n/a" if m.ttft_p99_s is None else f"{m.ttft_p99_s:.3f}s"
            rows.append((f"traffic/{backend}_x{factor:g}", m.wall_s * 1e6,
                         f"shed_rate={m.shed_rate:.2f}"
                         f"_p99_ttft={p99}"
                         f"_tok_per_s={m.tokens_per_s:.1f}"))
        per_backend[backend] = levels
    payload = bench_payload(
        "traffic", elapsed,
        {"arch": mcfg.name, "slots": slots, "max_len": max_len,
         "max_pending": max_pending, "step_cost_s": step_cost_s,
         "seed": seed, "policy": "priority", "traffic": base},
        overload_factors=[1.0, 2.0, 4.0],
        backends=per_backend)
    with open(_json_path("BENCH_traffic.json"), "w") as f:
        json.dump(payload, f, indent=2)
    return rows


def bench_accuracy_voltage(fast: bool) -> List[Tuple[str, float, str]]:
    """BEYOND PAPER: the paper's stated future work (ii) — the trade-off
    between DNN accuracy (timing-failure corruption) and power as voltage
    drops through the critical region, measured on the fault-injecting
    systolic simulator (16x16, vtr-22nm)."""
    from repro.core import (RazorConfig, SystolicSim, TimingModel, TECH_NODES,
                            model_for, quadrant_floorplan)
    tm = TimingModel(n=16, tech=TECH_NODES["vtr-22nm"], seed=2021)
    pm = model_for("vtr-22nm")
    rng = np.random.default_rng(0)
    a, w = rng.normal(size=(48, 16)), rng.normal(size=(16, 16))
    out = []
    vmax = float(tm.min_safe_voltage().max())
    for v in (1.0, round(vmax + 0.02, 3), round(vmax - 0.01, 3),
              round(vmax - 0.05, 3), 0.6):
        fp = quadrant_floorplan(16).with_voltages([v] * 4)
        sim = SystolicSim(tm, fp, RazorConfig())

        def run(sim=sim):
            return sim.matmul(a, w)

        us, (c, stats) = _time_us(run, repeats=1)
        power = pm.partitioned_mw(16, [v] * 4, v_ref=1.0)
        out.append((f"accuracy_voltage/v{v}", us,
                    f"rel_err={stats.rel_error:.2e}"
                    f"_replays={stats.replay_cycles}"
                    f"_silent={int(stats.silent.sum())}"
                    f"_power={power:.0f}mW"))
    return out


def bench_analysis(fast: bool) -> List[Tuple[str, float, str]]:
    """jaxpr census over the family configs: host round-trips (pure_callback),
    dot count and flop estimate per model call under reference routing —
    ROADMAP item 1's worklist, written to BENCH_analysis.json and pinned by
    the lint-invariants CI gate."""
    from repro.analysis import CENSUS_ARCHS, census_config

    archs = CENSUS_ARCHS[:2] if fast else list(CENSUS_ARCHS)
    out: List[Tuple[str, float, str]] = []
    configs: Dict[str, Dict] = {}
    t_all = time.perf_counter()
    for arch in archs:
        t0 = time.perf_counter()
        report = census_config(arch, backend="reference")
        us = (time.perf_counter() - t0) * 1e6
        configs[arch] = report
        for phase in ("prefill", "decode"):
            c = report.get(phase)
            if c is None:
                continue
            out.append((
                f"analysis/{arch}_{phase}", us,
                f"callbacks={c['pure_callbacks']}_dots={c['dots']}"
                f"_flops={c['flops']:.3e}"))
    payload = bench_payload(
        "analysis", time.perf_counter() - t_all,
        {"archs": archs, "backend": "reference"},
        census=configs)
    with open(_json_path("BENCH_analysis.json"), "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return out


def bench_resilience(fast: bool) -> List[Tuple[str, float, str]]:
    """ABFT guard economics + end-to-end chaos campaign
    (repro.resilience): (a) guard overhead per mode at nominal rails,
    (b) detection coverage / corrected rate / silent escapes per corruption
    model at crash-region rails with the escalation ladder disabled (pure
    verification), (c) the reduced-scale fault campaign over the full
    serving stack.  Writes BENCH_resilience.json; the CI resilience gate
    (``--resilience-gate``) pins abft silent escapes to zero and the
    campaign to all-green."""
    from repro.backend import EmulatedBackend
    from repro.resilience import GuardedBackend
    from repro.resilience.chaos import V_CRASH, run_campaign

    rng = np.random.default_rng(0)
    shapes = [(8, 8, 8), (16, 24, 8), (12, 40, 20)]
    # integer-valued operands: checksums are exact in f64, so clean GEMMs
    # match the ideal product bit for bit and every mismatch is injected
    ops = [(rng.integers(-4, 5, size=(m, k)).astype(np.float64),
            rng.integers(-4, 5, size=(k, n)).astype(np.float64))
           for m, k, n in shapes]
    rows: List[Tuple[str, float, str]] = []
    t_all = time.perf_counter()

    # (a) verification overhead at nominal (fault-free) rails
    overhead: Dict[str, Dict] = {}
    for mode in ("unguarded", "freivalds", "abft"):
        be = EmulatedBackend.nominal() if mode == "unguarded" else \
            GuardedBackend(EmulatedBackend.nominal(), mode=mode)

        def run(be=be):
            for a, b in ops:
                be.matmul(a, b)

        us, _ = _time_us(run, repeats=3 if fast else 10)
        overhead[mode] = {"us_per_3gemms": us}
        rows.append((f"resilience/nominal_{mode}", us, "faults=none"))
    for mode in ("freivalds", "abft"):
        pct = 100.0 * (overhead[mode]["us_per_3gemms"]
                       / max(overhead["unguarded"]["us_per_3gemms"], 1e-9)
                       - 1.0)
        overhead[mode]["overhead_pct"] = pct
        rows.append((f"resilience/overhead_{mode}", 0.0,
                     f"overhead={pct:.1f}%"))

    # (b) detection coverage at crash-region rails, ladder disabled: no
    # retries, no heal, fail_open — what the verifier alone sees
    rounds = 5 if fast else 20
    sweep: Dict[str, Dict] = {}
    for mode in ("freivalds", "abft"):
        sweep[mode] = {}
        for corruption in ("bitflip", "stale", "tedrop"):
            guard = GuardedBackend(
                EmulatedBackend.nominal(corruption=corruption), mode=mode,
                policy="fail_open", max_retries=0, heal=False)
            accel = guard.accel
            accel.set_rails(np.full(accel.n_partitions, V_CRASH))
            srng = np.random.default_rng(7)
            n = corrupted = detected = corrected = escapes = 0
            for _ in range(rounds):
                for m, k, nn in shapes:
                    a = srng.integers(-4, 5, size=(m, k)).astype(np.float64)
                    b = srng.integers(-4, 5, size=(k, nn)).astype(np.float64)
                    out, tel = guard.matmul(a, b)
                    bad = not np.array_equal(np.asarray(out), a @ b)
                    n += 1
                    corrupted += int(bad or tel.guard_detected > 0)
                    detected += int(tel.guard_detected > 0)
                    corrected += int(tel.guard_corrected > 0)
                    escapes += int(bad and tel.guard_detected == 0)
            cov = detected / max(corrupted, 1)
            sweep[mode][corruption] = {
                "gemms": n, "corrupted": corrupted, "detected": detected,
                "corrected": corrected, "silent_escapes": escapes,
                "detection_coverage": cov,
                "corrupted_rate": corrupted / n,
            }
            rows.append((f"resilience/{mode}_{corruption}", 0.0,
                         f"coverage={cov:.2f}_corrected={corrected}"
                         f"_escapes={escapes}"))

    # (c) the full-stack chaos campaign (engine + HTTP frontend + client)
    report = run_campaign(fast=True)
    rows.append(("resilience/campaign", report.elapsed_s * 1e6,
                 f"ok={report.ok}_crashes={report.crashes}"
                 f"_corrupted_streams={report.corrupted_streams}"))

    payload = bench_payload(
        "resilience", time.perf_counter() - t_all,
        {"shapes": shapes, "rounds": rounds, "v_crash": V_CRASH, "seed": 0,
         "tech": "vtr-22nm", "array_n": 8},
        overhead=overhead, corruption_sweep=sweep,
        campaign=report.to_dict())
    with open(_json_path("BENCH_resilience.json"), "w") as f:
        json.dump(payload, f, indent=2)
    return rows


def bench_obs(fast: bool) -> List[Tuple[str, float, str]]:
    """Observability overhead + determinism (repro.obs): (a) the same
    seeded workload served with full tracing/flight-recording
    (``ObsBus(enabled=True)``) vs counters-only
    (``ObsBus(enabled=False)``) — the marginal cost of the optional
    instrumentation, min-of-repeats; (b) two identical virtual-time
    ``LoadHarness`` replays must render bit-identical metric snapshots.
    Writes BENCH_obs.json; ``--obs-overhead-gate`` pins (a) under a
    percentage and (b) to True."""
    import jax
    from repro.configs import get_config
    from repro.models import model_api
    from repro.obs import ObsBus
    from repro.serve import Request, ServeEngine
    from repro.server import (LoadHarness, TrafficConfig, TrafficGenerator,
                              VirtualClock, overload_rate_rps)
    cfg = get_config("starcoder2-3b", smoke=True)
    params = model_api(cfg).init_params(jax.random.PRNGKey(0))
    n_req = 6 if fast else 12
    repeats = 3 if fast else 6
    t_all = time.perf_counter()

    def workload(rng):
        return [Request(uid=uid,
                        prompt=rng.integers(3, cfg.vocab_size,
                                            int(rng.integers(1, 7))).tolist(),
                        max_new_tokens=int(rng.integers(2, 8)))
                for uid in range(n_req)]

    def serve(enabled):
        rng = np.random.default_rng(0)          # identical request sets
        eng = ServeEngine(cfg, params, slots=2, max_len=48,
                          obs=ObsBus(enabled=enabled))
        for req in workload(rng):
            eng.submit(req)
        eng.run_until_drained()
        return eng

    # (a) marginal cost of tracing: warm both paths, then interleave the
    # timed repeats and keep the minimum (least-noise estimator)
    timings = {True: math.inf, False: math.inf}
    for enabled in (True, False):
        serve(enabled)                          # jit warmup / caches
    eng_on = None
    for _ in range(repeats):
        for enabled in (True, False):
            t0 = time.perf_counter()
            eng = serve(enabled)
            timings[enabled] = min(timings[enabled],
                                   time.perf_counter() - t0)
            if enabled:
                eng_on = eng
    overhead_pct = 100.0 * (timings[True] / max(timings[False], 1e-9) - 1.0)
    rows = [
        (f"obs/enabled_{n_req}req", timings[True] * 1e6,
         f"trace_events={eng_on.obs.recorder.total_recorded}"),
        (f"obs/disabled_{n_req}req", timings[False] * 1e6,
         "trace_events=0"),
        ("obs/overhead", 0.0, f"overhead={overhead_pct:.2f}%"),
    ]

    # (b) virtual-time determinism: identical replays, identical scrapes
    def virtual_run():
        clock = VirtualClock()
        eng = ServeEngine(cfg, params, slots=2, max_len=32, clock=clock,
                          policy="priority", max_pending=6,
                          obs=ObsBus(clock=clock))
        tcfg = TrafficConfig(
            rate_rps=overload_rate_rps(2.0, 2, 0.02, TrafficConfig()),
            duration_s=1.0, seed=0, max_prompt_len=8, max_gen_len=8,
            vocab_size=cfg.vocab_size)
        LoadHarness(eng, clock, step_cost_s=0.02).replay(
            TrafficGenerator(tcfg).events())
        return eng.obs.render_prometheus()

    snap_a, snap_b = virtual_run(), virtual_run()
    deterministic = snap_a == snap_b
    rows.append(("obs/deterministic_snapshots", 0.0,
                 f"bit_identical={deterministic}"))

    payload = bench_payload(
        "obs", time.perf_counter() - t_all,
        {"arch": cfg.name, "requests": n_req, "slots": 2, "max_len": 48,
         "repeats": repeats, "seed": 0},
        enabled_s=timings[True], disabled_s=timings[False],
        overhead_pct=overhead_pct,
        trace_events=eng_on.obs.recorder.total_recorded,
        metrics_exported=len(eng_on.obs.registry.names()),
        deterministic_snapshots=deterministic,
        snapshot_lines=len(snap_a.splitlines()))
    with open(_json_path("BENCH_obs.json"), "w") as f:
        json.dump(payload, f, indent=2)
    return rows


def bench_railscale(fast: bool) -> List[Tuple[str, float, str]]:
    """Closed-loop energy-aware rail autoscaling (repro.railscale): the
    same seeded traffic traces replayed in virtual time through (a) the
    abft-guarded emulated array pinned at static nominal rails — clean
    by construction (zero failure probability at V_nom), so its tokens
    are the ground truth for the emulated arithmetic — and (b) the
    closed loop: guarded emulated array + hwloop watchdog + threshold/
    pid autoscaler over the flow-characterized operating-point ladder.
    Headline (gated by ``--railscale-gate``): at 0.25x load the closed
    loop's energy/token drops strictly below static nominal with zero
    guard-uncorrected escapes and zero corrupted completions, and at
    peak the closed loop's p99 TTFT matches static within the SLO.
    Writes BENCH_railscale.json (scenarios x modes + the diurnal gauge
    timeline)."""
    import jax
    from repro.backend import EmulatedBackend
    from repro.configs import get_config
    from repro.flow import ArtifactStore, FlowConfig
    from repro.flow import run as flow_run
    from repro.hwloop import HwLoopSession
    from repro.models import model_api
    from repro.railscale import Autoscaler, OperatingPointTable
    from repro.resilience import GuardedBackend
    from repro.serve import ServeEngine
    from repro.server import (LoadHarness, TrafficConfig, TrafficGenerator,
                              VirtualClock, overload_rate_rps)

    mcfg = get_config("starcoder2-3b", smoke=True)
    params = model_api(mcfg).init_params(jax.random.PRNGKey(0))
    # a coarser virtual step than bench_traffic keeps the emulated
    # pure_callback model-call count (the real wall-clock cost) bounded
    slots, max_len, step_cost_s = 2, 32, 0.05
    slo_ttft_s = 2.0
    duration_s = 1.5 if fast else 3.0
    fcfg = FlowConfig(array_n=8, tech="vtr-22nm", max_trials=8, seed=2021)
    store = ArtifactStore()
    report = flow_run(fcfg, store=store)
    table = OperatingPointTable.characterize(
        report, fcfg, n_levels=4, probe_steps=4 if fast else 8,
        seed=fcfg.seed)
    nominal = table.rails(0)   # static baseline == ladder level 0

    base = dict(duration_s=duration_s, seed=0, max_prompt_len=8,
                max_gen_len=8, vocab_size=mcfg.vocab_size)
    scenarios = {
        "low": dict(factor=0.25),
        "peak": dict(factor=1.0),
        "diurnal": dict(factor=1.0, diurnal_amplitude=0.9,
                        diurnal_period_s=duration_s),
    }
    modes = ("static", "threshold") if fast else ("static", "threshold",
                                                  "pid")

    def run_mode(mode, events):
        clock = VirtualClock()
        kw: Dict[str, object] = {
            "backend": GuardedBackend(
                EmulatedBackend.from_flow(report, fcfg,
                                          rails=nominal.copy()),
                mode="abft", policy="fail_open")}
        if mode != "static":
            kw["hwloop"] = HwLoopSession(fcfg, probe_rows=8,
                                         rail_margin=0.02, store=store)
            # faster cadence than the serving default: the short virtual
            # trace must leave room for a full descent to the floor
            kw["autoscaler"] = Autoscaler(table, mode,
                                          slo_ttft_s=slo_ttft_s,
                                          start_level=0, decide_every=2,
                                          dwell_steps=4)
        eng = ServeEngine(mcfg, params, slots=slots, max_len=max_len,
                          clock=clock, **kw)
        harness = LoadHarness(eng, clock, step_cost_s=step_cost_s,
                              sample_every_s=0.1)
        m = harness.replay(events)
        tokens = {r.uid: list(r.out_tokens) for r in harness.requests
                  if r.done and not r.truncated and not r.shed}
        bs = eng.backend.summary()
        out = {"metrics": m.to_dict(), "tokens": tokens,
               "samples": harness.samples,
               "energy_per_token_j": bs.get("energy_per_token_j"),
               "guard_uncorrected": int(bs.get("guard_uncorrected", 0)),
               "flags": int(bs.get("flags", 0)),
               "replays": int(bs.get("replays", 0))}
        if eng.autoscaler is not None:
            out["railscale"] = eng.autoscaler.summary()
        return out

    rows: List[Tuple[str, float, str]] = []
    results: Dict[str, Dict] = {}
    t_all = time.perf_counter()
    for name, spec in scenarios.items():
        spec = dict(spec)
        factor = spec.pop("factor")
        tcfg = TrafficConfig(
            rate_rps=overload_rate_rps(factor, slots, step_cost_s,
                                       TrafficConfig(**base)),
            **base, **spec)
        events = TrafficGenerator(tcfg).events()
        reference: Dict[int, List[int]] = {}
        per_mode: Dict[str, Dict] = {}
        for mode in modes:
            t0 = time.perf_counter()
            res = run_mode(mode, events)
            wall = time.perf_counter() - t0
            # ground truth: the static-nominal run is fail-free by
            # construction (same emulated arithmetic, zero failure
            # probability at V_nom), so a closed-loop completion with
            # different tokens means the guard let corruption through
            if mode == "static":
                reference = res.pop("tokens")
                res["corrupted_completions"] = 0
            else:
                res["corrupted_completions"] = sum(
                    1 for uid, toks in res.pop("tokens").items()
                    if toks != reference.get(uid))
            m = res["metrics"]
            e = res["energy_per_token_j"]
            rows.append((
                f"railscale/{name}_{mode}", wall * 1e6,
                f"energy_per_token={'n/a' if e is None else f'{e:.3e}'}"
                f"_p99_ttft={m['ttft_p99_s'] if m['ttft_p99_s'] is None else round(m['ttft_p99_s'], 3)}"
                f"_corrupted={res['corrupted_completions']}"
                + (f"_level={res['railscale']['level']}"
                   f"_transitions={res['railscale']['transitions']}"
                   if "railscale" in res else "")))
            per_mode[mode] = res
        results[name] = {"factor": factor,
                         "reference_completed": len(reference),
                         "modes": per_mode}

    payload = bench_payload(
        "railscale", time.perf_counter() - t_all,
        {"arch": mcfg.name, "slots": slots, "max_len": max_len,
         "step_cost_s": step_cost_s, "seed": 0, "array_n": fcfg.array_n,
         "tech": fcfg.tech, "slo_ttft_s": slo_ttft_s,
         "duration_s": duration_s, "guard": "abft", "traffic": base},
        table={"levels": len(table), "meta": table.meta,
               "points": [p.to_dict() for p in table.points]},
        modes=list(modes),
        scenarios=results)
    with open(_json_path("BENCH_railscale.json"), "w") as f:
        json.dump(payload, f, indent=2)
    return rows


BENCHES: Dict[str, Callable] = {
    "analysis": bench_analysis,
    "tableII": bench_tableII,
    "fig15_16": bench_fig15_16,
    "clustering": bench_clustering,
    "cadflow": bench_cadflow,
    "flow_sweep": bench_flow_sweep,
    "flow": bench_flow,
    "systolic_sim": bench_systolic_sim,
    "kernels": bench_kernels,
    "power_report": bench_power_report,
    "serve": bench_serve,
    "hwloop": bench_hwloop,
    "traffic": bench_traffic,
    "accuracy_voltage": bench_accuracy_voltage,
    "resilience": bench_resilience,
    "obs": bench_obs,
    "railscale": bench_railscale,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", choices=sorted(BENCHES), default=None)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_*.json artifacts (default: CWD)")
    ap.add_argument("--json-out", default=None,
                    help="exact JSON artifact path; only meaningful with "
                         "--only on a scenario that writes one")
    ap.add_argument("--min-flow-speedup", type=float, default=None,
                    help="fail (exit 1) unless the flow scenario's vectorized "
                         "sweep beats the reference by at least this factor")
    ap.add_argument("--resilience-gate", action="store_true",
                    help="fail (exit 1) unless the resilience scenario shows "
                         "zero abft silent escapes and an all-green chaos "
                         "campaign")
    ap.add_argument("--obs-overhead-gate", type=float, default=None,
                    metavar="PCT",
                    help="fail (exit 1) unless the obs scenario's tracing "
                         "overhead is below PCT%% and virtual-time metric "
                         "snapshots are bit-identical")
    ap.add_argument("--railscale-gate", action="store_true",
                    help="fail (exit 1) unless the railscale scenario shows "
                         "closed-loop energy/token at 0.25x load strictly "
                         "below static nominal, zero guard-uncorrected "
                         "escapes, zero corrupted completions, and peak p99 "
                         "TTFT within the SLO and no worse than static")
    args = ap.parse_args()
    if args.json_out and not args.only:
        ap.error("--json-out requires --only (it names a single artifact)")
    _OUT["dir"] = args.out_dir
    _OUT["json_out"] = args.json_out

    names = [args.only] if args.only else list(BENCHES)
    if args.min_flow_speedup is not None and "flow" not in names:
        ap.error("--min-flow-speedup requires the flow scenario to run")
    if args.resilience_gate and "resilience" not in names:
        ap.error("--resilience-gate requires the resilience scenario to run")
    if args.obs_overhead_gate is not None and "obs" not in names:
        ap.error("--obs-overhead-gate requires the obs scenario to run")
    if args.railscale_gate and "railscale" not in names:
        ap.error("--railscale-gate requires the railscale scenario to run")
    print("name,us_per_call,derived")
    for name in names:
        for row_name, us, derived in BENCHES[name](args.fast):
            print(f"{row_name},{us:.1f},{derived}", flush=True)

    if args.min_flow_speedup is not None:
        path = args.json_out if (args.json_out and args.only == "flow") \
            else os.path.join(args.out_dir, "BENCH_flow.json")
        with open(path) as f:
            payload = json.load(f)
        ok = (payload["speedup"] >= args.min_flow_speedup
              and payload["bit_identical_reports"])
        print(f"flow gate: speedup={payload['speedup']:.2f} "
              f"(need >= {args.min_flow_speedup}), "
              f"bit_identical={payload['bit_identical_reports']} -> "
              f"{'PASS' if ok else 'FAIL'}", flush=True)
        if not ok:
            sys.exit(1)

    if args.resilience_gate:
        path = args.json_out if (args.json_out
                                 and args.only == "resilience") \
            else os.path.join(args.out_dir, "BENCH_resilience.json")
        with open(path) as f:
            payload = json.load(f)
        escapes = sum(c["silent_escapes"]
                      for c in payload["corruption_sweep"]["abft"].values())
        campaign_ok = payload["campaign"]["ok"] \
            and payload["campaign"]["crashes"] == 0 \
            and payload["campaign"]["corrupted_streams"] == 0
        ok = escapes == 0 and campaign_ok
        print(f"resilience gate: abft_silent_escapes={escapes} (need 0), "
              f"campaign_ok={campaign_ok} -> {'PASS' if ok else 'FAIL'}",
              flush=True)
        if not ok:
            sys.exit(1)

    if args.railscale_gate:
        path = args.json_out if (args.json_out and args.only == "railscale") \
            else os.path.join(args.out_dir, "BENCH_railscale.json")
        with open(path) as f:
            payload = json.load(f)
        slo = payload["config"]["slo_ttft_s"]
        closed_modes = [m for m in payload["modes"] if m != "static"]
        checks: List[Tuple[str, bool]] = []
        static_low = payload["scenarios"]["low"]["modes"]["static"]
        static_peak = payload["scenarios"]["peak"]["modes"]["static"]
        for mode in closed_modes:
            low = payload["scenarios"]["low"]["modes"][mode]
            peak = payload["scenarios"]["peak"]["modes"][mode]
            checks.append((
                f"{mode}: low-load energy/token "
                f"{low['energy_per_token_j']:.3e} < static "
                f"{static_low['energy_per_token_j']:.3e}",
                low["energy_per_token_j"]
                < static_low["energy_per_token_j"]))
            checks.append((
                f"{mode}: peak p99 TTFT {peak['metrics']['ttft_p99_s']:.3f}s"
                f" <= SLO {slo}s and <= static "
                f"{static_peak['metrics']['ttft_p99_s']:.3f}s",
                peak["metrics"]["ttft_p99_s"] <= slo
                and (peak["metrics"]["ttft_p99_s"]
                     <= static_peak["metrics"]["ttft_p99_s"] + 1e-9)))
            checks.append((
                f"{mode}: closed loop actually undervolted at low load",
                payload["scenarios"]["low"]["modes"][mode]["railscale"]
                ["transitions"]["down"] > 0))
        for name in payload["scenarios"]:
            for mode, res in payload["scenarios"][name]["modes"].items():
                checks.append((
                    f"{name}/{mode}: zero guard-uncorrected + zero "
                    f"corrupted completions",
                    res["guard_uncorrected"] == 0
                    and res["corrupted_completions"] == 0))
        ok = all(c for _, c in checks)
        for desc, c in checks:
            print(f"railscale gate: {desc} -> {'PASS' if c else 'FAIL'}",
                  flush=True)
        if not ok:
            sys.exit(1)

    if args.obs_overhead_gate is not None:
        path = args.json_out if (args.json_out and args.only == "obs") \
            else os.path.join(args.out_dir, "BENCH_obs.json")
        with open(path) as f:
            payload = json.load(f)
        ok = (payload["overhead_pct"] < args.obs_overhead_gate
              and payload["deterministic_snapshots"])
        print(f"obs gate: overhead={payload['overhead_pct']:.2f}% "
              f"(need < {args.obs_overhead_gate}), deterministic="
              f"{payload['deterministic_snapshots']} -> "
              f"{'PASS' if ok else 'FAIL'}", flush=True)
        if not ok:
            sys.exit(1)


if __name__ == "__main__":
    main()
