"""Data pipeline, optimizer, checkpointing, fault tolerance, trainer, serving."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, PrefetchLoader, SyntheticDataset
from repro.models import model_api
from repro.runtime import HeartbeatMonitor, plan_elastic_remap
from repro.serve import Request, ServeEngine
from repro.train import TrainConfig, train


# ------------------------------------------------------------------- data ----

def test_data_deterministic_replay():
    cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=8)
    a = SyntheticDataset(cfg).batch_at(7)
    b = SyntheticDataset(cfg).batch_at(7)
    np.testing.assert_array_equal(a.data["tokens"], b.data["tokens"])
    c = SyntheticDataset(cfg).batch_at(8)
    assert not np.array_equal(a.data["tokens"], c.data["tokens"])


def test_data_sharding_partitions_global_batch():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8)
    whole = SyntheticDataset(cfg).batch_at(3).data["tokens"]
    parts = [SyntheticDataset(cfg, shard=s, num_shards=4).batch_at(3)
             .data["tokens"] for s in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), whole)


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=2)
    b = SyntheticDataset(cfg).batch_at(0)
    np.testing.assert_array_equal(b.data["labels"][:, :-1],
                                  b.data["tokens"][:, 1:])


def test_data_packing_has_eos():
    cfg = DataConfig(vocab_size=512, seq_len=2048, global_batch=2,
                     mean_doc_len=128)
    b = SyntheticDataset(cfg).batch_at(0)
    assert (b.data["tokens"] == 1).sum() > 0


def test_prefetch_loader_ordering():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2)
    loader = PrefetchLoader(SyntheticDataset(cfg), start_step=5)
    batches = [next(loader) for _ in range(3)]
    loader.close()
    assert [b.step for b in batches] == [5, 6, 7]


@given(st.integers(0, 1000), st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=20, deadline=None)
def test_data_tokens_in_vocab(step, shards):
    cfg = DataConfig(vocab_size=97, seq_len=32, global_batch=8)
    b = SyntheticDataset(cfg, shard=0, num_shards=shards).batch_at(step)
    assert b.data["tokens"].min() >= 1
    assert b.data["tokens"].max() < 97


def test_data_rejects_nondivisible_shards():
    cfg = DataConfig(vocab_size=97, seq_len=32, global_batch=8)
    with pytest.raises(ValueError):
        SyntheticDataset(cfg, shard=0, num_shards=3)


# -------------------------------------------------------------- optimizer ----

def _tiny_params():
    k = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(k, (8, 16), jnp.float32).astype(jnp.bfloat16),
            "b": jnp.zeros((16,), jnp.float32)}


def test_adamw_descends_quadratic():
    cfg = optim.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                            schedule="constant")
    params = _tiny_params()
    state = optim.init_state(params, cfg)
    target = jax.tree.map(lambda p: jnp.ones_like(p), params)

    def loss_fn(p):
        return sum(jnp.sum((a.astype(jnp.float32) - t) ** 2)
                   for a, t in zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    l0 = float(loss_fn(params))
    for _ in range(60):
        grads = jax.grad(loss_fn)(params)
        params, state = optim.apply_updates(params, state, grads, cfg)
    assert float(loss_fn(params)) < 0.1 * l0
    assert int(state["step"]) == 60


def test_adamw_grad_clip():
    cfg = optim.AdamWConfig(lr=1e-3, grad_clip=1.0)
    params = _tiny_params()
    state = optim.init_state(params, cfg)
    huge = jax.tree.map(lambda p: 1e6 * jnp.ones_like(p, jnp.float32), params)
    new_params, _ = optim.apply_updates(params, state, huge, cfg)
    delta = max(float(jnp.abs(n.astype(jnp.float32) - p.astype(jnp.float32)).max())
                for n, p in zip(jax.tree.leaves(new_params),
                                jax.tree.leaves(params)))
    assert delta < 0.1            # clip bounded the update


def test_adamw_int8_moments_roughly_match_fp32():
    params = _tiny_params()
    g = jax.tree.map(lambda p: 0.01 * jnp.ones_like(p, jnp.float32), params)
    cfg32 = optim.AdamWConfig(lr=0.01, int8_moments=False, weight_decay=0.0)
    cfg8 = optim.AdamWConfig(lr=0.01, int8_moments=True, weight_decay=0.0)
    p32, s32 = params, optim.init_state(params, cfg32)
    p8, s8 = params, optim.init_state(params, cfg8)
    for _ in range(10):
        p32, s32 = optim.apply_updates(p32, s32, g, cfg32)
        p8, s8 = optim.apply_updates(p8, s8, g, cfg8)
    for a, b in zip(jax.tree.leaves(p32), jax.tree.leaves(p8)):
        np.testing.assert_allclose(np.array(a, np.float32),
                                   np.array(b, np.float32), atol=5e-3)
    # compression is real: moments stored as int8
    assert s8["per_param"]["w"]["mu"].dtype == jnp.int8


def test_lr_schedule():
    cfg = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(optim.lr_at(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6
    assert lrs[99] < lrs[50] < lrs[12]


# ------------------------------------------------------------- checkpoint ----

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(12, dtype=np.float32).reshape(4, 3),
            "nest": {"b": np.ones((2, 2), np.int32)},
            "scalar": np.float32(3.5)}
    mgr = CheckpointManager(tmp_path)
    mgr.save(10, tree)
    out = mgr.restore(tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["nest"]["b"], tree["nest"]["b"])
    assert out["scalar"] == tree["scalar"]
    assert mgr.latest_step() == 10


def test_checkpoint_elastic_reshard(tmp_path):
    """Write with 4 hosts, restore on 1 (and vice versa)."""
    tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
    writers = [CheckpointManager(tmp_path, host_id=h, num_hosts=4)
               for h in range(4)]
    for w in writers:
        w.save(5, tree)
    reader = CheckpointManager(tmp_path, host_id=0, num_hosts=1)
    out = reader.restore(tree)
    np.testing.assert_array_equal(out["w"], tree["w"])


def test_checkpoint_async_and_gc(tmp_path):
    tree = {"x": np.ones((4,), np.float32)}
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=False)
        mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_restore_specific_step(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"x": np.zeros(3, np.float32)})
    mgr.save(2, {"x": np.ones(3, np.float32)})
    out = mgr.restore({"x": np.zeros(3, np.float32)}, step=1)
    np.testing.assert_array_equal(out["x"], np.zeros(3))


# ---------------------------------------------------------- fault tolerance ----

def test_heartbeat_detects_dead_host():
    mon = HeartbeatMonitor(num_hosts=4, timeout_steps=2)
    for step in range(5):
        for h in range(4):
            if h == 2 and step >= 1:
                continue                      # host 2 dies after step 0
            mon.beat(h, step, 0.1)
        dead = mon.check_dead(step)
        if step >= 3:
            assert dead == [2] or 2 not in mon.alive_hosts()
    assert mon.alive_hosts() == [0, 1, 3]


def test_straggler_detection():
    """Patience counts consecutive *monitoring checks*: the monitor is polled
    once per step, and flags the slow host only after `patience` flags."""
    mon = HeartbeatMonitor(num_hosts=8, straggler_z=3.0, straggler_patience=2)
    reports = []
    for step in range(6):
        for h in range(8):
            mon.beat(h, step, 1.0 if h != 5 else 4.0)
        reports = mon.stragglers()
        if step == 0:
            assert reports == []                 # patience not yet reached
    assert [r.host_id for r in reports] == [5]
    assert reports[0].z_score > 3.0


def test_no_straggler_on_uniform_times():
    mon = HeartbeatMonitor(num_hosts=8)
    for step in range(6):
        for h in range(8):
            mon.beat(h, step, 1.0 + 0.01 * h)
    assert mon.stragglers() == []


def test_elastic_remap_drops_incomplete_groups():
    # 8 hosts, 2 hosts per model-parallel group -> 4 dp groups; hosts 2,5 die
    alive = [0, 1, 3, 4, 6, 7]
    plan = plan_elastic_remap(alive, model_parallel=2, hosts_per_dp_group=2)
    assert plan.data_parallel == 2                 # groups {0,1} and {6,7}
    assert plan.host_to_shard == {0: 0, 1: 0, 6: 1, 7: 1}
    assert set(plan.dropped_hosts) == {3, 4}


def test_elastic_remap_all_dead_raises():
    with pytest.raises(RuntimeError):
        plan_elastic_remap([0], model_parallel=2, hosts_per_dp_group=2)


# ---------------------------------------------------------------- trainer ----

def test_train_loss_decreases_and_resumes(tmp_path):
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    shape = ShapeConfig("t", 32, 4, "train")
    tc = TrainConfig(steps=16, log_every=0, checkpoint_every=8,
                     checkpoint_dir=str(tmp_path), async_checkpoint=False)
    res = train(cfg, shape, tc, optim.AdamWConfig(lr=5e-3, warmup_steps=2,
                                                  total_steps=16))
    assert res.steps_done == 16
    assert np.isfinite(res.losses).all()
    assert np.mean(res.losses[-4:]) < np.mean(res.losses[:4]) - 0.05

    # crash/restart: resume from step 16 checkpoint, run to 20
    tc2 = dataclasses.replace(tc, steps=20)
    res2 = train(cfg, shape, tc2, optim.AdamWConfig(lr=5e-3, warmup_steps=2,
                                                    total_steps=16),
                 resume=True)
    assert res2.steps_done == 4                     # resumed, not restarted


def test_train_resume_bit_identical(tmp_path):
    """Uninterrupted 6-step run == (4 steps, crash, resume 2 steps)."""
    cfg = get_config("starcoder2-3b", smoke=True)
    shape = ShapeConfig("t", 32, 4, "train")
    ocfg = optim.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=6)

    straight = train(cfg, shape,
                     TrainConfig(steps=6, log_every=0, checkpoint_every=0),
                     ocfg)
    part1 = train(cfg, shape,
                  TrainConfig(steps=4, log_every=0, checkpoint_every=4,
                              checkpoint_dir=str(tmp_path),
                              async_checkpoint=False), ocfg)
    part2 = train(cfg, shape,
                  TrainConfig(steps=6, log_every=0, checkpoint_every=0,
                              checkpoint_dir=str(tmp_path)), ocfg,
                  resume=True)
    np.testing.assert_allclose(straight.losses[4:], part2.losses, rtol=1e-5)


# ---------------------------------------------------------------- serving ----

def test_serve_engine_drains_queue():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    api = model_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=48)
    for uid in range(5):
        eng.submit(Request(uid=uid, prompt=[3, 4, 5 + uid],
                           max_new_tokens=4))
    stats = eng.run_until_drained()
    assert stats.completed == 5
    assert stats.truncated == 0 and stats.unserved == 0
    assert stats.tokens_generated == 20
    assert len(eng.queue) == 0 and eng.scheduler.drained()


def test_serve_engine_ssm_family():
    cfg = get_config("rwkv6-1.6b", smoke=True)
    api = model_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    eng.submit(Request(uid=0, prompt=[3, 4], max_new_tokens=3))
    stats = eng.run_until_drained()
    assert stats.completed == 1 and stats.tokens_generated == 3


def test_serve_greedy_is_deterministic():
    cfg = get_config("starcoder2-3b", smoke=True)
    api = model_api(cfg)
    params = api.init_params(jax.random.PRNGKey(1))
    outs = []
    for _ in range(2):
        eng = ServeEngine(cfg, params, slots=1, max_len=32)
        req = Request(uid=0, prompt=[5, 6, 7], max_new_tokens=5)
        eng.submit(req)
        eng.run_until_drained()
        outs.append(tuple(req.out_tokens))
    assert outs[0] == outs[1]
