"""kernels.tuning: platform interpret defaults, block/chunk selection, and
the fused Razor flag-count epilogue."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import tuning
from repro.kernels.razor_matmul import razor_matmul
from repro.kernels.systolic_mac import systolic_mac


# ----------------------------------------------------------- selection ----

def test_select_blocks_prefers_mxu_tiles():
    assert tuning.select_blocks(256, 256, 256) == (128, 128, 128)
    assert tuning.select_blocks(512, 1024, 384) == (128, 128, 128)


def test_select_blocks_degrades_to_divisors():
    assert tuning.select_blocks(96, 48, 40) == (32, 16, 8)
    # prime-ish axes fall back to the whole axis (always divides)
    assert tuning.select_blocks(100, 7, 13) == (100, 7, 13)


def test_select_blocks_custom_table():
    got = tuning.select_blocks(256, 256, 256, table={"m": (64,), "k": (32,)})
    assert got == (64, 128, 32)


def test_selected_blocks_always_divide():
    for m in (8, 24, 100, 128, 300, 4096):
        for axis, b in zip((m, m), tuning.select_blocks(m, m)):
            assert axis % b == 0


def test_select_chunk():
    assert tuning.select_chunk(256) == 128
    assert tuning.select_chunk(96) == 32
    assert tuning.select_chunk(10) == 10          # nothing divides -> whole


def test_default_interpret_matches_backend():
    assert tuning.default_interpret() == (jax.default_backend() == "cpu")
    assert tuning.resolve_interpret(None) == tuning.default_interpret()
    assert tuning.resolve_interpret(True) is True
    assert tuning.resolve_interpret(False) is False


@pytest.mark.skipif(jax.default_backend() != "cpu",
                    reason="fallback warning only fires on CPU")
def test_interpret_fallback_warns_once(monkeypatch):
    import warnings as w
    monkeypatch.setattr(tuning, "_INTERPRET_WARNED", False)
    with pytest.warns(RuntimeWarning, match="interpret mode"):
        assert tuning.default_interpret() is True
    # second resolution is silent: the fallback is announced once per process
    with w.catch_warnings():
        w.simplefilter("error")
        assert tuning.default_interpret() is True
        assert tuning.resolve_interpret(None) is True


def test_resolve_interpret_explicit_overrides_never_warn(monkeypatch):
    import warnings as w
    monkeypatch.setattr(tuning, "_INTERPRET_WARNED", False)
    with w.catch_warnings():
        w.simplefilter("error")
        # explicit values bypass platform resolution entirely
        assert tuning.resolve_interpret(True) is True
        assert tuning.resolve_interpret(False) is False


def test_sequential_grid_platform_matrix(monkeypatch):
    # interpret mode always serializes the grid, on every platform
    assert tuning.sequential_grid(True) is True
    for platform, compiled_sequential in (("tpu", True), ("gpu", False),
                                          ("cpu", False)):
        monkeypatch.setattr(tuning.jax, "default_backend", lambda p=platform: p)
        assert tuning.sequential_grid(True) is True
        assert tuning.sequential_grid(False) is compiled_sequential


# ------------------------------------------------------ fused epilogue ----

def _ab(m, k, n, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(k1, (m, k), jnp.float32),
            jax.random.normal(k2, (k, n), jnp.float32))


def test_systolic_mac_fused_count_matches_flag_sum():
    a, b = _ab(256, 128, 256)
    v_map = jnp.asarray([[0.9, 0.7], [0.6, 1.0]])
    v_safe = jnp.asarray([[0.8, 0.8], [0.8, 0.8]])
    c, flags, count = systolic_mac(a, b, v_map, v_safe, count_flags=True)
    assert int(count) == int(np.asarray(flags).sum()) == 2
    # default return shape is unchanged (two outputs)
    c2, flags2 = systolic_mac(a, b, v_map, v_safe)
    np.testing.assert_array_equal(np.asarray(flags), np.asarray(flags2))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c2))


def test_systolic_mac_blocks_default_from_vmap_shape():
    a, b = _ab(256, 128, 512)
    v_map = jnp.full((2, 4), 1.0)                 # 128x128 cells
    v_safe = jnp.full((2, 4), 0.8)
    c, flags = systolic_mac(a, b, v_map, v_safe)
    assert c.shape == (256, 512) and flags.shape == (2, 4)
    assert not np.asarray(flags).any()


def test_razor_fused_count_matches_flag_sum():
    a, b = _ab(256, 128, 256, seed=3)
    b = b.at[0, 0].set(500.0)                     # poison one tile's scale
    _, flags_all, rel = razor_matmul(a, b, tol=1e-6)
    c, flags, rel, count = razor_matmul(
        a, b, tol=float(np.sort(np.asarray(rel).ravel())[-2] * 0.99),
        count_flags=True)
    assert int(count) == int(np.asarray(flags).sum()) >= 1


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 128)])
def test_razor_defaults_match_explicit_blocks(shape):
    m, k, n = shape
    a, b = _ab(m, k, n, seed=1)
    c_auto, f_auto, r_auto = razor_matmul(a, b)
    c_exp, f_exp, r_exp = razor_matmul(a, b, block_m=128, block_n=128)
    np.testing.assert_array_equal(np.asarray(c_auto), np.asarray(c_exp))
    np.testing.assert_array_equal(np.asarray(f_auto), np.asarray(f_exp))
