"""Per-kernel shape/dtype sweeps, interpret=True vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.precision_island import precision_island
from repro.kernels.razor_matmul import razor_matmul
from repro.kernels.ssd_chunk import ssd_chunk
from repro.kernels.systolic_mac import systolic_mac
from repro.kernels.wkv6 import wkv6

KEY = jax.random.PRNGKey(0)


def _ab(m, k, n, dtype, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(k1, (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(k2, (k, n), jnp.float32).astype(dtype)
    return a, b


# --------------------------------------------------------------- systolic ----

@pytest.mark.parametrize("m,k,n,block", [(256, 256, 256, 128),
                                         (128, 512, 384, 128),
                                         (256, 128, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_systolic_mac_sweep(m, k, n, block, dtype):
    a, b = _ab(m, k, n, dtype)
    gm, gn = m // block, n // block
    rng = np.random.default_rng(1)
    v_map = jnp.asarray(rng.uniform(0.6, 1.0, (gm, gn)))
    v_safe = jnp.full((gm, gn), 0.8)
    c, flags = systolic_mac(a, b, v_map, v_safe, block_m=block, block_n=block,
                            block_k=min(block, k), interpret=True)
    c_ref, f_ref = ref.systolic_mac(a, b, v_map, v_safe, block=block)
    np.testing.assert_array_equal(np.array(flags), np.array(f_ref))
    # clean tiles: tight; corrupted tiles: one truncation quantum of headroom
    scale = float(jnp.abs(c_ref).max())
    fail = np.array(f_ref, bool)
    cn, rn = np.array(c), np.array(c_ref)
    for i in range(gm):
        for j in range(gn):
            tile = (slice(i * block, (i + 1) * block),
                    slice(j * block, (j + 1) * block))
            tol = scale * (2 ** -8 * 2.5 if fail[i, j] else 1e-5)
            np.testing.assert_allclose(cn[tile], rn[tile], atol=tol)


def test_systolic_mac_nominal_voltage_exact():
    a, b = _ab(128, 128, 128, jnp.float32)
    v = jnp.ones((1, 1))
    c, flags = systolic_mac(a, b, v, v * 0.8, interpret=True)
    np.testing.assert_allclose(np.array(c), np.array(a @ b), rtol=1e-6)
    assert int(flags[0, 0]) == 0


# ------------------------------------------------------------------ razor ----

@pytest.mark.parametrize("m,k,n", [(256, 256, 256), (128, 384, 256)])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_razor_matmul_sweep(m, k, n, dtype):
    a, b = _ab(m, k, n, dtype, seed=2)
    c, flags, rel = razor_matmul(a, b, tol=0.05, interpret=True)
    c_ref, f_ref, rel_ref = ref.razor_matmul(a, b, tol=0.05, block=128)
    np.testing.assert_array_equal(np.array(flags), np.array(f_ref))
    np.testing.assert_allclose(np.array(rel), np.array(rel_ref),
                               rtol=1e-3, atol=1e-5)
    # int8 round-to-nearest ties can flip by 1 ULP between the pallas
    # interpreter and the oracle (x/scale exactly .5) — allow one
    # quantization quantum of slack on the main-path tiles
    np.testing.assert_allclose(np.array(c), np.array(c_ref),
                               rtol=3e-3, atol=0.15)


def test_razor_flags_fire_on_outliers():
    """A single huge element wrecks its row's int8 scale (symmetric per-row
    quantization zeroes everything else) -> the tile must flag and be
    corrected to the shadow (f32) value.  Note a whole-column scale-up would
    NOT fire: per-row scaling is scale-invariant."""
    a, b = _ab(128, 256, 256, jnp.float32, seed=3)
    b = b.at[0, 0].set(1000.0)            # outlier inside b.T row 0
    # pick tol strictly between the poisoned tile's error and the clean one's
    _, _, rel_ref = ref.razor_matmul(a, b, tol=1.0, block=128)
    r0, r1 = float(rel_ref[0, 0]), float(rel_ref[0, 1])
    assert r0 > r1 * 1.2, "poisoned tile must have visibly higher error"
    tol = float(0.5 * (r0 + r1))
    c, flags, rel = razor_matmul(a, b, tol=tol, interpret=True)
    assert int(flags[0, 0]) == 1 and int(flags[0, 1]) == 0
    shadow = np.array(a @ b)
    np.testing.assert_allclose(np.array(c)[:, :128], shadow[:, :128],
                               rtol=1e-5)


# -------------------------------------------------------------- precision ----

@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("tiers", [[[0, 1], [2, 0]], [[2, 2], [2, 2]],
                                   [[0, 0], [0, 0]]])
def test_precision_island_sweep(tiers, dtype):
    a, b = _ab(256, 256, 256, dtype, seed=4)
    t = np.asarray(tiers)
    c = np.array(precision_island(a, b, jnp.asarray(t, jnp.int32),
                                  interpret=True))
    c_ref = np.array(ref.precision_island(a, b, jnp.asarray(t, jnp.int32),
                                          block=128))
    # Quantized tiers hit round-to-nearest ties (x/scale exactly .5) whose
    # direction differs by 1 ULP between the interpreter and the oracle;
    # bf16 inputs amplify this (duplicate values tie together).  Compare
    # quantized tiles by relative Frobenius distance, exact tiles tightly.
    for i in range(2):
        for j in range(2):
            blk = (slice(i * 128, (i + 1) * 128), slice(j * 128, (j + 1) * 128))
            if t[i, j] == 2:
                np.testing.assert_allclose(c[blk], c_ref[blk], rtol=1e-4,
                                           atol=1e-4)
            else:
                num = np.linalg.norm(c[blk] - c_ref[blk])
                den = np.linalg.norm(c_ref[blk]) + 1e-9
                # int4-on-bf16 is the worst tie case (coarse grid x coarse
                # mantissa): allow 4% Frobenius; int8/f32 stay well under
                bound = 4e-2 if (t[i, j] == 0 and dtype == jnp.bfloat16) \
                    else 2e-2
                assert num / den < bound, (i, j, num / den)


def test_precision_tiers_order_error():
    """int4 tile error > int8 tile error > f32 tile error vs exact."""
    a, b = _ab(128, 256, 128, jnp.float32, seed=5)
    exact = np.array(a @ b)
    errs = []
    for tier in (0, 1, 2):
        c = precision_island(a, b, jnp.full((1, 1), tier, jnp.int32),
                             interpret=True)
        errs.append(np.abs(np.array(c) - exact).max())
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 1e-4


# ------------------------------------------------------------------- wkv6 ----

@pytest.mark.parametrize("b,s,h,p,chunk", [(2, 64, 2, 16, 16),
                                           (1, 128, 3, 32, 32),
                                           (2, 32, 1, 8, 32)])
def test_wkv6_kernel_vs_naive_ref(b, s, h, p, chunk):
    ks = jax.random.split(jax.random.PRNGKey(b * s), 5)
    r = jax.random.normal(ks[0], (b, s, h, p))
    k = jax.random.normal(ks[1], (b, s, h, p))
    v = jax.random.normal(ks[2], (b, s, h, p))
    w_log = -jnp.exp(jax.random.normal(ks[3], (b, s, h, p)) * 0.5)
    u = jax.random.normal(ks[4], (h, p)) * 0.1
    s0 = jax.random.normal(ks[0], (b, h, p, p)) * 0.1
    y, s_out = wkv6(r, k, v, w_log, u, s0, chunk=chunk, interpret=True)
    y_ref, s_ref = ref.wkv6(r, k, v, w_log, u, s0)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.array(s_out), np.array(s_ref), rtol=2e-4,
                               atol=2e-4)


def test_wkv6_matches_model_chunked_form():
    """Kernel == the model's wkv6_chunked (the jnp chunked oracle)."""
    from repro.models.ssm import wkv6_chunked
    b, s, h, p = 1, 64, 2, 16
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, p)) for i in range(3))
    w_log = -jnp.exp(jax.random.normal(ks[3], (b, s, h, p)) * 0.3)
    u = jax.random.normal(ks[4], (h, p)) * 0.1
    s0 = jnp.zeros((b, h, p, p))
    y_k, s_k = wkv6(r, k, v, w_log, u, s0, chunk=16, interpret=True)
    y_m, s_m = wkv6_chunked(r, k, v, w_log, u, s0, 16)
    np.testing.assert_allclose(np.array(y_k), np.array(y_m), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.array(s_k), np.array(s_m), rtol=1e-4,
                               atol=1e-5)


# -------------------------------------------------------------------- ssd ----

@pytest.mark.parametrize("b,s,h,p,n,chunk", [(2, 64, 2, 16, 8, 16),
                                             (1, 96, 4, 32, 16, 32),
                                             (2, 32, 1, 8, 4, 8)])
def test_ssd_kernel_vs_naive_ref(b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(s + h), 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A_log = jax.random.normal(ks[2], (h,)) * 0.3
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jax.random.normal(ks[5], (h,))
    s0 = jnp.zeros((b, h, n, p))
    y, s_out = ssd_chunk(x, dt, A_log, B, C, D, s0, chunk=chunk,
                         interpret=True)
    y_ref, s_ref = ref.ssd(x, dt, A_log, B, C, D, s0)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), rtol=3e-4,
                               atol=3e-4)
    np.testing.assert_allclose(np.array(s_out), np.array(s_ref), rtol=3e-4,
                               atol=3e-4)


def test_ssd_nonzero_initial_state():
    b, s, h, p, n = 1, 32, 2, 8, 4
    ks = jax.random.split(KEY, 7)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A_log = jax.random.normal(ks[2], (h,)) * 0.3
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jax.random.normal(ks[5], (h,))
    s0 = jax.random.normal(ks[6], (b, h, n, p))
    y, s_out = ssd_chunk(x, dt, A_log, B, C, D, s0, chunk=8, interpret=True)
    y_ref, s_ref = ref.ssd(x, dt, A_log, B, C, D, s0)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), rtol=3e-4,
                               atol=3e-4)
    np.testing.assert_allclose(np.array(s_out), np.array(s_ref), rtol=3e-4,
                               atol=3e-4)


# ------------------------------------------------------------ composed op ----

def test_voltage_scaled_matmul_flow():
    from repro.kernels.ops import voltage_scaled_matmul
    a, b = _ab(256, 256, 512, jnp.bfloat16, seed=7)
    c, info = voltage_scaled_matmul(a, b, block=128, n_partitions=4,
                                    v_min=1.0, v_crash=0.7, interpret=True)
    assert c.shape == (256, 512)
    assert info["energy_ratio_vs_nominal"] < 1.0      # saves energy
    # runtime step raised every flagged partition's rail
    raised = info["v_runtime"] >= info["v_static"]
    assert raised[np.array(info["flags_static"], bool)].all()
