"""Roofline machinery: HLO collective parsing, analytic FLOPs/HBM models,
artifact-driven analysis (deliverable g code paths)."""

import json
from pathlib import Path

import pytest

from repro.configs import ARCHS, SHAPES, get_config
from repro.roofline.analytic import (active_params, forward_flops,
                                     hbm_bytes_per_device, model_flops)
from repro.roofline.hlo import (CollectiveOp, parse_collectives,
                                summarize_collectives, total_collective_bytes)

ART = Path(__file__).resolve().parents[1] / "artifacts"


# ------------------------------------------------------------- HLO parsing ----

HLO_SAMPLE = """
  %ag = bf16[8,1024,512]{2,1,0} all-gather(%p0), replica_groups=[16,16]<=[256]T(1,0), dimensions={0}
  %ar.1 = f32[256,128]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[16,64]{1,0} reduce-scatter(%y), replica_groups=[4,4]<=[16], dimensions={0}
  %a2a = bf16[32,32]{1,0} all-to-all(%z), replica_groups={{0,1}}
  %cp = u8[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot.5 = f32[128,128]{1,0} dot(%a, %b)
"""


def test_parse_collectives_kinds_and_sizes():
    ops = parse_collectives(HLO_SAMPLE)
    kinds = [o.kind for o in ops]
    assert kinds == ["all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute"]
    ag, ar, rs, a2a, cp = ops
    assert ag.result_bytes == 8 * 1024 * 512 * 2
    assert ag.group == 16                      # iota [16,16]: group size 16
    assert ar.group == 4
    assert rs.operand_bytes == 16 * 64 * 4 * 4   # result x group
    assert cp.wire_bytes == 128


def test_collective_wire_models():
    ar = CollectiveOp("all-reduce", result_bytes=1000, group=4, line="")
    assert ar.wire_bytes == int(2 * 1000 * 3 / 4)
    ag = CollectiveOp("all-gather", result_bytes=1000, group=4, line="")
    assert ag.operand_bytes == 250
    assert ag.wire_bytes == 750


def test_summarize_and_totals():
    ops = parse_collectives(HLO_SAMPLE)
    s = summarize_collectives(ops)
    assert s["all-gather"]["count"] == 1
    op_b, wire_b = total_collective_bytes(ops)
    assert op_b > 0 and wire_b > 0


def test_parse_ignores_non_collectives():
    assert parse_collectives("%d = f32[8] dot(%a, %b)") == []


# ------------------------------------------------------- analytic models ----

def test_model_flops_dense_matches_6nd():
    """For a dense LM, train MODEL_FLOPS ~ 6*N*D (+attention)."""
    cfg = get_config("qwen1.5-110b")
    shape = SHAPES["train_4k"]
    mf = model_flops(cfg, shape)
    n = active_params(cfg)
    six_nd = 6 * n * shape.global_batch * shape.seq_len
    assert six_nd * 0.95 < mf < six_nd * 1.3      # attention adds a few %


def test_model_flops_moe_counts_active_only():
    cfg = get_config("grok-1-314b")
    shape = SHAPES["train_4k"]
    mf = model_flops(cfg, shape)
    n_act = active_params(cfg)
    n_tot = 315.7e9
    assert n_act < 0.45 * n_tot                   # top-2 of 8 experts
    six_nd = 6 * n_act * shape.global_batch * shape.seq_len
    assert six_nd * 0.9 < mf < six_nd * 1.35


def test_decode_flops_much_smaller_than_train():
    cfg = get_config("phi4-mini-3.8b")
    assert model_flops(cfg, SHAPES["decode_32k"]) < \
        model_flops(cfg, SHAPES["train_4k"]) / 100


def test_hbm_bytes_orderings():
    cfg = get_config("qwen1.5-110b")
    train = hbm_bytes_per_device(cfg, SHAPES["train_4k"], 256)
    dec = hbm_bytes_per_device(cfg, SHAPES["decode_32k"], 256)
    assert train > dec > 0
    # int8 KV halves the decode KV stream
    import dataclasses
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    dec8 = hbm_bytes_per_device(cfg8, SHAPES["decode_32k"], 256)
    assert dec8 < dec


def test_every_runnable_cell_has_positive_model_flops():
    from repro.configs import cell_is_runnable
    for arch in ARCHS:
        cfg = get_config(arch)
        for name, shape in SHAPES.items():
            ok, _ = cell_is_runnable(cfg, shape)
            if ok:
                assert model_flops(cfg, shape) > 0, (arch, name)


# ------------------------------------------------------- artifact analysis ----

@pytest.mark.skipif(not (ART / "roofline").exists(),
                    reason="estimator artifacts not generated")
def test_estimates_cover_all_runnable_cells():
    from repro.configs import cell_is_runnable
    missing = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for name, shape in SHAPES.items():
            ok, _ = cell_is_runnable(cfg, shape)
            p = ART / "roofline" / f"{arch}_{name}_pod_16x16.json"
            if ok and not p.exists():
                missing.append((arch, name))
    assert not missing


@pytest.mark.skipif(not (ART / "roofline").exists(),
                    reason="estimator artifacts not generated")
def test_analysis_rows_consistent():
    from repro.roofline.analysis import all_rows
    rows = [r for r in all_rows() if r.status == "ok"]
    assert len(rows) >= 30
    for r in rows:
        assert r.t_compute > 0 and r.t_memory > 0
        assert r.dominant in ("compute", "memory", "collective")
        assert 0 < r.roofline_fraction <= 1.0 + 1e-9, (r.arch, r.shape)
        assert r.hlo_over_model >= 0.9, (r.arch, r.shape, r.hlo_over_model)


@pytest.mark.skipif(not (ART / "roofline").exists(),
                    reason="estimator artifacts not generated")
def test_perf_iterations_recorded():
    """§Perf artifacts exist for the hillclimbed cells (before + after)."""
    tags = ["qwen1.5-110b_decode_32k_pod_16x16_optA3.json",
            "qwen1.5-110b_train_4k_pod_16x16_optB4.json",
            "rwkv6-1.6b_train_4k_pod_16x16_optC2.json",
            "llama4-scout-17b-a16e_train_4k_pod_16x16_optD1.json"]
    for t in tags:
        p = ART / "roofline" / t
        assert p.exists(), t
        assert json.loads(p.read_text())["status"] == "ok", t
    # the flagship D1 claim: >= 4x compute-term reduction vs baseline
    base = json.loads((ART / "roofline" /
                       "llama4-scout-17b-a16e_train_4k_pod_16x16.json"
                       ).read_text())["estimate"]["flops"]
    opt = json.loads((ART / "roofline" /
                      "llama4-scout-17b-a16e_train_4k_pod_16x16_optD1.json"
                      ).read_text())["estimate"]["flops"]
    assert base / opt > 4.0
