"""Continuous-batching engine: bit-identity vs a slots=1 reference decode,
honest truncation accounting, and strictly fewer model steps than the wave
baseline on a mixed workload."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import model_api
from repro.serve import Request, ServeEngine, WaveServeEngine

KEY = jax.random.PRNGKey(0)

# mixed prompt lengths AND mixed output budgets: the workload that
# head-of-line blocks a wave scheduler
PROMPTS = [[5, 6, 7], [3], [9, 8, 7, 6, 5, 4], [11, 12], [4, 4, 4, 4]]
MAX_NEW = [4, 7, 2, 5, 3]


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("starcoder2-3b", smoke=True)
    api = model_api(cfg)
    return cfg, api, api.init_params(KEY)


def _requests():
    return [Request(uid=i, prompt=list(p), max_new_tokens=m)
            for i, (p, m) in enumerate(zip(PROMPTS, MAX_NEW))]


def _reference(api, params, prompt, max_new, max_len):
    """Greedy decode of one request alone (the slots=1 ground truth)."""
    if api.cfg.family in ("dense", "moe", "vlm", "encdec"):
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
        logits, state = api.prefill(params, {"tokens": toks}, max_len=max_len)
    else:
        state = api.make_decode_state(ShapeConfig("r", max_len, 1, "decode"))
        logits = None
        for t in prompt:
            logits, state = api.decode_step(params, state,
                                            jnp.asarray([[t]], np.int32))
    step = jax.jit(api.decode_step)
    out = [int(np.asarray(logits)[0].argmax())]
    while len(out) < max_new:
        logits, state = step(params, state,
                             jnp.asarray([[out[-1]]], np.int32))
        out.append(int(np.asarray(logits)[0].argmax()))
    return out


def test_drained_outputs_bit_identical_to_reference(dense):
    cfg, api, params = dense
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    reqs = _requests()
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.completed == len(reqs)
    assert stats.truncated == 0 and stats.unserved == 0
    assert stats.tokens_generated == sum(MAX_NEW)
    for r in reqs:
        ref = _reference(api, params, r.prompt, r.max_new_tokens, 32)
        assert r.out_tokens == ref, f"req {r.uid}: {r.out_tokens} != {ref}"


def test_fewer_model_steps_than_wave_engine(dense):
    cfg, api, params = dense
    cont = ServeEngine(cfg, params, slots=2, max_len=32)
    wave = WaveServeEngine(cfg, params, slots=2, max_len=32)
    for eng in (cont, wave):
        for r in _requests():
            eng.submit(r)
    cs, ws = cont.run_until_drained(), wave.run_until_drained()
    assert cs.completed == ws.completed == len(PROMPTS)
    # acceptance: strictly fewer total model invocations on mixed lengths
    assert cs.model_steps < ws.model_steps
    # and every decode slot stays saturated until the tail drains
    assert all(o > 0.5 for o in cs.occupancy())


def test_ssm_family_continuous_matches_reference():
    cfg = get_config("rwkv6-1.6b", smoke=True)
    api = model_api(cfg)
    params = api.init_params(KEY)
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    reqs = [Request(uid=0, prompt=[3, 4], max_new_tokens=3),
            Request(uid=1, prompt=[7, 8, 9], max_new_tokens=2),
            Request(uid=2, prompt=[5], max_new_tokens=4)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.completed == 3
    for r in reqs:
        ref = _reference(api, params, r.prompt, r.max_new_tokens, 32)
        assert r.out_tokens == ref


def test_budget_truncation_is_reported_not_swallowed(dense):
    cfg, api, params = dense
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=[3 + i] * 2, max_new_tokens=10)
            for i in range(6)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained(max_steps=5)
    # in-flight requests were cut short: truncated, NOT completed
    assert stats.completed == 0
    assert stats.truncated == 2
    assert stats.unserved == 4                     # still queued, reported
    for r in reqs[:2]:
        assert r.done and r.truncated
        assert 0 < len(r.out_tokens) < r.max_new_tokens
    for r in reqs[2:]:
        assert not r.done and not r.out_tokens


def test_budget_bounds_ssm_absorption():
    """SSM prompts absorb token-by-token; the budget must gate admissions
    per request (overshoot bounded by ONE prompt, not slots * prompt_len)."""
    cfg = get_config("rwkv6-1.6b", smoke=True)
    api = model_api(cfg)
    params = api.init_params(KEY)
    eng = ServeEngine(cfg, params, slots=4, max_len=32)
    reqs = [Request(uid=i, prompt=[3 + i] * 10, max_new_tokens=8)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained(max_steps=12)
    assert stats.model_steps <= 12 + 10    # not 4 * 10 = 40
    assert stats.completed + stats.truncated + stats.unserved == 4
    assert stats.unserved >= 2             # deferred requests went back FIFO


def test_max_len_truncation_and_oversized_prompt(dense):
    cfg, api, params = dense
    eng = ServeEngine(cfg, params, slots=1, max_len=8)
    fits = Request(uid=0, prompt=[3, 4, 5], max_new_tokens=50)
    too_long = Request(uid=1, prompt=list(range(3, 15)), max_new_tokens=4)
    for r in (fits, too_long):
        eng.submit(r)
    stats = eng.run_until_drained()
    assert fits.truncated and len(fits.out_tokens) == 8 - 3
    assert too_long.truncated and too_long.out_tokens == []
    assert stats.truncated == 2 and stats.completed == 0


def test_midflight_admission_no_head_of_line_blocking(dense):
    """A short request admitted after a long one must finish first and its
    slot must be refilled mid-flight (per-slot TTFT, not per-wave)."""
    cfg, api, params = dense
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    long_req = Request(uid=0, prompt=[5, 6], max_new_tokens=12)
    shorts = [Request(uid=1 + i, prompt=[7 + i], max_new_tokens=2)
              for i in range(3)]
    for r in (long_req, *shorts):
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.completed == 4
    # the three short requests shared slot 1 while slot 0 held the long one:
    # admissions happened mid-flight, so decode steps stay below the wave
    # engine's per-wave max and outputs still match the reference
    for r in (long_req, *shorts):
        ref = _reference(api, params, r.prompt, r.max_new_tokens, 32)
        assert r.out_tokens == ref


def test_stats_split_prefill_vs_decode(dense):
    """Prompt absorption must NOT inflate decode throughput numbers."""
    cfg, api, params = dense
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    reqs = _requests()
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    # dense family: one batched prefill call per admitted request
    assert stats.prefill_steps == len(reqs)
    # each decode model call yields at most one token per occupied slot;
    # the first token of each request comes from its prefill logits
    assert stats.decode_steps >= max(MAX_NEW) - 1
    assert stats.decode_steps < sum(MAX_NEW)
    assert stats.model_steps == stats.prefill_steps + stats.decode_steps
    # telemetry present: TTFT per request, per-slot occupancy
    assert len(stats.ttft_s) == len(reqs)
    assert len(stats.occupancy()) == 2


def test_stats_to_dict_json_schema(dense):
    """The serialized telemetry must carry the derived quantities (model
    steps, per-slot occupancy, mean TTFT) and the hwloop fields, and be
    plain-JSON serializable."""
    import json

    cfg, api, params = dense
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    for r in _requests():
        eng.submit(r)
    stats = eng.run_until_drained()
    d = stats.to_dict()
    expected = {
        # raw counters
        "prefill_steps", "decode_steps", "waves", "admitted", "completed",
        "truncated", "unserved", "tokens_generated", "slot_busy_steps",
        "ttft_s",
        # derived values (not just the raw dataclass fields)
        "model_steps", "occupancy", "ttft_mean_s",
        # hardware-in-the-loop telemetry (None/empty without a session)
        "hwloop_step_flags", "hwloop",
    }
    assert expected <= set(d)
    assert d["model_steps"] == d["prefill_steps"] + d["decode_steps"]
    assert d["occupancy"] == stats.occupancy()
    assert d["ttft_mean_s"] == pytest.approx(
        sum(stats.ttft_s) / len(stats.ttft_s))
    assert d["hwloop"] is None and d["hwloop_step_flags"] == []
    json.dumps(d)          # plain-JSON serializable, end to end
