"""Exactly-once ``on_finish`` delivery: every admitted (or shed) request
fires its finish callback exactly once, on every terminal path — shed,
drain truncation, cancellation, pump fail-open, and the wave engine."""

import jax
import pytest

from repro.configs import get_config
from repro.models import model_api
from repro.serve import Request, ServeEngine, WaveServeEngine


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("starcoder2-3b", smoke=True)
    api = model_api(cfg)
    return cfg, api.init_params(jax.random.PRNGKey(0))


def _counted(uid, counts, **kw):
    req = Request(uid=uid, prompt=[3 + uid, 4 + uid], **kw)
    counts[uid] = 0

    def on_finish(r):
        counts[r.uid] += 1

    req.on_finish = on_finish
    return req


def test_completed_and_shed_fire_finish_once(dense):
    cfg, params = dense
    eng = ServeEngine(cfg, params, slots=1, max_len=32, policy="priority",
                      max_pending=1)
    counts = {}
    reqs = [_counted(i, counts, max_new_tokens=2) for i in range(4)]
    accepted = [eng.submit(r) for r in reqs]
    assert not all(accepted)                      # the 1-deep queue shed some
    eng.run_until_drained()
    assert all(n == 1 for n in counts.values()), counts
    for r, acc in zip(reqs, accepted):
        assert r.status == ("shed" if not acc else
                            "completed" if not r.truncated else "truncated")
        if not acc:
            assert r.shed_reason == "queue_full"


def test_drain_truncation_fires_finish_once(dense):
    cfg, params = dense
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    counts = {}
    reqs = [_counted(i, counts, max_new_tokens=500) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_steps=3)            # budget exhausts mid-decode
    assert all(r.status == "truncated" for r in reqs)
    assert all(n == 1 for n in counts.values()), counts
    # draining again must not re-deliver
    eng.run_until_drained(max_steps=3)
    assert all(n == 1 for n in counts.values()), counts


def test_cancellation_fires_finish_once_and_is_counted(dense):
    cfg, params = dense
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    counts = {}
    reqs = [_counted(i, counts, max_new_tokens=6) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.step()                                    # both admitted
    reqs[0].cancelled = True                      # client went away
    stats = eng.run_until_drained()
    assert reqs[0].status == "cancelled" and reqs[0].done
    assert reqs[1].status == "completed"
    assert stats.cancelled == 1 and stats.completed == 1
    assert counts == {0: 1, 1: 1}


def test_cancelled_request_is_reaped_from_pending_queue(dense):
    cfg, params = dense
    eng = ServeEngine(cfg, params, slots=1, max_len=32)
    counts = {}
    reqs = [_counted(i, counts, max_new_tokens=3) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    reqs[2].cancelled = True                      # cancelled while queued
    stats = eng.run_until_drained()
    assert reqs[2].status == "cancelled"
    assert len(reqs[2].out_tokens) == 0           # never reached a slot
    assert stats.cancelled == 1
    assert counts == {0: 1, 1: 1, 2: 1}


def test_pump_fail_open_is_idempotent(dense):
    from repro.server import ServeFrontend

    cfg, params = dense
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    counts = {}
    reqs = [_counted(i, counts, max_new_tokens=4) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    frontend = ServeFrontend(eng)
    frontend._fail_open()                         # pump died mid-serve
    frontend._fail_open()                         # double-fault: no re-fire
    assert all(r.done and r.truncated for r in reqs)
    assert counts == {0: 1, 1: 1}


def test_wave_engine_fires_finish_once(dense):
    cfg, params = dense
    eng = WaveServeEngine(cfg, params, slots=2, max_len=32)
    counts = {}
    reqs = [_counted(0, counts, max_new_tokens=2),
            _counted(1, counts, max_new_tokens=500)]   # hits max_len: trunc
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert reqs[0].status == "completed"
    assert reqs[1].truncated
    assert counts == {0: 1, 1: 1}
