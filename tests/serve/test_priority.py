"""Priority/SLO admission policy (pure python, no model): tier ordering,
EDF within a tier, bounded-queue backpressure, deadline shedding, and
bit-compatibility of the default FIFO path."""

import pytest

from repro.serve import Priority, Request, SlotScheduler


class FakeClock:
    def __init__(self, now=0.0):
        self.now = now

    def __call__(self):
        return self.now


def _req(uid, priority=Priority.NORMAL, deadline_s=None, submit_t=0.0):
    r = Request(uid=uid, prompt=[3, 4], max_new_tokens=4,
                priority=priority, deadline_s=deadline_s)
    r.submit_t = submit_t
    return r


# ---- admission ordering ------------------------------------------------------

def test_priority_tiers_win_admission():
    s = SlotScheduler(1, policy="priority", clock=FakeClock())
    for uid, prio in enumerate([Priority.LOW, Priority.NORMAL,
                                Priority.HIGH]):
        s.submit(_req(uid, prio))
    order = []
    while s.pending:
        [(slot, req)] = s.admit()
        order.append(req.uid)
        s.evict(slot)
    assert order == [2, 1, 0]          # HIGH, NORMAL, LOW


def test_edf_within_tier_fifo_tiebreak():
    s = SlotScheduler(1, policy="priority", clock=FakeClock())
    s.submit(_req(0, deadline_s=9.0))
    s.submit(_req(1, deadline_s=2.0))   # tightest SLO jumps the queue
    s.submit(_req(2))                   # no SLO sorts last
    s.submit(_req(3, deadline_s=9.0))   # ties with 0 -> FIFO
    order = []
    while s.pending:
        [(slot, req)] = s.admit()
        order.append(req.uid)
        s.evict(slot)
    assert order == [1, 0, 3, 2]


def test_fifo_policy_ignores_priority_fields():
    s = SlotScheduler(1)                # default: seed-compatible FIFO
    s.submit(_req(0, Priority.LOW, deadline_s=0.0))
    s.submit(_req(1, Priority.HIGH))
    [(slot, req)] = s.admit()
    assert req.uid == 0                 # strict arrival order, nothing shed
    assert s.n_shed == 0


# ---- bounded queue / backpressure --------------------------------------------

def test_bounded_queue_rejects_newcomer_at_equal_priority():
    s = SlotScheduler(1, policy="priority", max_pending=2, clock=FakeClock())
    assert s.submit(_req(0))
    assert s.submit(_req(1))
    late = _req(2)
    assert not s.submit(late)           # backpressure: shed, not buffered
    assert late.shed and late.done and late.shed_reason == "queue_full"
    assert s.n_pending == 2 and s.n_shed == 1


def test_bounded_queue_sheds_lowest_priority_victim():
    s = SlotScheduler(1, policy="priority", max_pending=2, clock=FakeClock())
    low, norm = _req(0, Priority.LOW), _req(1, Priority.NORMAL)
    s.submit(low)
    s.submit(norm)
    high = _req(2, Priority.HIGH)
    assert s.submit(high)               # displaces the LOW victim
    assert low.shed and low.shed_reason == "queue_full"
    assert not high.shed and not norm.shed
    assert [r.uid for r in s.pending] == [1, 2]


def test_fifo_bounded_queue_never_displaces():
    s = SlotScheduler(1, policy="fifo", max_pending=1)
    s.submit(_req(0, Priority.LOW))
    high = _req(1, Priority.HIGH)
    assert not s.submit(high)           # FIFO has no displacement
    assert high.shed


def test_shed_notifies_on_finish():
    s = SlotScheduler(1, policy="priority", max_pending=1, clock=FakeClock())
    s.submit(_req(0))
    seen = []
    victim = _req(1)
    victim.on_finish = seen.append
    s.submit(victim)
    assert seen == [victim] and victim.status == "shed"


# ---- deadline shedding -------------------------------------------------------

def test_expired_deadline_is_shed_not_decoded():
    clock = FakeClock()
    s = SlotScheduler(1, policy="priority", clock=clock)
    doomed = _req(0, deadline_s=1.0)
    fine = _req(1, deadline_s=10.0)
    s.submit(doomed)
    s.submit(fine)
    clock.now = 5.0                     # doomed's TTFT SLO already blown
    admissions = s.admit()
    assert [r.uid for _, r in admissions] == [1]
    assert doomed.shed and doomed.shed_reason == "deadline"
    assert doomed.finish_t == 5.0       # stamped from the injected clock
    assert s.n_shed == 1


def test_unexpired_deadline_survives_admission():
    clock = FakeClock()
    s = SlotScheduler(2, policy="priority", clock=clock)
    s.submit(_req(0, deadline_s=1.0))
    clock.now = 0.5
    assert [r.uid for _, r in s.admit()] == [0]
    assert s.n_shed == 0


# ---- request status surface --------------------------------------------------

def test_request_status_and_deadline_met():
    r = _req(0, deadline_s=1.0)
    assert r.status == "pending"
    assert r.deadline_met() is False    # no first token yet
    r.out_tokens.append(7)
    r.first_token_t = 0.4
    assert r.status == "running" and r.deadline_met() is True
    r.done = True
    assert r.status == "completed"
    assert _req(1).deadline_met() is None   # no SLO -> no verdict


def test_scheduler_validates_arguments():
    with pytest.raises(ValueError):
        SlotScheduler(1, policy="lifo")
    with pytest.raises(ValueError):
        SlotScheduler(1, max_pending=0)
