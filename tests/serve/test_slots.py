"""Per-slot decode-state surgery on ModelAPI: one batch row is sliced,
scattered or reset without disturbing the other slots."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import model_api

SHAPE = ShapeConfig("t", 16, 3, "decode")
SUB = ShapeConfig("t", 16, 1, "decode")


def _filled_state(api, shape, value):
    return jax.tree.map(lambda z: jnp.full_like(z, value),
                        api.make_decode_state(shape))


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "rwkv6-1.6b",
                                  "zamba2-2.7b", "seamless-m4t-medium"])
def test_slot_update_touches_only_target_row(arch):
    api = model_api(get_config(arch, smoke=True))
    state = api.make_decode_state(SHAPE)
    sub = _filled_state(api, SUB, 1)
    new = api.slot_update(SHAPE, state, jnp.int32(1), sub)
    for spec, before, after in zip(
            jax.tree.leaves(api.decode_state_specs(SHAPE),
                            is_leaf=lambda x: hasattr(x, "logical")),
            jax.tree.leaves(state), jax.tree.leaves(new)):
        ax = spec.logical.index("batch")
        moved = np.moveaxis(np.asarray(after, np.float32), ax, 0)
        assert (moved[1] == 1).all()                  # target row written
        assert (moved[0] == 0).all() and (moved[2] == 0).all()


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "rwkv6-1.6b"])
def test_slot_slice_roundtrips(arch):
    api = model_api(get_config(arch, smoke=True))
    state = _filled_state(api, SHAPE, 2)
    sub = api.slot_slice(SHAPE, state, jnp.int32(2))
    for spec, leaf in zip(
            jax.tree.leaves(api.decode_state_specs(SUB),
                            is_leaf=lambda x: hasattr(x, "logical")),
            jax.tree.leaves(sub)):
        assert leaf.shape == spec.shape
        assert (np.asarray(leaf, np.float32) == 2).all()
    # scattering the slice back into a zero state reproduces one row of 2s
    back = api.slot_update(SHAPE, api.make_decode_state(SHAPE),
                           jnp.int32(0), sub)
    spec0 = jax.tree.leaves(api.decode_state_specs(SHAPE),
                            is_leaf=lambda x: hasattr(x, "logical"))
    for spec, leaf in zip(spec0, jax.tree.leaves(back)):
        moved = np.moveaxis(np.asarray(leaf, np.float32),
                            spec.logical.index("batch"), 0)
        assert (moved[0] == 2).all() and (moved[1:] == 0).all()


def test_slot_reset_zeroes_one_row():
    api = model_api(get_config("phi4-mini-3.8b", smoke=True))
    state = _filled_state(api, SHAPE, 3)
    new = api.slot_reset(SHAPE, state, jnp.int32(1))
    for spec, leaf in zip(
            jax.tree.leaves(api.decode_state_specs(SHAPE),
                            is_leaf=lambda x: hasattr(x, "logical")),
            jax.tree.leaves(new)):
        moved = np.moveaxis(np.asarray(leaf, np.float32),
                            spec.logical.index("batch"), 0)
        assert (moved[1] == 0).all()
        assert (moved[0] == 3).all() and (moved[2] == 3).all()


def test_per_row_index_advances_independently():
    """decode_step with per-row indices: every row advances its own
    position — the invariant continuous batching rests on."""
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    api = model_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    state = api.make_decode_state(SHAPE)
    state["index"] = jnp.asarray([0, 3, 7], jnp.int32)
    _, state = jax.jit(api.decode_step)(params, state,
                                        jnp.full((3, 1), 5, jnp.int32))
    assert state["index"].tolist() == [1, 4, 8]
