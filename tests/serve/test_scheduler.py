"""SlotScheduler admission/eviction invariants (pure python, no model)."""

import pytest

from repro.serve import Request, SlotScheduler


def _req(uid):
    return Request(uid=uid, prompt=[3, 4], max_new_tokens=4)


def test_fifo_admission_fills_free_slots():
    s = SlotScheduler(2)
    for uid in range(5):
        s.submit(_req(uid))
    admissions = s.admit()
    assert [slot for slot, _ in admissions] == [0, 1]
    assert [r.uid for _, r in admissions] == [0, 1]       # FIFO order
    assert s.n_active == 2 and s.n_pending == 3
    assert s.admit() == []                                # no free slot left


def test_evict_frees_slot_for_next_request():
    s = SlotScheduler(2)
    for uid in range(3):
        s.submit(_req(uid))
    s.admit()
    done = s.evict(0)
    assert done.uid == 0
    assert s.free_slots() == [0]
    admissions = s.admit()
    assert admissions[0][0] == 0 and admissions[0][1].uid == 2
    assert s.n_pending == 0


def test_no_double_occupancy_and_slot_identity():
    s = SlotScheduler(3)
    for uid in range(3):
        s.submit(_req(uid))
    slots = [slot for slot, _ in s.admit()]
    assert sorted(slots) == [0, 1, 2]
    assert len(set(slots)) == 3
    with pytest.raises(KeyError):
        s.evict(7)                                        # never admitted
    s.evict(1)
    with pytest.raises(KeyError):
        s.evict(1)                                        # already evicted


def test_drained_reflects_both_queue_and_slots():
    s = SlotScheduler(1)
    assert s.drained()
    s.submit(_req(0))
    assert not s.drained()
    s.admit()
    assert not s.drained()
    s.evict(0)
    assert s.drained()


def test_zero_slots_rejected():
    with pytest.raises(ValueError):
        SlotScheduler(0)
