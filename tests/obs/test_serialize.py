"""The shared telemetry serializer and the schema pins that keep
``EngineStats.to_dict`` / ``BackendTelemetry.to_dict`` bit-compatible
with their pre-obs-bus shapes (consumers: BENCH_* artifacts, /v1/stats,
the chaos report)."""

import dataclasses
import enum
import json

import numpy as np
import pytest

from repro.backend.base import BackendTelemetry
from repro.obs import to_plain
from repro.serve.engine import EngineStats


# ---- to_plain coercions ------------------------------------------------------

def test_passthrough_types():
    for v in (None, True, 3, 2.5, "s"):
        assert to_plain(v) is v or to_plain(v) == v


def test_numpy_scalars_and_arrays():
    assert to_plain(np.float64(2.5)) == 2.5
    assert type(to_plain(np.float64(2.5))) is float
    assert to_plain(np.int32(7)) == 7
    assert type(to_plain(np.int32(7))) is int
    assert to_plain(np.bool_(True)) is True
    assert to_plain(np.array([1, 2])) == [1, 2]
    assert to_plain(np.array([[True, False]])) == [[True, False]]
    assert to_plain(np.array(3.0)) == 3.0          # 0-d array


def test_containers_enums_dataclasses():
    class K(enum.Enum):
        HIGH = 0

    @dataclasses.dataclass
    class D:
        b: int
        a: float

    out = to_plain({"k": K.HIGH, "d": D(b=1, a=np.float64(0.5)),
                    "t": (1, [np.int64(2)])})
    assert out == {"k": "HIGH", "d": {"b": 1, "a": 0.5}, "t": [1, [2]]}
    assert list(out["d"]) == ["b", "a"]            # declaration order kept
    json.dumps(out)                                # fully JSON-serializable


def test_unsupported_type_raises():
    with pytest.raises(TypeError):
        to_plain(object())


# ---- schema pins -------------------------------------------------------------

# The exact key order of the pre-bus dataclass serializations. A change
# here is a breaking change for every stored BENCH_*.json / chaos
# artifact; update deliberately, not accidentally.
ENGINE_STATS_KEYS = (
    "prefill_steps", "decode_steps", "waves", "admitted", "completed",
    "truncated", "unserved", "shed", "cancelled", "tokens_generated",
    "slot_busy_steps", "ttft_s", "hwloop_step_flags", "hwloop",
    "backend", "backend_step_flags", "backend_telemetry",
    "guard_step_events", "railscale", "model_steps", "occupancy",
    "ttft_mean_s",
)

BACKEND_TELEMETRY_KEYS = (
    "calls", "macs", "flags", "replays", "silent", "energy_j",
    "rel_error", "partition_flags", "guard_checks", "guard_detected",
    "guard_corrected", "guard_retries", "guard_heals",
    "guard_uncorrected",
)


def test_engine_stats_to_dict_schema_pinned():
    stats = EngineStats(slot_busy_steps=[3, 1])
    stats.completed = 2
    stats.decode_steps = 4
    stats.record_ttft(0.25)
    d = stats.to_dict()
    assert tuple(d) == ENGINE_STATS_KEYS
    assert d["completed"] == 2 and isinstance(d["completed"], int)
    assert d["ttft_s"] == [0.25]
    assert d["ttft_mean_s"] == 0.25
    assert d["occupancy"] == [0.75, 0.25]
    json.dumps(d)


def test_backend_telemetry_to_dict_schema_pinned():
    tel = BackendTelemetry(calls=3, macs=10, flags=1,
                           partition_flags=[True, False],
                           energy_j=np.float64(0.5))
    d = tel.to_dict()
    assert tuple(d) == BACKEND_TELEMETRY_KEYS
    assert d["partition_flags"] == [True, False]
    assert d["energy_j"] == 0.5 and type(d["energy_j"]) is float
    json.dumps(d)


def test_stat_counter_properties_support_both_assignment_and_increment():
    stats = EngineStats()
    stats.shed = 5           # absolute snapshot assignment (scheduler path)
    stats.shed += 2          # increment (engine path)
    assert stats.shed == 7
    # the registry cell is the same source of truth the scrape reads
    reg = stats.obs.registry
    assert reg.counter("serve_requests_shed_total").value() == 7.0
