"""MetricsRegistry primitives: bucket-edge semantics, label handling,
get-or-create identity, and the deterministic Prometheus/JSON renders."""

import math

import pytest

from repro.obs import DEFAULT_LATENCY_BUCKETS, MetricsRegistry


# ---- counters / gauges -------------------------------------------------------

def test_counter_inc_and_monotonicity():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "help text")
    c.inc()
    c.inc(2.5)
    assert c.value() == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_counter_labels_are_independent_cells():
    reg = MetricsRegistry()
    c = reg.counter("events_total", labels=("kind",))
    c.inc(kind="detect")
    c.inc(3, kind="correct")
    bound = c.labels(kind="detect")
    bound.inc()
    assert c.value(kind="detect") == 2
    assert c.value(kind="correct") == 3
    with pytest.raises(ValueError):
        c.inc(wrong="x")          # unknown label name
    with pytest.raises(ValueError):
        c.inc()                   # missing label


def test_gauge_set_inc_dec():
    reg = MetricsRegistry()
    g = reg.gauge("queue_depth")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value() == 3
    g.set(-1.5)                   # gauges may go negative
    assert g.value() == -1.5


# ---- histogram bucket edges --------------------------------------------------

def test_histogram_bucket_edges_are_le_inclusive():
    """An observation exactly on a bound lands in that bucket (Prometheus
    `le` semantics), and the cumulative render reflects it."""
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(0.1, 1.0, 5.0))
    for v in (0.1, 0.10000001, 1.0, 5.0, 7.0):
        h.observe(v)
    buckets, total, n = h.snapshot()
    assert n == 5
    assert total == pytest.approx(13.20000001)
    cum = {bound: c for bound, c in buckets}
    assert cum[0.1] == 1          # 0.1 is <= 0.1
    assert cum[1.0] == 3          # + 0.10000001, 1.0
    assert cum[5.0] == 4          # + 5.0 (edge-inclusive)
    assert cum[math.inf] == 5     # + 7.0 overflows to +Inf only


def test_histogram_auto_appends_inf_and_sorts_bounds():
    reg = MetricsRegistry()
    h = reg.histogram("h", buckets=(5.0, 0.5, 1.0))
    assert h.buckets == (0.5, 1.0, 5.0, math.inf)
    h2 = reg.histogram("h2", buckets=(1.0, math.inf))
    assert h2.buckets == (1.0, math.inf)


def test_histogram_render_is_cumulative_with_inf_sum_count():
    reg = MetricsRegistry()
    h = reg.histogram("ttft_seconds", "ttft", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 2.0):
        h.observe(v)
    text = reg.render_prometheus()
    assert 'ttft_seconds_bucket{le="0.1"} 1' in text
    assert 'ttft_seconds_bucket{le="1"} 2' in text
    assert 'ttft_seconds_bucket{le="+Inf"} 3' in text
    assert "ttft_seconds_sum 2.55" in text
    assert "ttft_seconds_count 3" in text
    assert "# TYPE ttft_seconds histogram" in text


def test_default_latency_buckets_cover_harness_and_real_scales():
    assert DEFAULT_LATENCY_BUCKETS[0] <= 0.001
    assert DEFAULT_LATENCY_BUCKETS[-1] >= 10.0
    assert list(DEFAULT_LATENCY_BUCKETS) == sorted(DEFAULT_LATENCY_BUCKETS)


# ---- registry get-or-create --------------------------------------------------

def test_get_or_create_returns_same_metric():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "first")
    b = reg.counter("x_total", "second registration ignored")
    assert a is b
    a.inc()
    assert b.value() == 1


def test_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x_total")
    with pytest.raises(ValueError):
        reg.gauge("x_total")
    reg.gauge("g", labels=("a",))
    with pytest.raises(ValueError):
        reg.gauge("g", labels=("b",))


# ---- renderers ---------------------------------------------------------------

def test_render_prometheus_sorted_and_escaped():
    reg = MetricsRegistry()
    reg.gauge("zz").set(1)
    c = reg.counter("aa", "first metric", labels=("path",))
    c.inc(path='say "hi"\\')
    text = reg.render_prometheus()
    assert text.index("# TYPE aa counter") < text.index("# TYPE zz gauge")
    assert 'aa{path="say \\"hi\\"\\\\"} 1' in text
    assert text.endswith("\n")
    # integers render without a trailing .0 (Prometheus-conventional)
    assert "zz 1\n" in text


def test_render_json_mirrors_prometheus_data():
    reg = MetricsRegistry()
    reg.counter("c", "help").inc(2)
    reg.histogram("h", buckets=(1.0,)).observe(0.5)
    j = reg.render_json()
    assert j["c"]["type"] == "counter"
    assert j["c"]["values"] == [{"labels": {}, "value": 2.0}]
    assert j["h"]["values"][0]["buckets"] == {"1": 1, "+Inf": 1}
    assert j["h"]["values"][0]["count"] == 1


def test_injected_clock_is_carried():
    t = [0.0]
    reg = MetricsRegistry(clock=lambda: t[0])
    assert reg.clock() == 0.0
    t[0] = 7.5
    assert reg.clock() == 7.5
