"""Tracer spans under an injected clock, flight-recorder wraparound and
NDJSON dumps, and the ObsBus wiring that ties them together."""

import io
import json

import pytest

from repro.obs import FlightRecorder, ObsBus, Tracer


class _Clock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


# ---- tracer ------------------------------------------------------------------

def test_event_and_span_timing_under_injected_clock():
    clock, out = _Clock(), []
    tr = Tracer(clock=clock, sinks=[out.append])
    tr.event("request_submitted", uid=1)
    clock.now = 2.0
    with tr.span("prefill", uid=1) as sp:
        clock.now = 2.5
        sp.set(tokens=4)
    assert out[0] == {"kind": "event", "name": "request_submitted",
                      "t": 0.0, "uid": 1}
    assert out[1] == {"kind": "span", "name": "prefill", "t": 2.0,
                      "dur_s": 0.5, "uid": 1, "tokens": 4}


def test_span_end_is_idempotent_and_exception_sets_error_attr():
    clock, out = _Clock(), []
    tr = Tracer(clock=clock, sinks=[out.append])
    sp = tr.span("decode")
    sp.end()
    sp.end()
    assert len(out) == 1
    with pytest.raises(RuntimeError):
        with tr.span("verify"):
            raise RuntimeError("boom")
    assert out[1]["error"] == "RuntimeError"


def test_disabled_tracer_emits_nothing_and_costs_no_sink_calls():
    out = []
    tr = Tracer(enabled=False, sinks=[out.append])
    tr.event("x")
    with tr.span("y") as sp:
        sp.set(a=1)
    assert out == []


def test_add_remove_sink():
    a, b = [], []
    tr = Tracer(clock=_Clock(), sinks=[a.append])
    tr.add_sink(b.append)
    tr.event("one")
    tr.remove_sink(a.append)      # bound methods compare equal by target
    tr.event("two")
    assert [e["name"] for e in a] == ["one"]
    assert [e["name"] for e in b] == ["one", "two"]


# ---- flight recorder ---------------------------------------------------------

def test_wraparound_keeps_last_capacity_events():
    rec = FlightRecorder(capacity=4)
    for i in range(10):
        rec.record({"kind": "event", "name": "e", "t": float(i), "i": i})
    assert len(rec) == 4
    assert rec.total_recorded == 10
    assert rec.dropped == 6
    assert [e["i"] for e in rec.to_list()] == [6, 7, 8, 9]   # oldest first


def test_dump_ndjson_roundtrip_filelike_and_path(tmp_path):
    rec = FlightRecorder(capacity=8)
    rec.record({"kind": "event", "name": "a", "t": 0.0})
    rec.record({"kind": "span", "name": "b", "t": 0.0, "dur_s": 1.0})
    buf = io.StringIO()
    assert rec.dump_ndjson(buf) == 2
    lines = buf.getvalue().strip().split("\n")
    assert [json.loads(ln)["name"] for ln in lines] == ["a", "b"]
    p = tmp_path / "flight.ndjson"
    assert rec.dump_ndjson(p) == 2
    assert [json.loads(ln)["kind"] for ln in p.read_text().splitlines()] \
        == ["event", "span"]


def test_clear_resets_ring_but_not_lifetime_count():
    rec = FlightRecorder(capacity=2)
    rec.record({"a": 1})
    rec.clear()
    assert len(rec) == 0 and rec.total_recorded == 1
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)


# ---- bus ---------------------------------------------------------------------

def test_bus_routes_events_into_recorder_and_shares_clock():
    clock = _Clock()
    bus = ObsBus(clock=clock, recorder_capacity=16)
    assert bus.registry.clock is clock
    clock.now = 3.0
    bus.event("guard_detect", bad=2)
    ring = bus.recorder.to_list()
    assert ring == [{"kind": "event", "name": "guard_detect", "t": 3.0,
                     "bad": 2}]


def test_disabled_bus_keeps_registry_live_but_records_nothing():
    bus = ObsBus(enabled=False)
    bus.event("x")
    with bus.span("y"):
        pass
    assert len(bus.recorder) == 0
    bus.registry.counter("c").inc()       # registry still works
    assert "c 1" in bus.render_prometheus()


def test_trace_file_sink_streams_ndjson(tmp_path):
    clock = _Clock()
    bus = ObsBus(clock=clock)
    path = tmp_path / "trace.ndjson"
    bus.attach_trace_file(path)
    bus.event("one", uid=7)
    with bus.span("two"):
        clock.now = 1.0
    with pytest.raises(RuntimeError):
        bus.attach_trace_file(path)       # one sink at a time
    bus.close_trace()
    bus.event("after-close")              # must not land in the file
    rows = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert [r["name"] for r in rows] == ["one", "two"]
    assert rows[1] == {"kind": "span", "name": "two", "t": 0.0, "dur_s": 1.0}
