"""Observability over a live serving stack: bit-identical metric
snapshots across two identical virtual-time runs, the EngineStats view
agreeing with the registry it fronts, lifecycle events landing in the
flight recorder, and concurrent ``/metrics`` scrapes while streams are
in flight (the scrape path must never stall the pump)."""

import asyncio

import jax
import pytest

from repro.configs import get_config
from repro.models import model_api
from repro.obs import ObsBus
from repro.serve import Request, ServeEngine
from repro.server import (LoadHarness, ServeFrontend, TrafficConfig,
                          TrafficGenerator, VirtualClock, get_json,
                          overload_rate_rps, stream_generate)
from repro.server.client import _request
from repro.server.frontend import PROMETHEUS_CONTENT_TYPE

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("starcoder2-3b", smoke=True)
    api = model_api(cfg)
    return cfg, api.init_params(KEY)


def _virtual_run(cfg, params, seed=0):
    clock = VirtualClock()
    eng = ServeEngine(cfg, params, slots=2, max_len=32, clock=clock,
                      policy="priority", max_pending=6,
                      obs=ObsBus(clock=clock))
    tcfg = TrafficConfig(
        rate_rps=overload_rate_rps(2.0, 2, 0.02, TrafficConfig()),
        duration_s=1.0, seed=seed, max_prompt_len=8, max_gen_len=8,
        vocab_size=cfg.vocab_size)
    events = TrafficGenerator(tcfg).events()
    metrics = LoadHarness(eng, clock, step_cost_s=0.02).replay(events)
    return eng, metrics


# ---- determinism -------------------------------------------------------------

def test_virtual_time_metrics_bit_identical_across_runs(dense):
    """Two identical virtual-time replays must render byte-for-byte
    identical metric snapshots — the property that lets CI diff scrapes."""
    cfg, params = dense
    eng_a, _ = _virtual_run(cfg, params)
    eng_b, _ = _virtual_run(cfg, params)
    text_a = eng_a.obs.render_prometheus()
    assert text_a == eng_b.obs.render_prometheus()
    assert eng_a.obs.render_json() == eng_b.obs.render_json()
    # a different seed must actually change the snapshot (the check above
    # is vacuous if the render ignores the run)
    eng_c, _ = _virtual_run(cfg, params, seed=5)
    assert text_a != eng_c.obs.render_prometheus()


def test_stats_view_agrees_with_registry_and_scrape(dense):
    cfg, params = dense
    eng, metrics = _virtual_run(cfg, params)
    reg = eng.obs.registry
    stats = eng.stats
    assert reg.counter("serve_tokens_generated_total").value() \
        == stats.tokens_generated == metrics.tokens_generated
    assert reg.counter("serve_requests_completed_total").value() \
        == stats.completed
    _, _, n = reg.histogram("serve_ttft_seconds").snapshot()
    assert n == len(stats.ttft_s) > 0
    text = eng.obs.render_prometheus()
    assert f"serve_tokens_generated_total {stats.tokens_generated}" in text
    assert f"serve_ttft_seconds_count {n}" in text
    # the full-lifecycle gauges settled: nothing queued or active at drain
    assert reg.gauge("serve_queue_depth").value() == 0
    assert reg.gauge("serve_active_slots").value() == 0
    assert reg.gauge("serve_slots").value() == 2


def test_lifecycle_events_reach_flight_recorder(dense):
    cfg, params = dense
    clock = VirtualClock()
    eng = ServeEngine(cfg, params, slots=1, max_len=16, clock=clock,
                      obs=ObsBus(clock=clock))
    eng.submit(Request(uid=0, prompt=[5, 6], max_new_tokens=2))
    eng.run_until_drained()
    names = [e["name"] for e in eng.obs.recorder.to_list()]
    for expected in ("request_submitted", "request_admitted", "prefill",
                     "decode_step", "request_finished"):
        assert expected in names, f"missing {expected} in {names}"
    # spans carry durations in virtual time
    spans = [e for e in eng.obs.recorder.to_list() if e["kind"] == "span"]
    assert spans and all("dur_s" in s for s in spans)


# ---- live scrape during streaming --------------------------------------------

def test_concurrent_metrics_scrapes_during_streaming(dense):
    """`GET /metrics` and `/v1/stats` answered from the asyncio thread
    while the pump decodes: scrapes return live counters and never block
    the streams."""
    cfg, params = dense

    async def scenario():
        engine = ServeEngine(cfg, params, slots=2, max_len=32,
                             policy="priority")
        frontend = ServeFrontend(engine)
        host, port = await frontend.start()

        async def scrape_loop(n=8):
            seen = []
            for _ in range(n):
                status, headers, payload = await _request(
                    host, port, "GET", "/metrics")
                assert status == 200
                assert headers["content-type"] == PROMETHEUS_CONTENT_TYPE
                seen.append(payload.decode())
                await asyncio.sleep(0.002)
            return seen

        streams = [stream_generate(host, port, [5 + i, 6], max_new_tokens=4)
                   for i in range(4)]
        results, scrapes_a, scrapes_b = await asyncio.gather(
            asyncio.gather(*streams), scrape_loop(), scrape_loop())
        stats = await get_json(host, port, "/v1/stats")
        final = await _request(host, port, "GET", "/metrics")
        await frontend.drain()
        await frontend.close()
        return results, scrapes_a + scrapes_b, stats, final[2].decode()

    results, scrapes, stats, final_text = asyncio.run(scenario())
    # every stream survived concurrent scraping with its full budget
    assert all(r.ok and len(r.tokens) == 4 for r in results)
    # every scrape was well-formed Prometheus text with the serve metrics
    for text in scrapes:
        assert "# TYPE serve_tokens_generated_total counter" in text
    assert "serve_tokens_generated_total 16" in final_text
    assert "serve_ttft_seconds_count 4" in final_text
    # /v1/stats carries health + the bit-compatible stats dict + metrics
    assert stats["_http_status"] == 200
    assert stats["health"]["completed"] == 4
    assert stats["engine"]["tokens_generated"] == 16
    assert stats["metrics"]["serve_requests_completed_total"]["values"] \
        == [{"labels": {}, "value": 4.0}]
