"""Vectorized SystolicSim vs the reference loop propagation: bit parity of
products, fault statistics and trial flags across the whole voltage range —
nominal, Razor-detection window, and deep crash region (chained silent
failures exercising the forward-fill)."""

import numpy as np
import pytest

from repro.core import (RazorConfig, SystolicSim, TimingModel, TECH_NODES,
                        quadrant_floorplan)


@pytest.fixture(scope="module")
def tm():
    return TimingModel(n=16, tech=TECH_NODES["vtr-22nm"], seed=2021)


def _pair(tm, voltages):
    fp = quadrant_floorplan(16).with_voltages(voltages)
    return (SystolicSim(tm, fp, RazorConfig()),
            SystolicSim(tm, fp, RazorConfig(), impl="reference"))


# voltages spanning: all-clean, detection window, partial silent, full crash
VOLTAGE_POINTS = [1.0, 0.9, 0.75, 0.68, 0.64, 0.62, 0.55]


@pytest.mark.parametrize("v", VOLTAGE_POINTS)
def test_matmul_bit_identical(tm, v):
    sv, sr = _pair(tm, [v, v * 1.03, v * 0.97, v])
    rng = np.random.default_rng(11)
    a, w = rng.normal(size=(48, 16)), rng.normal(size=(16, 16))
    cv, stv = sv.matmul(a, w)
    cr, str_ = sr.matmul(a, w)
    np.testing.assert_array_equal(cv, cr)
    np.testing.assert_array_equal(stv.detected, str_.detected)
    np.testing.assert_array_equal(stv.silent, str_.silent)
    np.testing.assert_array_equal(stv.partition_fail, str_.partition_fail)
    assert stv.replay_cycles == str_.replay_cycles
    assert stv.rel_error == str_.rel_error


def test_partial_silent_forward_fill_chains(tm):
    """A mixed-voltage floorplan where only some partitions go silent — the
    forward fill must chain stale values exactly like the element loop."""
    sv, sr = _pair(tm, [0.60, 1.0, 0.66, 0.70])
    rng = np.random.default_rng(5)
    a, w = rng.normal(size=(64, 16)), rng.normal(size=(16, 16))
    cv, stv = sv.matmul(a, w)
    cr, str_ = sr.matmul(a, w)
    assert 0 < stv.silent.sum() < stv.silent.size * a.shape[0]  # genuinely mixed
    np.testing.assert_array_equal(cv, cr)
    np.testing.assert_array_equal(stv.silent, str_.silent)


@pytest.mark.parametrize("v", VOLTAGE_POINTS)
@pytest.mark.parametrize("fail_on_silent", [True, False])
def test_trial_run_flags_identical(tm, v, fail_on_silent):
    sv, sr = _pair(tm, [v] * 4)
    for seed in range(4):
        fv = sv.trial_run(np.array([v, v * 1.05, v * 0.95, v]), seed=seed,
                          fail_on_silent=fail_on_silent)
        fr = sr.trial_run(np.array([v, v * 1.05, v * 0.95, v]), seed=seed,
                          fail_on_silent=fail_on_silent)
        np.testing.assert_array_equal(fv, fr)


def test_partition_detected_bincount_reduction(tm):
    sv, _ = _pair(tm, [0.68] * 4)
    rng = np.random.default_rng(2)
    a, w = rng.normal(size=(32, 16)), rng.normal(size=(16, 16))
    _, stats = sv.matmul(a, w)
    part = sv.floorplan.partition_of_mac()
    got = stats.partition_detected(part)
    want = np.array([(stats.detected.reshape(-1)[part == p] > 0).any()
                     for p in range(int(part.max()) + 1)])
    np.testing.assert_array_equal(got, want)


def test_invalid_impl_rejected(tm):
    with pytest.raises(ValueError, match="impl"):
        SystolicSim(tm, quadrant_floorplan(16).with_voltages([1.0] * 4),
                    RazorConfig(), impl="numba")
