"""Timing-model calibration against the paper's Table I and Figs. 4/5."""

import numpy as np
import pytest

from repro.core import TECH_NODES, TimingModel, delay_scale, render_report_table


@pytest.fixture(scope="module")
def tm16():
    return TimingModel(n=16, seed=2021)


def test_table1_worst_path_statistics(tm16):
    """Worst 100 setup paths must match Table I's ranges (100 MHz, Artix-7)."""
    rep = tm16.report(100)
    slacks = np.array([p.slack_ns for p in rep])
    totals = np.array([p.total_delay_ns for p in rep])
    logics = np.array([p.logic_delay_ns for p in rep])
    nets = np.array([p.net_delay_ns for p in rep])
    assert 5.2 <= slacks.min() <= 5.6            # paper: 5.34
    assert 4.0 <= totals.max() <= 4.6            # paper: 4.40
    assert 2.4 <= logics.max() <= 3.1            # paper: 2.89
    assert 1.3 <= nets.max() <= 1.7              # paper: 1.57
    assert all(p.requirement_ns == 10.0 for p in rep)
    # slack consistent with delay + uncertainty
    np.testing.assert_allclose(slacks + totals, 10.0 - 0.25, atol=0.02)


def test_report_paths_sorted_worst_first(tm16):
    rep = tm16.report(50)
    slacks = [p.slack_ns for p in rep]
    assert slacks == sorted(slacks)


def test_bottom_rows_have_less_slack(tm16):
    """Paper Sec. V-C: partial sums move to bottom rows -> less min slack."""
    ms = tm16.min_slack_ns
    assert ms[12:].mean() < ms[:4].mean() - 0.5


def test_min_slack_multimodal_bands(tm16):
    """Four row bands should be separable (the Figs. 11-14 structure)."""
    ms = tm16.min_slack_ns
    band_means = [ms[i * 4:(i + 1) * 4].mean() for i in range(4)]
    diffs = -np.diff(band_means)
    assert (diffs > 0.15).all()


def test_determinism():
    a = TimingModel(n=16, seed=7).min_slack_flat()
    b = TimingModel(n=16, seed=7).min_slack_flat()
    np.testing.assert_array_equal(a, b)
    c = TimingModel(n=16, seed=8).min_slack_flat()
    assert not np.array_equal(a, c)


@pytest.mark.parametrize("n", [16, 32, 64])
def test_scales_to_paper_array_sizes(n):
    tm = TimingModel(n=n, seed=1)
    assert tm.min_slack_flat().shape == (n * n,)
    assert (tm.min_slack_flat() > 0).all()      # meets timing at nominal V


def test_delay_scale_monotone_in_voltage():
    tech = TECH_NODES["vtr-22nm"]
    vs = np.linspace(0.55, 1.2, 50)
    d = delay_scale(tech, vs)
    assert (np.diff(d) < 0).all()               # lower voltage -> slower
    assert delay_scale(tech, tech.v_nom) == pytest.approx(1.0)


def test_fails_at_low_voltage_not_at_nominal(tm16):
    assert not tm16.fails_at(tm16.tech.v_nom).any()
    assert tm16.fails_at(0.55).all()


def test_min_safe_voltage_bisect(tm16):
    v = tm16.min_safe_voltage()
    assert not tm16.fails_at(v + 1e-3).any()
    assert tm16.fails_at(v - 2e-3).all()


def test_implementation_report_matches_synthesis(tm16):
    """Figs. 4/5: per-MAC clustering keeps post-P&R delays within a few % of
    synthesis; the abandoned per-path flow blows up ~2x (Sec. II-D)."""
    synth = np.sort(tm16.path_delays_ns.reshape(-1))[::-1][:100]
    impl = tm16.implementation_report(100, partitioned=True)
    assert np.abs(impl / synth - 1.0).max() < 0.08
    bad = tm16.implementation_report(100, partitioned=False)
    assert (bad / synth).mean() > 1.5


def test_render_report_table(tm16):
    txt = render_report_table(tm16.report(5))
    assert "Path 1" in txt and "sig_mac_out_reg" in txt
    assert len(txt.splitlines()) == 6
