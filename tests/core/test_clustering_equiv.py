"""Vectorized clustering vs the loop oracles: bit-identical labels and
bit-identical FlowReport partition/voltage outputs.

The vectorized rewrites in ``repro.core.clustering`` must replicate
``repro.core.clustering_ref`` exactly — same merge order, same tie-breaking,
same noise handling — across all four algorithms, multiple seeds and array
sizes.  ``FlowConfig(impl=...)`` threads the same choice through the staged
pipeline, so the end-to-end reports are compared too.

The reference agglomerative is O(n^3) with per-merge submatrix copies and
reference mean-shift iterates full pairwise kernels, so at the 64x64 array
(4096 MACs) those two oracles are compared on deterministic strided
subsamples (512 / 1024 points) to keep the suite's wall clock sane; k-means
and DBSCAN run the full 4096 points.
"""

import numpy as np
import pytest

from repro.core import TimingModel
from repro.core import clustering as cl
from repro.core import clustering_ref as cl_ref

SEEDS = (2021, 2022, 2023, 2024, 2025)
SIZES = (8, 16, 64)


def _slack(array_n: int, seed: int) -> np.ndarray:
    return TimingModel(n=array_n, seed=seed).min_slack_flat()


def _subsample(x: np.ndarray, limit: int) -> np.ndarray:
    if len(x) <= limit:
        return x
    stride = len(x) // limit
    return x[::stride][:limit]


# ------------------------------------------------------- label identity ----


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("array_n", SIZES)
def test_kmeans_matches_reference(array_n, seed):
    x = _slack(array_n, seed)
    np.testing.assert_array_equal(cl.kmeans(x, 4, seed=seed),
                                  cl_ref.kmeans(x, 4, seed=seed))


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("array_n", SIZES)
def test_dbscan_matches_reference(array_n, seed):
    x = _slack(array_n, seed)
    spread = x.max() - x.min()
    eps, mp = spread / 12, max(4, len(x) // 64)
    np.testing.assert_array_equal(cl.dbscan(x, eps=eps, min_pts=mp),
                                  cl_ref.dbscan(x, eps=eps, min_pts=mp))


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("array_n", SIZES)
def test_hierarchical_matches_reference(array_n, seed):
    x = _subsample(_slack(array_n, seed), 512)   # oracle is O(n^3)
    for linkage in ("average", "single", "complete"):
        np.testing.assert_array_equal(
            cl.hierarchical(x, 4, linkage=linkage),
            cl_ref.hierarchical(x, 4, linkage=linkage))


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("array_n", SIZES)
def test_meanshift_matches_reference(array_n, seed):
    x = _subsample(_slack(array_n, seed), 1024)  # oracle pairwise iterations
    bw = 0.17 * float(x.max() - x.min())
    np.testing.assert_array_equal(cl.meanshift(x, bandwidth=bw),
                                  cl_ref.meanshift(x, bandwidth=bw))


@pytest.mark.parametrize("seed", range(4))
def test_random_mixtures_match_reference(seed):
    """Unstructured data (not the timing model's neat bands)."""
    rng = np.random.default_rng(seed)
    x = np.concatenate([rng.normal(i * 1.5, 0.4, 60) for i in range(4)])
    np.testing.assert_array_equal(cl.kmeans(x, 5, seed=seed),
                                  cl_ref.kmeans(x, 5, seed=seed))
    np.testing.assert_array_equal(cl.hierarchical(x, 3),
                                  cl_ref.hierarchical(x, 3))
    np.testing.assert_array_equal(cl.meanshift(x, bandwidth=0.9),
                                  cl_ref.meanshift(x, bandwidth=0.9))
    np.testing.assert_array_equal(cl.dbscan(x, eps=0.25, min_pts=5),
                                  cl_ref.dbscan(x, eps=0.25, min_pts=5))


def test_dendrogram_matches_reference():
    x = _slack(16, 2021)
    dv = cl.hierarchical_dendrogram(x)
    dr = cl_ref.hierarchical_dendrogram(x)
    np.testing.assert_array_equal(dv.left, dr.left)
    np.testing.assert_array_equal(dv.right, dr.right)
    np.testing.assert_array_equal(dv.size, dr.size)
    np.testing.assert_array_equal(dv.height, dr.height)
    for k in (2, 3, 4, 7):
        np.testing.assert_array_equal(dv.cut(k), dr.cut(k))


def test_helpers_match_reference():
    x = _slack(16, 2021)
    lab = cl_ref.dbscan(x, eps=(x.max() - x.min()) / 12, min_pts=8)
    np.testing.assert_array_equal(cl.relabel_by_feature_mean(x, lab),
                                  cl_ref.relabel_by_feature_mean(x, lab))
    np.testing.assert_array_equal(cl.relabel_by_feature_mean(x, lab,
                                                             descending=False),
                                  cl_ref.relabel_by_feature_mean(
                                      x, lab, descending=False))
    np.testing.assert_array_equal(cl.attach_noise_to_nearest(x, lab),
                                  cl_ref.attach_noise_to_nearest(x, lab))
    assert cl.silhouette(x, lab) == pytest.approx(cl_ref.silhouette(x, lab),
                                                  abs=1e-12)


# ------------------------------------------------- FlowReport identity ----


def _report_fields(rep):
    return (rep.labels, rep.static_v, np.asarray(rep.runtime_v),
            rep.n_partitions, rep.baseline_mw, rep.static_mw, rep.runtime_mw,
            rep.razor_trials, rep.xdc, rep.sdc)


@pytest.mark.parametrize("algo", ["kmeans", "hierarchical", "meanshift",
                                  "dbscan"])
@pytest.mark.parametrize("array_n,seed", [(8, 2021), (8, 7), (16, 2021)])
def test_flow_reports_bit_identical_across_impls(algo, array_n, seed):
    from repro.flow import FlowConfig, run
    base = dict(array_n=array_n, algo=algo, seed=seed, max_trials=16)
    rv = run(FlowConfig(impl="vectorized", **base))
    rr = run(FlowConfig(impl="reference", **base))
    for a, b in zip(_report_fields(rv), _report_fields(rr)):
        if isinstance(a, np.ndarray):
            np.testing.assert_array_equal(a, b)
        else:
            assert a == b
