"""Precision-island controller (TPU analogue of the voltage schemes)."""

import numpy as np
import pytest

from repro.core import (ENERGY_PER_MAC, TIERS, PrecisionController, energy_ratio,
                        static_tier_assignment, tile_headroom)


def test_tile_headroom_shape_and_ordering():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(256, 256)).astype(np.float32)
    # a tile with huge outliers quantizes poorly -> lower headroom
    w2 = w.copy()
    w2[:128, :128] *= 1.0
    w2[0, 0] = 500.0
    h = tile_headroom(w2, tile=128)
    assert h.shape == (2, 2)
    assert h[0, 0] < h[1, 1]


def test_static_assignment_bands():
    h = np.array([[3.0, 2.0], [1.0, 0.0]])
    t = static_tier_assignment(h, n_tiers=3)
    assert t[0, 0] == 0            # highest headroom -> cheapest tier (int4)
    assert t[1, 1] == 2            # lowest headroom -> bf16
    assert t.min() >= 0 and t.max() <= 2


def test_static_assignment_uniform_headroom():
    t = static_tier_assignment(np.full((4, 4), 2.5))
    assert (t == 0).all()


def test_controller_step_is_algorithm2_on_tiers():
    c = PrecisionController()
    t = np.array([0, 1, 2, 1])
    nt = c.step(t, np.array([True, False, False, True]))
    np.testing.assert_array_equal(nt, [1, 0, 1, 2])


def test_controller_calibrates_to_cheapest_clean_tier():
    # oracle: tile i needs at least tier need[i]
    need = np.array([0, 1, 2, 0, 1])

    def trial(t):
        return t < need

    c = PrecisionController()
    out = c.calibrate(np.full(5, 2), trial)
    np.testing.assert_array_equal(out, need)


def test_energy_ratio():
    assert energy_ratio(np.array([2, 2, 2])) == pytest.approx(1.0)
    assert energy_ratio(np.array([0, 0])) == pytest.approx(ENERGY_PER_MAC["int4"])
    mixed = energy_ratio(np.array([0, 2]))
    assert ENERGY_PER_MAC["int4"] < mixed < 1.0


def test_tiers_ordered_cheapest_first():
    assert TIERS == ("int4", "int8", "bf16")
    assert (ENERGY_PER_MAC["int4"] < ENERGY_PER_MAC["int8"]
            < ENERGY_PER_MAC["bf16"])
