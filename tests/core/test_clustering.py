"""Unit + property tests for the four from-scratch clustering algorithms."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (attach_noise_to_nearest, cluster, dbscan, hierarchical,
                        hierarchical_dendrogram, kmeans, meanshift,
                        relabel_by_feature_mean, silhouette, TimingModel)


@pytest.fixture(scope="module")
def slack16():
    return TimingModel(n=16, seed=2021).min_slack_flat()


def _well_separated(rng, k=4, per=40, gap=10.0):
    return np.concatenate([rng.normal(i * gap, 0.3, per) for i in range(k)])


# ---------------------------------------------------------------- k-means ----

def test_kmeans_recovers_separated_clusters():
    x = _well_separated(np.random.default_rng(0))
    lab = kmeans(x, 4, seed=1)
    assert len(set(lab)) == 4
    for c in range(4):
        assert len(set(lab[c * 40:(c + 1) * 40])) == 1     # band purity


def test_kmeans_on_paper_slacks(slack16):
    lab, centers = kmeans(slack16, 4, seed=0, return_centers=True)
    sizes = np.bincount(lab)
    assert sizes.shape == (4,) and (np.abs(sizes - 64) <= 8).all()


def test_kmeans_deterministic(slack16):
    a = kmeans(slack16, 4, seed=3)
    b = kmeans(slack16, 4, seed=3)
    np.testing.assert_array_equal(a, b)


def test_kmeans_assigns_to_nearest_center(slack16):
    lab, centers = kmeans(slack16, 4, seed=0, return_centers=True)
    d = np.abs(slack16[:, None] - centers.T[0][None, :])
    np.testing.assert_array_equal(lab, np.argmin(d, axis=1))


# ----------------------------------------------------------- hierarchical ----

def test_hierarchical_dendrogram_monotone(slack16):
    dg = hierarchical_dendrogram(slack16, linkage="average")
    # average-linkage heights are not strictly monotone in general, but the
    # final (most dissimilar) merges must dominate (paper Fig. 10)
    assert dg.height[-1] == max(dg.height)
    assert dg.height[-1] > 3 * np.median(dg.height)


@pytest.mark.parametrize("k", [2, 3, 4])
def test_hierarchical_cut_sizes(slack16, k):
    lab = hierarchical(slack16, k)
    assert len(set(lab)) == k
    assert len(lab) == 256


def test_hierarchical_separated():
    x = _well_separated(np.random.default_rng(1))
    lab = hierarchical(x, 4, linkage="single")
    assert len(set(lab)) == 4
    for c in range(4):
        assert len(set(lab[c * 40:(c + 1) * 40])) == 1


# ------------------------------------------------------------- mean-shift ----

def test_meanshift_paper_radius_four_clusters(slack16):
    spread = slack16.max() - slack16.min()
    lab = meanshift(slack16, bandwidth=0.17 * spread)
    assert len(set(lab)) == 4                     # paper Fig. 13: 4 clusters
    assert (np.bincount(lab) == 64).all()         # equal row bands


def test_meanshift_single_blob():
    x = np.random.default_rng(2).normal(0, 0.1, 100)
    assert len(set(meanshift(x, bandwidth=1.0))) == 1


# ----------------------------------------------------------------- dbscan ----

def test_dbscan_paper_slacks(slack16):
    spread = slack16.max() - slack16.min()
    lab = dbscan(slack16, eps=spread / 12, min_pts=8)
    assert len(set(lab) - {-1}) == 4              # paper Fig. 14
    assert (lab == -1).mean() < 0.05


def test_dbscan_identifies_outliers():
    x = np.concatenate([np.zeros(50), np.ones(50), [10.0]])
    lab = dbscan(x, eps=0.2, min_pts=5)
    assert lab[-1] == -1                          # the paper's key DBSCAN win
    assert len(set(lab) - {-1}) == 2


def test_attach_noise(slack16):
    x = np.concatenate([np.zeros(50), np.ones(50), [10.0]])
    lab = attach_noise_to_nearest(x, dbscan(x, eps=0.2, min_pts=5))
    assert (lab >= 0).all()
    assert lab[-1] == lab[50]                     # joined the nearest (=1) blob


# ------------------------------------------------------------- shared/API ----

def test_cluster_dispatch(slack16):
    assert len(cluster(slack16, "kmeans", k=3, seed=0)) == 256
    assert len(cluster(slack16, "dbscan", eps=0.1, min_pts=4)) == 256
    with pytest.raises(ValueError):
        cluster(slack16, "qmeans")


def test_relabel_by_feature_mean(slack16):
    lab = relabel_by_feature_mean(slack16, kmeans(slack16, 4, seed=0))
    means = [slack16[lab == c].mean() for c in range(4)]
    assert means == sorted(means, reverse=True)   # cluster 0 = highest slack


def test_silhouette_ranks_good_clustering_higher(slack16):
    good = kmeans(slack16, 4, seed=0)
    bad = np.arange(256) % 4                       # interleaved nonsense
    assert silhouette(slack16, good) > 0.5 > silhouette(slack16, bad)


# ------------------------------------------------------------- properties ----

@st.composite
def float_arrays(draw):
    n = draw(st.integers(8, 60))
    return np.array(draw(st.lists(
        st.floats(-100, 100, allow_nan=False, width=32), min_size=n, max_size=n)))


@given(float_arrays(), st.integers(1, 5), st.integers(0, 3))
@settings(max_examples=40, deadline=None)
def test_kmeans_partitions_everything(x, k, seed):
    lab = kmeans(x, k, seed=seed)
    assert lab.shape == x.shape
    assert ((lab >= 0) & (lab < max(k, len(x)))).all()


@given(float_arrays(), st.floats(0.05, 5.0), st.integers(2, 6))
@settings(max_examples=40, deadline=None)
def test_dbscan_core_points_never_noise(x, eps, min_pts):
    lab = dbscan(x, eps=eps, min_pts=min_pts)
    d = np.abs(x[:, None] - x[None, :])
    core = (d <= eps).sum(1) >= min_pts
    assert (lab[core] >= 0).all()


@given(float_arrays(), st.integers(2, 5))
@settings(max_examples=30, deadline=None)
def test_hierarchical_cut_produces_k_clusters(x, k):
    k = min(k, len(x))
    lab = hierarchical(x, k)
    assert len(set(lab)) == k


@given(float_arrays())
@settings(max_examples=30, deadline=None)
def test_meanshift_labels_cover_all(x):
    lab = meanshift(x, bandwidth=max(1e-3, (x.max() - x.min()) / 5 + 1e-3))
    assert (lab >= 0).all() and lab.shape == x.shape
