"""Power model vs the paper's Table II (all 15 rows) and Figs. 15/16 trends."""

import numpy as np
import pytest

from repro.core import (PAPER_TABLE2, PowerModel, TECH_NODES, fit_power_exponent,
                        model_for, validate_against_table2)


def test_table2_all_rows_within_one_point():
    """Model reduction vs paper reduction: |delta| <= 1 percentage point for
    every row of Table II (guard-band and critical-region)."""
    rows = validate_against_table2()
    assert len(rows) == 15
    for r in rows:
        assert abs(r["delta_pp"]) <= 1.0, r


def test_table2_guardband_flagship_numbers():
    """The headline numbers: 408->~382 mW (16x16 28nm), 5920->~5534 mW."""
    m = model_for("vivado-28nm")
    v = [0.96, 0.97, 0.98, 0.99]
    assert m.baseline_mw(16) == pytest.approx(408.0)
    assert m.partitioned_mw(16, v) == pytest.approx(382.0, abs=2.5)
    assert m.baseline_mw(64) == pytest.approx(408.0 * 16)
    assert m.partitioned_mw(64, v) == pytest.approx(5534.0 * (408 * 16 / 5920), rel=0.02)


def test_reduction_ordering_across_techs():
    """Paper: 28nm reduces most, then 22nm ~ 45nm, then 130nm least.  All
    techs compared at the same 1.0 V baseline, as in Table II."""
    v = [0.96, 0.97, 0.98, 0.99]
    red = {t: model_for(t).reduction_pct(16, v, v_ref=1.0) for t in TECH_NODES}
    assert red["vivado-28nm"] > red["vtr-22nm"] >= red["vtr-45nm"] > red["vtr-130nm"]


def test_critical_region_reductions():
    """4th Table II instant: 64x64, baseline 0.9 V, partitions {0.7..1.0}."""
    v = [0.7, 0.8, 0.9, 1.0]
    for tech, paper in [("vtr-22nm", 3.7), ("vtr-45nm", 2.4), ("vtr-130nm", 1.37)]:
        pred = model_for(tech).reduction_pct(64, v, v_ref=0.9)
        assert pred == pytest.approx(paper, abs=1.0)


def test_power_scales_with_array_size():
    m = model_for("vtr-22nm")
    assert m.baseline_mw(32) == pytest.approx(4 * m.baseline_mw(16))
    assert m.baseline_mw(64) == pytest.approx(16 * m.baseline_mw(16))


def test_power_monotone_in_voltage():
    m = model_for("vivado-28nm")
    vs = np.linspace(0.7, 1.0, 10)
    p = [m.baseline_mw(16, v) for v in vs]
    assert (np.diff(p) > 0).all()


def test_unequal_partition_fractions():
    """More MACs at low voltage -> lower power (Fig. 15's best variant logic:
    2x(32x64){0.5,0.6} wins because *most* MACs run at minimum V)."""
    m = model_for("vtr-22nm")
    lopsided = m.partitioned_mw(64, [0.5, 1.0], partition_frac=[0.9, 0.1])
    balanced = m.partitioned_mw(64, [0.5, 1.0], partition_frac=[0.5, 0.5])
    assert lopsided < balanced


def test_fig15_16_variant_ordering():
    """Fig. 15/16: among the paper's named 64x64 variants, the minimum-power
    one is 2x(32x64){0.5,0.6} on 22/45nm and 2x(32x64){0.7,0.8} on 130nm."""
    variants_2245 = {
        "2x(32x64){0.5,0.6}": [0.5, 0.6],
        "4x(32x32){0.5,0.6,0.7,0.8}": [0.5, 0.6, 0.7, 0.8],
        "4x(32x32){0.8,1.0,1.2,1.2}": [0.8, 1.0, 1.2, 1.2],
        "2x(32x64){1.0,1.2}": [1.0, 1.2],
    }
    for tech in ("vtr-22nm", "vtr-45nm"):
        m = model_for(tech)
        p = {k: m.partitioned_mw(64, v) for k, v in variants_2245.items()}
        assert min(p, key=p.get) == "2x(32x64){0.5,0.6}"
    m130 = model_for("vtr-130nm")
    variants_130 = {
        "2x(32x64){0.7,0.8}": [0.7, 0.8],
        "4x(32x32){0.7,0.9,1.1,1.3}": [0.7, 0.9, 1.1, 1.3],
        "4x(32x32){0.8,1.0,1.2,1.3}": [0.8, 1.0, 1.2, 1.3],
    }
    p = {k: m130.partitioned_mw(64, v) for k, v in variants_130.items()}
    assert min(p, key=p.get) == "2x(32x64){0.7,0.8}"


def test_fig15_16_spread_direction():
    """Power spread across variants grows with the voltage range available;
    paper reports 18/21/39% for 22/45/130nm.  With a shared variant set the
    *relative ordering by exponent k* must hold: bigger k -> bigger spread."""
    spread = {}
    for tech in ("vtr-22nm", "vtr-45nm", "vtr-130nm"):
        m = model_for(tech)
        lo, hi = (0.7, 1.3) if tech == "vtr-130nm" else (0.5, 1.2)
        configs = [[lo, lo], [lo, hi], [hi, hi], [lo, (lo + hi) / 2]]
        p = [m.partitioned_mw(64, v) for v in configs]
        spread[tech] = (max(p) - min(p)) / max(p)
    ks = {t: fit_power_exponent(t) for t in spread}
    order = sorted(spread, key=spread.get)
    assert order == sorted(ks, key=ks.get)


def test_energy_per_mac_anchoring():
    m = model_for("vivado-28nm")
    # P16 = 256 MACs * E_mac * f  =>  E_mac at nominal
    e = m.energy_per_mac_pj(1.0)
    assert e == pytest.approx(408e-3 / (256 * 100e6) * 1e12)
    assert m.energy_per_mac_pj(0.95) < e
    # total energy for a GEMM's MACs
    j = m.macs_energy_j(1e9, [0.96, 0.97, 0.98, 0.99])
    assert j == pytest.approx(1e9 * 1e-12 * np.mean(
        [m.energy_per_mac_pj(v) for v in [0.96, 0.97, 0.98, 0.99]]), rel=1e-6)


def test_exponent_fit_is_stable():
    for tech in TECH_NODES:
        k1 = fit_power_exponent(tech)
        k2 = fit_power_exponent(tech)
        assert k1 == pytest.approx(k2)
        assert 0.05 < k1 < 4.0
