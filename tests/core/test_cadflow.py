"""End-to-end CAD flow (paper Fig. 9) + partition/constraint artifacts."""

import numpy as np
import pytest

from repro.core import (Floorplan, grid_floorplan, paper_table2_flow,
                        partition_min_slack, quadrant_floorplan, run_flow,
                        TimingModel)
from repro.core.constraints import generate_sdc, generate_xdc, mac_cell_name


@pytest.fixture(scope="module")
def flow16():
    return run_flow(array_n=16, tech="vivado-28nm", algo="dbscan", seed=2021)


def test_flow_reproduces_table2_guardband(flow16):
    """Static scheme on the 16x16 Artix-7 array: paper reports 6.37%."""
    assert flow16.n_partitions == 4
    assert flow16.static_reduction_pct == pytest.approx(6.37, abs=0.8)
    np.testing.assert_allclose(np.round(flow16.static_v, 2),
                               [0.96, 0.97, 0.98, 0.99])


def test_flow_runtime_beats_static_in_guardband(flow16):
    """Guard band has no timing failures (paper: 100% accuracy region), so the
    runtime scheme anneals every rail to the floor -> more savings than
    static.  This is the 'lower bound' headroom the paper points at."""
    assert flow16.calibrated_fail_free
    assert flow16.runtime_reduction_pct > flow16.static_reduction_pct
    assert (flow16.runtime_v >= 0.95 - 1e-9).all()


def test_flow_all_algorithms_agree_on_bands():
    reds = {}
    for algo in ("kmeans", "hierarchical", "meanshift", "dbscan"):
        r = run_flow(array_n=16, algo=algo, seed=2021)
        assert r.n_partitions == 4
        reds[algo] = r.static_reduction_pct
    assert max(reds.values()) - min(reds.values()) < 0.5


def test_flow_critical_region_safety():
    """In the VTR critical region the static scheme under-volts the
    highest-slack partition below its min-safe voltage; runtime calibration
    must end fail-free with voltages at/above static's unsafe rail."""
    r = run_flow(array_n=16, tech="vtr-22nm", algo="dbscan", seed=2021)
    assert r.calibrated_fail_free
    tm = TimingModel(n=16, tech=r.floorplan.partitions and
                     __import__("repro.core", fromlist=["TECH_NODES"]).TECH_NODES["vtr-22nm"],
                     seed=2021)
    min_safe = tm.min_safe_voltage().reshape(-1)
    for p in r.floorplan.partitions:
        part_safe = min_safe[list(p.mac_ids)].max()
        assert r.runtime_v[p.index] >= part_safe - 1e-6


def test_paper_table2_flow_helper():
    out = paper_table2_flow(16, "vivado-28nm")
    assert out["baseline_mw"] == pytest.approx(408.0)
    assert out["reduction_pct"] == pytest.approx(6.55, abs=0.1)


def test_flow_report_artifacts(flow16):
    assert "create_pblock" in flow16.xdc
    assert "create_clock" in flow16.xdc and "create_clock" in flow16.sdc
    assert flow16.xdc.count("create_pblock") == flow16.n_partitions
    assert flow16.labels.shape == (256,)
    assert flow16.min_slack.shape == (256,)


# ------------------------------------------------------------ floorplans ----

def test_quadrant_floorplan_covers_all_macs():
    fp = quadrant_floorplan(16)
    part = fp.partition_of_mac()
    assert part.shape == (256,)
    np.testing.assert_array_equal(np.bincount(part), [64, 64, 64, 64])
    # Fig. 8 geometry: MAC (0,0) in partition 0 (top-left), (15,15) in 3
    assert part[0] == 0 and part[255] == 3
    assert part[15] == 1 and part[240] == 2


def test_grid_floorplan_proportional_rows():
    labels = np.repeat([0, 1], [192, 64])
    fp = grid_floorplan(labels, 16)
    sizes = [p.n_macs for p in fp.partitions]
    assert sizes == [192, 64]
    part = fp.partition_of_mac()
    np.testing.assert_array_equal(part, labels)


def test_grid_floorplan_rejects_noise():
    labels = np.zeros(256, dtype=np.int64)
    labels[0] = -1
    with pytest.raises(ValueError):
        grid_floorplan(labels, 16)


def test_voltage_map_matches_partitions():
    fp = quadrant_floorplan(16).with_voltages([0.96, 0.97, 0.98, 0.99])
    vm = fp.voltage_map()
    assert vm.shape == (16, 16)
    assert vm[0, 0] == 0.96 and vm[0, 15] == 0.97
    assert vm[15, 0] == 0.98 and vm[15, 15] == 0.99


def test_partition_min_slack():
    slack = np.arange(256, dtype=float)
    labels = np.repeat([0, 1, 2, 3], 64)
    np.testing.assert_array_equal(partition_min_slack(labels, slack),
                                  [0.0, 64.0, 128.0, 192.0])


def test_xdc_sdc_generation():
    fp = quadrant_floorplan(16).with_voltages([0.96, 0.97, 0.98, 0.99])
    xdc = generate_xdc(fp, clock_ns=10.0)
    assert xdc.count("create_pblock") == 4
    assert "SLICE_X" in xdc
    assert mac_cell_name(0, 16) == "GEN_REG_I[0].GEN_REG_J[0].uut"
    assert mac_cell_name(17, 16) == "GEN_REG_I[1].GEN_REG_J[1].uut"
    sdc = generate_sdc(fp)
    assert "create_clock -period 10.000 clk" in sdc
    assert sdc.count("partition-") == 4
