"""Partitioned systolic-array simulator with voltage-dependent faults."""

import numpy as np
import pytest

from repro.core import (RazorConfig, SystolicSim, TimingModel, TECH_NODES,
                        fast_fault_matmul, quadrant_floorplan)


@pytest.fixture(scope="module")
def sim16():
    tm = TimingModel(n=16, tech=TECH_NODES["vtr-22nm"], seed=2021)
    fp = quadrant_floorplan(16).with_voltages([1.0, 1.0, 1.0, 1.0])
    return SystolicSim(tm, fp, RazorConfig(clock_ns=10.0))


def test_exact_matmul_at_nominal_voltage(sim16):
    rng = np.random.default_rng(0)
    a = rng.normal(size=(24, 16))
    w = rng.normal(size=(16, 16))
    c, stats = sim16.matmul(a, w)
    np.testing.assert_allclose(c, a @ w, rtol=1e-12)
    assert stats.rel_error < 1e-12          # only fp association-order noise
    assert stats.replay_cycles == 0
    assert not stats.partition_fail.any()
    assert stats.silent.sum() == 0


def test_low_voltage_detected_errors_are_corrected(sim16):
    """In the detection window Razor corrects values: product stays exact but
    replay cycles accumulate (the paper's runtime-failure signal)."""
    tm = sim16.timing
    # pick a voltage where worst delay lands inside (T, T + T_del]
    v = float(tm.min_safe_voltage().max()) - 0.012
    fp = quadrant_floorplan(16).with_voltages([v] * 4)
    rng = np.random.default_rng(1)
    a, w = rng.normal(size=(32, 16)), rng.normal(size=(16, 16))
    c, stats = SystolicSim(tm, fp, sim16.razor).matmul(a, w)
    assert stats.replay_cycles > 0
    assert stats.partition_fail.any()
    if stats.silent.sum() == 0:
        np.testing.assert_allclose(c, a @ w, rtol=1e-12)


def test_crash_voltage_silent_corruption(sim16):
    """Deep in the crash region arrivals exceed the shadow window: silent
    corruption, non-zero relative error (paper Fig. 7: accuracy -> 0)."""
    tm = sim16.timing
    fp = quadrant_floorplan(16).with_voltages([0.55] * 4)
    rng = np.random.default_rng(2)
    a, w = rng.normal(size=(32, 16)), rng.normal(size=(16, 16))
    c, stats = SystolicSim(tm, fp, sim16.razor).matmul(a, w)
    assert stats.silent.sum() > 0
    assert stats.rel_error > 0.05


def test_per_partition_voltages_differentiate(sim16):
    """Only the under-volted partition's MACs should fail."""
    tm = sim16.timing
    v_hot = float(tm.min_safe_voltage().max()) - 0.012
    fp = quadrant_floorplan(16).with_voltages([1.0, 1.0, v_hot, v_hot])
    rng = np.random.default_rng(3)
    a, w = rng.normal(size=(32, 16)), rng.normal(size=(16, 16))
    _, stats = SystolicSim(tm, fp, sim16.razor).matmul(a, w)
    det = stats.detected + stats.silent
    assert det[:8].sum() == 0                # top quadrants at nominal: clean
    assert det[8:].sum() > 0                 # bottom quadrants under-volted


def test_trial_run_flags_match_partitions(sim16):
    tm = sim16.timing
    flags_nominal = sim16.trial_run(np.array([1.0] * 4), seed=0)
    assert not flags_nominal.any()
    v_hot = float(tm.min_safe_voltage().max()) - 0.012
    flags_hot = sim16.trial_run(np.array([1.0, 1.0, 1.0, v_hot]), seed=0)
    assert flags_hot[3] and not flags_hot[:3].any()


def test_fast_fault_matmul_modes():
    rng = np.random.default_rng(4)
    a, w = rng.normal(size=(8, 16)), rng.normal(size=(16, 16))
    none = fast_fault_matmul(a, w, np.zeros((16, 16), bool))
    np.testing.assert_allclose(none, a @ w)
    mask = np.zeros((16, 16), bool)
    mask[0, 0] = True
    dropped = fast_fault_matmul(a, w, mask, mode="drop")
    expect = a @ w - np.outer(a[:, 0], np.eye(16)[0] * w[0, 0])
    np.testing.assert_allclose(dropped, expect)


def test_activity_dependence():
    """Constant inputs toggle no bits -> fewer failures than noisy inputs at
    the same marginal voltage (the paper's NTC observation)."""
    tm = TimingModel(n=16, tech=TECH_NODES["vtr-22nm"], seed=5)
    v = float(tm.min_safe_voltage().max()) - 0.002
    fp = quadrant_floorplan(16).with_voltages([v] * 4)
    sim = SystolicSim(tm, fp, RazorConfig(clock_ns=10.0))
    rng = np.random.default_rng(6)
    w = rng.normal(size=(16, 16))
    a_const = np.ones((32, 16))
    a_noisy = rng.normal(size=(32, 16))
    _, s_const = sim.matmul(a_const, w)
    _, s_noisy = sim.matmul(a_noisy, w)
    total_const = s_const.detected.sum() + s_const.silent.sum()
    total_noisy = s_noisy.detected.sum() + s_noisy.silent.sum()
    assert total_noisy > total_const
