"""Razor flip-flop behavioural model (paper Sec. II-E, Fig. 6)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DETECTED, OK, SILENT, RazorConfig, RazorMac,
                        classify_arrival, effective_arrival, switching_activity)

CFG = RazorConfig(clock_ns=10.0, t_del_ns=2.5, beta=0.25)


def test_classify_windows():
    a = np.array([9.9, 10.0, 10.1, 12.5, 12.51, 99.0])
    np.testing.assert_array_equal(
        classify_arrival(a, CFG), [OK, OK, DETECTED, DETECTED, SILENT, SILENT])


@given(st.floats(0.1, 50.0))
@settings(max_examples=100, deadline=None)
def test_classify_exhaustive(arrival):
    s = int(classify_arrival(np.float64(arrival), CFG))
    if arrival <= CFG.clock_ns:
        assert s == OK
    elif arrival <= CFG.clock_ns + CFG.t_del_ns:
        assert s == DETECTED
    else:
        assert s == SILENT


def test_switching_activity_bounds_and_values():
    prev = np.array([0b0000, 0b1111, 0b1010])
    cur = np.array([0b0000, 0b0000, 0b0101])
    act = switching_activity(prev, cur, n_bits=4)
    np.testing.assert_allclose(act, [0.0, 1.0, 1.0])
    act2 = switching_activity(np.array([0b0001]), np.array([0b0011]), n_bits=4)
    assert act2[0] == pytest.approx(0.25)


@given(st.integers(0, 2**16 - 1), st.integers(0, 2**16 - 1))
@settings(max_examples=100, deadline=None)
def test_switching_activity_popcount(a, b):
    act = switching_activity(np.array([a]), np.array([b]), 16)[0]
    assert act == pytest.approx(bin(a ^ b).count("1") / 16)


def test_effective_arrival_raises_with_activity():
    """Paper: higher input fluctuation -> higher failure probability at NTC."""
    base = effective_arrival(np.float64(9.8), np.float64(0.0), CFG)
    hot = effective_arrival(np.float64(9.8), np.float64(1.0), CFG)
    assert base == pytest.approx(9.8)
    assert hot == pytest.approx(9.8 * 1.25)
    assert classify_arrival(base, CFG) == OK
    assert classify_arrival(hot, CFG) == DETECTED


def test_razor_mac_detected_corrects_and_counts_replay():
    mac = RazorMac(delay_ns=10.5, cfg=CFG)    # lands in detection window
    val, status = mac.cycle(a=2.0, b=3.0, acc=1.0, activity=0.0)
    assert status == DETECTED
    assert val == 7.0                          # shadow FF corrected the value
    assert mac.replays == 1 and mac.silent_failures == 0


def test_razor_mac_silent_keeps_stale_value():
    mac = RazorMac(delay_ns=9.0, cfg=CFG)
    val, status = mac.cycle(2.0, 3.0, 0.0, activity=0.0)   # ok: reg=6
    assert status == OK and val == 6.0
    # activity pushes arrival past the shadow window: 9*(1+.25)=11.25<12.5 det;
    # use huge activity via a slower MAC instead
    mac2 = RazorMac(delay_ns=13.0, cfg=CFG)
    mac2.cycle(1.0, 1.0, 0.0, activity=0.0)                # silent from cycle 1
    assert mac2.silent_failures == 1
    val2, st2 = mac2.cycle(5.0, 5.0, 0.0, activity=0.0)
    assert st2 == SILENT
    assert val2 == 0.0                          # stale register leaked through


def test_razor_doubles_sampling_not_free():
    """Inclusion of Razor doubles mult/add hardware (paper Sec. II-E): the
    replay counter is the runtime cost we surface."""
    mac = RazorMac(delay_ns=10.2, cfg=CFG)
    for i in range(5):
        mac.cycle(1.0, float(i), 0.0, activity=0.0)
    assert mac.replays == 5


# ---------------------------------------------------------------------------
# Boundary/property tests for classify_arrival and switching_activity
# (previously only exercised indirectly through the systolic simulator)
# ---------------------------------------------------------------------------


def test_classify_exact_window_edges():
    """The windows are half-open on the left: arrival == T is still OK
    (setup met exactly), arrival == T + t_del is still DETECTED (the shadow
    register samples it), and only strictly beyond is SILENT."""
    T, D = CFG.clock_ns, CFG.t_del_ns
    eps = 1e-9
    a = np.array([T - eps, T, T + eps, T + D - eps, T + D, T + D + eps])
    np.testing.assert_array_equal(
        classify_arrival(a, CFG),
        [OK, OK, DETECTED, DETECTED, DETECTED, SILENT])


@given(st.floats(0.1, 50.0), st.floats(0.1, 50.0))
@settings(max_examples=200, deadline=None)
def test_classify_monotone_in_arrival(a, b):
    """Later arrivals can only be as bad or worse: OK <= DETECTED <= SILENT
    is monotone in arrival time."""
    lo, hi = sorted((a, b))
    s_lo = int(classify_arrival(np.float64(lo), CFG))
    s_hi = int(classify_arrival(np.float64(hi), CFG))
    assert s_lo <= s_hi


@given(st.floats(0.1, 30.0), st.floats(0.0, 1.0), st.floats(0.0, 1.0))
@settings(max_examples=100, deadline=None)
def test_effective_arrival_monotone_in_activity(delay, act_a, act_b):
    """Paper Sec. II-E: more input-bit fluctuation never *reduces* the
    effective arrival time, so failures are monotone in activity."""
    lo, hi = sorted((act_a, act_b))
    arr_lo = float(effective_arrival(np.float64(delay), np.float64(lo), CFG))
    arr_hi = float(effective_arrival(np.float64(delay), np.float64(hi), CFG))
    assert arr_lo <= arr_hi
    assert int(classify_arrival(np.float64(arr_lo), CFG)) <= \
        int(classify_arrival(np.float64(arr_hi), CFG))


@given(st.integers(0, 2**16 - 1))
@settings(max_examples=100, deadline=None)
def test_switching_activity_self_is_zero(x):
    assert switching_activity(np.array([x]), np.array([x]), 16)[0] == 0.0


@given(st.integers(0, 2**16 - 1), st.integers(0, 2**16 - 1))
@settings(max_examples=100, deadline=None)
def test_switching_activity_symmetric(a, b):
    fwd = switching_activity(np.array([a]), np.array([b]), 16)[0]
    rev = switching_activity(np.array([b]), np.array([a]), 16)[0]
    assert fwd == rev


@given(st.integers(0, 2**16 - 1), st.integers(0, 15))
@settings(max_examples=100, deadline=None)
def test_switching_activity_single_bit_toggle(x, bit):
    """Toggling exactly one in-range bit moves the activity by exactly
    1/n_bits."""
    act = switching_activity(np.array([x]), np.array([x ^ (1 << bit)]), 16)[0]
    assert act == pytest.approx(1.0 / 16)


def test_switching_activity_counts_exact_toggles():
    """Known bit patterns: the popcount of the XOR, normalized by width."""
    prev = np.array([0x0000, 0xFFFF, 0xAAAA, 0x00FF])
    cur = np.array([0xFFFF, 0xFFFF, 0x5555, 0x0F0F])
    act = switching_activity(prev, cur, n_bits=16)
    np.testing.assert_allclose(act, [1.0, 0.0, 1.0, 8 / 16])


def test_switching_activity_masks_to_width():
    """Bits above n_bits are ignored: only in-width toggles count."""
    act = switching_activity(np.array([0]), np.array([1 << 8]), n_bits=8)
    assert act[0] == 0.0
    act16 = switching_activity(np.array([0]), np.array([1 << 8]), n_bits=16)
    assert act16[0] == pytest.approx(1.0 / 16)
