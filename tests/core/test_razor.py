"""Razor flip-flop behavioural model (paper Sec. II-E, Fig. 6)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DETECTED, OK, SILENT, RazorConfig, RazorMac,
                        classify_arrival, effective_arrival, switching_activity)

CFG = RazorConfig(clock_ns=10.0, t_del_ns=2.5, beta=0.25)


def test_classify_windows():
    a = np.array([9.9, 10.0, 10.1, 12.5, 12.51, 99.0])
    np.testing.assert_array_equal(
        classify_arrival(a, CFG), [OK, OK, DETECTED, DETECTED, SILENT, SILENT])


@given(st.floats(0.1, 50.0))
@settings(max_examples=100, deadline=None)
def test_classify_exhaustive(arrival):
    s = int(classify_arrival(np.float64(arrival), CFG))
    if arrival <= CFG.clock_ns:
        assert s == OK
    elif arrival <= CFG.clock_ns + CFG.t_del_ns:
        assert s == DETECTED
    else:
        assert s == SILENT


def test_switching_activity_bounds_and_values():
    prev = np.array([0b0000, 0b1111, 0b1010])
    cur = np.array([0b0000, 0b0000, 0b0101])
    act = switching_activity(prev, cur, n_bits=4)
    np.testing.assert_allclose(act, [0.0, 1.0, 1.0])
    act2 = switching_activity(np.array([0b0001]), np.array([0b0011]), n_bits=4)
    assert act2[0] == pytest.approx(0.25)


@given(st.integers(0, 2**16 - 1), st.integers(0, 2**16 - 1))
@settings(max_examples=100, deadline=None)
def test_switching_activity_popcount(a, b):
    act = switching_activity(np.array([a]), np.array([b]), 16)[0]
    assert act == pytest.approx(bin(a ^ b).count("1") / 16)


def test_effective_arrival_raises_with_activity():
    """Paper: higher input fluctuation -> higher failure probability at NTC."""
    base = effective_arrival(np.float64(9.8), np.float64(0.0), CFG)
    hot = effective_arrival(np.float64(9.8), np.float64(1.0), CFG)
    assert base == pytest.approx(9.8)
    assert hot == pytest.approx(9.8 * 1.25)
    assert classify_arrival(base, CFG) == OK
    assert classify_arrival(hot, CFG) == DETECTED


def test_razor_mac_detected_corrects_and_counts_replay():
    mac = RazorMac(delay_ns=10.5, cfg=CFG)    # lands in detection window
    val, status = mac.cycle(a=2.0, b=3.0, acc=1.0, activity=0.0)
    assert status == DETECTED
    assert val == 7.0                          # shadow FF corrected the value
    assert mac.replays == 1 and mac.silent_failures == 0


def test_razor_mac_silent_keeps_stale_value():
    mac = RazorMac(delay_ns=9.0, cfg=CFG)
    val, status = mac.cycle(2.0, 3.0, 0.0, activity=0.0)   # ok: reg=6
    assert status == OK and val == 6.0
    # activity pushes arrival past the shadow window: 9*(1+.25)=11.25<12.5 det;
    # use huge activity via a slower MAC instead
    mac2 = RazorMac(delay_ns=13.0, cfg=CFG)
    mac2.cycle(1.0, 1.0, 0.0, activity=0.0)                # silent from cycle 1
    assert mac2.silent_failures == 1
    val2, st2 = mac2.cycle(5.0, 5.0, 0.0, activity=0.0)
    assert st2 == SILENT
    assert val2 == 0.0                          # stale register leaked through


def test_razor_doubles_sampling_not_free():
    """Inclusion of Razor doubles mult/add hardware (paper Sec. II-E): the
    replay counter is the runtime cost we surface."""
    mac = RazorMac(delay_ns=10.2, cfg=CFG)
    for i in range(5):
        mac.cycle(1.0, float(i), 0.0, activity=0.0)
    assert mac.replays == 5
