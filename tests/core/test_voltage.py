"""Algorithm 1 (static) and Algorithm 2 (runtime) voltage scaling."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (RuntimeScheme, assign_partition_voltages,
                        runtime_voltage_scaling, static_voltage_scaling)


def test_algorithm1_paper_example():
    """n=4, [V_crash, V_min] = [0.95, 1.00] -> the paper's partition voltages
    (printed rounded as 0.96/0.97/0.98/0.99)."""
    v = static_voltage_scaling(v_min=1.00, v_crash=0.95, n=4)
    np.testing.assert_allclose(v, [0.95625, 0.96875, 0.98125, 0.99375])
    np.testing.assert_allclose(np.round(v, 2), [0.96, 0.97, 0.98, 0.99])


def test_algorithm1_critical_region_vtr():
    """The 4th Table II instant uses {0.7, 0.8, 0.9, 1.0}: with V_s = 0.1 the
    band midpoints are 0.75..1.05; the paper's values are band edges rounded
    to the 0.1 V supply step of [11]."""
    v = static_voltage_scaling(v_min=1.1, v_crash=0.7, n=4)
    np.testing.assert_allclose(v, [0.75, 0.85, 0.95, 1.05])


@given(st.floats(0.3, 1.0), st.floats(0.05, 0.6), st.integers(1, 12))
@settings(max_examples=60, deadline=None)
def test_algorithm1_properties(v_crash, width, n):
    v_min = v_crash + width
    v = static_voltage_scaling(v_min, v_crash, n)
    assert len(v) == n
    assert (np.diff(v) > 0).all()                       # ascending
    assert v[0] > v_crash and v[-1] < v_min             # strictly inside range
    step = (v_min - v_crash) / n
    np.testing.assert_allclose(np.diff(v), step, rtol=1e-9)  # uniform V_s


def test_algorithm1_rejects_bad_inputs():
    with pytest.raises(ValueError):
        static_voltage_scaling(0.9, 0.95, 4)
    with pytest.raises(ValueError):
        static_voltage_scaling(1.0, 0.95, 0)


def test_assign_partition_voltages_inverse_to_slack():
    """Higher min-slack cluster -> lower V_ccint (paper Sec. I)."""
    slack = [5.5, 7.2, 6.1, 6.9]
    v = assign_partition_voltages(slack, np.array([0.96, 0.97, 0.98, 0.99]))
    order = np.argsort(slack)          # lowest slack first
    assert (np.diff(v[order]) < 0).all()
    assert v[0] == 0.99 and v[1] == 0.96


def test_runtime_step_verbatim():
    """Algorithm 2: +V_s on failure else -V_s."""
    v = np.array([0.96, 0.97, 0.98, 0.99])
    nv = runtime_voltage_scaling(v, np.array([True, False, False, True]),
                                 v_s=0.0125)
    np.testing.assert_allclose(nv, [0.9725, 0.9575, 0.9675, 1.0025])


def test_runtime_step_clamps():
    s = RuntimeScheme(v_s=0.1, v_floor=0.5, v_ceil=1.0)
    nv = s.step(np.array([0.55, 0.95]), np.array([False, True]))
    np.testing.assert_allclose(nv, [0.5, 1.0])


def test_calibration_converges_to_min_safe_voltage():
    """With a threshold oracle, calibrate() must land each partition at the
    lowest clean voltage reachable on the V_s grid."""
    safe = np.array([0.62, 0.71, 0.86, 0.93])

    def trial(v):
        return v < safe                       # fails below the threshold

    s = RuntimeScheme(v_s=0.05, v_floor=0.5, v_ceil=1.2)
    out = s.calibrate(np.array([1.2, 1.2, 1.2, 1.2]), trial, max_trials=64)
    assert (out >= safe).all()
    assert (out - safe <= 0.05 + 1e-9).all()  # within one step of optimal


def test_calibration_floor_clean_partitions_reach_floor():
    def trial(v):
        return np.zeros_like(v, dtype=bool)   # never fails

    s = RuntimeScheme(v_s=0.05, v_floor=0.9, v_ceil=1.2)
    out = s.calibrate(np.array([1.1, 1.0]), trial)
    np.testing.assert_allclose(out, 0.9)


def test_partition_flag_or_vs_and():
    """The paper's text contradiction: OR protects any failing MAC, AND would
    only react when *every* MAC fails."""
    s_or = RuntimeScheme(v_s=0.1, v_floor=0, v_ceil=2, flag_reduce="or")
    s_and = RuntimeScheme(v_s=0.1, v_floor=0, v_ceil=2, flag_reduce="and")
    macs = np.array([True, False, False, False])
    part = np.zeros(4, dtype=np.int64)
    assert s_or.partition_flags(macs, part)[0]
    assert not s_and.partition_flags(macs, part)[0]


@given(st.lists(st.floats(0.5, 1.2), min_size=1, max_size=8),
       st.lists(st.booleans(), min_size=1, max_size=8))
@settings(max_examples=50, deadline=None)
def test_runtime_step_direction_property(vs, flags):
    n = min(len(vs), len(flags))
    v = np.array(vs[:n])
    f = np.array(flags[:n])
    nv = runtime_voltage_scaling(v, f, v_s=0.01, v_floor=0.0, v_ceil=10.0)
    assert ((nv > v) == f).all()
