"""The shared BENCH_*.json schema: every JSON-writing scenario emits the
same envelope (``scenario``, ``elapsed_s``, ``config``) and reuses the same
config key names for the same concepts.

Writers build their payloads through ``benchmarks.run.bench_payload``,
which validates eagerly — so a scenario that drifts from the schema fails
at write time (the CI jobs run all three writers); this module pins the
validator itself plus one real artifact end to end.
"""

import json

import numpy as np
import pytest

from benchmarks import run as br


# ------------------------------------------------------------ validator ----

def test_bench_payload_builds_valid_envelope():
    p = br.bench_payload("demo", 1.25, {"arch": "x", "requests": 3},
                         extra_metric=42)
    assert set(br.BENCH_SCHEMA_KEYS) <= set(p)
    assert p["scenario"] == "demo"
    assert p["elapsed_s"] == 1.25
    assert p["config"] == {"arch": "x", "requests": 3}
    assert p["extra_metric"] == 42
    json.dumps(p)                     # JSON-serializable


@pytest.mark.parametrize("payload", [
    {"elapsed_s": 1.0, "config": {}},                     # missing scenario
    {"scenario": "x", "config": {}},                      # missing elapsed_s
    {"scenario": "x", "elapsed_s": 1.0},                  # missing config
    {"scenario": "", "elapsed_s": 1.0, "config": {}},     # empty scenario
    {"scenario": "x", "elapsed_s": -1.0, "config": {}},   # negative elapsed
    {"scenario": "x", "elapsed_s": float("nan"), "config": {}},
    {"scenario": "x", "elapsed_s": 1.0, "config": [1]},   # config not a dict
])
def test_validator_rejects_schema_drift(payload):
    with pytest.raises(ValueError):
        br.validate_bench_payload(payload)


def test_writers_share_config_key_names():
    """The serve, hwloop and traffic scenarios describe the same serving
    deployment, so their config blocks must spell the shared concepts
    identically."""
    serve_cfg = {"arch": "starcoder2-3b", "requests": 4, "slots": 2,
                 "max_len": 48}
    hwloop_cfg = {**serve_cfg, "flow": {"array_n": 8}}
    traffic_cfg = {"arch": "starcoder2-3b", "slots": 2, "max_len": 48,
                   "seed": 0, "traffic": {"rate_rps": 4.0}}
    shared = {"arch", "slots", "max_len"}
    for cfg in (serve_cfg, hwloop_cfg, traffic_cfg):
        assert shared <= set(cfg)
    br.bench_payload("serve", 0.0, serve_cfg)
    br.bench_payload("hwloop", 0.0, hwloop_cfg)
    br.bench_payload("traffic", 0.0, traffic_cfg)


# ------------------------------------------------- real artifact (flow) ----

def test_flow_scenario_writes_schema_conformant_artifact(tmp_path,
                                                         monkeypatch):
    monkeypatch.setitem(br._OUT, "dir", str(tmp_path))
    monkeypatch.setitem(br._OUT, "json_out", None)
    br.bench_flow(fast=True)
    path = tmp_path / "BENCH_flow.json"
    assert path.exists()
    payload = json.loads(path.read_text())
    br.validate_bench_payload(payload)
    assert payload["scenario"] == "flow"
    assert payload["elapsed_s"] > 0 and np.isfinite(payload["elapsed_s"])
    cfg = payload["config"]
    for key in ("tech", "algo", "array_n", "seed", "repeats"):
        assert key in cfg, key
    # the CI perf gate's keys stay top-level
    assert payload["bit_identical_reports"] is True
    assert payload["speedup"] > 0


# ---------------------------------------------- real artifact (traffic) ----

def test_traffic_scenario_writes_schema_conformant_artifact(tmp_path,
                                                            monkeypatch):
    monkeypatch.setitem(br._OUT, "dir", str(tmp_path))
    monkeypatch.setitem(br._OUT, "json_out", None)
    br.bench_traffic(fast=True)
    path = tmp_path / "BENCH_traffic.json"
    assert path.exists()
    payload = json.loads(path.read_text())
    br.validate_bench_payload(payload)
    assert payload["scenario"] == "traffic"
    assert payload["elapsed_s"] > 0 and np.isfinite(payload["elapsed_s"])
    cfg = payload["config"]
    for key in ("arch", "slots", "max_len", "max_pending", "step_cost_s",
                "seed", "policy", "traffic"):
        assert key in cfg, key
    assert payload["overload_factors"] == [1.0, 2.0, 4.0]
    levels = payload["backends"]["ideal"]
    assert set(levels) == {"1x", "2x", "4x"}
    for m in levels.values():
        for key in ("ttft_p50_s", "ttft_p99_s", "tokens_per_s", "shed_rate",
                    "elapsed_virtual_s", "deadline_met_frac"):
            assert key in m, key
        assert m["completed"] + m["truncated"] + m["shed"] == m["n_events"]
    # offered load beyond capacity must shed monotonically more
    assert levels["4x"]["shed_rate"] >= levels["2x"]["shed_rate"] \
        >= levels["1x"]["shed_rate"]
    assert levels["4x"]["shed_rate"] > 0
