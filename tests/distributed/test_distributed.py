"""Distributed lowering tests.

Device count must differ from the rest of the suite (which sees 1 CPU
device), so each test spawns a subprocess with its own XLA_FLAGS — the same
isolation trick the dry-run uses.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[2] / "src")


def _run(script: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_smoke_train_step_lowering_on_4x2_mesh():
    """A smoke config train step must lower+compile on a (4, 2) mesh with
    FSDP+TP shardings and produce collectives."""
    out = _run("""
        import jax, json
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_test_mesh, rules_for_mesh
        from repro.launch.steps import build_train_step
        mesh = make_test_mesh((4, 2), ("data", "model"))
        cfg = get_config("phi4-mini-3.8b", smoke=True)
        shape = ShapeConfig("t", 64, 8, "train")
        step = build_train_step(cfg, shape, rules_for_mesh(mesh))
        compiled = step.lower().compile()
        hlo = compiled.as_text()
        print(json.dumps({
            "all_reduce": hlo.count("all-reduce("),
            "all_gather": hlo.count("all-gather("),
            "args": compiled.memory_analysis().argument_size_in_bytes,
        }))
    """)
    stats = json.loads(out.strip().splitlines()[-1])
    assert stats["all_reduce"] + stats["all_gather"] > 0
    assert stats["args"] > 0


def test_smoke_decode_step_lowering_seq_sharded_cache():
    out = _run("""
        import jax, json
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_test_mesh, rules_for_mesh
        from repro.launch.steps import build_decode_step
        mesh = make_test_mesh((2, 4), ("data", "model"))
        cfg = get_config("qwen1.5-110b", smoke=True)
        shape = ShapeConfig("t", 64, 4, "decode")
        step = build_decode_step(cfg, shape, rules_for_mesh(mesh))
        compiled = step.lower().compile()
        print(json.dumps({"ok": True,
                          "hlo_has_collective":
                          "all-gather(" in compiled.as_text() or
                          "all-reduce(" in compiled.as_text()}))
    """)
    stats = json.loads(out.strip().splitlines()[-1])
    assert stats["ok"]


def test_moe_ep_a2a_produces_all_to_all():
    """The expert-parallel MoE path must lower a real all-to-all."""
    out = _run("""
        import dataclasses, jax, json
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_test_mesh, rules_for_mesh
        from repro.launch.steps import build_train_step
        mesh = make_test_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(get_config("llama4-scout-17b-a16e",
                                             smoke=True),
                                  moe_impl="ep_a2a", n_experts=4,
                                  moe_shard="expert")
        shape = ShapeConfig("t", 64, 4, "train")
        step = build_train_step(cfg, shape, rules_for_mesh(mesh))
        hlo = step.lower().compile().as_text()
        print(json.dumps({"a2a": hlo.count("all-to-all(")}))
    """)
    stats = json.loads(out.strip().splitlines()[-1])
    assert stats["a2a"] > 0


def test_multi_pod_mesh_shards_pod_axis():
    """3-axis (pod, data, model) mesh: batch sharded across pod x data."""
    out = _run("""
        import jax, json
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_test_mesh, rules_for_mesh
        from repro.launch.steps import build_train_step
        mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("starcoder2-3b", smoke=True)
        shape = ShapeConfig("t", 64, 8, "train")
        step = build_train_step(cfg, shape, rules_for_mesh(mesh))
        compiled = step.lower().compile()
        ma = compiled.memory_analysis()
        print(json.dumps({"args": ma.argument_size_in_bytes}))
    """)
    stats = json.loads(out.strip().splitlines()[-1])
    assert stats["args"] > 0


def test_dryrun_artifacts_exist_and_pass():
    """The production dry-run artifacts must exist for every (arch x shape x
    mesh) and contain no errors (deliverable e)."""
    art = Path(__file__).resolve().parents[2] / "artifacts" / "dryrun"
    if not art.exists():
        pytest.skip("dry-run artifacts not generated yet")
    records = [json.loads(p.read_text()) for p in art.glob("*.json")
               if "_opt" not in p.stem]
    base = [r for r in records if not r.get("tag")]
    assert len(base) >= 80, f"expected 80 cells, found {len(base)}"
    errors = [r for r in base if r["status"] == "error"]
    assert not errors, [f"{r['arch']}x{r['shape']}x{r['mesh']}"
                        for r in errors]
    ok = [r for r in base if r["status"] == "ok"]
    skipped = [r for r in base if r["status"] == "skipped"]
    assert len(ok) + len(skipped) == len(base)
    # every ok cell produced collectives and cost analysis
    for r in ok:
        assert r["cost"].get("flops", 0) > 0
        assert r["collective_wire_bytes"] >= 0
    # multi-pod records exist for every ok single-pod record's cell
    multi = {(r["arch"], r["shape"]) for r in ok
             if r["mesh"] == "multipod_2x16x16"}
    single = {(r["arch"], r["shape"]) for r in ok if r["mesh"] == "pod_16x16"}
    assert single == multi
