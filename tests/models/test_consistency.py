"""Cross-path consistency: chunked/parallel training forms vs recurrent decode
forms must agree; chunked losses vs naive; masks behave causally."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import model_api
from repro.models import ssm as S
from repro.models.layers import (attention, attention_param_specs,
                                 chunked_softmax_xent, embed, logits_last,
                                 rmsnorm)
from repro.models.shardlib import init_param_tree

KEY = jax.random.PRNGKey(42)


def _zero_state(api, shape):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        api.decode_state_specs(shape),
                        is_leaf=lambda x: hasattr(x, "struct"))


def _decode_all(api, params, toks):
    T = toks.shape[1]
    state = _zero_state(api, ShapeConfig("t", T, toks.shape[0], "decode"))
    step = jax.jit(api.decode_step)
    lg = None
    for t in range(T):
        lg, state = step(params, state, toks[:, t:t + 1])
    return lg


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "zamba2-2.7b", "phi4-mini-3.8b",
                                  "llava-next-mistral-7b"])
def test_decode_matches_parallel_forward(arch):
    """Running the prompt token-by-token through decode_step must produce the
    same last-position logits as the parallel (training) forward."""
    cfg = get_config(arch, smoke=True)
    api = model_api(cfg)
    params = api.init_params(KEY)
    T = 8
    toks = jax.random.randint(KEY, (1, T), 0, cfg.vocab_size)

    if cfg.family == "vlm":
        # compare text-only: patch prefix empty not supported -> skip frontend
        import dataclasses
        cfg = dataclasses.replace(cfg, frontend=None)
        api = model_api(cfg)
    batch = {"tokens": toks, "labels": toks}

    # parallel: reuse the loss path's backbone by asking for last logits
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import lm
        x = embed(toks, params)
        y = lm.backbone(params, x, cfg)
        full = logits_last(y[:, -1:], params["embedding"])
    elif cfg.family == "ssm":
        x = embed(toks, params)
        x, _ = jax.lax.scan(lambda c, lp: (S.rwkv6_block(c, lp, cfg), ()), x,
                            params["blocks"])
        full = logits_last(rmsnorm(x, params["final_norm"])[:, -1:],
                           params["embedding"])
    else:  # hybrid: recompute via the loss path pieces
        from repro.models.layers import chunked_softmax_xent  # noqa
        x = embed(toks, params)
        emb0 = x
        period = cfg.shared_attn_period
        n_groups = cfg.n_layers // period
        mamba = jax.tree.map(
            lambda a: a.reshape((n_groups, period) + a.shape[1:]),
            params["mamba"])

        def group(x, gp):
            def inner(c, lp):
                return c + S.mamba2_forward(rmsnorm(c, lp["norm"]), lp, cfg), ()
            x, _ = jax.lax.scan(inner, x, gp)
            x = S._zamba_shared_block(x, emb0, params["shared"], cfg)
            return x, ()

        x, _ = jax.lax.scan(group, x, mamba)
        full = logits_last(rmsnorm(x, params["final_norm"])[:, -1:],
                           params["embedding"])

    dec = _decode_all(api, params, toks)
    scale = float(jnp.abs(full).max()) + 1e-9
    err = float(jnp.abs(dec - full).max()) / scale
    assert err < 2e-2, f"{arch}: decode/parallel mismatch {err}"


def test_prefill_matches_decode_path():
    """prefill(prompt) then decode_step(next) == decoding everything."""
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    api = model_api(cfg)
    params = api.init_params(KEY)
    toks = jax.random.randint(KEY, (2, 9), 0, cfg.vocab_size)
    lg_pref, state = jax.jit(
        lambda p, b: api.prefill(p, b, max_len=9))(params, {"tokens": toks[:, :8]})
    lg_dec = _decode_all(api, params, toks[:, :8])
    scale = float(jnp.abs(lg_dec).max()) + 1e-9
    assert float(jnp.abs(lg_pref - lg_dec).max()) / scale < 2e-2


# ---------------------------------------------------------------------------
# oracle tests for the recurrence building blocks
# ---------------------------------------------------------------------------


def _naive_wkv(r, k, v, w_log, u, state):
    b, s, h, p = r.shape
    S_ = np.array(state, np.float64)
    w = np.exp(np.array(w_log, np.float64))
    r, k, v = (np.array(a, np.float64) for a in (r, k, v))
    u = np.array(u, np.float64)
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        kv = np.einsum("bhp,bhq->bhpq", k[:, t], v[:, t])
        ys[:, t] = np.einsum("bhp,bhpq->bhq", r[:, t],
                             S_ + u[None, :, :, None] * kv)
        S_ = S_ * w[:, t][..., None] + kv
    return ys, S_


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_wkv6_chunked_matches_naive(chunk):
    b, s, h, p = 2, 16, 3, 8
    key = jax.random.PRNGKey(chunk)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, p))
    k = jax.random.normal(ks[1], (b, s, h, p))
    v = jax.random.normal(ks[2], (b, s, h, p))
    w_log = -jnp.exp(jax.random.normal(ks[3], (b, s, h, p)) * 0.5)
    u = jax.random.normal(ks[4], (h, p)) * 0.1
    S0 = jnp.zeros((b, h, p, p))
    y, s_out = S.wkv6_chunked(r, k, v, w_log, u, S0, chunk)
    y_ref, s_ref = _naive_wkv(r, k, v, w_log, u, S0)
    np.testing.assert_allclose(np.array(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.array(s_out), s_ref, rtol=2e-4, atol=2e-4)


def test_mamba2_chunk_invariance():
    """SSD output must not depend on the chunk size."""
    import dataclasses
    cfg = get_config("zamba2-2.7b", smoke=True)
    api = model_api(cfg)
    params = api.init_params(KEY)
    lp = jax.tree.map(lambda a: a[0], params["mamba"])
    x = jax.random.normal(KEY, (2, 32, cfg.d_model)).astype(jnp.bfloat16)
    outs = []
    for ch in (4, 8, 32):
        c2 = dataclasses.replace(cfg, ssm_chunk=ch)
        outs.append(np.array(S.mamba2_forward(x, lp, c2), np.float32))
    np.testing.assert_allclose(outs[0], outs[1], rtol=3e-2, atol=3e-3)
    np.testing.assert_allclose(outs[0], outs[2], rtol=3e-2, atol=3e-3)


def test_mamba2_forward_matches_step():
    cfg = get_config("zamba2-2.7b", smoke=True)
    api = model_api(cfg)
    params = api.init_params(KEY)
    lp = jax.tree.map(lambda a: a[0], params["mamba"])
    dims = S.mamba2_dims(cfg)
    T = 6
    x = jax.random.normal(KEY, (1, T, cfg.d_model)).astype(jnp.bfloat16) * 0.3
    y_par = np.array(S.mamba2_forward(x, lp, cfg), np.float32)
    ssm_state = jnp.zeros((1, dims["n_heads"], dims["d_state"], dims["p"]))
    conv_state = jnp.zeros((1, 3, dims["conv_dim"]), jnp.bfloat16)
    ys = []
    for t in range(T):
        y, ssm_state, conv_state = S.mamba2_step(x[:, t:t + 1], lp, cfg,
                                                 ssm_state, conv_state)
        ys.append(np.array(y, np.float32)[:, 0])
    y_seq = np.stack(ys, axis=1)
    np.testing.assert_allclose(y_seq, y_par, rtol=5e-2, atol=5e-3)


# ---------------------------------------------------------------------------
# attention / loss properties
# ---------------------------------------------------------------------------


def test_chunked_xent_matches_naive():
    b, s, d, v = 2, 12, 16, 40
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, s, d), jnp.float32).astype(jnp.bfloat16)
    emb = jax.random.normal(key, (v, d), jnp.float32).astype(jnp.bfloat16)
    labels = jax.random.randint(key, (b, s), 0, v)
    for chunk in (3, 4, 12, 100):
        got = chunked_softmax_xent(x, emb, labels, chunk=chunk)
        logits = (x @ emb.T).astype(jnp.float32)
        ref = (jax.nn.logsumexp(logits, -1)
               - jnp.take_along_axis(logits, labels[..., None], -1)[..., 0])
        np.testing.assert_allclose(float(got), float(ref.mean()), rtol=1e-5)


def test_attention_is_causal():
    """Future tokens must not influence earlier positions."""
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    specs = attention_param_specs(cfg, layers=0)
    p = init_param_tree(KEY, specs)
    x1 = jax.random.normal(KEY, (1, 8, cfg.d_model)).astype(jnp.bfloat16)
    x2 = x1.at[:, 5:].set(jax.random.normal(
        jax.random.PRNGKey(9), (1, 3, cfg.d_model)).astype(jnp.bfloat16))
    y1 = attention(x1, p, cfg, causal=True)
    y2 = attention(x2, p, cfg, causal=True)
    np.testing.assert_allclose(np.array(y1[:, :5], np.float32),
                               np.array(y2[:, :5], np.float32), atol=1e-6)
    assert not np.allclose(np.array(y1[:, 5:], np.float32),
                           np.array(y2[:, 5:], np.float32))


def test_sliding_window_mask():
    """With window w, token t must ignore keys <= t - w."""
    import dataclasses
    cfg = dataclasses.replace(get_config("llava-next-mistral-7b", smoke=True),
                              frontend=None, sliding_window=4)
    specs = attention_param_specs(cfg, layers=0)
    p = init_param_tree(KEY, specs)
    x1 = jax.random.normal(KEY, (1, 12, cfg.d_model)).astype(jnp.bfloat16)
    # perturb position 0: outputs at positions >= 4 must be unchanged
    x2 = x1.at[:, 0].set(jax.random.normal(
        jax.random.PRNGKey(1), (1, cfg.d_model)).astype(jnp.bfloat16))
    y1 = attention(x1, p, cfg, causal=True)
    y2 = attention(x2, p, cfg, causal=True)
    np.testing.assert_allclose(np.array(y1[:, 4:], np.float32),
                               np.array(y2[:, 4:], np.float32), atol=1e-6)


def test_attention_chunk_invariance():
    import dataclasses
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    specs = attention_param_specs(cfg, layers=0)
    p = init_param_tree(KEY, specs)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model)).astype(jnp.bfloat16)
    outs = []
    for ch in (8, 16, 32):
        c2 = dataclasses.replace(cfg, attn_chunk=ch)
        outs.append(np.array(attention(x, p, c2, causal=True), np.float32))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-5)
