"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import ShapeConfig
from repro.models import model_api, param_count
from repro.models.shardlib import init_param_tree


KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64):
    batch = {"tokens": jnp.full((b, s), 3, jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.full(
            (b, cfg.frontend_tokens, cfg.d_model), 0.01, jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.full(
            (b, s // cfg.enc_frames_ratio, cfg.d_model), 0.01, jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    api = model_api(cfg)
    params = api.init_params(KEY)
    batch = _batch(cfg)

    def train(p, b):
        loss, grads = jax.value_and_grad(api.loss)(p, b)
        return loss, jax.tree.map(lambda x, g: x - 1e-3 * g.astype(x.dtype),
                                  p, grads)

    loss, new_params = jax.jit(train)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss)
    for leaf, old in zip(jax.tree.leaves(new_params), jax.tree.leaves(params)):
        assert leaf.shape == old.shape and leaf.dtype == old.dtype
        assert jnp.isfinite(leaf.astype(jnp.float32)).all()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    api = model_api(cfg)
    params = api.init_params(KEY)
    shape = ShapeConfig("t", 32, 2, "decode")
    state = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         api.decode_state_specs(shape),
                         is_leaf=lambda x: hasattr(x, "struct"))
    step = jax.jit(api.decode_step)
    logits, state = step(params, state, jnp.full((2, 1), 5, jnp.int32))
    assert logits.shape == (2, cfg.padded_vocab)
    assert jnp.isfinite(logits).all()
    logits2, state2 = step(params, state, jnp.full((2, 1), 7, jnp.int32))
    assert jnp.isfinite(logits2).all()
    assert state2["index"].shape == (2,)          # per-row (slot) positions
    assert (state2["index"] == 2).all()
    assert not jnp.allclose(logits, logits2)      # cache actually advanced


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "grok-1-314b",
                                  "llava-next-mistral-7b",
                                  "seamless-m4t-medium"])
def test_prefill_smoke(arch):
    cfg = get_config(arch, smoke=True)
    api = model_api(cfg)
    params = api.init_params(KEY)
    batch = {k: v for k, v in _batch(cfg, s=16).items() if k != "labels"}
    logits, state = jax.jit(lambda p, b: api.prefill(p, b, max_len=32))(
        params, batch)
    assert logits.shape == (2, cfg.padded_vocab)
    assert jnp.isfinite(logits).all()
    expect = 16 + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    assert state["index"].shape == (2,)           # per-row (slot) positions
    assert (state["index"] == expect).all()
    # continue decoding from the prefilled state
    lg, state = jax.jit(api.decode_step)(params, state,
                                         jnp.full((2, 1), 5, jnp.int32))
    assert jnp.isfinite(lg).all()


EXPECTED_PARAMS_B = {
    "llava-next-mistral-7b": 7.11, "grok-1-314b": 315.7,
    "llama4-scout-17b-a16e": 106.7, "granite-20b": 20.0,
    "qwen1.5-110b": 110.0, "starcoder2-3b": 3.03, "phi4-mini-3.8b": 3.84,
    "seamless-m4t-medium": 0.72, "zamba2-2.7b": 2.35, "rwkv6-1.6b": 1.45,
}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_param_count(arch):
    """Full (not smoke) configs carry the assigned dimensions: their param
    counts must match the architecture names."""
    n = param_count(model_api(get_config(arch)).param_specs()) / 1e9
    assert n == pytest.approx(EXPECTED_PARAMS_B[arch], rel=0.02)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_exact_assigned_dimensions(arch):
    cfg = get_config(arch)
    spec = {
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == spec
    if arch == "qwen1.5-110b":
        assert cfg.qkv_bias
    if arch == "llava-next-mistral-7b":
        assert cfg.sliding_window == 4096
    if arch == "grok-1-314b":
        assert (cfg.n_experts, cfg.top_k) == (8, 2)
    if arch == "llama4-scout-17b-a16e":
        assert (cfg.n_experts, cfg.top_k, cfg.shared_expert) == (16, 1, True)
    if arch == "zamba2-2.7b":
        assert cfg.ssm_state == 64 and cfg.shared_attn_period == 6
