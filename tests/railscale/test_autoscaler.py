"""Autoscaler: wiring into ServeEngine, closed-loop descent, watchdog-heal
coordination (heal preempts dwell, holdoff blocks re-undervolt, boosts
stay allowed), and the static policy's bit-compatibility guarantee."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.flow import FlowConfig
from repro.hwloop import HwLoopSession
from repro.models import model_api
from repro.obs import ObsBus
from repro.railscale import Autoscaler, OperatingPoint, OperatingPointTable
from repro.serve import Request, ServeEngine

# same flow coordinates as the session-scoped fixtures in conftest.py
FCFG = FlowConfig(array_n=8, tech="vtr-22nm", max_trials=8, seed=2021)


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("starcoder2-3b", smoke=True)
    api = model_api(cfg)
    return cfg, api.init_params(jax.random.PRNGKey(0))


def _session(store):
    return HwLoopSession(FCFG, probe_rows=8, rail_margin=0.02, store=store)


def _drain(cfg, params, session, auto, n_reqs=2, new_tokens=8):
    eng = ServeEngine(cfg, params, slots=2, max_len=32, hwloop=session,
                      autoscaler=auto)
    reqs = [Request(uid=i, prompt=[3 + i, 4 + i], max_new_tokens=new_tokens)
            for i in range(n_reqs)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    return eng, stats, [list(r.out_tokens) for r in reqs]


class FakeEngine:
    """Just enough engine surface for Autoscaler.attach in unit tests."""

    def __init__(self, session):
        self.hwloop = session
        self.obs = ObsBus()


# -- construction / wiring errors ---------------------------------------------


def test_constructor_and_attach_validation(table, flow):
    _, _, store = flow
    with pytest.raises(ValueError, match="decide_every"):
        Autoscaler(table, decide_every=0)
    with pytest.raises(KeyError, match="unknown rail policy"):
        Autoscaler(table, "warp-drive")

    # non-static policies refuse to run without an actuation path
    class NoLoop:
        hwloop = None
        obs = ObsBus()
    with pytest.raises(ValueError, match="hwloop"):
        Autoscaler(table, "threshold").attach(NoLoop())

    # one autoscaler binds to exactly one engine
    auto = Autoscaler(table, "threshold", start_level=0)
    eng = FakeEngine(_session(store))
    auto.attach(eng)
    with pytest.raises(RuntimeError, match="already attached"):
        auto.attach(eng)

    # ladder width must match the device
    narrow = OperatingPointTable([
        OperatingPoint(0, [1.0, 1.0], 1e-8, 0.0, 0.0, 1.0),
        OperatingPoint(1, [0.9, 0.9], 1e-8, 0.0, 0.0, 1.0)])
    with pytest.raises(ValueError, match="partitions"):
        Autoscaler(narrow, "threshold").attach(FakeEngine(_session(store)))


# -- closed loop end to end ---------------------------------------------------


def test_threshold_descends_and_saves_energy_vs_static_nominal(dense, flow,
                                                               table):
    cfg, params = dense
    _, _, store = flow
    nominal = table.rails(0)

    # baseline: rails pinned at nominal for the whole run
    s_static = _session(store)
    for p in range(s_static.n_partitions):
        s_static.set_partition_voltage(p, float(nominal[p]))
    _, st_static, toks_static = _drain(cfg, params, s_static, None)

    # closed loop: starts at nominal, idles down toward the floor
    s_auto = _session(store)
    auto = Autoscaler(table, "threshold", decide_every=1, dwell_steps=1,
                      start_level=0)
    eng, st_auto, toks_auto = _drain(cfg, params, s_auto, auto)

    rs = st_auto.railscale
    assert rs is not None and rs["policy"] == "threshold"
    assert rs["transitions"]["down"] > 0
    assert rs["level"] > 0
    assert eng.obs.registry.gauge("railscale_level").value() == rs["level"]
    # headline: undervolting at idle costs strictly less energy per token
    assert (st_auto.hwloop["energy_per_token_j"]
            < st_static.hwloop["energy_per_token_j"])
    # and never perturbs decoding — the loop only touches rails
    assert toks_auto == toks_static
    # every decision window leaves a trace event in the flight recorder
    events = [e for e in eng.obs.recorder.to_list()
              if e["name"] == "railscale_decision"]
    assert len(events) == rs["decisions"]
    assert {e["action"] for e in events} & {"down", "hold"}


def test_static_policy_is_a_bit_compatible_noop(dense, flow, table):
    cfg, params = dense
    _, _, store = flow

    s_plain = _session(store)
    rails_before = s_plain.rails.copy()
    _, st_plain, toks_plain = _drain(cfg, params, s_plain, None)

    s_static = _session(store)
    auto = Autoscaler(table, "static", start_level=0)  # start_level ignored
    _, st_auto, toks_auto = _drain(cfg, params, s_static, auto)

    # rails untouched, outputs identical to running with no autoscaler
    np.testing.assert_array_equal(s_static.rails, rails_before)
    assert toks_auto == toks_plain
    rs = st_auto.railscale
    assert rs["transitions"] == {"up": 0, "down": 0}
    assert rs["decisions"] == 0
    # anchored at the level nearest the session's calibrated rails
    assert rs["level"] == table.nearest_level(rails_before)


# -- watchdog-heal coordination (satellite: heal preempts the policy) ---------


def test_heal_preempts_dwell_and_holdoff_blocks_reundervolt(flow, table):
    _, _, store = flow
    session = HwLoopSession(FCFG, probe_rows=8, rail_margin=0.02,
                            patience=2, store=store)
    auto = Autoscaler(table, "threshold", decide_every=1, dwell_steps=4,
                      heal_holdoff_steps=10, start_level=0)
    auto.attach(FakeEngine(session))
    np.testing.assert_allclose(session.rails, table.rails(0))

    # force a watchdog heal: persistent flags on every partition
    ones = np.ones(session.n_partitions, dtype=bool)
    healed = False
    for _ in range(8):
        if session.observe_flags(ones):
            healed = True
            break
    assert healed and session.recalibrations == 1
    # the heal restored the guarded calibrated rails = the deepest rung
    deepest = len(table) - 1

    auto.on_decode_step()
    assert auto._heal_preemptions == 1
    assert auto.level == table.nearest_level(session.rails) == deepest
    # the heal preempted any pending dwell window and started a fresh one
    assert auto.clamp._last_transition_step == auto._steps

    # during holdoff a BOOST toward nominal is still allowed (urgent,
    # bypasses the heal's dwell): deep queue forces it
    auto._g_queue.set(5.0)
    rails_before = session.rails.copy()
    auto.on_decode_step()
    assert auto.level == deepest - 1
    assert auto._transitions["up"] == 1
    assert float(np.mean(session.rails)) > float(np.mean(rails_before))

    # pressure clears -> the policy wants to undervolt again, but the
    # just-healed device is inside the holdoff window: blocked
    auto._g_queue.set(0.0)
    rails_boosted = session.rails.copy()
    auto.on_decode_step()
    assert auto.level == deepest - 1                  # no re-undervolt
    np.testing.assert_array_equal(session.rails, rails_boosted)
    events = auto._obs.recorder.to_list()
    assert [e["name"] for e in events][:1] == ["railscale_heal_preempt"]
    assert events[-1]["action"] == "holdoff"

    # once the holdoff (and dwell) expire, descent resumes
    for _ in range(20):
        auto.on_decode_step()
        if auto.level == deepest:
            break
    assert auto.level == deepest
    assert auto._transitions["down"] >= 1
    assert auto._heal_preemptions == 1                # no further heals
