"""GuardbandClamp: the three safety properties every rail write crosses —
envelope bound, max step per transition, dwell between transitions."""

import numpy as np
import pytest

from repro.railscale import GuardbandClamp


class FakeSession:
    """Duck-typed rail target: records every per-partition write."""

    def __init__(self, rails):
        self._rails = np.asarray(rails, dtype=np.float64)
        self.writes = []

    @property
    def rails(self):
        return self._rails

    def set_partition_voltage(self, p, v):
        self._rails[int(p)] = float(v)
        self.writes.append((int(p), float(v)))


@pytest.fixture
def clamp():
    return GuardbandClamp([0.8, 0.8], [1.0, 1.0], max_step_v=0.05,
                          dwell_steps=4)


def test_ctor_validation():
    with pytest.raises(ValueError, match="matching 1-D"):
        GuardbandClamp([0.8], [1.0, 1.0])
    with pytest.raises(ValueError, match="finite"):
        GuardbandClamp([np.nan], [1.0])
    with pytest.raises(ValueError, match="floor above ceiling"):
        GuardbandClamp([1.1], [1.0])
    with pytest.raises(ValueError, match="max_step_v"):
        GuardbandClamp([0.8], [1.0], max_step_v=0.0)


def test_clamp_rejects_nan_and_shape_mismatch(clamp):
    with pytest.raises(ValueError, match="non-finite"):
        clamp.clamp([np.nan, 0.9])
    with pytest.raises(ValueError, match="non-finite"):
        clamp.clamp([np.inf, 0.9])
    with pytest.raises(ValueError, match="expected 2"):
        clamp.clamp([0.9])


def test_clamp_bounds_to_envelope(clamp):
    np.testing.assert_allclose(clamp.clamp([0.5, 2.0]), [0.8, 1.0])
    np.testing.assert_allclose(clamp.clamp([0.9, 0.95]), [0.9, 0.95])


def test_apply_is_rate_limited_per_transition(clamp):
    s = FakeSession([1.0, 1.0])
    applied = clamp.apply(s, [0.8, 0.8], step=0)
    # one transition moves at most max_step_v per rail
    np.testing.assert_allclose(applied, [0.95, 0.95])
    np.testing.assert_allclose(s.rails, [0.95, 0.95])


def test_apply_respects_dwell_then_reopens(clamp):
    s = FakeSession([1.0, 1.0])
    assert clamp.apply(s, [0.8, 0.8], step=0) is not None
    # dwell window blocks the next transition...
    assert clamp.apply(s, [0.8, 0.8], step=2) is None
    assert clamp.dwell_active(3)
    np.testing.assert_allclose(s.rails, [0.95, 0.95])
    # ...until dwell_steps have elapsed
    assert not clamp.dwell_active(4)
    np.testing.assert_allclose(clamp.apply(s, [0.8, 0.8], step=4),
                               [0.90, 0.90])


def test_urgent_boost_bypasses_dwell(clamp):
    s = FakeSession([0.9, 0.9])
    assert clamp.apply(s, [0.85, 0.85], step=0) is not None
    assert clamp.apply(s, [1.0, 1.0], step=1) is None          # dwell holds
    boosted = clamp.apply(s, [1.0, 1.0], step=1, urgent=True)  # boost doesn't
    np.testing.assert_allclose(boosted, [0.90, 0.90])


def test_apply_noop_at_target_returns_none(clamp):
    s = FakeSession([0.9, 0.9])
    assert clamp.apply(s, [0.9, 0.9], step=0) is None
    assert s.writes == []
    # a no-op does not start a dwell window
    assert not clamp.dwell_active(1)


def test_snap_jumps_whole_envelope_but_still_clamps(clamp):
    s = FakeSession([1.0, 1.0])
    np.testing.assert_allclose(clamp.snap(s, [0.7, 0.85]), [0.8, 0.85])
    np.testing.assert_allclose(s.rails, [0.8, 0.85])


def test_notify_heal_restarts_dwell(clamp):
    s = FakeSession([1.0, 1.0])
    assert not clamp.dwell_active(10)
    clamp.notify_heal(10)
    assert clamp.dwell_active(12)
    assert clamp.apply(s, [0.8, 0.8], step=12) is None
    assert not clamp.dwell_active(14)
