"""Rail policies: pure (signals, level, table) -> level decision logic."""

import pytest

from repro.railscale import (PIDPolicy, RailSignals, StaticPolicy,
                             ThresholdPolicy, get_policy)


class FakeTable:
    def __init__(self, n):
        self.n = n

    def __len__(self):
        return self.n


TABLE = FakeTable(4)


def sig(queue=0.0, active=0.0, flags=0.0, headroom=None, step=0):
    return RailSignals(step=step, queue_depth=queue, active_frac=active,
                       flag_rate=flags, replay_rate=0.0,
                       energy_per_token_j=None, ttft_headroom=headroom)


def test_static_holds_any_level():
    p = StaticPolicy()
    for level in range(4):
        assert p.decide(sig(queue=100.0, flags=1.0), level, TABLE) == level


def test_threshold_boosts_on_any_pressure_signal():
    p = ThresholdPolicy()
    assert p.decide(sig(queue=2.0), 2, TABLE) == 1            # deep queue
    assert p.decide(sig(flags=0.5), 2, TABLE) == 1            # flag burst
    assert p.decide(sig(headroom=0.1), 2, TABLE) == 1         # SLO pressure
    assert p.decide(sig(queue=5.0), 0, TABLE) == 0            # floor at nominal


def test_threshold_descends_only_when_comfortably_idle():
    p = ThresholdPolicy()
    assert p.decide(sig(), 1, TABLE) == 2                     # fully idle
    assert p.decide(sig(headroom=0.9), 1, TABLE) == 2         # wide headroom
    assert p.decide(sig(), 3, TABLE) == 3                     # already deepest


def test_threshold_hysteresis_gap_holds():
    # between the bands: not pressured (queue <= high), not idle
    # (queue > low) -> hold, never flap
    p = ThresholdPolicy(queue_low=0.0, queue_high=2.0)
    assert p.decide(sig(queue=1.0), 1, TABLE) == 1
    # thin-but-not-critical headroom also holds (below 2x headroom_low)
    assert p.decide(sig(headroom=0.4), 1, TABLE) == 1


def test_threshold_rejects_crossed_bands():
    with pytest.raises(ValueError, match="bands must not cross"):
        ThresholdPolicy(queue_low=3.0, queue_high=1.0)


def test_pid_converges_to_extremes():
    p = PIDPolicy()
    level = 0
    for _ in range(8):                      # zero pressure -> deepest level
        level = p.decide(sig(), level, TABLE)
    assert level == len(TABLE) - 1
    for _ in range(8):                      # sustained pressure -> nominal
        level = p.decide(sig(queue=8.0, flags=0.5, headroom=0.0),
                         level, TABLE)
    assert level == 0


def test_pid_integral_windup_is_clamped():
    p = PIDPolicy(i_max=2.0)
    for _ in range(100):
        p.decide(sig(queue=100.0), 0, TABLE)
    assert p._integral == 2.0
    # and it unwinds when pressure clears
    for _ in range(100):
        p.decide(sig(), 0, TABLE)
    assert p._integral == 0.0


def test_get_policy_resolution():
    assert get_policy("static").name == "static"
    assert isinstance(get_policy("threshold", flag_high=0.5), ThresholdPolicy)
    inst = PIDPolicy()
    assert get_policy(inst) is inst
    with pytest.raises(KeyError, match="unknown rail policy"):
        get_policy("warp-drive")
    with pytest.raises(TypeError, match="kwargs"):
        get_policy(inst, kp=2.0)
    with pytest.raises(TypeError, match="not a RailPolicy"):
        get_policy(object())
