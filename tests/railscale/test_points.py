"""Operating-point table: characterization, validation, serialization,
and the flow CLI's ``--points-out`` round trip."""

import dataclasses

import numpy as np
import pytest

from repro.flow.__main__ import main as flow_main
from repro.railscale import (OperatingPoint, OperatingPointTable, load_tables,
                             save_tables)


def _point(level, rails, **kw):
    base = dict(energy_per_token_j=1e-8, flag_rate=0.0, replay_rate=0.0,
                throughput_scale=1.0)
    base.update(kw)
    return OperatingPoint(level=level, rails_v=list(rails), **base)


# -- construction invariants --------------------------------------------------


def test_table_rejects_level_gaps_and_width_mismatch():
    with pytest.raises(ValueError, match="0..n-1"):
        OperatingPointTable([_point(0, [1.0]), _point(2, [0.9])])
    with pytest.raises(ValueError, match="partition counts"):
        OperatingPointTable([_point(0, [1.0, 1.0]), _point(1, [0.9])])
    with pytest.raises(ValueError, match="at least one"):
        OperatingPointTable([])


def test_table_rejects_non_monotone_ladder():
    with pytest.raises(ValueError, match="non-increasing"):
        OperatingPointTable([_point(0, [0.9, 0.9]), _point(1, [1.0, 1.0])])


def test_floor_ceil_nearest():
    t = OperatingPointTable([_point(0, [1.0, 1.0]), _point(1, [0.9, 0.95]),
                             _point(2, [0.8, 0.9])])
    np.testing.assert_allclose(t.floor_v(), [0.8, 0.9])
    np.testing.assert_allclose(t.ceil_v(), [1.0, 1.0])
    assert t.nearest_level([1.0, 1.0]) == 0
    assert t.nearest_level([0.79, 0.91]) == 2
    assert t.nearest_level([0.91, 0.94]) == 1


# -- characterization ---------------------------------------------------------


def test_characterize_ladder_shape_and_energy(flow, table):
    fcfg, report, _ = flow
    assert len(table) == 4
    assert table.n_partitions == len(report.runtime_v)
    # level 0 is nominal rails; the deepest level is the calibrated rails
    # plus the session guard margin (what a watchdog heal restores)
    np.testing.assert_allclose(table.rails(0), fcfg.node.v_nom)
    np.testing.assert_allclose(
        table.rails(3), np.asarray(report.runtime_v) + 0.02, atol=1e-12)
    # undervolting must pay off: deepest level strictly cheaper per token
    energies = [p.energy_per_token_j for p in table.points]
    assert energies[-1] < energies[0]
    assert all(e > 0 for e in energies)
    assert table.meta["tech"] == fcfg.tech
    assert table.meta["array_n"] == fcfg.array_n


def test_characterize_is_deterministic(flow, table):
    fcfg, report, _ = flow
    again = OperatingPointTable.characterize(report, fcfg, n_levels=4,
                                             probe_steps=4, seed=fcfg.seed)
    assert again.to_dict() == table.to_dict()


def test_characterize_requires_calibrated_report(flow):
    fcfg, report, _ = flow
    uncal = dataclasses.replace(report, runtime_v=None)
    with pytest.raises(ValueError, match="runtime_v"):
        OperatingPointTable.characterize(uncal, fcfg)


# -- serialization ------------------------------------------------------------


def test_json_round_trip(tmp_path, table):
    path = tmp_path / "points.json"
    table.save(path)
    loaded = OperatingPointTable.load(path)
    assert loaded.to_dict() == table.to_dict()


def test_multi_table_load_selectors(tmp_path):
    a = OperatingPointTable([_point(0, [1.0]), _point(1, [0.9])],
                            meta={"tech": "vtr-22nm", "array_n": 8})
    b = OperatingPointTable([_point(0, [1.0]), _point(1, [0.85])],
                            meta={"tech": "vivado-28nm", "array_n": 8})
    path = tmp_path / "multi.json"
    save_tables(path, [a, b])
    assert len(load_tables(path)) == 2
    got = OperatingPointTable.load(path, tech="vivado-28nm")
    assert got.to_dict() == b.to_dict()
    with pytest.raises(KeyError, match="no operating-point table"):
        OperatingPointTable.load(path, tech="nope")
    with pytest.raises(KeyError, match="2 tables match"):
        OperatingPointTable.load(path, array_n=8)


def test_load_rejects_unknown_version(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text('{"version": 99, "tables": []}')
    with pytest.raises(ValueError, match="version"):
        load_tables(path)


# -- flow CLI -----------------------------------------------------------------


def test_flow_cli_points_out_round_trip(tmp_path, capsys, flow, table):
    fcfg, _, _ = flow
    out = tmp_path / "cli_points.json"
    rc = flow_main(["run", "--array-n", str(fcfg.array_n),
                    "--tech", fcfg.tech, "--seed", str(fcfg.seed),
                    "--max-trials", str(fcfg.max_trials),
                    "--points-out", str(out),
                    "--points-probe-steps", "4"])
    assert rc == 0
    assert str(out) in capsys.readouterr().out
    loaded = OperatingPointTable.load(out, tech=fcfg.tech,
                                      array_n=fcfg.array_n)
    # the CLI run characterizes the same flow coordinates -> same ladder
    assert loaded.to_dict() == table.to_dict()
