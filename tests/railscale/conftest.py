"""Shared railscale fixtures: one cached CAD-flow report + its ladder."""

import pytest

from repro.flow import ArtifactStore, FlowConfig
from repro.flow import run as flow_run
from repro.railscale import OperatingPointTable

FCFG = FlowConfig(array_n=8, tech="vtr-22nm", max_trials=8, seed=2021)


@pytest.fixture(scope="session")
def flow():
    store = ArtifactStore()
    return FCFG, flow_run(FCFG, store=store), store


@pytest.fixture(scope="session")
def table(flow):
    fcfg, report, _ = flow
    return OperatingPointTable.characterize(report, fcfg, n_levels=4,
                                            probe_steps=4, seed=fcfg.seed)
