"""End-to-end asyncio serving: N concurrent clients streaming from a live
server over real sockets, overload shedding by priority tier under a seeded
2x traffic trace, and graceful drain (the ISSUE 6 acceptance scenario)."""

import asyncio
import time

import jax
import pytest

from repro.configs import get_config
from repro.models import model_api
from repro.serve import Priority, ServeEngine
from repro.server import (ServeFrontend, TrafficConfig, TrafficGenerator,
                          get_json, overload_rate_rps, stream_generate)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("starcoder2-3b", smoke=True)
    api = model_api(cfg)
    return cfg, api.init_params(KEY)


def _engine(cfg, params, **kw):
    base = dict(slots=2, max_len=32, policy="priority")
    base.update(kw)
    return ServeEngine(cfg, params, **base)


async def _serving(engine):
    frontend = ServeFrontend(engine)
    host, port = await frontend.start()
    return frontend, host, port


# ---- streaming ---------------------------------------------------------------

def test_concurrent_clients_stream_full_token_budget(dense):
    cfg, params = dense

    async def scenario():
        frontend, host, port = await _serving(_engine(cfg, params))
        n = 5
        results = await asyncio.gather(*[
            stream_generate(host, port, [5 + i, 6, 7], max_new_tokens=3 + i)
            for i in range(n)])
        await frontend.drain()
        await frontend.close()
        return results

    results = asyncio.run(scenario())
    for i, res in enumerate(results):
        assert res.ok and res.status == "completed"
        # the stream carried every generated token, in order, and the
        # summary's count matches what actually arrived on the wire
        assert len(res.tokens) == 3 + i == res.summary["n_tokens"]
        assert res.summary["ttft_s"] > 0


def test_healthz_and_routing(dense):
    cfg, params = dense

    async def scenario():
        frontend, host, port = await _serving(
            _engine(cfg, params, max_pending=4))
        health = await get_json(host, port, "/healthz")
        missing = await get_json(host, port, "/nope")
        bad = await stream_generate(host, port, ["not-a-token"])
        await frontend.close()
        return health, missing, bad

    health, missing, bad = asyncio.run(scenario())
    assert health["_http_status"] == 200
    assert health["status"] == "ok" and health["slots"] == 2
    assert health["policy"] == "priority" and health["max_pending"] == 4
    assert missing["_http_status"] == 404
    assert bad.http_status == 400


# ---- the acceptance scenario -------------------------------------------------

def test_overload_sheds_low_tiers_and_drain_completes_admitted(dense):
    """2x-overload seeded trace over real sockets: lower tiers are shed,
    every admitted-and-completed stream keeps its full token budget, and
    graceful drain finishes all admitted requests."""
    cfg, params = dense
    tcfg = TrafficConfig(
        rate_rps=overload_rate_rps(
            2.0, 2, 0.02, TrafficConfig(gen_len_log_mean=1.0,
                                        gen_len_log_sigma=0.5)),
        duration_s=1.0, seed=11, max_prompt_len=6, max_gen_len=6,
        gen_len_log_mean=1.0, gen_len_log_sigma=0.5,
        priority_weights=(0.5, 0.25, 0.25),
        deadline_s=(None, 30.0, 30.0),      # generous: shed by queue, not SLO
        vocab_size=cfg.vocab_size)
    events = TrafficGenerator(tcfg).events()
    assert len(events) >= 8
    n_high = sum(ev.priority is Priority.HIGH for ev in events)

    async def scenario():
        # max_pending > n_high makes "never shed HIGH" a guaranteed property
        # (a full queue always holds a lower tier to displace), not a race
        engine = _engine(cfg, params, max_pending=n_high + 1)
        frontend, host, port = await _serving(engine)
        # warm the jit caches through the socket so the burst below hits a
        # serving engine, not a compiling one
        warm = await stream_generate(host, port, [3, 4], max_new_tokens=1)
        assert warm.status == "completed"
        # the warm smoke model steps in microseconds and would out-serve any
        # burst the event loop can deliver; pace it to a realistic per-step
        # model latency so overload behaviour is what's under test
        real_step = engine.step

        def paced_step():
            time.sleep(0.004)
            return real_step()

        engine.step = paced_step

        async def fire(ev):
            res = await stream_generate(
                host, port, ev.prompt, max_new_tokens=ev.max_new_tokens,
                priority=ev.priority.name.lower(), deadline_s=ev.deadline_s)
            return ev, res

        # fire the trace as one closed burst (2x the engine's service rate
        # over the trace horizon, delivered at once against a bounded queue)
        tasks = [asyncio.create_task(fire(ev)) for ev in events]
        # every submission lands in exactly one scheduler bucket, so this
        # sum hits len(events) + warmup only once the whole burst arrived
        loop = asyncio.get_running_loop()
        deadline = loop.time() + 60.0
        while True:
            h = await get_json(host, port, "/healthz")
            landed = (h["pending"] + h["active"] + h["completed"]
                      + h["truncated"] + h["shed"])
            if landed >= len(events) + 1:
                break
            assert loop.time() < deadline, "burst never fully arrived"
            await asyncio.sleep(0.01)
        # drain while streams are still in flight: stops admission but must
        # finish every request already admitted
        drained = await frontend.drain(timeout_s=120.0)
        results = await asyncio.gather(*tasks)
        late = await stream_generate(host, port, [5], max_new_tokens=1)
        health = await get_json(host, port, "/healthz")
        await frontend.close()
        return drained, results, late, health

    drained, results, late, health = asyncio.run(scenario())
    assert drained
    statuses = {s: [ev for ev, r in results if r.status == s]
                for s in ("completed", "shed")}
    assert statuses["shed"], "2x overload against a bounded queue must shed"
    # shedding protects the top tier
    assert all(ev.priority is not Priority.HIGH for ev in statuses["shed"])
    for ev, res in results:
        if res.status == "completed":
            # no admitted request lost tokens: the stream delivered the
            # full budget and it matches the server-side count
            assert len(res.tokens) == ev.max_new_tokens
            assert res.summary["n_tokens"] == ev.max_new_tokens
            if ev.deadline_s is not None:
                assert res.summary["deadline_met"] is True
        elif res.status == "shed":
            assert res.http_status == 503 and res.tokens == []
    # graceful drain: nothing left in flight, and late arrivals are refused
    assert health["pending"] == 0 and health["active"] == 0
    assert health["status"] == "draining"
    assert late.http_status == 503
    assert late.summary.get("error") == "draining"
    # +1: the warmup request also completed
    assert health["completed"] == len(statuses["completed"]) + 1
