"""Client retry machinery (repro.server.client) against a scripted fake
server — no engine, no jax: seeded-jitter determinism, the exponential
backoff schedule, Retry-After precedence, transport-error retries, and
retry exhaustion."""

import asyncio
import json

import pytest

from repro.server.client import (RETRYABLE_ERRORS, RetryPolicy,
                                 stream_generate)
from repro.server.frontend import _json_response, _unavailable


def _ok_stream(tokens, summary):
    """A 200 chunked NDJSON response in the frontend's wire format."""
    lines = [json.dumps({"token": t}) for t in tokens] + [json.dumps(summary)]
    body = b"".join(
        f"{len(line) + 1:x}\r\n".encode() + (line + "\n").encode() + b"\r\n"
        for line in lines) + b"0\r\n\r\n"
    return (b"HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\n"
            b"Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n" + body)


OK = _ok_stream([5, 6], {"done": True, "status": "completed", "n_tokens": 2})
SHED = _unavailable({"error": "overloaded", "status": "shed"})
PLAIN_503 = _json_response(503, {"error": "overloaded", "status": "shed"})


class _FakeServer:
    """One scripted raw response per connection; ``None`` aborts the
    connection before answering (a retryable transport error)."""

    def __init__(self, script):
        self.script = list(script)
        self.hits = 0
        self._server = None
        self.addr = None

    async def _handle(self, reader, writer):
        head = await reader.readuntil(b"\r\n\r\n")
        for line in head.split(b"\r\n"):          # drain the request body
            if line.lower().startswith(b"content-length:"):
                await reader.readexactly(int(line.split(b":")[1]))
        resp = self.script[min(self.hits, len(self.script) - 1)]
        self.hits += 1
        if resp is None:
            writer.transport.abort()
            return
        writer.write(resp)
        await writer.drain()
        writer.close()

    async def __aenter__(self):
        self._server = await asyncio.start_server(self._handle,
                                                  "127.0.0.1", 0)
        self.addr = self._server.sockets[0].getsockname()[:2]
        return self

    async def __aexit__(self, *exc):
        self._server.close()
        await self._server.wait_closed()


def _run(script, **kw):
    sleeps = []

    async def fake_sleep(s):
        sleeps.append(s)

    async def go():
        async with _FakeServer(script) as srv:
            res = await stream_generate(*srv.addr, [3, 4], max_new_tokens=2,
                                        sleep=fake_sleep, **kw)
            return res, srv.hits

    res, hits = asyncio.run(go())
    return res, hits, sleeps


# ---- RetryPolicy unit behaviour ---------------------------------------------


def test_seeded_jitter_is_deterministic_per_policy():
    seq = [RetryPolicy(seed=42).delay_s(k) for k in range(4)]
    assert seq == [RetryPolicy(seed=42).delay_s(k) for k in range(4)]
    assert seq != [RetryPolicy(seed=43).delay_s(k) for k in range(4)]


def test_backoff_schedule_is_exponential_within_jitter():
    p = RetryPolicy(backoff_s=0.05, multiplier=2.0, jitter=0.1, seed=7)
    for k in range(4):
        lo, hi = 0.05 * 2 ** k * 0.9, 0.05 * 2 ** k * 1.1
        assert lo <= p.delay_s(k) <= hi


def test_retry_after_takes_precedence_when_longer():
    p = RetryPolicy(backoff_s=0.05, seed=0)
    assert p.delay_s(0, retry_after_s=9.0) == 9.0
    # ...but a SHORTER Retry-After never truncates the computed backoff
    assert p.delay_s(6, retry_after_s=0.001) > 1.0


def test_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.5)


# ---- stream_generate retry loop ---------------------------------------------


def test_503s_retried_until_success_honouring_retry_after():
    res, hits, sleeps = _run(
        [SHED, SHED, OK],
        retry=RetryPolicy(max_retries=3, backoff_s=0.01, seed=1))
    assert res.ok and res.tokens == [5, 6]
    assert res.attempts == 3 and hits == 3
    # the frontend's Retry-After (1 s) dominates the 10 ms backoff
    assert sleeps == [1.0, 1.0]


def test_backoff_used_when_503_lacks_retry_after():
    res, hits, sleeps = _run(
        [PLAIN_503, PLAIN_503, PLAIN_503, OK],
        retry=RetryPolicy(max_retries=5, backoff_s=0.05, multiplier=2.0,
                          jitter=0.1, seed=9))
    assert res.ok and res.attempts == 4
    assert len(sleeps) == 3
    for k, s in enumerate(sleeps):
        assert 0.05 * 2 ** k * 0.9 <= s <= 0.05 * 2 ** k * 1.1


def test_transport_errors_retried_then_succeed():
    res, hits, sleeps = _run(
        [None, None, OK], retry=RetryPolicy(max_retries=3, backoff_s=0.01,
                                            seed=2))
    assert res.ok and res.attempts == 3 and hits == 3
    assert len(sleeps) == 2


def test_transport_error_propagates_without_retry_policy():
    async def go():
        async with _FakeServer([None]) as srv:
            await stream_generate(*srv.addr, [3], max_new_tokens=1)

    with pytest.raises(RETRYABLE_ERRORS):
        asyncio.run(go())


def test_exhausted_retries_return_last_503():
    res, hits, sleeps = _run(
        [SHED], retry=RetryPolicy(max_retries=2, backoff_s=0.01, seed=3))
    assert res.http_status == 503 and not res.ok
    assert res.status == "shed"
    assert res.attempts == 3 and hits == 3        # 1 try + 2 retries
    assert res.headers.get("retry-after") == "1"


def test_no_retry_by_default_on_503():
    res, hits, sleeps = _run([SHED, OK])          # retry=None
    assert res.http_status == 503
    assert res.attempts == 1 and hits == 1 and sleeps == []
