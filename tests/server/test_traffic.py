"""Traffic generator: determinism under seed, distribution shape, diurnal
envelope, trace-file round-trips, and config validation (no jax)."""

import io

import numpy as np
import pytest

from repro.serve import Priority
from repro.server import (TraceEvent, TrafficConfig, TrafficGenerator,
                          load_trace, save_trace)


def _cfg(**kw):
    base = dict(rate_rps=20.0, duration_s=10.0, seed=7)
    base.update(kw)
    return TrafficConfig(**base)


def test_same_seed_same_trace_different_seed_different():
    a = TrafficGenerator(_cfg()).events()
    b = TrafficGenerator(_cfg()).events()
    c = TrafficGenerator(_cfg(seed=8)).events()
    assert a == b
    assert a != c


def test_arrivals_sorted_within_horizon_and_poisson_scale():
    cfg = _cfg()
    ev = TrafficGenerator(cfg).events()
    ts = [e.t_s for e in ev]
    assert ts == sorted(ts)
    assert all(0 <= t < cfg.duration_s for t in ts)
    # law of large numbers: ~rate * duration arrivals (+-40%)
    expected = cfg.rate_rps * cfg.duration_s
    assert 0.6 * expected < len(ev) < 1.4 * expected


def test_lengths_clipped_and_heavy_tailed():
    cfg = _cfg(max_prompt_len=16, max_gen_len=12)
    ev = TrafficGenerator(cfg).events()
    plens = np.asarray([len(e.prompt) for e in ev])
    glens = np.asarray([e.max_new_tokens for e in ev])
    assert plens.min() >= 1 and plens.max() <= 16
    assert glens.min() >= 1 and glens.max() <= 12
    # heavy tail: mean above median for the lognormal draw
    assert plens.mean() >= np.median(plens)


def test_priority_mix_and_per_tier_deadlines():
    cfg = _cfg(priority_weights=(0.0, 0.0, 1.0),
               deadline_s=(None, 2.0, 0.5))
    ev = TrafficGenerator(cfg).events()
    assert ev and all(e.priority is Priority.HIGH for e in ev)
    assert all(e.deadline_s == 0.5 for e in ev)
    cfg = _cfg(priority_weights=(1.0, 0.0, 0.0), deadline_s=(None, 2.0, 0.5))
    ev = TrafficGenerator(cfg).events()
    assert ev and all(e.deadline_s is None for e in ev)


def test_diurnal_envelope_modulates_arrival_density():
    # amplitude 1 with period == duration: first half boosted, second half
    # suppressed (sin is positive then negative)
    cfg = _cfg(rate_rps=40.0, duration_s=20.0, diurnal_amplitude=1.0,
               diurnal_period_s=20.0)
    ev = TrafficGenerator(cfg).events()
    half = cfg.duration_s / 2
    first = sum(1 for e in ev if e.t_s < half)
    second = len(ev) - first
    assert first > 2 * second


def test_trace_roundtrip_through_file(tmp_path):
    ev = TrafficGenerator(_cfg(duration_s=2.0)).events()
    path = str(tmp_path / "trace.ndjson")
    save_trace(ev, path)
    assert load_trace(path) == ev
    buf = io.StringIO()
    save_trace(ev, buf)
    buf.seek(0)
    assert load_trace(buf) == ev


def test_trace_event_to_request_carries_qos():
    ev = TraceEvent(t_s=0.5, uid=3, prompt=[4, 5], max_new_tokens=6,
                    priority=Priority.HIGH, deadline_s=0.25)
    req = ev.to_request()
    assert req.uid == 3 and req.prompt == [4, 5]
    assert req.max_new_tokens == 6
    assert req.priority is Priority.HIGH and req.deadline_s == 0.25


@pytest.mark.parametrize("bad", [
    dict(rate_rps=0.0),
    dict(duration_s=-1.0),
    dict(diurnal_amplitude=1.5),
    dict(priority_weights=(0.5, 0.5, 0.5)),
])
def test_config_validation(bad):
    with pytest.raises(ValueError):
        _cfg(**bad)
