"""Virtual-time load harness: deterministic latency telemetry under the
injected clock, overload shedding, and metric integrity on a real (smoke)
engine."""

import jax
import pytest

from repro.configs import get_config
from repro.models import model_api
from repro.serve import Request, ServeEngine
from repro.server import (LoadHarness, TrafficConfig, TrafficGenerator,
                          TrafficMetrics, VirtualClock, overload_rate_rps)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("starcoder2-3b", smoke=True)
    api = model_api(cfg)
    return cfg, api.init_params(KEY)


def _traffic(factor, slots=2, step_cost_s=0.02, **kw):
    base = dict(duration_s=1.5, seed=0, max_prompt_len=8, max_gen_len=8,
                prompt_len_log_mean=0.8, prompt_len_log_sigma=0.5,
                gen_len_log_mean=1.0, gen_len_log_sigma=0.5)
    base.update(kw)
    rate = overload_rate_rps(factor, slots, step_cost_s,
                             TrafficConfig(**base))
    return TrafficConfig(rate_rps=rate, **base)


def _replay(cfg, params, factor, **engine_kw):
    clock = VirtualClock()
    eng = ServeEngine(cfg, params, slots=2, max_len=32, clock=clock,
                      policy="priority", max_pending=6, **engine_kw)
    events = TrafficGenerator(_traffic(factor)).events()
    return LoadHarness(eng, clock, step_cost_s=0.02).replay(events)


# ---- clock injection (engine-level) ------------------------------------------

def test_engine_clock_injection_exact_ttft(dense):
    """With a virtual clock, latency telemetry is exact, not approximate."""
    cfg, params = dense
    clock = VirtualClock()
    eng = ServeEngine(cfg, params, slots=2, max_len=32, clock=clock)
    req = Request(uid=0, prompt=[5, 6], max_new_tokens=3)
    eng.submit(req)
    assert req.submit_t == 0.0
    clock.advance(1.5)
    eng.step()       # absorbs the prompt + one decode: tokens 1 and 2
    assert req.first_token_t == 1.5
    assert req.ttft_s == 1.5                    # exact equality: virtual time
    assert eng.stats.ttft_s == [1.5]
    clock.advance(0.25)
    eng.step()       # token 3 -> done
    stats = eng.run_until_drained()
    assert req.finish_t == 1.75
    assert stats.ttft_s == [1.5]


def test_harness_requires_matching_clock(dense):
    cfg, params = dense
    eng = ServeEngine(cfg, params, slots=1, max_len=16)   # wall clock
    with pytest.raises(ValueError):
        LoadHarness(eng, VirtualClock())


# ---- deterministic replay ----------------------------------------------------

def test_replay_metrics_bit_deterministic(dense):
    cfg, params = dense
    a = _replay(cfg, params, 2.0)
    b = _replay(cfg, params, 2.0)
    da, db = a.to_dict(), b.to_dict()
    da.pop("wall_s"), db.pop("wall_s")          # only wall time may differ
    assert da == db
    assert a.ttft_p50_s is not None and a.ttft_p99_s is not None
    assert a.ttft_p50_s <= a.ttft_p99_s


def test_overload_monotonically_increases_shedding(dense):
    cfg, params = dense
    light = _replay(cfg, params, 1.0)
    heavy = _replay(cfg, params, 4.0)
    assert heavy.n_events > light.n_events
    assert heavy.shed_rate > light.shed_rate
    assert heavy.shed_rate > 0.3                # 4x offered load must shed
    # priority shedding protects the top tier: HIGH sheds no more often
    # than LOW in absolute count under heavy overload
    assert heavy.shed_by_priority["HIGH"] <= heavy.shed_by_priority["LOW"] \
        + heavy.shed_by_priority["NORMAL"]


def test_accounting_adds_up_and_no_token_loss(dense):
    cfg, params = dense
    m = _replay(cfg, params, 2.0)
    assert m.completed + m.truncated + m.shed == m.n_events
    assert m.tokens_generated > 0
    assert m.tokens_per_s == pytest.approx(
        m.tokens_generated / m.elapsed_virtual_s)
    assert 0.0 <= m.shed_rate <= 1.0
    assert isinstance(m, TrafficMetrics)


def test_completed_requests_receive_full_budget(dense):
    """Load shedding must never clip a request it admitted and completed."""
    cfg, params = dense
    clock = VirtualClock()
    eng = ServeEngine(cfg, params, slots=2, max_len=32, clock=clock,
                      policy="priority", max_pending=6)
    events = TrafficGenerator(_traffic(2.0)).events()
    h = LoadHarness(eng, clock, step_cost_s=0.02)
    h.replay(events)
    completed = [r for r in h.requests if r.done and not r.shed
                 and not r.truncated]
    assert completed
    for r in completed:
        assert len(r.out_tokens) == r.max_new_tokens
    for r in h.requests:
        if r.shed:
            assert r.out_tokens == []           # shed before any decode
