"""The `hwloop` flow stage + sweep integration: voltage→(energy/token,
replay-rate) Pareto tables across tech nodes."""

import numpy as np
import pytest

from repro.flow import (HWLOOP_COLUMNS, FlowConfig, Pipeline, get_stage, run,
                        sweep)
from repro.hwloop import hwloop_pipeline

BASE = FlowConfig(array_n=8, max_trials=8, seed=2021, hwloop_steps=4,
                  hwloop_rows=8)


def test_hwloop_stage_is_registered_and_opt_in():
    assert get_stage("hwloop").name == "hwloop"
    pipe = hwloop_pipeline()
    names = [s.name for s in pipe.stages]
    assert "hwloop" in names
    assert names.index("hwloop") == names.index("power") + 1
    # the default chain stays untouched
    assert "hwloop" not in [s.name for s in Pipeline().stages]


def test_run_with_hwloop_stage_populates_report():
    rep = run(BASE, pipeline=hwloop_pipeline())
    assert rep.hwloop_energy_per_token_j is not None
    assert np.isfinite(rep.hwloop_energy_per_token_j)
    assert rep.hwloop_energy_per_token_j > 0
    assert rep.hwloop_replay_rate is not None and rep.hwloop_replay_rate >= 0
    assert len(rep.hwloop_flag_rate) == rep.n_partitions
    # default run (no hwloop stage): fields stay None
    rep_plain = run(BASE)
    assert rep_plain.hwloop_energy_per_token_j is None


def test_sweep_produces_pareto_table_across_tech_nodes():
    """Acceptance: sweep() with the hwloop stage yields a voltage→
    (energy/token, replay-rate) table for >= 2 tech nodes."""
    res = sweep({"tech": ["vtr-22nm", "vtr-45nm"]}, BASE,
                pipeline=hwloop_pipeline())
    rows = res.rows()
    assert len(rows) == 2
    for row in rows:
        for col in HWLOOP_COLUMNS:
            assert col in row, col
        assert np.isfinite(row["hwloop_energy_per_token_j"])
        assert row["hwloop_energy_per_token_j"] > 0
        assert row["hwloop_replay_rate"] >= 0
        assert len(row["hwloop_flag_rate"]) == row["n_partitions"]
    # distinct tech nodes -> distinct energy operating points
    assert rows[0]["hwloop_energy_per_token_j"] != \
        rows[1]["hwloop_energy_per_token_j"]
    # the rendered table carries the hwloop columns automatically
    header = res.table().splitlines()[0]
    assert "hwloop_energy_per_token_j" in header


def test_sweep_without_hwloop_stage_keeps_stable_columns():
    res = sweep({"tech": ["vtr-22nm"]}, BASE)
    assert "hwloop_energy_per_token_j" not in res.rows()[0]
    assert "hwloop_energy_per_token_j" not in res.table().splitlines()[0]


def test_config_validates_hwloop_fields():
    with pytest.raises(ValueError, match="hwloop_corruption"):
        FlowConfig(hwloop_corruption="nope")
    with pytest.raises(ValueError, match="hwloop_steps"):
        FlowConfig(hwloop_steps=0)
    with pytest.raises(ValueError, match="hwloop_rows"):
        FlowConfig(hwloop_rows=-1)
    # round-trips through the serializer with the new fields
    cfg = FlowConfig(hwloop_steps=3, hwloop_corruption="tedrop")
    assert FlowConfig.from_json(cfg.to_json()) == cfg
