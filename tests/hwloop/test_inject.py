"""Silent-corruption models (repro.hwloop.inject): registry, hand-checkable
per-model semantics, determinism under a fixed seed, and corruption-rate
scaling as rails drop through the crash region."""

import numpy as np
import pytest

from repro.backend import EmulatedBackend
from repro.hwloop.inject import (CORRUPTION_MODELS, bit_flip, get_corruption,
                                 stale_psum, te_drop)

#: Deep in the vtr-22nm crash region — every partition silently corrupts
#: (pinned by tests/hwloop/test_device.py and the resilience chaos campaign).
V_CRASH = 0.58


def _terms(m=6, k=4, n=5, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.integers(-3, 4, size=(m, k)).astype(np.float64)
    w = rng.integers(-3, 4, size=(k, n)).astype(np.float64)
    return a[:, :, None] * w[None, :, :]          # (M, K, N) rank-1 terms


# ---- registry ---------------------------------------------------------------


def test_registry_names_and_lookup():
    assert {"stale", "tedrop", "bitflip"} <= set(CORRUPTION_MODELS)
    assert get_corruption("stale") is stale_psum
    with pytest.raises(KeyError, match="unknown corruption model"):
        get_corruption("bit_flip")                # underscore spelling is not
        # registered — configs must use the canonical short names


# ---- per-model semantics ----------------------------------------------------


def test_all_models_exact_when_nothing_is_silent():
    terms = _terms()
    silent = np.zeros(terms.shape, dtype=bool)
    rng = np.random.default_rng(0)
    exact = terms.sum(axis=1)
    for name in ("stale", "tedrop", "bitflip"):
        out = get_corruption(name)(terms, silent, rng)
        assert np.array_equal(out, exact), name


def test_tedrop_zeroes_exactly_the_silent_terms():
    terms = _terms(seed=1)
    rng = np.random.default_rng(1)
    silent = rng.random(terms.shape) < 0.15
    out = te_drop(terms, silent, rng)
    exact = terms.sum(axis=1)
    assert np.allclose(out, exact - np.where(silent, terms, 0.0).sum(axis=1))
    hit = silent.any(axis=1)
    clean_unchanged = np.array_equal(out[~hit], exact[~hit])
    assert clean_unchanged                        # error stays localized


def test_bitflip_perturbs_only_hit_elements_and_stays_finite():
    rng = np.random.default_rng(2)
    terms = rng.uniform(0.5, 2.0, size=(6, 4, 5))  # positive: outputs != 0
    silent = rng.random(terms.shape) < 0.1
    out = bit_flip(terms, silent, rng)
    exact = terms.sum(axis=1)
    hit = silent.any(axis=1)
    assert np.array_equal(out[~hit], exact[~hit])
    assert (out[hit] != exact[hit]).all()         # every hit element flipped
    # bit 40 of the f64 mantissa: a ~2^-12 relative perturbation, no inf/nan
    rel = np.abs(out[hit] - exact[hit]) / np.abs(exact[hit])
    assert np.isfinite(out).all()
    assert 0 < rel.max() < 1e-2


def test_stale_forward_fills_from_last_clean_row():
    # hand-traceable case: one silent MAC at (row, stage, col) = (1, 1, 0)
    m, k, n = 3, 3, 2
    terms = np.arange(m * k * n, dtype=np.float64).reshape(m, k, n) + 1.0
    silent = np.zeros((m, k, n), dtype=bool)
    silent[1, 1, 0] = True
    out = stale_psum(terms, silent, np.random.default_rng(0))
    exact = terms.sum(axis=1)
    # the corrupted element inherits row 0's psum at stage 1, then accrues
    # its own remaining terms
    expect = terms[0, :2, 0].sum() + terms[1, 2, 0]
    assert out[1, 0] == expect
    # everything else is untouched
    mask = np.ones_like(exact, dtype=bool)
    mask[1, 0] = False
    assert np.array_equal(out[mask], exact[mask])

    # a silent MAC in row 0 has no clean row above: its psum resets to zero
    silent = np.zeros((m, k, n), dtype=bool)
    silent[0, 0, 1] = True
    out = stale_psum(terms, silent, np.random.default_rng(0))
    assert out[0, 1] == terms[0, 1:, 1].sum()


# ---- device-level behaviour -------------------------------------------------


def _collapsed(corruption, seed=2021):
    be = EmulatedBackend.nominal(corruption=corruption, seed=seed)
    accel = be.accel
    accel.set_rails(np.full(accel.n_partitions, V_CRASH))
    return accel


def _corrupted_fraction(accel, rounds=6, seed=3):
    rng = np.random.default_rng(seed)
    bad = total = 0
    for _ in range(rounds):
        a = rng.integers(-4, 5, size=(16, 8)).astype(np.float64)
        w = rng.integers(-4, 5, size=(8, 8)).astype(np.float64)
        out, _ = accel.matmul(a, w)
        bad += int(np.sum(np.asarray(out) != a @ w))
        total += out.size
    return bad / total


@pytest.mark.parametrize("corruption", ["stale", "tedrop", "bitflip"])
def test_corruption_deterministic_under_fixed_seed(corruption):
    outs = []
    for _ in range(2):                            # two independent devices
        accel = _collapsed(corruption)
        rng = np.random.default_rng(5)
        a = rng.normal(size=(16, 8))
        w = rng.normal(size=(8, 8))
        out, tel = accel.matmul(a, w)
        outs.append((np.asarray(out).copy(), int(tel.silent_p.sum())))
    assert np.array_equal(outs[0][0], outs[1][0])
    assert outs[0][1] == outs[1][1] > 0           # same silent-failure count


def test_corruption_rate_scales_with_rail_undervolt():
    be = EmulatedBackend.nominal(corruption="bitflip")
    accel = be.accel
    v_nom = float(accel.timing.tech.v_nom)
    rates = []
    for v in (v_nom, 0.66, V_CRASH):              # deeper and deeper droop
        accel.set_rails(np.full(accel.n_partitions, v))
        rates.append(_corrupted_fraction(accel))
    assert rates[0] == 0.0                        # nominal rails: clean
    assert rates[-1] > 0.0                        # crash region: corrupted
    assert rates == sorted(rates)                 # monotone in undervolt
