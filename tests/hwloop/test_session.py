"""HwLoopSession: the online loop — undervolt, flag, recalibrate, heal —
plus artifact-cache reuse across the mid-serve recalibration."""

import numpy as np
import pytest

from repro.flow import FlowConfig
from repro.hwloop import HwLoopSession

CFG = FlowConfig(array_n=8, tech="vtr-22nm", max_trials=12, seed=2021)


@pytest.fixture
def session():
    return HwLoopSession(CFG, patience=2, rail_margin=0.05, probe_rows=8)


def test_clean_steps_produce_no_flags_and_account_energy(session):
    for i in range(4):
        tel = session.step([3 + i, 11 * i])
        assert not tel.flags.any()
        assert not tel.recalibrated
        assert tel.rel_error == 0.0
    assert session.recalibrations == 0
    assert np.all(session.flag_rate() == 0.0)
    s = session.summary()
    assert s["steps"] == 4 and s["tokens"] == 8
    assert s["energy_per_token_j"] > 0 and np.isfinite(s["energy_per_token_j"])


def test_undervolt_flags_then_watchdog_recalibrates_and_heals(session):
    """Acceptance: a rail below its safe point raises that partition's
    DETECTED rate; after the watchdog's patience the cached
    runtime_calibration stage re-runs mid-serve and the rails heal."""
    session.step([5])                                  # clean warm-up
    v_safe = float(session.accel.timing.min_safe_voltage()
                   [session.accel._part_grid == 0].max())
    session.set_partition_voltage(0, v_safe - 0.02)

    recal_at = None
    for i in range(6):
        tel = session.step([17, i])
        if tel.recalibrated:
            recal_at = i
            break
        assert tel.flags[0]                            # flag fires every step
    assert recal_at is not None and session.recalibrations == 1
    # rails healed: back above the undervolted value, with the guard band
    assert session.rails[0] > v_safe - 0.02
    np.testing.assert_allclose(
        session.rails, np.asarray(session.watchdog.runtime_v) + 0.05)
    # and the loop is clean again
    tel = session.step([23])
    assert not tel.flags.any()
    # per-partition flag-rate telemetry reflects the episode
    assert session.flag_rate()[0] > 0
    assert session.summary()["recalibrations"] == 1


def test_recalibration_reuses_cached_prefix(session):
    """The mid-serve re-run only re-executes the calibration suffix; the
    timing/cluster/floorplan prefix is served from the shared store."""
    store = session.watchdog.store
    v_safe = float(session.accel.timing.min_safe_voltage()
                   [session.accel._part_grid == 0].max())
    session.set_partition_voltage(0, v_safe - 0.02)
    for _ in range(4):
        if session.step([9]).recalibrated:
            break
    assert session.recalibrations == 1
    for stage in ("timing", "cluster", "floorplan", "static_voltage"):
        assert store.runs_of(stage) == 1, stage
    assert store.runs_of("runtime_calibration") == 2


def test_step_telemetry_feeds_engine_shapes(session):
    tel = session.step([1, 2, 3], n_tokens=3)
    assert tel.flags.shape == (session.n_partitions,)
    assert tel.detected_p.shape == (session.n_partitions,)
    assert session.accel.ledger.tokens == 3


def test_set_partition_voltage_rejects_garbage(session):
    for bad in (float("nan"), float("inf"), float("-inf")):
        with pytest.raises(ValueError, match="non-finite"):
            session.set_partition_voltage(0, bad)
    for bad_p in (-1, session.n_partitions):
        with pytest.raises(IndexError, match="out of range"):
            session.set_partition_voltage(bad_p, 0.9)
    # a rejected write leaves the rails untouched
    before = session.rails.copy()
    with pytest.raises(ValueError):
        session.set_partition_voltage(0, float("nan"))
    np.testing.assert_array_equal(session.rails, before)


def test_set_partition_voltage_clamps_to_physical_envelope(session):
    lo, hi = session.rail_envelope
    node = session.config.node
    assert lo == node.v_th and hi == max(node.v_nom, node.v_min)
    session.set_partition_voltage(0, lo - 1.0)     # below threshold voltage
    assert session.rails[0] == lo
    session.set_partition_voltage(0, hi + 1.0)     # above the scaling range
    assert session.rails[0] == hi
    session.set_partition_voltage(0, 0.9)          # in-band writes unclamped
    assert session.rails[0] == 0.9


def test_manual_rail_write_republishes_gauges(session):
    from repro.obs import ObsBus

    bus = ObsBus()
    session.attach_obs(bus)
    gauge = bus.registry.gauge("hwloop_rail_volts", labels=("partition",))
    assert gauge.value(partition="0") == session.rails[0]
    session.set_partition_voltage(0, 0.91)
    # the exported telemetry can never go stale after a manual write
    assert gauge.value(partition="0") == pytest.approx(0.91)
