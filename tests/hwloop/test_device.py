"""EmulatedAccelerator: nominal-voltage parity, undervolting flag rates,
corruption models, and energy accounting."""

import numpy as np
import pytest

from repro.core import TECH_NODES
from repro.flow import FlowConfig, run
from repro.hwloop import EmulatedAccelerator, get_corruption

CFG = FlowConfig(array_n=8, tech="vtr-22nm", max_trials=8, seed=2021)


@pytest.fixture(scope="module")
def report():
    return run(CFG)


def _nominal(report, **kw):
    rails = np.full(report.n_partitions, CFG.node.v_nom)
    return EmulatedAccelerator.from_flow(report, CFG, rails=rails, **kw)


def test_nominal_voltage_is_bit_identical_to_ideal(report):
    """Acceptance: at nominal rails no error is injected and the product is
    bit-identical to the ideal kernel — while the energy ledger still
    accounts the work."""
    accel = _nominal(report)
    rng = np.random.default_rng(0)
    a, w = rng.normal(size=(32, 8)), rng.normal(size=(8, 8))
    c, tel = accel.matmul(a, w)
    assert np.array_equal(c, a @ w)                 # bit-identical
    assert tel.detected_p.sum() == 0 and tel.silent_p.sum() == 0
    assert not tel.partition_flags.any()
    assert tel.rel_error == 0.0
    # the ledger is populated regardless of faults
    assert accel.ledger.dynamic_j > 0
    assert accel.ledger.leakage_j > 0
    assert accel.ledger.total_macs == 32 * 8 * 8
    assert accel.ledger.replay_cycles == 0


def test_multi_tile_shapes_cover_all_macs(report):
    """(M, K) @ (K, N) with K, N not multiples of the array size tile
    correctly and account exactly M*K*N MAC ops."""
    accel = _nominal(report)
    rng = np.random.default_rng(1)
    a, w = rng.normal(size=(5, 20)), rng.normal(size=(20, 13))
    c, tel = accel.matmul(a, w)
    np.testing.assert_allclose(c, a @ w, rtol=1e-12)
    assert tel.macs_p.sum() == 5 * 20 * 13


def test_undervolting_raises_partition_detected_rate(report):
    """Acceptance: lowering one partition's rail below its safe voltage
    measurably raises THAT partition's DETECTED flag rate; others stay
    clean."""
    accel = EmulatedAccelerator.from_flow(report, CFG)
    rng = np.random.default_rng(2)
    a, w = rng.normal(size=(32, 8)), rng.normal(size=(8, 8))
    _, tel_before = accel.matmul(a, w)

    v_safe = float(accel.timing.min_safe_voltage()
                   [accel._part_grid == 0].max())
    accel.set_partition_voltage(0, v_safe - 0.02)
    _, tel_after = accel.matmul(a, w)
    assert tel_after.detected_rate[0] > tel_before.detected_rate[0]
    assert tel_after.detected_p[0] > 0
    assert tel_after.partition_flags[0]
    # partitions whose rails were untouched keep their flag state
    np.testing.assert_array_equal(tel_after.partition_flags[1:],
                                  tel_before.partition_flags[1:])


def test_rails_validation(report):
    with pytest.raises(ValueError, match="rail"):
        EmulatedAccelerator.from_flow(report, CFG, rails=np.array([1.0]))


def _silent_setup(corruption, report):
    """Device with every rail deep in the crash region: silent failures."""
    accel = EmulatedAccelerator.from_flow(
        report, CFG, rails=np.full(report.n_partitions, 0.58),
        corruption=corruption)
    rng = np.random.default_rng(3)
    return accel, rng.normal(size=(16, 8)), rng.normal(size=(8, 8))


@pytest.mark.parametrize("corruption", ["stale", "tedrop", "bitflip"])
def test_corruption_models_corrupt_silently(corruption, report):
    accel, a, w = _silent_setup(corruption, report)
    c, tel = accel.matmul(a, w)
    assert tel.silent_p.sum() > 0
    assert tel.rel_error > 0
    assert not np.array_equal(c, a @ w)
    assert np.isfinite(c).all()                 # corrupted, never inf/nan


def test_tedrop_drops_failing_terms(report):
    """TE-Drop semantics: the corrupted product equals the sum of the
    non-silent rank-1 terms (reconstructed from the status the device
    classified)."""
    accel, a, w = _silent_setup("tedrop", report)
    c, tel = accel.matmul(a, w)
    # reconstruct the mask exactly as the device classified it
    from repro.core.razor import SILENT, classify_arrival, effective_arrival
    from repro.hwloop import quantized_activity
    act = quantized_activity(a, accel.quant_bits)
    arrival = effective_arrival(accel.timing.delays_at(accel.v_map)[None],
                                act[:, :, None], accel.razor)
    sil = classify_arrival(arrival, accel.razor) == SILENT
    terms = a[:, :, None] * w[None, :, :]
    np.testing.assert_array_equal(c, np.where(sil, 0.0, terms).sum(axis=1))


def test_stale_matches_systolic_simulator_semantics(report):
    """The "stale" model is the simulator's forward-fill, so a single-tile
    emulated matmul must agree with SystolicSim.matmul bit for bit."""
    from repro.core import RazorConfig, SystolicSim, TimingModel

    tm = TimingModel(n=8, clock_ns=CFG.clock_ns, tech=CFG.node, seed=CFG.seed)
    fp = report.floorplan.with_voltages([0.58] * report.n_partitions)
    sim = SystolicSim(tm, fp, RazorConfig(clock_ns=CFG.clock_ns))
    accel = EmulatedAccelerator(
        tm, fp, razor=RazorConfig(clock_ns=CFG.clock_ns), corruption="stale")
    rng = np.random.default_rng(4)
    a, w = rng.normal(size=(16, 8)), rng.normal(size=(8, 8))
    c_sim, stats = sim.matmul(a, w)
    c_emu, tel = accel.matmul(a, w)
    np.testing.assert_array_equal(c_emu, c_sim)
    assert tel.silent_p.sum() == stats.silent.sum()
    assert tel.replay_cycles == stats.replay_cycles


def test_energy_tracks_voltage_and_replays(report):
    """Lower rails cost less dynamic energy per MAC (P ~ V^k); replays add
    energy on top."""
    from repro.core import model_for
    pm = model_for(CFG.tech)
    lo = pm.energy_per_mac_pj(0.8)
    hi = pm.energy_per_mac_pj(1.0)
    assert lo < hi

    accel = _nominal(report)
    rng = np.random.default_rng(5)
    a, w = rng.normal(size=(16, 8)), rng.normal(size=(8, 8))
    accel.matmul(a, w)
    assert accel.ledger.replay_j == 0.0

    # a rail in the detection window: replays fire, replay energy accrues
    v_safe = float(accel.timing.min_safe_voltage().max())
    accel.set_rails(np.full(report.n_partitions, v_safe - 0.02))
    _, tel = accel.matmul(a, w)
    assert tel.replay_cycles > 0
    assert accel.ledger.replay_j > 0.0
    assert accel.ledger.replay_rate > 0.0


def test_energy_per_token_requires_token_attribution(report):
    accel = _nominal(report)
    rng = np.random.default_rng(6)
    accel.matmul(rng.normal(size=(8, 8)), rng.normal(size=(8, 8)))
    assert accel.ledger.energy_per_token_j is None      # no tokens yet
    accel.ledger.add_tokens(4)
    e = accel.ledger.energy_per_token_j
    assert e is not None and np.isfinite(e) and e > 0


def test_unknown_corruption_model_rejected():
    with pytest.raises(KeyError, match="unknown corruption"):
        get_corruption("nope")
