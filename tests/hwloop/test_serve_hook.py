"""ServeEngine x HwLoopSession: per-step flag + energy telemetry rides the
engine's EngineStats."""

import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.flow import FlowConfig
from repro.hwloop import HwLoopSession
from repro.models import model_api
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("starcoder2-3b", smoke=True)
    api = model_api(cfg)
    return cfg, api.init_params(jax.random.PRNGKey(0))


def test_engine_surfaces_hwloop_telemetry(dense):
    cfg, params = dense
    session = HwLoopSession(
        FlowConfig(array_n=8, tech="vtr-22nm", max_trials=8, seed=2021),
        probe_rows=8, rail_margin=0.02)
    eng = ServeEngine(cfg, params, slots=2, max_len=32, hwloop=session)
    reqs = [Request(uid=i, prompt=[3 + i, 4 + i], max_new_tokens=3)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.completed == 3

    # one emulated step per decode step, one flag vector per step
    assert len(stats.hwloop_step_flags) == stats.decode_steps
    assert all(len(f) == session.n_partitions
               for f in stats.hwloop_step_flags)
    # summary telemetry: energy attributed to the decode-step tokens
    hw = stats.hwloop
    assert hw is not None
    assert hw["steps"] == stats.decode_steps
    # each admission's first token comes from prefill logits, outside the
    # emulated decode loop; everything else is attributed to the ledger
    assert hw["tokens"] == stats.tokens_generated - stats.admitted
    e = hw["energy_per_token_j"]
    assert e is not None and np.isfinite(e) and e > 0
    assert len(hw["flag_rate"]) == session.n_partitions
    json.dumps(stats.to_dict())          # whole telemetry is plain JSON


def test_outputs_unchanged_by_emulation(dense):
    """The emulation observes the engine — it must not perturb decoding."""
    cfg, params = dense

    def drain(hwloop):
        eng = ServeEngine(cfg, params, slots=2, max_len=32, hwloop=hwloop)
        reqs = [Request(uid=i, prompt=[5 + i], max_new_tokens=3)
                for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        return [r.out_tokens for r in reqs]

    session = HwLoopSession(
        FlowConfig(array_n=8, tech="vtr-22nm", max_trials=8, seed=2021),
        probe_rows=8, rail_margin=0.02)
    assert drain(None) == drain(session)
