"""Invariant linter: per-rule fixtures (each trips its rule exactly once),
suppression syntax, baseline round-trip, and the repo-wide dogfood gate."""

from collections import Counter
from pathlib import Path

import pytest

from repro.analysis import (RULES, Finding, lint_file, lint_paths,
                            load_baseline, rule_codes, write_baseline)
from repro.analysis.checker import lint_source

FIXTURES = Path(__file__).parent / "fixtures"
REPO_ROOT = Path(__file__).resolve().parents[2]

CASES = [
    ("models/rp001_gemm.py", "RP001"),
    ("server/rp002_async.py", "RP002"),
    ("serve/rp003_clock.py", "RP003"),
    ("hwloop/rp004_random.py", "RP004"),
    ("common/rp005_mutable.py", "RP005"),
    ("kernels/rp006_blocks.py", "RP006"),
    ("serve/rp007_except.py", "RP007"),
    ("obs/rp008_print.py", "RP008"),
    ("railscale/rp009_rails.py", "RP009"),
]


@pytest.mark.parametrize("rel,code", CASES, ids=[c for _, c in CASES])
def test_fixture_trips_rule_exactly_once(rel, code):
    findings = lint_file(FIXTURES / rel, root=FIXTURES)
    assert [f.code for f in findings] == [code], findings
    f = findings[0]
    assert f.path == rel
    assert f.fix_hint                      # every rule ships a fix-hint
    assert f.line_text                     # baseline key is the source text


def test_clean_fixtures_stay_clean():
    for rel in ("models/rp001_einsum_clean.py", "models/suppressed.py"):
        assert lint_file(FIXTURES / rel, root=FIXTURES) == []


def test_inline_suppression_marker_on_line_above():
    src = (
        "import jax.numpy as jnp\n"
        "def f(x, p):\n"
        "    # lint: allow=RP001 reason lives here\n"
        "    return jnp.dot(x, p)\n"
    )
    assert lint_source(src, "models/x.py") == []
    # without the marker the same source trips
    assert [f.code for f in
            lint_source(src.replace("# lint: allow=RP001 reason lives here",
                                    "pass"), "models/x.py")] == ["RP001"]


def test_rule_scoping_by_path_segment():
    src = "import jax.numpy as jnp\ndef f(x, p):\n    return jnp.dot(x, p)\n"
    assert [f.code for f in lint_source(src, "models/a.py")] == ["RP001"]
    assert lint_source(src, "serve/a.py") == []   # RP001 scoped to models/


def test_baseline_roundtrip(tmp_path):
    all_findings = []
    for rel, _ in CASES:
        all_findings += lint_file(FIXTURES / rel, root=FIXTURES)
    baseline = tmp_path / "baseline.json"
    write_baseline(all_findings, baseline)

    loaded = load_baseline(baseline)
    assert sum(loaded.values()) == len(all_findings)

    fresh, absorbed = lint_paths(
        [FIXTURES / rel for rel, _ in CASES], root=FIXTURES,
        baseline_path=baseline)
    assert fresh == [] and absorbed == len(all_findings)

    # a brand-new violation is NOT absorbed
    extra = lint_source(
        "import jax.numpy as jnp\ndef g(a, b):\n    return jnp.matmul(a, b)\n",
        "models/new.py")
    assert [f.code for f in extra] == ["RP001"]
    assert load_baseline(baseline)[extra[0].key()] == 0


def test_baseline_counts_duplicates(tmp_path):
    f = Finding("RP001", "models/x.py", 3, 0, "m", "h", "y = jnp.dot(a, b)")
    twin = Finding("RP001", "models/x.py", 9, 0, "m", "h", "y = jnp.dot(a, b)")
    baseline = tmp_path / "b.json"
    write_baseline([f], baseline)
    from repro.analysis.findings import apply_baseline
    # same source text twice, only one budgeted -> second stays fresh
    assert apply_baseline([f, twin], load_baseline(baseline)) == [twin]


def test_rule_registry_complete():
    assert rule_codes() == [f"RP00{i}" for i in range(1, 10)]
    assert all(r.fix_hint and r.description for r in RULES)


def test_rp007_variants():
    # bare except is flagged regardless of what the body does
    bare = ("def f(q):\n"
            "    try:\n"
            "        return q.pop()\n"
            "    except:\n"
            "        return None\n")
    assert [f.code for f in lint_source(bare, "server/x.py")] == ["RP007"]
    # narrow-typed pass is the sanctioned client-went-away idiom
    narrow = ("def f(w):\n"
              "    try:\n"
              "        w.close()\n"
              "    except (ConnectionResetError, BrokenPipeError):\n"
              "        pass\n")
    assert lint_source(narrow, "server/x.py") == []
    # a broad except that HANDLES the fault (surfaces it) is fine
    handled = ("def f(q, log):\n"
               "    try:\n"
               "        return q.pop()\n"
               "    except Exception as e:\n"
               "        log.append(e)\n"
               "        raise\n")
    assert lint_source(handled, "serve/x.py") == []
    # ...but a pass-only broad except swallows it
    swallowed = ("def f(q):\n"
                 "    try:\n"
                 "        return q.pop()\n"
                 "    except BaseException:\n"
                 "        ...\n")
    assert [f.code for f in lint_source(swallowed, "hwloop/x.py")] == \
        ["RP007"]
    # out of scope: the rule only polices the serving/hardware path
    assert lint_source(swallowed, "models/x.py") == []


def test_repo_src_is_clean_under_checked_in_baseline():
    """The dogfood gate: src/repro must lint clean with the repo baseline
    (intentional exemptions are inline-suppressed, not baselined)."""
    fresh, _ = lint_paths([REPO_ROOT / "src"], root=REPO_ROOT,
                          baseline_path=REPO_ROOT / "lint_baseline.json")
    assert fresh == [], "\n".join(f.format() for f in fresh)
