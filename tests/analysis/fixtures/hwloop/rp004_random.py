"""Fixture: exactly one RP004 violation (unseeded global np.random draw);
the explicit-Generator idiom below is allowed."""

import numpy as np


def noisy(shape):
    return np.random.randn(*shape)


def seeded(shape, seed=0):
    return np.random.default_rng(seed).standard_normal(shape)
