"""Fixture: exactly one RP002 violation (jnp call inside an async handler)."""

import jax.numpy as jnp


async def handle(payload):
    return jnp.asarray(payload)
