"""Fixture: a real violation silenced by the inline allow marker."""

import jax.numpy as jnp


def ideal_only(x, p):
    return jnp.dot(x, p)  # lint: allow=RP001 fixture exemption
