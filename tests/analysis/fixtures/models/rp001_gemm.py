"""Fixture: exactly one RP001 violation (direct jnp.dot in models/)."""

import jax.numpy as jnp


def project(x, p):
    return jnp.dot(x, p)
