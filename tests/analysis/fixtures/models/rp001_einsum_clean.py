"""Fixture: activation-activation einsums must NOT trip RP001 (no
subscripted parameter operand), while a param-leaf einsum does elsewhere."""

import jax.numpy as jnp


def attention_scores(q, k):
    return jnp.einsum("bqhd,bkhd->bhqk", q, k)
