"""Fixture: exactly one RP003 violation (direct monotonic read); the
default-argument *reference* below is the allowed idiom and must not trip."""

import time


def stamp(clock=time.monotonic):
    return time.monotonic()
