"""RP007 fixture: a pass-only broad except in the serving path."""


def reap(queue):
    try:
        return queue.pop()
    except Exception:
        pass
