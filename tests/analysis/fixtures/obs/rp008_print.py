"""RP008 fixture: a bare print() in the observability path."""


def announce(event):
    print("flag burst:", event)
