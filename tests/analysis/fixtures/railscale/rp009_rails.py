"""RP009 fixture: a policy writing rails directly, skipping the clamp."""


def undervolt(session, target_v):
    session.set_rails(target_v)
    return target_v
