"""Fixture: exactly one RP005 violation (mutable default argument)."""


def accumulate(x, acc=[]):
    acc.append(x)
    return acc


def fine(x, acc=None):
    return (acc or []) + [x]
