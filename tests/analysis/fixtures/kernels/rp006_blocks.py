"""Fixture: exactly one RP006 violation (literal chunk default bypassing
the tuning tables); the None-defaulted twin is the allowed idiom."""


def bad_kernel(x, *, chunk=64):
    return x, chunk


def good_kernel(x, *, chunk=None):
    return x, chunk
