"""jaxpr census: smoke on two small configs with counts pinned to the
checked-in baseline, the ideal-backend zero-callback invariant, and the CI
gate's failure modes."""

import copy
import json
from pathlib import Path

import pytest

from repro.analysis import census_config, check_census

REPO_ROOT = Path(__file__).resolve().parents[2]
BASELINE = REPO_ROOT / "census_baseline.json"


@pytest.fixture(scope="module")
def baseline():
    return json.loads(BASELINE.read_text())


@pytest.mark.parametrize("arch", ["starcoder2-3b", "rwkv6-1.6b"])
def test_census_counts_stable_and_pinned(arch, baseline):
    report = census_config(arch, backend="reference")
    pinned = baseline["configs"][arch]
    for phase in ("prefill", "decode"):
        cur, base = report[phase], pinned[phase]
        if base is None:
            assert cur is None           # ssm: prompts absorbed via decode
            continue
        assert cur["pure_callbacks"] == base["pure_callbacks"], phase
        assert cur["dots"] == base["dots"], phase
        assert cur["flops"] == base["flops"], phase
        assert cur["dot_dtypes"] == base["dot_dtypes"], phase
    # reference routing really crosses to the host
    assert report["decode"]["pure_callbacks"] > 0
    assert report["decode"]["flops"] > 0


def test_ideal_backend_never_leaves_device():
    report = census_config("starcoder2-3b", backend="ideal")
    assert report["decode"]["pure_callbacks"] == 0
    assert report["prefill"]["pure_callbacks"] == 0
    assert report["decode"]["dots"] > 0  # the GEMMs are still there, on-device


def test_gate_passes_on_identical_census(baseline):
    assert check_census(baseline, baseline) == []


def test_gate_fails_on_new_host_roundtrip(baseline):
    worse = copy.deepcopy(baseline)
    cfg = worse["configs"]["starcoder2-3b"]["decode"]
    cfg["pure_callbacks"] += 1
    problems = check_census(worse, baseline)
    assert any("pure_callbacks rose" in p for p in problems)
    # a DROP is fine (that is ROADMAP item 1 succeeding)
    better = copy.deepcopy(baseline)
    better["configs"]["starcoder2-3b"]["decode"]["pure_callbacks"] = 0
    assert all("pure_callbacks" not in p
               for p in check_census(better, baseline))


def test_gate_fails_on_dot_census_drift(baseline):
    drifted = copy.deepcopy(baseline)
    drifted["configs"]["rwkv6-1.6b"]["decode"]["dots"] -= 1
    problems = check_census(drifted, baseline)
    assert any("dot count changed" in p for p in problems)


def test_gate_fails_on_missing_config(baseline):
    partial = copy.deepcopy(baseline)
    del partial["configs"]["starcoder2-3b"]
    problems = check_census(partial, baseline)
    assert any("missing from current census" in p for p in problems)
