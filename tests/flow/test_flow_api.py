"""repro.flow: config serialization, stage composition, caching, reports."""

import numpy as np
import pytest

from repro.core import quadrant_floorplan, run_flow
from repro.core.voltage import CalibrationResult, RuntimeScheme
from repro.flow import (Artifacts, ArtifactStore, FlowConfig, FunctionStage,
                        Pipeline, execute, get_stage, report_from, run)

CHEAP = dict(array_n=8, tech="vtr-22nm", max_trials=12, seed=2021)


# ---------------------------------------------------------------- config ----

def test_config_roundtrip_serialization():
    cfg = FlowConfig(array_n=32, tech="vtr-45nm", algo="meanshift",
                     n_clusters=None, max_trials=7,
                     algo_params={"bandwidth": 0.3})
    again = FlowConfig.from_dict(cfg.to_dict())
    assert again == cfg
    assert FlowConfig.from_json(cfg.to_json()) == cfg


def test_config_normalizes_algo_aliases():
    assert FlowConfig(algo="K-Means").algo == "kmeans"
    assert FlowConfig(algo="mean-shift").algo == "meanshift"


def test_config_validation_errors():
    with pytest.raises(ValueError, match="tech"):
        FlowConfig(tech="tsmc-3nm")
    with pytest.raises(ValueError, match="algorithm"):
        FlowConfig(algo="spectral")
    with pytest.raises(ValueError, match="array_n"):
        FlowConfig(array_n=0)
    with pytest.raises(ValueError, match="V_min"):
        FlowConfig(v_min=0.5, v_crash=0.9)
    with pytest.raises(ValueError, match="unknown FlowConfig fields"):
        FlowConfig.from_dict({"array": 16})


def test_config_replace_revalidates():
    cfg = FlowConfig()
    assert cfg.replace(algo="kmeans").algo == "kmeans"
    with pytest.raises(ValueError):
        cfg.replace(max_trials=-1)


# ------------------------------------------------------------- artifacts ----

def test_artifacts_are_append_only_and_raise_helpfully():
    art = Artifacts({"a": 1})
    art2 = art.with_(b=2)
    assert "b" not in art and art2["b"] == 2 and art2.a == 1
    with pytest.raises(KeyError, match="available"):
        art["missing"]
    with pytest.raises(AttributeError, match="available"):
        art.missing
    assert art2.delta_from(art) == {"b": 2}


# ------------------------------------------------------ pipeline parity -----

def test_pipeline_matches_run_flow_wrapper():
    """The deprecated monolith wrapper and the explicit pipeline must agree
    bit for bit (same seeds -> same voltages/power/constraints)."""
    old = run_flow(array_n=16, tech="vivado-28nm", algo="dbscan", seed=2021)
    cfg = FlowConfig(array_n=16, tech="vivado-28nm", algo="dbscan", seed=2021)
    new = report_from(Pipeline().run(cfg), cfg)
    np.testing.assert_array_equal(old.labels, new.labels)
    np.testing.assert_array_equal(old.static_v, new.static_v)
    np.testing.assert_array_equal(np.asarray(old.runtime_v),
                                  np.asarray(new.runtime_v))
    assert old.baseline_mw == new.baseline_mw
    assert old.static_mw == new.static_mw
    assert old.runtime_mw == new.runtime_mw
    assert old.xdc == new.xdc and old.sdc == new.sdc
    assert old.razor_trials == new.razor_trials


# ------------------------------------------------- composition: replace -----

def test_stage_replacement_quadrant_cluster():
    """Swap the clustering stage for a fixed quadrant partitioning; the rest
    of the flow runs unchanged on the injected labels."""
    def quadrant_labels(art, cfg):
        labels = quadrant_floorplan(cfg.array_n).partition_of_mac()
        return art.with_(labels=labels, n_partitions=4,
                         n_partitions_requested=4)

    pipe = Pipeline().replace("cluster", FunctionStage(
        "cluster", quadrant_labels, requires=("slack",),
        provides=("labels", "n_partitions", "n_partitions_requested")))
    cfg = FlowConfig(**CHEAP)
    rep = report_from(pipe.run(cfg), cfg)
    assert rep.n_partitions == 4
    np.testing.assert_array_equal(
        np.bincount(rep.labels), [16, 16, 16, 16])
    assert len(rep.static_v) == 4
    assert rep.xdc.count("create_pblock") == 4


def test_stage_insert_after():
    seen = {}

    def probe(art, cfg):
        seen["n"] = art.n_partitions
        return art

    pipe = Pipeline().insert_after("cluster", FunctionStage(
        "probe", probe, requires=("n_partitions",)))
    pipe.run(FlowConfig(**CHEAP))
    assert seen["n"] >= 1


# ----------------------------------------------------- composition: skip ----

def test_stage_skip_runtime_calibration():
    """Without the calibration stage the report falls back to the static
    scheme: runtime voltages/power mirror static, zero Razor trials."""
    cfg = FlowConfig(**CHEAP)
    pipe = Pipeline().without("runtime_calibration")
    rep = report_from(pipe.run(cfg), cfg)
    np.testing.assert_array_equal(np.asarray(rep.runtime_v), rep.static_v)
    assert rep.runtime_mw == rep.static_mw
    assert rep.razor_trials == 0
    assert rep.calibration_converged is None


def test_stage_skip_constraints():
    cfg = FlowConfig(**CHEAP)
    rep = report_from(Pipeline().without("constraints").run(cfg), cfg)
    assert rep.xdc == "" and rep.sdc == ""


def test_pipeline_check_rejects_broken_order():
    with pytest.raises(ValueError, match="requires"):
        Pipeline().without("cluster").run(FlowConfig(**CHEAP))


def test_stage_registry_constructs_by_name():
    assert get_stage("timing").name == "timing"
    with pytest.raises(KeyError, match="registered"):
        get_stage("nonsense")


# ------------------------------------------------------- prefix caching -----

def test_artifact_prefix_caching_shares_timing():
    """Two configs differing only in the clustering algorithm must reuse the
    cached timing stage (same (tech, array_n, clock_ns, seed) prefix)."""
    store = ArtifactStore()
    a = execute(FlowConfig(algo="kmeans", **CHEAP), store=store)
    b = execute(FlowConfig(algo="dbscan", **CHEAP), store=store)
    assert store.runs_of("timing") == 1
    assert store.stats["timing"].hits == 1
    assert a.timing_model is b.timing_model        # the very same object
    # a config change in the prefix invalidates it
    execute(FlowConfig(algo="kmeans", **{**CHEAP, "seed": 5}), store=store)
    assert store.runs_of("timing") == 2


def test_replaced_stage_does_not_reuse_default_stage_cache():
    """A replacement stage with the same name must not inherit the default
    stage's cached output (the store keys on implementation identity)."""
    store = ArtifactStore()
    cfg = FlowConfig(**CHEAP)
    Pipeline().run(cfg, store=store)

    def one_cluster(art, c):
        labels = np.zeros(c.array_n * c.array_n, dtype=np.int64)
        return art.with_(labels=labels, n_partitions=1,
                         n_partitions_requested=1)

    pipe = Pipeline().replace("cluster", FunctionStage(
        "cluster", one_cluster, requires=("slack",),
        provides=("labels", "n_partitions", "n_partitions_requested"),
        config_keys=("algo", "n_clusters", "seed", "algo_params")))
    art = pipe.run(cfg, store=store)
    assert art.n_partitions == 1                   # not the cached 4
    assert len(art.static_v) == 1                  # downstream invalidated too
    # the untouched timing prefix is still shared
    assert store.runs_of("timing") == 1


def test_initial_artifacts_bypass_store():
    """Runs seeded with initial artifacts must not serve cached outputs —
    the artifact contents are not part of the cache key."""
    store = ArtifactStore()
    double = FunctionStage("double", lambda a, c: a.with_(y=a.x * 2),
                           requires=("x",), provides=("y",))
    pipe = Pipeline([double])
    a = pipe.run(FlowConfig(), store=store, initial=Artifacts({"x": 1}))
    b = pipe.run(FlowConfig(), store=store, initial=Artifacts({"x": 21}))
    assert (a.y, b.y) == (2, 42)
    assert len(store) == 0


def test_cached_rerun_is_bitwise_identical():
    store = ArtifactStore()
    cfg = FlowConfig(**CHEAP)
    first = report_from(execute(cfg, store=store), cfg)
    second = report_from(execute(cfg, store=store), cfg)
    assert store.stats["power"].hits == 1
    np.testing.assert_array_equal(np.asarray(first.runtime_v),
                                  np.asarray(second.runtime_v))
    assert first.xdc == second.xdc


# ------------------------------------- satellite: requested vs actual P -----

def test_density_algorithms_surface_actual_partition_count():
    """meanshift/DBSCAN pick their own cluster count; the report now carries
    both the requested and the actual number instead of silently diverging."""
    cfg = FlowConfig(array_n=16, tech="vivado-28nm", algo="dbscan",
                     n_clusters=7, seed=2021)
    rep = run(cfg)
    assert rep.n_partitions_requested == 7
    assert rep.n_partitions != 7            # dbscan found its own bands
    assert f"req {rep.n_partitions_requested}" in rep.summary()
    # partition-count-dependent artifacts follow the *actual* count
    assert len(rep.static_v) == rep.n_partitions
    assert rep.xdc.count("create_pblock") == rep.n_partitions


def test_kmeans_honors_requested_partition_count():
    rep = run(FlowConfig(algo="kmeans", n_clusters=3, **CHEAP))
    assert rep.n_partitions_requested == 3
    assert rep.n_partitions == 3
    assert "req" not in rep.summary()


# --------------------------------- satellite: calibration converged flag ----

def test_calibrate_flags_partitions_without_clean_trials():
    scheme = RuntimeScheme(v_s=0.05, v_floor=0.5, v_ceil=1.0)
    out = scheme.calibrate(np.array([0.9, 0.9]),
                           lambda v: np.array([True, False]), max_trials=8)
    assert isinstance(out, CalibrationResult)
    np.testing.assert_array_equal(out.converged, [False, True])
    assert not out.all_converged
    assert out[0] == 1.0                    # pinned at v_ceil, but flagged


def test_calibrate_converged_when_clean():
    scheme = RuntimeScheme(v_s=0.05, v_floor=0.5, v_ceil=1.0)
    out = scheme.calibrate(np.array([0.9, 0.9]),
                           lambda v: np.zeros(2, dtype=bool), max_trials=32)
    assert out.all_converged
    np.testing.assert_allclose(np.asarray(out), 0.5)


def test_flow_report_carries_convergence():
    rep = run(FlowConfig(**CHEAP))
    assert rep.calibration_converged is not None
    assert rep.calibration_converged.shape == (rep.n_partitions,)
    assert rep.calibration_converged.dtype == bool
