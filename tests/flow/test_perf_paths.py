"""The perf plumbing added with the vectorized hot paths: FlowConfig impl /
calibration_method knobs, content-addressed stage caching, sweep timings,
batched bisection calibration, and the benchmark harness's output routing."""

import json

import numpy as np
import pytest

from repro.core.voltage import RuntimeScheme
from repro.flow import FlowConfig, Pipeline, run, sweep

CHEAP = dict(array_n=8, max_trials=12, seed=2021)


# ---------------------------------------------------------------- config ----

def test_config_validates_impl_and_method():
    assert FlowConfig(impl="reference").impl == "reference"
    assert FlowConfig(calibration_method="bisect").calibration_method == "bisect"
    with pytest.raises(ValueError, match="impl"):
        FlowConfig(impl="turbo")
    with pytest.raises(ValueError, match="calibration_method"):
        FlowConfig(calibration_method="newton")


def test_config_roundtrips_new_fields():
    cfg = FlowConfig(impl="reference", calibration_method="bisect", **CHEAP)
    assert FlowConfig.from_dict(cfg.to_dict()) == cfg


# ------------------------------------------------------- content caching ----

def test_sweep_shares_clustering_across_techs():
    """Min-slack structure is tech-independent, so with content caching the
    cluster stage runs once per algorithm, not once per (tech, algorithm)."""
    grid = {"tech": ["vivado-28nm", "vtr-22nm"], "algo": ["kmeans", "dbscan"]}
    res = sweep(grid, FlowConfig(**CHEAP))
    assert len(res.reports) == 4
    assert res.store.runs_of("cluster") == 2       # one per algo
    assert res.store.runs_of("timing") == 2        # still one per tech
    # floorplan keys on label *values*: both algos happen to agree at 8x8,
    # so it can even collapse to a single run
    assert 1 <= res.store.runs_of("floorplan") <= 2

    legacy = sweep(grid, FlowConfig(**CHEAP),
                   pipeline=Pipeline(content_cache=False))
    assert legacy.store.runs_of("cluster") == 4    # prefix keying: per tech
    for a, b in zip(res.reports, legacy.reports):
        np.testing.assert_array_equal(a.labels, b.labels)
        np.testing.assert_array_equal(np.asarray(a.runtime_v),
                                      np.asarray(b.runtime_v))


def test_pipeline_edits_preserve_content_cache_flag():
    p = Pipeline(content_cache=False)
    assert p.without("constraints").content_cache is False
    assert Pipeline().without("constraints").content_cache is True


def test_sweep_records_elapsed():
    res = sweep({"algo": ["kmeans", "dbscan"]}, FlowConfig(**CHEAP))
    assert len(res.elapsed_s) == 2
    assert res.total_elapsed_s == pytest.approx(sum(res.elapsed_s))
    assert all(t >= 0 for t in res.elapsed_s)


# ----------------------------------------------------- bisect calibration ----

def test_calibrate_bisect_converges_to_threshold():
    """With a deterministic threshold oracle, bisection must land each rail
    within tol above its true minimum safe voltage."""
    v_min_safe = np.array([0.62, 0.71, 0.85, 0.55])
    s = RuntimeScheme(v_s=0.05, v_floor=0.5, v_ceil=1.0)
    out = s.calibrate_bisect(np.full(4, 0.75),
                             lambda v: v < v_min_safe, max_trials=32,
                             tol=1e-4)
    assert out.all_converged
    assert (np.asarray(out) >= v_min_safe).all()
    assert (np.asarray(out) <= v_min_safe + 1e-3).all()


def test_calibrate_bisect_flags_unconvergeable_rails():
    s = RuntimeScheme(v_s=0.05, v_floor=0.5, v_ceil=1.0)
    always_fail = np.array([False, True])

    out = s.calibrate_bisect(np.full(2, 0.8),
                             lambda v: always_fail.copy(), max_trials=16)
    assert out.converged.tolist() == [True, False]
    assert float(out[1]) == 1.0                    # pinned at v_ceil


def test_flow_with_bisect_method_produces_safe_rails():
    rep_a = run(FlowConfig(calibration_method="anneal", **CHEAP))
    rep_b = run(FlowConfig(calibration_method="bisect", **CHEAP))
    assert rep_b.calibrated_fail_free
    # same partitioning; rails differ only by method resolution
    np.testing.assert_array_equal(rep_a.labels, rep_b.labels)
    assert np.asarray(rep_b.runtime_v).shape == np.asarray(rep_a.runtime_v).shape


# ------------------------------------------------------ benchmark routing ----

def test_benchmark_json_path_routing(tmp_path, monkeypatch):
    import benchmarks.run as br
    monkeypatch.setitem(br._OUT, "dir", str(tmp_path / "sub"))
    monkeypatch.setitem(br._OUT, "json_out", None)
    p = br._json_path("BENCH_x.json")
    assert p == str(tmp_path / "sub" / "BENCH_x.json")
    assert (tmp_path / "sub").is_dir()             # created on demand
    monkeypatch.setitem(br._OUT, "json_out", str(tmp_path / "exact.json"))
    assert br._json_path("BENCH_x.json") == str(tmp_path / "exact.json")


def test_bench_flow_payload_schema(tmp_path, monkeypatch):
    """Run the real flow benchmark once (fast) and validate the JSON gate
    fields CI depends on."""
    import benchmarks.run as br
    monkeypatch.setitem(br._OUT, "dir", str(tmp_path))
    monkeypatch.setitem(br._OUT, "json_out", None)
    rows = br.bench_flow(fast=True)
    assert any(name.startswith("flow/speedup") for name, _, _ in rows)
    payload = json.loads((tmp_path / "BENCH_flow.json").read_text())
    assert payload["configs"] == 16
    assert payload["bit_identical_reports"] is True
    assert payload["speedup"] > 1.0
    assert len(payload["vectorized"]["per_config_s"]) == 16
    assert payload["vectorized"]["cluster_stage_runs"] == 4
    assert payload["reference"]["cluster_stage_runs"] == 16
