"""repro.flow.sweep: grid expansion, shared-prefix caching, tidy tables, CLI."""

import numpy as np
import pytest

from repro.flow import FlowConfig, expand_grid, sweep
from repro.flow.__main__ import main as flow_main

BASE = FlowConfig(array_n=8, max_trials=12, seed=2021)


def test_expand_grid_product_and_order():
    cfgs = expand_grid({"tech": ["vivado-28nm", "vtr-22nm"],
                        "algo": ["kmeans", "dbscan"]}, BASE)
    assert len(cfgs) == 4
    # last axis varies fastest
    assert [(c.tech, c.algo) for c in cfgs] == [
        ("vivado-28nm", "kmeans"), ("vivado-28nm", "dbscan"),
        ("vtr-22nm", "kmeans"), ("vtr-22nm", "dbscan")]
    assert all(c.array_n == 8 for c in cfgs)


def test_expand_grid_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown FlowConfig field"):
        expand_grid({"technology": ["vtr-22nm"]})


def test_sweep_two_tech_two_algo_shares_timing():
    """Acceptance slice: >= 2 tech nodes x 2 algorithms in one process with
    the timing stage computed once per (tech, array_n, seed) triple."""
    res = sweep({"tech": ["vivado-28nm", "vtr-22nm"],
                 "algo": ["kmeans", "hierarchical"]}, BASE)
    assert len(res.reports) == 4
    assert res.timing_stage_runs() == 2            # once per tech
    assert res.store.stats["timing"].hits == 2
    rows = res.rows()
    assert {r["tech"] for r in rows} == {"vivado-28nm", "vtr-22nm"}
    # same tech + same labels -> identical static power across algorithms
    by_tech = {}
    for r in rows:
        by_tech.setdefault(r["tech"], set()).add(round(r["static_mw"], 9))
    for tech, vals in by_tech.items():
        assert len(vals) == 1, (tech, vals)


def test_sweep_full_grid_four_tech_four_algo():
    """Acceptance: the full 4 tech x 4 algorithm grid completes in one
    process with the timing stage computed once per (tech, array_n, seed)."""
    res = sweep({"tech": ["vivado-28nm", "vtr-22nm", "vtr-45nm", "vtr-130nm"],
                 "algo": ["kmeans", "hierarchical", "meanshift", "dbscan"]},
                BASE)
    assert len(res.reports) == 16
    assert res.timing_stage_runs() == 4
    assert all(r["calibrated_fail_free"] for r in res.rows())


def test_sweep_accepts_explicit_config_list():
    cfgs = [BASE, BASE.replace(algo="kmeans")]
    res = sweep(cfgs)
    assert [r.algo for r in res.reports] == ["dbscan", "kmeans"]
    assert res.timing_stage_runs() == 1


def test_sweep_table_renders_tidy_columns():
    res = sweep({"algo": ["kmeans", "dbscan"]}, BASE)
    table = res.table()
    lines = table.splitlines()
    assert "tech" in lines[0] and "runtime_reduction_pct" in lines[0]
    assert len(lines) == 2 + len(res.reports)      # header + rule + rows
    assert res.best()["runtime_reduction_pct"] == max(
        r["runtime_reduction_pct"] for r in res.rows())


def test_sweep_array_sizes_change_baseline():
    res = sweep({"array_n": [8, 16]}, BASE)
    rows = res.rows()
    assert rows[1]["baseline_mw"] > rows[0]["baseline_mw"]
    assert res.timing_stage_runs() == 2            # distinct prefix per size


# ----------------------------------------------------------------- CLI ------

def test_cli_run_smoke(capsys):
    rc = flow_main(["run", "--array-n", "8", "--tech", "vtr-22nm",
                    "--algo", "kmeans", "--max-trials", "8"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "8x8 vtr-22nm kmeans" in out
    assert "runtime V_ccint" in out and "power: baseline" in out


def test_cli_sweep_smoke(capsys):
    rc = flow_main(["sweep", "--tech", "vivado-28nm,vtr-22nm",
                    "--algo", "kmeans,dbscan", "--array-n", "8",
                    "--max-trials", "8"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "timing stage executed 2x" in out
    assert "best runtime reduction" in out


def test_cli_no_calibrate(capsys):
    rc = flow_main(["run", "--array-n", "8", "--no-calibrate"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "razor trials: 0" in out
