"""CalibrationWatchdog: flow-integrated voltage recalibration on Razor flags."""

import numpy as np
import pytest

from repro.flow import FlowConfig
from repro.runtime import CalibrationWatchdog


@pytest.fixture(scope="module")
def watchdog():
    return CalibrationWatchdog(
        FlowConfig(array_n=8, tech="vtr-22nm", max_trials=12, seed=2021),
        patience=2)


def test_watchdog_initial_calibration(watchdog):
    assert watchdog.runtime_v.shape == (watchdog.report.n_partitions,)
    assert watchdog.recalibrations == 0
    assert not watchdog.needs_recalibration().any()


def test_watchdog_recalibrates_on_persistent_flags(watchdog):
    p = watchdog.report.n_partitions
    clean = [False] * p
    noisy = [True] + [False] * (p - 1)
    assert watchdog.observe(clean) is None
    assert watchdog.observe(noisy) is None          # streak 1 < patience
    report = watchdog.observe(noisy)                # streak 2 -> recalibrate
    assert report is not None
    assert watchdog.recalibrations == 1
    # only the calibration suffix re-ran: the timing prefix stayed cached
    assert watchdog.store.runs_of("timing") == 1
    assert watchdog.store.runs_of("runtime_calibration") == 2


def test_watchdog_transient_flags_are_tolerated(watchdog):
    p = watchdog.report.n_partitions
    before = watchdog.recalibrations
    assert watchdog.observe([True] * p) is None     # one bad step
    assert watchdog.observe([False] * p) is None    # recovers -> streak reset
    assert watchdog.observe([True] * p) is None
    assert watchdog.recalibrations == before


def test_watchdog_rejects_wrong_flag_count(watchdog):
    with pytest.raises(ValueError, match="partition flags"):
        watchdog.observe([True])


def test_watchdog_unconverged_retries_are_bounded(monkeypatch):
    """A calibration that can never converge must not recalibrate on every
    clean serving step — retries are capped."""
    wd = CalibrationWatchdog(
        FlowConfig(array_n=8, tech="vtr-22nm", max_trials=12, seed=2021),
        patience=2, max_unconverged_retries=2)
    p = wd.report.n_partitions
    monkeypatch.setattr(
        type(wd), "needs_recalibration",
        lambda self: np.ones(self.report.n_partitions, dtype=bool))
    assert wd.observe([False] * p) is not None     # retry 1
    assert wd.observe([False] * p) is not None     # retry 2 (cap)
    assert wd.observe([False] * p) is None         # capped: no more re-runs
    assert wd.recalibrations == 2
    # persistent Razor failures still trigger, independent of the cap
    assert wd.observe([True] * p) is None
    assert wd.observe([True] * p) is not None


def test_recalibration_reuses_cached_upstream_artifacts():
    """End to end: persistent partition flags trigger a re-calibration that
    re-executes ONLY the calibration suffix — the timing / cluster /
    floorplan / static-voltage prefix must come back as cache hits from the
    shared artifact store."""
    wd = CalibrationWatchdog(
        FlowConfig(array_n=8, tech="vtr-22nm", max_trials=12, seed=2021),
        patience=1)
    p = wd.report.n_partitions
    # the initial flow populated the store: every stage ran exactly once
    for stage in ("timing", "cluster", "floorplan", "static_voltage",
                  "runtime_calibration", "power"):
        assert wd.store.runs_of(stage) == 1, stage
    baseline_hits = {s: wd.store.stats[s].hits
                     for s in ("timing", "cluster", "floorplan")}

    report = wd.observe([True] + [False] * (p - 1))   # patience 1 -> recal
    assert report is not None and wd.recalibrations == 1
    # prefix stages did NOT re-execute ...
    for stage in ("timing", "cluster", "floorplan", "static_voltage"):
        assert wd.store.runs_of(stage) == 1, stage
    # ... they were served from cache (hit counters advanced) ...
    for stage, before in baseline_hits.items():
        assert wd.store.stats[stage].hits > before, stage
    # ... and only the calibration suffix ran again
    assert wd.store.runs_of("runtime_calibration") == 2
    assert report.n_partitions == p
