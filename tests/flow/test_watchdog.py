"""CalibrationWatchdog: flow-integrated voltage recalibration on Razor flags."""

import numpy as np
import pytest

from repro.flow import FlowConfig
from repro.runtime import CalibrationWatchdog


@pytest.fixture(scope="module")
def watchdog():
    return CalibrationWatchdog(
        FlowConfig(array_n=8, tech="vtr-22nm", max_trials=12, seed=2021),
        patience=2)


def test_watchdog_initial_calibration(watchdog):
    assert watchdog.runtime_v.shape == (watchdog.report.n_partitions,)
    assert watchdog.recalibrations == 0
    assert not watchdog.needs_recalibration().any()


def test_watchdog_recalibrates_on_persistent_flags(watchdog):
    p = watchdog.report.n_partitions
    clean = [False] * p
    noisy = [True] + [False] * (p - 1)
    assert watchdog.observe(clean) is None
    assert watchdog.observe(noisy) is None          # streak 1 < patience
    report = watchdog.observe(noisy)                # streak 2 -> recalibrate
    assert report is not None
    assert watchdog.recalibrations == 1
    # only the calibration suffix re-ran: the timing prefix stayed cached
    assert watchdog.store.runs_of("timing") == 1
    assert watchdog.store.runs_of("runtime_calibration") == 2


def test_watchdog_transient_flags_are_tolerated(watchdog):
    p = watchdog.report.n_partitions
    before = watchdog.recalibrations
    assert watchdog.observe([True] * p) is None     # one bad step
    assert watchdog.observe([False] * p) is None    # recovers -> streak reset
    assert watchdog.observe([True] * p) is None
    assert watchdog.recalibrations == before


def test_watchdog_rejects_wrong_flag_count(watchdog):
    with pytest.raises(ValueError, match="partition flags"):
        watchdog.observe([True])


def test_watchdog_unconverged_retries_are_bounded(monkeypatch):
    """A calibration that can never converge must not recalibrate on every
    clean serving step — retries are capped."""
    wd = CalibrationWatchdog(
        FlowConfig(array_n=8, tech="vtr-22nm", max_trials=12, seed=2021),
        patience=2, max_unconverged_retries=2)
    p = wd.report.n_partitions
    monkeypatch.setattr(
        type(wd), "needs_recalibration",
        lambda self: np.ones(self.report.n_partitions, dtype=bool))
    assert wd.observe([False] * p) is not None     # retry 1
    assert wd.observe([False] * p) is not None     # retry 2 (cap)
    assert wd.observe([False] * p) is None         # capped: no more re-runs
    assert wd.recalibrations == 2
    # persistent Razor failures still trigger, independent of the cap
    assert wd.observe([True] * p) is None
    assert wd.observe([True] * p) is not None
