"""GuardedBackend: ABFT verification, single-element locate-and-correct,
the escalation ladder (retry -> rail heal -> policy), and the PR's
acceptance criterion — SILENT corruption at crash-region rails restored to
outputs bit-identical to the ideal backend."""

import numpy as np
import pytest

from repro.backend import EmulatedBackend, IdealBackend, get_backend
from repro.resilience import GuardedBackend, GuardError
from repro.resilience.chaos import V_CRASH

#: The parity-matrix shapes (tests/backend/test_parity.py) the acceptance
#: criterion is stated over.
SHAPES = [(8, 8, 8), (16, 24, 8), (12, 40, 20)]


def _int_ops(m, k, n, seed):
    """Integer-valued f32 operands: f64 checksums are exact, so a verified
    product is bit-identical to the ideal one."""
    rng = np.random.default_rng(seed)
    return (rng.integers(-4, 5, size=(m, k)).astype(np.float32),
            rng.integers(-4, 5, size=(k, n)).astype(np.float32))


def _crashed_guard(corruption="bitflip", **kw):
    guard = GuardedBackend(EmulatedBackend.nominal(corruption=corruption),
                           **kw)
    accel = guard.accel
    accel.set_rails(np.full(accel.n_partitions, V_CRASH))
    return guard


# ---- acceptance: bit-identical restoration under silent corruption ----------


@pytest.mark.parametrize("corruption", ["bitflip", "stale", "tedrop"])
@pytest.mark.parametrize("shape", SHAPES, ids=["%dx%dx%d" % s for s in SHAPES])
def test_guard_restores_bit_identical_outputs(corruption, shape):
    m, k, n = shape
    a, b = _int_ops(m, k, n, seed=m + k + n)
    ref, _ = IdealBackend().matmul(a, b)

    # the unguarded device at these rails really corrupts this product
    raw_be = EmulatedBackend.nominal(corruption=corruption)
    raw_be.accel.set_rails(np.full(raw_be.accel.n_partitions, V_CRASH))
    raw, _ = raw_be.matmul(a, b)
    assert not np.array_equal(np.asarray(raw), np.asarray(ref))

    # ...and the guard's ladder (detect -> retry -> heal) restores it
    guard = _crashed_guard(corruption=corruption)
    out, tel = guard.matmul(a, b)
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    assert tel.guard_detected >= 1
    assert tel.guard_heals == 1          # deterministic fault: heal required
    assert tel.guard_uncorrected == 0
    assert tel.calls == 1                # one protocol call despite re-runs
    # healed: rails are back at (or above) the crash region
    assert float(guard.accel.rails.min()) > V_CRASH


def test_heal_restores_nominal_rails_without_session():
    guard = _crashed_guard()
    a, b = _int_ops(8, 8, 8, seed=1)
    guard.matmul(a, b)
    v_nom = float(guard.accel.timing.tech.v_nom)
    assert np.allclose(guard.accel.rails, v_nom)


def test_heal_via_attached_session_watchdog():
    from repro.flow import FlowConfig
    from repro.hwloop import HwLoopSession

    session = HwLoopSession(
        FlowConfig(array_n=8, tech="vtr-22nm", max_trials=8, seed=2021),
        probe_rows=8, rail_margin=0.02, patience=2)
    guard = GuardedBackend(EmulatedBackend(session.accel), session=session)
    session.accel.set_rails(np.full(session.accel.rails.shape[0], V_CRASH))
    a, b = _int_ops(8, 8, 8, seed=2)
    ref, _ = IdealBackend().matmul(a, b)
    out, tel = guard.matmul(a, b)
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    assert tel.guard_heals == 1
    assert session.recalibrations >= 1   # healed THROUGH the watchdog
    assert float(session.accel.rails.min()) > V_CRASH


# ---- locate-and-correct -----------------------------------------------------


def _flaky_ideal(n_bad=1, delta=7.0, at=(2, 3)):
    """An ideal inner whose first ``n_bad`` executions corrupt one element —
    the single-element signature ABFT corrects without re-execution."""
    inner = IdealBackend()
    real = inner._execute
    calls = {"n": 0}

    def flaky(a, b):
        out, tel = real(a, b)
        out = np.asarray(out, dtype=np.float64).copy()
        calls["n"] += 1
        if calls["n"] <= n_bad:
            out[at] += delta
        return out, tel

    inner._execute = flaky
    return inner, calls


def test_abft_corrects_single_element_in_place():
    inner, calls = _flaky_ideal()
    guard = GuardedBackend(inner, mode="abft")
    a, b = _int_ops(8, 8, 8, seed=3)
    out, tel = guard.matmul(a, b)
    assert np.array_equal(np.asarray(out),
                          a.astype(np.float64) @ b.astype(np.float64))
    assert calls["n"] == 1               # corrected WITHOUT re-execution
    assert tel.guard_detected == 1
    assert tel.guard_corrected == 1
    assert tel.guard_retries == 0 and tel.guard_heals == 0


def test_freivalds_detects_and_recovers_by_retry():
    # detection-only mode cannot localize: it must re-execute instead
    inner, calls = _flaky_ideal()
    guard = GuardedBackend(inner, mode="freivalds")
    a, b = _int_ops(8, 8, 8, seed=4)
    out, tel = guard.matmul(a, b)
    assert np.array_equal(np.asarray(out),
                          a.astype(np.float64) @ b.astype(np.float64))
    assert calls["n"] == 2               # one retry cleared the transient
    assert tel.guard_detected == 1
    assert tel.guard_retries == 1 and tel.guard_corrected == 0


# ---- policy rungs -----------------------------------------------------------


def test_fail_closed_raises_on_unhealable_corruption():
    inner, _ = _flaky_ideal(n_bad=10 ** 9)          # corrupts forever
    guard = GuardedBackend(inner, mode="freivalds", max_retries=1,
                           heal=False, policy="fail_closed")
    a, b = _int_ops(8, 8, 8, seed=5)
    with pytest.raises(GuardError):
        guard.matmul(a, b)


def test_fail_open_returns_flagged_product():
    inner, _ = _flaky_ideal(n_bad=10 ** 9)
    guard = GuardedBackend(inner, mode="freivalds", max_retries=1,
                           heal=False, policy="fail_open")
    a, b = _int_ops(8, 8, 8, seed=6)
    out, tel = guard.matmul(a, b)
    assert tel.guard_uncorrected == 1    # honest telemetry about the escape
    assert not np.array_equal(np.asarray(out),
                              a.astype(np.float64) @ b.astype(np.float64))


def test_mode_off_is_transparent():
    guard = _crashed_guard(mode="off")
    a, b = _int_ops(8, 8, 8, seed=7)
    out, tel = guard.matmul(a, b)
    assert tel.guard_checks == 0 and tel.guard_detected == 0
    # pass-through: the corrupted product flows out unverified
    assert not np.array_equal(np.asarray(out),
                              a.astype(np.float64) @ b.astype(np.float64))


# ---- wiring -----------------------------------------------------------------


def test_constructor_validation_and_registry():
    with pytest.raises(ValueError):
        GuardedBackend(IdealBackend(), mode="checksum")
    with pytest.raises(ValueError):
        GuardedBackend(IdealBackend(), policy="retry")
    with pytest.raises(ValueError):
        GuardedBackend(IdealBackend(), max_retries=-1)
    be = get_backend("guarded")
    assert isinstance(be, GuardedBackend)
    assert be.is_guarded and not be.is_ideal
    assert be.name == "guarded[emulated]"
    assert be.summary()["mode"] == "abft"


def test_summary_surfaces_inner_energy_accounting():
    guard = GuardedBackend(EmulatedBackend.nominal())
    a, b = _int_ops(8, 8, 8, seed=8)
    guard.matmul(a, b)
    guard.add_tokens(1)
    s = guard.summary()
    assert s["inner"]["backend"] == "emulated"
    assert s["energy_per_token_j"] is not None
    assert s["energy_per_token_j"] > 0
