"""Chaos campaign harness: scenario registry, report aggregation, and two
end-to-end scenarios at reduced scale (one engine-level, one full-stack
HTTP) — the full six-scenario campaign runs in the resilience benchmark and
the CI resilience-smoke job."""

import json

import pytest

from repro.resilience import (ChaosReport, ScenarioResult, SCENARIOS,
                              run_campaign, run_scenario)


def test_scenario_registry():
    assert set(SCENARIOS) == {"silent_burst", "rail_droop", "watchdog_delay",
                              "slow_decode", "client_disconnect",
                              "overload_shed"}
    with pytest.raises(KeyError):
        run_scenario("rowhammer")


def test_report_aggregation_and_json():
    rep = ChaosReport(results=[
        ScenarioResult("a", ok=True, violations=[],
                       details={"crashed": 0, "corrupted_streams": 0}),
        ScenarioResult("b", ok=False, violations=["stream 1 corrupted"],
                       details={"crashed": 1, "corrupted_streams": 2}),
    ], elapsed_s=1.5)
    assert not rep.ok
    assert rep.crashes == 1 and rep.corrupted_streams == 2
    d = json.loads(json.dumps(rep.to_dict()))      # plain JSON
    assert d["ok"] is False and len(d["scenarios"]) == 2


def test_silent_burst_scenario_end_to_end():
    """Engine-level: repeated rail collapses into the silent region; the
    guard keeps every stream bit-clean and the per-step telemetry shows it."""
    res = run_scenario("silent_burst", fast=True, seed=0)
    assert res.ok, res.violations
    assert res.details["crashed"] == 0
    assert res.details["corrupted_streams"] == 0
    assert res.details["guard_detected"] >= 1
    assert res.details["guard_heals"] >= 1
    assert res.details["guard_uncorrected"] == 0
    assert res.details["guard_step_events"] >= 1


def test_overload_shed_scenario_end_to_end():
    """Full HTTP stack: bounded-queue shed with Retry-After, a retrying
    client that eventually lands, and balanced terminal accounting."""
    rep = run_campaign(fast=True, only=["overload_shed"])
    assert rep.ok, [r.violations for r in rep.results]
    d = rep.results[0].details
    assert d["shed"] >= 1
    assert d["shed"] + d["completed"] == d["requests"]
    assert rep.crashes == 0 and rep.corrupted_streams == 0
