"""Backend parity matrix: {ideal, reference, simulated, emulated@nominal}
x {f32, int8} x 3 shapes — bit-identity + telemetry invariants.

Bit-identity across execution machineries (XLA f32 dot, jnp oracles, f64
tiled cycle simulation, f64 tiled emulation) is only meaningful when the
result is independent of reduction order, so the matrix uses small
integer-valued operands: every partial product and sum is exactly
representable in both f32 and f64, making the exact product THE unique
answer every backend must hit bit for bit.  The int8 tier additionally
exercises the shared host quantizer/dequantizer.
"""

import numpy as np
import pytest

from repro.backend import BackendTelemetry, get_backend

BACKENDS = ("ideal", "reference", "simulated", "emulated")
#: (M, K, N): one array-aligned, one K/N-ragged vs the 8x8 array, one with
#: K and N spilling over multiple tiles non-uniformly.
SHAPES = ((8, 8, 8), (16, 24, 8), (12, 40, 20))


@pytest.fixture(scope="module")
def backends():
    # "simulated"/"emulated" resolve to nominal-rail 8x8 arrays (zero-fault
    # operating points); "emulated" still prices every MAC in its ledger
    return {name: get_backend(name) for name in BACKENDS}


def _int_valued(rng, shape):
    return rng.integers(-4, 5, size=shape).astype(np.float32)


@pytest.mark.parametrize("precision", ["f32", "int8"])
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "x".join(map(str, s)))
def test_backend_parity_matrix(backends, shape, precision):
    m, k, n = shape
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a = _int_valued(rng, (m, k))
    b = _int_valued(rng, (k, n))
    outs, tels = {}, {}
    for name, be in backends.items():
        out, tel = be.matmul(a, b, precision=precision)
        outs[name], tels[name] = np.asarray(out), tel
        assert outs[name].dtype == np.float32
        assert outs[name].shape == (m, n)

    # acceptance: nominal-rail emulated (and everything else) bit-identical
    # to ideal
    ref = outs["ideal"]
    for name in BACKENDS[1:]:
        assert np.array_equal(outs[name], ref), \
            f"{name} diverged from ideal at {shape} {precision}"

    # telemetry invariants: zero flags/replays/silent at nominal rails, the
    # full M*K*N MAC count attributed, energy only where a ledger exists
    for name, tel in tels.items():
        assert isinstance(tel, BackendTelemetry)
        assert tel.calls == 1
        assert tel.macs == m * k * n, name
        assert tel.flags == 0, name
        assert tel.replays == 0, name
        assert tel.silent == 0, name
        assert tel.rel_error == 0.0, name
        if tel.partition_flags is not None:
            assert not any(tel.partition_flags), name
    assert tels["emulated"].energy_j > 0          # ledger prices clean MACs
    assert tels["ideal"].energy_j == 0.0
    assert tels["reference"].energy_j == 0.0


def test_native_precision_parity(backends):
    """precision=None (the model-routing tier) keeps f32 inputs f32 and is
    bit-identical across backends on order-independent data."""
    rng = np.random.default_rng(7)
    a = _int_valued(rng, (16, 24))
    b = _int_valued(rng, (24, 8))
    ref, _ = backends["ideal"].matmul(a, b)
    for name in BACKENDS[1:]:
        out, _ = backends[name].matmul(a, b)
        assert out.dtype == np.float32
        assert np.array_equal(np.asarray(out), np.asarray(ref)), name


def test_undervolted_emulated_breaks_parity_and_reports_flags():
    """The parity guarantee is a *nominal-rail* property: dropping a rail
    into the Razor window raises flags/replays in the telemetry (and below
    it, silent corruption) — the emulated backend is not a no-op shim."""
    be = get_backend("emulated")
    v_safe = float(be.accel.timing.min_safe_voltage().max())
    be.accel.set_rails(np.full(be.accel.n_partitions, v_safe - 0.02))
    rng = np.random.default_rng(3)
    a = rng.normal(size=(32, 8))
    b = rng.normal(size=(8, 8))
    _, tel = be.matmul(a, b)
    assert tel.flags > 0 and tel.replays > 0
    assert any(tel.partition_flags)


def test_count_flags_false_suppresses_flag_telemetry():
    be = get_backend("emulated")
    v_safe = float(be.accel.timing.min_safe_voltage().max())
    be.accel.set_rails(np.full(be.accel.n_partitions, v_safe - 0.02))
    rng = np.random.default_rng(4)
    _, tel = be.matmul(rng.normal(size=(16, 8)), rng.normal(size=(8, 8)),
                       count_flags=False)
    assert tel.flags == 0 and tel.partition_flags is None
    assert tel.replays > 0            # the physics still happened
