"""ServeEngine x repro.backend: all decode GEMMs on the emulated
voltage-scaled array, per-step flag/energy telemetry in EngineStats, and the
hwloop session as a thin watchdog adapter over the real GEMM flags."""

import json

import jax
import numpy as np
import pytest

from repro.backend import get_backend
from repro.configs import get_config
from repro.models import model_api
from repro.serve import Request, ServeEngine

# Serving on the emulated backend routes every decode GEMM through
# jax.pure_callback.  On single-core hosts these tests used to deadlock (the
# callback ran on XLA's only compute thread and starved the jit'd decode
# step); the repo-wide conftest now forces a second virtual host device via
# ensure_host_callback_capacity(), so they run everywhere.


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("starcoder2-3b", smoke=True)
    api = model_api(cfg)
    return cfg, api.init_params(jax.random.PRNGKey(0))


def _drain(cfg, params, n_req=2, max_new=3, **engine_kw):
    eng = ServeEngine(cfg, params, slots=2, max_len=32, **engine_kw)
    reqs = [Request(uid=i, prompt=[3 + i, 4 + i], max_new_tokens=max_new)
            for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    return eng, eng.run_until_drained(), reqs


def test_emulated_backend_serves_all_decode_gemms(dense):
    cfg, params = dense
    be = get_backend("emulated")                 # nominal rails: zero flags
    eng, stats, reqs = _drain(cfg, params, backend=be)
    assert stats.completed == len(reqs)
    assert stats.backend == "emulated"
    # one flag vector per decode step, sized to the array's partitions
    assert len(stats.backend_step_flags) == stats.decode_steps
    assert all(len(f) == be.accel.n_partitions
               for f in stats.backend_step_flags)
    assert not any(any(f) for f in stats.backend_step_flags)
    bt = stats.backend_telemetry
    assert bt is not None and bt["backend"] == "emulated"
    # the decode GEMMs really ran on the accelerator: MACs + energy accrued
    assert bt["macs"] > 0 and bt["calls"] > 0
    assert bt["flags"] == 0 and bt["replays"] == 0
    # energy attributed to the decode-step tokens (prefill-emitted tokens are
    # outside the decode loop, as in the legacy hwloop accounting)
    assert bt["tokens"] == stats.tokens_generated - stats.admitted
    assert bt["energy_per_token_j"] is not None
    assert np.isfinite(bt["energy_per_token_j"])
    assert bt["energy_per_token_j"] > 0
    json.dumps(stats.to_dict())                  # telemetry is plain JSON


def test_ideal_backend_is_a_zero_overhead_passthrough(dense):
    """backend='ideal' must not change outputs vs no backend at all (the
    router lowers it to the native dot), and adds no telemetry."""
    cfg, params = dense
    _, stats_none, reqs_none = _drain(cfg, params)
    _, stats_ideal, reqs_ideal = _drain(cfg, params, backend="ideal")
    assert [r.out_tokens for r in reqs_none] == \
        [r.out_tokens for r in reqs_ideal]
    assert stats_ideal.backend == "ideal"
    assert stats_ideal.backend_step_flags == []
    assert stats_ideal.backend_telemetry is None
    assert stats_none.backend is None


def test_hwloop_session_becomes_thin_adapter_over_backend(dense):
    """With an emulated backend the session stops generating probe traffic:
    the real GEMM flags feed its watchdog, and a mid-serve undervolt of the
    SERVING device raises flags then heals through recalibration."""
    from repro.flow import FlowConfig
    from repro.hwloop import HwLoopSession

    cfg, params = dense
    session = HwLoopSession(
        FlowConfig(array_n=8, tech="vtr-22nm", max_trials=8, seed=2021),
        probe_rows=8, rail_margin=0.02, patience=2)
    from repro.backend import EmulatedBackend
    be = EmulatedBackend(session.accel)          # serve on the session's device
    eng, stats, _ = _drain(cfg, params, n_req=3, max_new=4,
                           backend=be, hwloop=session)
    # adapter mode: session steps == decode steps, and the hwloop step-flag
    # schema mirrors the backend's (no probe traffic ran)
    assert session.steps == stats.decode_steps
    assert stats.hwloop_step_flags == stats.backend_step_flags
    assert stats.hwloop is not None
    assert stats.hwloop["steps"] == stats.decode_steps

    # undervolt partition 0 below its safe point on the LIVE serving device
    v_safe = float(be.accel.timing.min_safe_voltage()
                   [be.accel._part_grid == 0].max())
    session.set_partition_voltage(0, v_safe - 0.02)
    eng2, stats2, _ = _drain(cfg, params, n_req=3, max_new=4,
                             backend=be, hwloop=session)
    flagged = [f[0] for f in stats2.backend_step_flags]
    assert any(flagged)                          # real GEMMs tripped Razor
    assert session.recalibrations >= 1           # watchdog healed the rails
    assert be.accel.rails[0] > v_safe - 0.02
