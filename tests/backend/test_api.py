"""repro.backend API: registry, scoping, and the traced model router."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backend as B


def test_registry_constructs_all_first_class_backends():
    assert set(B.available_backends()) >= {"ideal", "reference", "simulated",
                                           "emulated"}
    for name in ("ideal", "reference", "simulated", "emulated"):
        be = B.get_backend(name)
        assert isinstance(be, B.MatmulBackend)
        assert be.name == name
    assert B.get_backend("ideal").is_ideal
    assert not B.get_backend("emulated").is_ideal


def test_registry_unknown_name_and_instance_passthrough():
    with pytest.raises(KeyError, match="unknown backend"):
        B.get_backend("nope")
    be = B.get_backend("reference")
    assert B.get_backend(be) is be
    with pytest.raises(ValueError, match="keyword"):
        B.get_backend(be, array_n=8)


def test_registry_factory_kwargs():
    be = B.get_backend("emulated", array_n=4, tech="vtr-45nm")
    assert be.accel.timing.n == 4
    assert be.accel.timing.tech.name == "vtr-45nm"


def test_use_backend_scoping_and_set_default():
    assert B.current_backend().is_ideal                  # process default
    emu = B.get_backend("emulated")
    with B.use_backend(emu) as be:
        assert be is emu and B.current_backend() is emu
        with B.use_backend("reference"):
            assert B.current_backend().name == "reference"
        assert B.current_backend() is emu
    assert B.current_backend().is_ideal
    try:
        prev = B.set_default("reference")
        assert B.current_backend() is prev
    finally:
        B.set_default("ideal")
    assert B.current_backend().is_ideal


def test_router_ideal_is_native_dot():
    a = jnp.asarray(np.arange(12, dtype=np.float32).reshape(3, 4))
    b = jnp.asarray(np.arange(8, dtype=np.float32).reshape(4, 2))
    np.testing.assert_array_equal(np.asarray(B.matmul(a, b)),
                                  np.asarray(a @ b))


def test_router_reshapes_leading_dims_through_host_backend():
    rng = np.random.default_rng(0)
    a = rng.integers(-3, 4, size=(2, 5, 8)).astype(np.float32)
    w = rng.integers(-3, 4, size=(8, 6)).astype(np.float32)
    emu = B.get_backend("emulated")
    with B.use_backend(emu):
        out = B.matmul(jnp.asarray(a), jnp.asarray(w))
    assert out.shape == (2, 5, 6)
    np.testing.assert_array_equal(np.asarray(out), a @ w)
    assert emu.total.calls == 1 and emu.total.macs == 2 * 5 * 8 * 6


def test_router_under_jit_and_scan_accumulates_telemetry():
    """The emulated backend's host callback fires inside jit'd lax.scan —
    the shape of every routed model decode step."""
    rng = np.random.default_rng(1)
    x = rng.integers(-3, 4, size=(4, 8)).astype(np.float32)
    ws = rng.integers(-3, 4, size=(3, 8, 8)).astype(np.float32)
    emu = B.get_backend("emulated")

    with B.use_backend(emu):
        @jax.jit
        def fwd(x, ws):
            def body(c, w):
                return B.matmul(c, w), ()
            out, _ = jax.lax.scan(body, x, ws)
            return out

        out = np.asarray(fwd(jnp.asarray(x), jnp.asarray(ws)))
    expect = x
    for w in ws:
        expect = expect @ w
    np.testing.assert_array_equal(out, expect)
    assert emu.total.calls == 3                  # one host GEMM per layer
    tel = emu.pop_telemetry()
    assert tel.calls == 3 and tel.flags == 0
    assert emu.pop_telemetry().calls == 0        # drained


def test_grad_through_nonideal_backend_uses_ideal_path_vjp():
    """Training through an injected-fault forward: the backward pass is the
    exact straight-through gradient, so value_and_grad(api.loss) works for
    every backend and matches the ideal backend's gradient at nominal rails
    (order-independent data -> bit-comparable)."""
    rng = np.random.default_rng(5)
    x = rng.integers(-3, 4, size=(4, 8)).astype(np.float32)
    w = rng.integers(-3, 4, size=(8, 6)).astype(np.float32)

    def loss(w, x):
        return jnp.sum(B.matmul(jnp.asarray(x), w) ** 2)

    g_ideal = np.asarray(jax.grad(loss)(jnp.asarray(w), x))
    with B.use_backend("emulated"):
        val, g_emu = jax.value_and_grad(loss)(jnp.asarray(w), x)
    assert np.isfinite(float(val))
    np.testing.assert_array_equal(np.asarray(g_emu), g_ideal)


def test_pop_telemetry_splits_steps_but_keeps_totals():
    be = B.get_backend("reference")
    a = np.ones((4, 4), np.float32)
    be.matmul(a, a)
    first = be.pop_telemetry()
    assert first.calls == 1
    be.matmul(a, a)
    be.matmul(a, a)
    second = be.pop_telemetry()
    assert second.calls == 2
    assert be.total.calls == 3
    assert be.summary()["backend"] == "reference"
    assert be.summary()["calls"] == 3


def test_matmul_rejects_bad_shapes_and_precision():
    be = B.get_backend("reference")
    with pytest.raises(ValueError, match="matmul expects"):
        be.matmul(np.ones((2, 3)), np.ones((4, 2)))
    with pytest.raises(ValueError, match="precision"):
        be.matmul(np.ones((2, 3)), np.ones((3, 2)), precision="fp4")


def test_emulated_summary_carries_ledger_and_rails():
    be = B.get_backend("emulated")
    rng = np.random.default_rng(2)
    be.matmul(rng.normal(size=(8, 8)), rng.normal(size=(8, 8)))
    be.add_tokens(2)
    s = be.summary()
    assert s["backend"] == "emulated"
    assert len(s["rails_v"]) == be.accel.n_partitions
    assert s["tokens"] == 2
    assert s["energy_per_token_j"] > 0
    import json
    json.dumps(s)
