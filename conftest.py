"""Repo-wide pytest config.

Two pieces:

* Single-core CI hosts deadlock the ``pure_callback`` serving path (XLA's
  CPU client gets a one-thread pool there, and a host callback waiting on a
  jax array starves the enclosing jit'd step).
  ``ensure_host_callback_capacity`` injects
  ``--xla_force_host_platform_device_count=2`` into ``XLA_FLAGS`` before any
  test creates the CPU client, which gives the pool a second thread and
  makes the emulated/guarded serving tests runnable everywhere.

* The container does not ship ``hypothesis``; four test modules use it for
  property tests.  Rather than losing those modules' example-based tests to
  a collection error, install a minimal shim that skips ``@given`` tests
  when the real library is unavailable.
"""

import os
import sys
import types

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))

from repro.backend.base import ensure_host_callback_capacity  # noqa: E402

ensure_host_callback_capacity()

try:  # pragma: no cover - exercised only where hypothesis exists
    import hypothesis  # noqa: F401
except ImportError:
    import pytest

    def _strategy(*args, **kwargs):
        return None

    st = types.ModuleType("hypothesis.strategies")
    for _name in ("floats", "integers", "lists", "booleans", "sampled_from",
                  "just", "tuples", "text", "none", "one_of"):
        setattr(st, _name, _strategy)

    def _composite(fn):
        def build(*args, **kwargs):
            return None
        return build

    st.composite = _composite

    hyp = types.ModuleType("hypothesis")

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed; property test skipped")(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def assume(condition):
        return bool(condition)

    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
