"""Repo-wide pytest config.

The container does not ship ``hypothesis``; four test modules use it for
property tests.  Rather than losing those modules' example-based tests to a
collection error, install a minimal shim that skips ``@given`` tests when the
real library is unavailable.
"""

import sys
import types

try:  # pragma: no cover - exercised only where hypothesis exists
    import hypothesis  # noqa: F401
except ImportError:
    import pytest

    def _strategy(*args, **kwargs):
        return None

    st = types.ModuleType("hypothesis.strategies")
    for _name in ("floats", "integers", "lists", "booleans", "sampled_from",
                  "just", "tuples", "text", "none", "one_of"):
        setattr(st, _name, _strategy)

    def _composite(fn):
        def build(*args, **kwargs):
            return None
        return build

    st.composite = _composite

    hyp = types.ModuleType("hypothesis")

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed; property test skipped")(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def assume(condition):
        return bool(condition)

    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
