"""Hardware-in-the-loop quickstart: serve a smoke model on the emulated
voltage-scaled accelerator, undervolt one rail mid-serve, and watch the
Razor flags drive a live recalibration.

    PYTHONPATH=src python examples/hwloop_serve.py [--arch starcoder2-3b]

Walkthrough:
  1. the CAD flow (repro.flow) calibrates per-partition rails for an 8x8
     array on vtr-22nm;
  2. an HwLoopSession wraps those rails in an EmulatedAccelerator and a
     CalibrationWatchdog;
  3. the continuous-batching ServeEngine decodes real requests with the
     session attached — each decode step runs data-dependent probe traffic
     through the emulated array and accounts energy per token;
  4. we then undervolt partition 0 below its safe point and serve again:
     DETECTED flags fire, the watchdog re-runs the cached
     runtime_calibration stage, and the rails heal.
"""

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.flow import FlowConfig
from repro.hwloop import HwLoopSession
from repro.models import model_api
from repro.serve import Request, ServeEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="starcoder2-3b", choices=sorted(ARCHS))
ap.add_argument("--requests", type=int, default=4)
ap.add_argument("--max-new", type=int, default=5)
args = ap.parse_args()

cfg = get_config(args.arch, smoke=True)
api = model_api(cfg)
params = api.init_params(jax.random.PRNGKey(0))

flow_cfg = FlowConfig(array_n=8, tech="vtr-22nm", max_trials=12, seed=2021)
session = HwLoopSession(flow_cfg, probe_rows=8, rail_margin=0.02, patience=2)
print(f"calibrated rails: {np.round(session.rails, 3).tolist()}")


def serve_batch(tag):
    engine = ServeEngine(cfg, params, slots=2, max_len=48, hwloop=session)
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(3, cfg.vocab_size, rng.integers(2, 6)).tolist(),
            max_new_tokens=args.max_new))
    stats = engine.run_until_drained()
    hw = stats.hwloop
    rates = ", ".join(f"{x:.2f}" for x in hw["flag_rate"])
    e = hw["energy_per_token_j"]            # None when no decode step ran
    print(f"[{tag}] {stats.tokens_generated} tokens, flag rates [{rates}], "
          f"{hw['recalibrations']} recalibrations, "
          f"{'n/a' if e is None else f'{e:.3g}'} J/token, "
          f"replay rate {hw['replay_rate']:.2e}")


serve_batch("calibrated")

# undervolt partition 0 below its safe point: flags fire, the watchdog
# re-runs the (cached-prefix) calibration and restores safe rails mid-serve
v_safe = float(session.accel.timing.min_safe_voltage()
               [session.accel._part_grid == 0].max())
session.set_partition_voltage(0, v_safe - 0.02)
print(f"undervolting partition 0 to {v_safe - 0.02:.3f} V "
      f"(safe point {v_safe:.3f} V)")
serve_batch("undervolted")
print(f"healed rails: {np.round(session.rails, 3).tolist()}")
