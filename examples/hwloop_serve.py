"""Hardware-in-the-loop quickstart: serve a smoke model with ALL decode
GEMMs on the emulated voltage-scaled accelerator, undervolt one rail
mid-serve, and watch the real traffic's Razor flags drive a live
recalibration.

    PYTHONPATH=src python examples/hwloop_serve.py [--arch starcoder2-3b]
        [--backend emulated|probe]

Walkthrough (--backend emulated, the default):
  1. the CAD flow (repro.flow) calibrates per-partition rails for an 8x8
     array on vtr-22nm;
  2. an HwLoopSession wraps those rails in an EmulatedAccelerator and a
     CalibrationWatchdog, and an EmulatedBackend turns that same device
     into the serving execution target;
  3. the continuous-batching ServeEngine decodes real requests with
     backend=emulated — every dense GEMM of every decode step runs on the
     voltage-scaled array, with per-step flags and energy/token in
     EngineStats; the session rides along as a thin watchdog adapter over
     those real flags;
  4. we then undervolt partition 0 below its safe point and serve again:
     the REAL model traffic trips DETECTED flags, the watchdog re-runs the
     cached runtime_calibration stage, and the rails heal.

``--backend probe`` keeps the legacy side-channel mode: the engine decodes
on the ideal path and the session emulates per-step probe traffic instead.
"""

import argparse

import jax
import numpy as np

from repro.backend import EmulatedBackend
from repro.configs import ARCHS, get_config
from repro.flow import FlowConfig
from repro.hwloop import HwLoopSession
from repro.models import model_api
from repro.serve import Request, ServeEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="starcoder2-3b", choices=sorted(ARCHS))
ap.add_argument("--backend", default="emulated",
                choices=("emulated", "probe"),
                help="emulated: serve all decode GEMMs on the "
                     "voltage-scaled array; probe: legacy probe-traffic "
                     "side channel")
ap.add_argument("--requests", type=int, default=4)
ap.add_argument("--max-new", type=int, default=5)
args = ap.parse_args()

cfg = get_config(args.arch, smoke=True)
api = model_api(cfg)
params = api.init_params(jax.random.PRNGKey(0))

flow_cfg = FlowConfig(array_n=8, tech="vtr-22nm", max_trials=12, seed=2021)
session = HwLoopSession(flow_cfg, probe_rows=8, rail_margin=0.02, patience=2)
print(f"calibrated rails: {np.round(session.rails, 3).tolist()}")

# the session's calibrated device doubles as the serving backend: real
# decode GEMMs and watchdog healing share one set of rails
backend = EmulatedBackend(session.accel) if args.backend == "emulated" \
    else None


def serve_batch(tag):
    engine = ServeEngine(cfg, params, slots=2, max_len=48,
                         hwloop=session, backend=backend)
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(3, cfg.vocab_size, rng.integers(2, 6)).tolist(),
            max_new_tokens=args.max_new))
    stats = engine.run_until_drained()
    hw = stats.hwloop
    rates = ", ".join(f"{x:.2f}" for x in hw["flag_rate"])
    e = hw["energy_per_token_j"]            # None when no decode step ran
    line = (f"[{tag}] {stats.tokens_generated} tokens, flag rates [{rates}], "
            f"{hw['recalibrations']} recalibrations, "
            f"{'n/a' if e is None else f'{e:.3g}'} J/token, "
            f"replay rate {hw['replay_rate']:.2e}")
    if stats.backend_telemetry:
        bt = stats.backend_telemetry
        line += (f" | backend:{stats.backend} {bt['calls']} GEMMs, "
                 f"{bt['macs']} MACs, {bt['flags']} flags")
    print(line)


serve_batch("calibrated")

# undervolt partition 0 below its safe point: the serving traffic's own
# flags fire, the watchdog re-runs the (cached-prefix) calibration and
# restores safe rails mid-serve
v_safe = float(session.accel.timing.min_safe_voltage()
               [session.accel._part_grid == 0].max())
session.set_partition_voltage(0, v_safe - 0.02)
print(f"undervolting partition 0 to {v_safe - 0.02:.3f} V "
      f"(safe point {v_safe:.3f} V)")
serve_batch("undervolted")
print(f"healed rails: {np.round(session.rails, 3).tolist()}")
