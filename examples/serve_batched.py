"""End-to-end serving driver: batched requests through a (smoke-scale)
assigned architecture, with the paper's simulated accelerator power report
for the work performed.

    PYTHONPATH=src python examples/serve_batched.py [--arch starcoder2-3b]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.configs.base import ShapeConfig
from repro.core import model_for, run_flow
from repro.models import model_api
from repro.roofline.analytic import forward_flops
from repro.serve import Request, ServeEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="starcoder2-3b", choices=sorted(ARCHS))
ap.add_argument("--requests", type=int, default=6)
ap.add_argument("--max-new", type=int, default=6)
args = ap.parse_args()

cfg = get_config(args.arch, smoke=True)
api = model_api(cfg)
params = api.init_params(jax.random.PRNGKey(0))
engine = ServeEngine(cfg, params, slots=2, max_len=64)

rng = np.random.default_rng(0)
reqs = []
for uid in range(args.requests):
    prompt = rng.integers(3, cfg.vocab_size, rng.integers(2, 6)).tolist()
    r = Request(uid=uid, prompt=prompt, max_new_tokens=args.max_new)
    reqs.append(r)
    engine.submit(r)

t0 = time.time()
stats = engine.run_until_drained()
dt = time.time() - t0
print(f"served {stats.completed} requests / {stats.tokens_generated} tokens "
      f"in {stats.prefill_steps} prefill + {stats.decode_steps} decode model "
      f"steps, {dt:.1f}s")
for r in reqs[:3]:
    print(f"  req {r.uid}: {r.prompt} -> {r.out_tokens}")

# --- paper power model for the serving work just performed: token-positions
# processed = absorbed prompt tokens (batch-1 prefill) + 2 slots per batched
# decode step, each priced at the one-token batch-1 forward cost
per_tok_shape = ShapeConfig("serve", 64, 1, "decode")
prompt_toks = sum(len(r.prompt) for r in reqs)
macs = forward_flops(cfg, per_tok_shape) / 2 \
    * (prompt_toks + 2 * stats.decode_steps)
flow = run_flow(array_n=16, tech="vtr-22nm", algo="dbscan", seed=2021)
pm = model_for("vtr-22nm")
frac = np.bincount(flow.labels, minlength=flow.n_partitions) / flow.labels.size
base = pm.macs_energy_j(macs, [pm.tech.v_nom] * flow.n_partitions, frac)
tuned = pm.macs_energy_j(macs, flow.runtime_v, frac)
print(f"\nsimulated accelerator energy for this serving session "
      f"(paper's voltage-scaled partitioning, vtr-22nm):")
print(f"  nominal rails: {base * 1e3:.3f} mJ")
print(f"  calibrated voltage islands: {tuned * 1e3:.3f} mJ "
      f"({100 * (1 - tuned / base):.1f}% saved)")
