"""Overload a live streaming server with generated traffic — on the
voltage-scaled emulated array if asked.

Starts the ``repro.server`` asyncio frontend over a smoke-scale engine
(priority scheduling, bounded admission queue), generates a seeded traffic
trace (Poisson arrivals, heavy-tailed lengths, burst envelope) at a chosen
overload factor, fires it over real sockets with per-token streaming, and
prints the measured envelope: completion/shed split by priority tier, TTFT
percentiles, and SLO attainment — then drains gracefully.

    PYTHONPATH=src python examples/traffic_overload.py \
        [--backend emulated] [--overload 2.0] [--rate-scale 10]

``--backend emulated`` runs the CAD flow first and serves every GEMM on the
calibrated fault-injecting array (see README "Architecture: execution
backends"), so the overload envelope includes the emulated hardware's
energy/flag telemetry.
"""

import argparse
import asyncio
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model_api
from repro.serve import Priority, ServeEngine
from repro.server import (ServeFrontend, TrafficConfig, TrafficGenerator,
                          get_json, overload_rate_rps, stream_generate)

ap = argparse.ArgumentParser()
# phi4's smoke GEMM shapes stay tractable on the host-emulated backends;
# any arch works on --backend ideal
ap.add_argument("--arch", default="phi4-mini-3.8b")
ap.add_argument("--backend", default="ideal",
                choices=("ideal", "reference", "simulated", "emulated"))
ap.add_argument("--overload", type=float, default=2.0,
                help="offered load as a multiple of serving capacity")
ap.add_argument("--duration", type=float, default=2.0,
                help="trace horizon in trace-seconds")
ap.add_argument("--rate-scale", type=float, default=10.0,
                help="replay speed-up: trace-seconds / rate-scale = wall")
ap.add_argument("--slots", type=int, default=2)
ap.add_argument("--max-pending", type=int, default=4)
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

cfg = get_config(args.arch, smoke=True)
params = model_api(cfg).init_params(jax.random.PRNGKey(args.seed))

engine_kw = {}
if args.backend == "emulated":
    from repro.backend import EmulatedBackend
    from repro.flow import FlowConfig
    from repro.flow import run as flow_run
    fcfg = FlowConfig(array_n=8, tech="vtr-22nm", max_trials=8, seed=2021)
    engine_kw["backend"] = EmulatedBackend.from_flow(flow_run(fcfg), fcfg)
elif args.backend != "ideal":
    from repro.backend import get_backend
    engine_kw["backend"] = get_backend(args.backend)

engine = ServeEngine(cfg, params, slots=args.slots, max_len=48,
                     policy="priority", max_pending=args.max_pending,
                     **engine_kw)

tcfg = TrafficConfig(
    rate_rps=overload_rate_rps(args.overload, args.slots, 0.05,
                               TrafficConfig()),
    duration_s=args.duration, seed=args.seed, diurnal_amplitude=0.6,
    diurnal_period_s=args.duration, max_prompt_len=8, max_gen_len=10,
    vocab_size=cfg.vocab_size)
events = TrafficGenerator(tcfg).events()
print(f"offered load: {len(events)} requests over {args.duration}s "
      f"({args.overload}x capacity, backend={args.backend})")


async def drive():
    frontend = ServeFrontend(engine)
    host, port = await frontend.start()
    t0 = time.perf_counter()

    async def fire(ev):
        await asyncio.sleep(ev.t_s / args.rate_scale)
        res = await stream_generate(
            host, port, ev.prompt, max_new_tokens=ev.max_new_tokens,
            priority=ev.priority.name.lower(), deadline_s=ev.deadline_s)
        return ev, res

    results = await asyncio.gather(*[fire(ev) for ev in events])
    health = await get_json(host, port, "/healthz")
    drained = await frontend.drain()
    await frontend.close()
    wall = time.perf_counter() - t0

    by_tier = {p.name: {"completed": 0, "shed": 0} for p in Priority}
    ttfts, met, slo = [], 0, 0
    for ev, res in results:
        tier = by_tier[ev.priority.name]
        if res.status == "completed":
            tier["completed"] += 1
        elif res.status == "shed":
            tier["shed"] += 1
        if res.summary.get("ttft_s") is not None:
            ttfts.append(res.summary["ttft_s"])
        if ev.deadline_s is not None and res.status != "shed":
            slo += 1
            met += bool(res.summary.get("deadline_met"))
    ttfts.sort()
    p50 = f"{1e3 * np.percentile(ttfts, 50):.0f}ms" if ttfts else "n/a"
    p99 = f"{1e3 * np.percentile(ttfts, 99):.0f}ms" if ttfts else "n/a"
    print(f"per tier: {by_tier}")
    print(f"TTFT p50 {p50} / p99 {p99}; SLO met {met}/{slo}; "
          f"shed_rate {health['shed_rate']:.2f}; "
          f"{health['tokens_generated']} tokens in {wall:.1f}s wall; "
          f"drained={drained}")
    bt = engine.stats.backend_telemetry or (
        engine.backend.summary() if engine.backend is not None else None)
    if bt:
        e = bt.get("energy_per_token_j")
        print(f"[backend:{engine.stats.backend}] {bt['calls']} GEMMs, "
              f"{bt['flags']} flags, {bt['replays']} replays, "
              f"{'n/a' if e is None else f'{e:.3g}'} J/token")


asyncio.run(drive())
