"""Beyond-paper example: the voltage-island control loop running on MXU
precision tiers (DESIGN.md Sec. 2b), expressed as a *custom* repro.flow
pipeline — the same Stage/Artifacts machinery that runs the paper's CAD
flow, with every step swapped for its precision analogue: headroom
extraction ~ timing, static tier assignment ~ Algorithm 1, Razor shadow
flags + calibration ~ Algorithm 2, and an energy report.

    PYTHONPATH=src python examples/precision_islands.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import (PrecisionController, energy_ratio,
                                  static_tier_assignment, tier_names,
                                  tile_headroom)
from repro.flow import Artifacts, FunctionStage, Pipeline
from repro.kernels.ops import precision_mm, razor_mm


@dataclasses.dataclass(frozen=True)
class IslandConfig:
    """Config for the precision-island pipeline (any object works — stages
    only read the fields they declare)."""
    block: int = 128
    tol: float = 0.02


rng = jax.random.PRNGKey(0)
k1, k2 = jax.random.split(rng)
M = K = N = 256
a = jax.random.normal(k1, (M, K), jnp.bfloat16)
w = jax.random.normal(k2, (K, N), jnp.float32)
# give one weight tile heavy outliers (low quantization headroom)
w = w.at[0, 128:].mul(40.0)
w = w.astype(jnp.bfloat16)


# 1. "timing extraction": per-tile quantization headroom == min slack
def extract_headroom(art: Artifacts, cfg: IslandConfig) -> Artifacts:
    head = tile_headroom(np.asarray(art.weights, np.float32), tile=cfg.block)
    return art.with_(headroom=head)


# 2. Algorithm-1 analogue: band headroom -> static tiers
def assign_static_tiers(art: Artifacts, cfg: IslandConfig) -> Artifacts:
    gm, gn = M // cfg.block, N // cfg.block
    tiers = np.zeros((gm, gn), np.int64)
    tiers[:] = static_tier_assignment(
        np.broadcast_to(art.headroom.mean(0), (gm, gn)))
    return art.with_(static_tiers=tiers)


# 3+4. Algorithm-2 analogue: Razor shadow flags drive tier calibration
def calibrate_tiers(art: Artifacts, cfg: IslandConfig) -> Artifacts:
    _, flags, _ = razor_mm(art.activations, art.weights, tol=cfg.tol)
    ctrl = PrecisionController()

    def trial(t):
        _, f, _ = razor_mm(art.activations, art.weights, tol=cfg.tol)
        # a tile flags iff it's running below the tier its headroom needs
        need = np.where(np.asarray(f) > 0, 2, 0)
        return t < need

    calibrated = ctrl.calibrate(art.static_tiers, trial)
    return art.with_(razor_flags=np.asarray(flags), tiers=calibrated)


# 5. execute on the precision-island kernel + energy report
def execute_and_report(art: Artifacts, cfg: IslandConfig) -> Artifacts:
    c = precision_mm(art.activations, art.weights,
                     jnp.asarray(art.tiers, jnp.int32))
    exact = (np.asarray(art.activations, np.float32)
             @ np.asarray(art.weights, np.float32))
    err = np.linalg.norm(np.asarray(c) - exact) / np.linalg.norm(exact)
    return art.with_(product=c, rel_error=err,
                     energy_vs_bf16=energy_ratio(art.tiers),
                     static_energy_vs_bf16=energy_ratio(art.static_tiers))


pipe = Pipeline([
    FunctionStage("headroom", extract_headroom,
                  requires=("weights",), provides=("headroom",)),
    FunctionStage("static_tiers", assign_static_tiers,
                  requires=("headroom",), provides=("static_tiers",)),
    FunctionStage("calibrate", calibrate_tiers,
                  requires=("activations", "weights", "static_tiers"),
                  provides=("razor_flags", "tiers")),
    FunctionStage("execute", execute_and_report,
                  requires=("activations", "weights", "tiers"),
                  provides=("product", "rel_error", "energy_vs_bf16")),
])
print("custom pipeline:", [s.name for s in pipe.stages])

art = pipe.run(IslandConfig(block=128, tol=0.02),
               initial=Artifacts({"activations": a, "weights": w}))

print("tile headroom (higher = more slack):\n", art.headroom.round(2))
print("static tiers:\n", tier_names(art.static_tiers))
print("razor mismatch flags:\n", art.razor_flags)
print("calibrated tiers:\n", tier_names(art.tiers))
print(f"\nresult rel-error vs f32: {art.rel_error:.4f}")
print(f"energy vs all-bf16: {art.energy_vs_bf16:.2f}x "
      f"(static would be {art.static_energy_vs_bf16:.2f}x, "
      f"all-bf16 = 1.00x)")
