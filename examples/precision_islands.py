"""Beyond-paper example: the voltage-island control loop running on MXU
precision tiers (DESIGN.md Sec. 2b) — static assignment from weight-tile
headroom, Razor-style shadow flags, Algorithm-2 calibration, energy report.

    PYTHONPATH=src python examples/precision_islands.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import (PrecisionController, energy_ratio,
                                  static_tier_assignment, tier_names,
                                  tile_headroom)
from repro.kernels.ops import precision_mm, razor_mm

rng = jax.random.PRNGKey(0)
k1, k2 = jax.random.split(rng)
M = K = N = 256
BLK = 128
a = jax.random.normal(k1, (M, K), jnp.bfloat16)
w = jax.random.normal(k2, (K, N), jnp.float32)
# give one weight tile heavy outliers (low quantization headroom)
w = w.at[0, 128:].mul(40.0)
w = w.astype(jnp.bfloat16)

# 1. "timing extraction": per-tile quantization headroom == min slack
head = tile_headroom(np.asarray(w, np.float32), tile=BLK)
print("tile headroom (higher = more slack):\n", head.round(2))

# 2. Algorithm-1 analogue: band headroom -> static tiers
gm, gn = M // BLK, N // BLK
tiers = np.zeros((gm, gn), np.int64)
tiers[:] = static_tier_assignment(np.broadcast_to(head.mean(0), (gm, gn)))
print("static tiers:\n", tier_names(tiers))

# 3. Razor shadow flags on the int8 main path
_, flags, rel = razor_mm(a, w, tol=0.02)
print("razor mismatch flags:\n", np.asarray(flags))

# 4. Algorithm-2 calibration driven by shadow flags
ctrl = PrecisionController()


def trial(t):
    _, f, _ = razor_mm(a, w, tol=0.02)
    # a tile flags iff it's running below the tier its headroom needs
    need = np.where(np.asarray(f) > 0, 2, 0)
    return t < need


calibrated = ctrl.calibrate(tiers, trial)
print("calibrated tiers:\n", tier_names(calibrated))

# 5. execute on the precision-island kernel + energy
c = precision_mm(a, w, jnp.asarray(calibrated, jnp.int32))
exact = np.asarray(a, np.float32) @ np.asarray(w, np.float32)
err = np.linalg.norm(np.asarray(c) - exact) / np.linalg.norm(exact)
print(f"\nresult rel-error vs f32: {err:.4f}")
print(f"energy vs all-bf16: {energy_ratio(calibrated):.2f}x "
      f"(static would be {energy_ratio(tiers):.2f}x, "
      f"all-bf16 = 1.00x)")
