"""End-to-end training driver: a ~100M-parameter decoder LM for a few hundred
steps on CPU, with checkpoint/restart and the heartbeat monitor attached.

Default scale keeps a single-core CPU run tolerable (~20M params, 100 steps);
pass --d-model 768 --layers 12 --steps 300 for the full ~100M x 300-step run.

    PYTHONPATH=src python examples/train_lm.py [--steps 100]
"""

import argparse
import dataclasses

from repro import optim
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.runtime import HeartbeatMonitor
from repro.train import TrainConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=100)
ap.add_argument("--d-model", type=int, default=256)
ap.add_argument("--layers", type=int, default=4)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

cfg = dataclasses.replace(
    get_config("phi4-mini-3.8b", smoke=True),
    n_layers=args.layers, d_model=args.d_model,
    n_heads=args.d_model // 64, n_kv_heads=max(args.d_model // 128, 1),
    d_head=64, d_ff=4 * args.d_model, vocab_size=8192,
    attn_chunk=64, loss_chunk=64)

n_params = (cfg.vocab_size * cfg.d_model
            + cfg.n_layers * (2 * cfg.d_model * cfg.q_dim
                              + 2 * cfg.d_model * cfg.kv_dim
                              + 3 * cfg.d_model * cfg.d_ff))
print(f"training {n_params / 1e6:.1f}M-param decoder LM "
      f"({cfg.n_layers}L d={cfg.d_model}) for {args.steps} steps")

monitor = HeartbeatMonitor(num_hosts=1)
res = train(cfg,
            ShapeConfig("example", args.seq, args.batch, "train"),
            TrainConfig(steps=args.steps, log_every=10,
                        checkpoint_every=max(args.steps // 4, 1),
                        checkpoint_dir=args.checkpoint_dir),
            optim.AdamWConfig(lr=3e-3, warmup_steps=max(args.steps // 10, 1),
                              total_steps=args.steps),
            monitor=monitor)

print(f"\nloss {res.losses[0]:.4f} -> {res.losses[-1]:.4f} over "
      f"{res.steps_done} steps ({res.wall_s:.1f}s); stragglers: "
      f"{[r.host_id for r in monitor.stragglers()]}")
print(f"checkpoints in {args.checkpoint_dir} (resume with the same command)")
