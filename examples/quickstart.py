"""Quickstart: the paper's full CAD flow on the staged repro.flow pipeline —
config -> pipeline -> report, then a multi-scenario sweep.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import TimingModel, render_report_table
from repro.flow import ArtifactStore, FlowConfig, Pipeline, report_from, sweep

# --- the paper's pipeline (Fig. 9) as a declarative config + stage chain:
#     synthesis timing -> DBSCAN clustering of per-MAC min-slack -> floorplan
#     -> Algorithm 1 (static V_ccint) -> Algorithm 2 (Razor runtime
#     calibration) -> power report + constraint files
cfg = FlowConfig(array_n=16, tech="vivado-28nm", algo="dbscan", seed=2021)
pipe = Pipeline()                      # the default Fig. 9 stage chain
print("stages:", [s.name for s in pipe.stages])

store = ArtifactStore()                # caches stage outputs across runs
artifacts = pipe.run(cfg, store=store)
report = report_from(artifacts, cfg)
print(report.summary())
print()

# --- what the synthesis 'timing report' looks like (paper Table I)
tm = TimingModel(n=16, seed=2021)
print("worst 5 synthesis paths (cf. paper Table I):")
print(render_report_table(tm.report(5)))
print()

# --- the voltages the two schemes chose
print("static  V_ccint per partition:", np.round(report.static_v, 4))
print("runtime V_ccint per partition:", np.round(report.runtime_v, 4))
print(f"razor trial runs used: {report.razor_trials}; "
      f"fail-free after calibration: {report.calibrated_fail_free}; "
      f"converged: {report.calibration_converged.tolist()}")
print()

# --- the constraint artifact the flow hands to the vendor tool
print("first 6 lines of the generated XDC:")
print("\n".join(report.xdc.splitlines()[:6]))
print()

# --- power outcome (paper Table II row: 16x16 Artix-7)
print(f"power: baseline {report.baseline_mw:.0f} mW -> static "
      f"{report.static_mw:.0f} mW ({report.static_reduction_pct:.2f}% saved, "
      f"paper reports 6.37%) -> runtime {report.runtime_mw:.0f} mW "
      f"({report.runtime_reduction_pct:.2f}%)")
print()

# --- sweep two tech nodes x two algorithms; the shared store means the
#     timing stage runs once per tech, not once per config
result = sweep({"tech": ["vivado-28nm", "vtr-22nm"],
                "algo": ["kmeans", "dbscan"]}, cfg, store=store)
print(result.table(columns=("tech", "algo", "n_partitions",
                            "static_reduction_pct", "runtime_reduction_pct")))
print(f"(timing stage executed {result.timing_stage_runs()}x "
      f"for {len(result.configs)} configs)")
