"""Quickstart: the paper's full CAD flow in five lines, then a look inside.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import run_flow, render_report_table, TimingModel

# --- the paper's pipeline (Fig. 9): synthesis timing -> DBSCAN clustering of
#     per-MAC min-slack -> floorplan -> Algorithm 1 (static V_ccint) ->
#     Algorithm 2 (Razor runtime calibration) -> power report
report = run_flow(array_n=16, tech="vivado-28nm", algo="dbscan", seed=2021)
print(report.summary())
print()

# --- what the synthesis 'timing report' looks like (paper Table I)
tm = TimingModel(n=16, seed=2021)
print("worst 5 synthesis paths (cf. paper Table I):")
print(render_report_table(tm.report(5)))
print()

# --- the voltages the two schemes chose
print("static  V_ccint per partition:", np.round(report.static_v, 4))
print("runtime V_ccint per partition:", np.round(report.runtime_v, 4))
print(f"razor trial runs used: {report.razor_trials}; "
      f"fail-free after calibration: {report.calibrated_fail_free}")
print()

# --- the constraint artifact the flow hands to the vendor tool
print("first 6 lines of the generated XDC:")
print("\n".join(report.xdc.splitlines()[:6]))
print()

# --- power outcome (paper Table II row: 16x16 Artix-7)
print(f"power: baseline {report.baseline_mw:.0f} mW -> static "
      f"{report.static_mw:.0f} mW ({report.static_reduction_pct:.2f}% saved, "
      f"paper reports 6.37%) -> runtime {report.runtime_mw:.0f} mW "
      f"({report.runtime_reduction_pct:.2f}%)")
