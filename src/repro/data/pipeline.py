"""Deterministic synthetic data pipeline.

Stateless-hash token generation keyed by (seed, host, step): after a restart
(or an elastic remap onto fewer hosts) the pipeline replays bit-identically —
the property the fault-tolerance tests assert (DESIGN.md Sec. 7).

Features: document sampling + packing to fixed seq_len with EOS boundaries,
per-data-shard slicing of the global batch, background prefetch thread.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

EOS = 1


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 17
    mean_doc_len: int = 512
    frontend: Optional[str] = None     # vision | audio | None
    frontend_tokens: int = 0
    d_model: int = 0
    enc_frames_ratio: int = 4


def _hash_u64(x: np.ndarray) -> np.ndarray:
    """splitmix64 — cheap stateless PRNG."""
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def _tokens_for(cfg: DataConfig, sample_ids: np.ndarray) -> np.ndarray:
    """(n, seq_len) packed token ids for global sample indices."""
    n = len(sample_ids)
    s = cfg.seq_len
    pos = np.arange(s, dtype=np.uint64)[None, :]
    base = (sample_ids.astype(np.uint64)[:, None] * np.uint64(1_000_003)
            + np.uint64(cfg.seed) * np.uint64(0x51F1))
    h = _hash_u64(base + pos)
    toks = (h % np.uint64(max(cfg.vocab_size - 2, 1))).astype(np.int64) + 2
    # deterministic document boundaries -> EOS markers (packing)
    doc_h = _hash_u64(base + pos + np.uint64(0xABCDEF))
    eos_mask = (doc_h % np.uint64(cfg.mean_doc_len)) == 0
    toks[eos_mask] = EOS
    return toks


@dataclasses.dataclass
class Batch:
    step: int
    data: Dict[str, np.ndarray]


class SyntheticDataset:
    """Sharded deterministic stream: host ``shard`` of ``num_shards`` sees
    rows [shard * per_shard, (shard+1) * per_shard) of each global batch."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        if cfg.global_batch % num_shards:
            raise ValueError("global batch must divide across shards")
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.per_shard = cfg.global_batch // num_shards

    def batch_at(self, step: int) -> Batch:
        cfg = self.cfg
        start = step * cfg.global_batch + self.shard * self.per_shard
        ids = np.arange(start, start + self.per_shard, dtype=np.int64)
        toks = _tokens_for(cfg, ids)
        data: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1].astype(np.int32) if False else
            toks.astype(np.int32),
            "labels": np.roll(toks, -1, axis=1).astype(np.int32),
        }
        if cfg.frontend == "vision" and cfg.frontend_tokens:
            rng_h = _hash_u64(ids.astype(np.uint64)[:, None]
                              + np.uint64(0xBEEF) * np.arange(
                                  cfg.frontend_tokens, dtype=np.uint64)[None])
            emb = ((rng_h % np.uint64(2048)).astype(np.float32) / 1024.0 - 1.0)
            data["patch_embeds"] = np.repeat(
                emb[:, :, None], cfg.d_model, axis=2).astype(np.float32) * 0.02
        if cfg.frontend == "audio":
            t_enc = max(cfg.seq_len // cfg.enc_frames_ratio, 1)
            rng_h = _hash_u64(ids.astype(np.uint64)[:, None]
                              + np.uint64(0xF00D) * np.arange(
                                  t_enc, dtype=np.uint64)[None])
            emb = ((rng_h % np.uint64(2048)).astype(np.float32) / 1024.0 - 1.0)
            data["frames"] = np.repeat(
                emb[:, :, None], cfg.d_model, axis=2).astype(np.float32) * 0.02
        return Batch(step=step, data=data)

    def iterate(self, start_step: int = 0) -> Iterator[Batch]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchLoader:
    """Background-thread prefetch over a SyntheticDataset."""

    def __init__(self, dataset: SyntheticDataset, start_step: int = 0,
                 depth: int = 2):
        self._ds = dataset
        self._q: "queue.Queue[Batch]" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self._ds.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> Batch:
        return self._q.get()

    def __iter__(self):
        return self

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2.0)
