"""Deterministic synthetic data pipeline (sharded, packed, prefetched)."""
from .pipeline import Batch, DataConfig, PrefetchLoader, SyntheticDataset, EOS
