"""Autoscaler driver: the closed loop between the ObsBus and the rails.

``ServeEngine(autoscaler=Autoscaler(table, "threshold"))`` hooks
:meth:`Autoscaler.on_decode_step` into the engine's decode loop, right
after the step's telemetry (queue gauges, backend counters, hwloop
flags) lands in the registry.  Every ``decide_every`` decode steps the
driver samples :class:`~repro.railscale.policy.RailSignals` off the
registry — plain float reads, no jax anywhere on the decision path —
asks the policy for a target ladder level, and actuates through the
:class:`~repro.railscale.clamp.GuardbandClamp` onto the engine's
``HwLoopSession``.  Virtual-time harness runs are therefore
bit-deterministic: decisions depend only on step counts and telemetry,
never on wall-clock.

Watchdog coordination: the driver watches ``session.recalibrations``
every step.  A heal (the watchdog rewriting rails after persistent
flags) re-anchors the policy at the ladder level nearest the healed
rails, preempts the clamp's dwell timer, and opens a
``heal_holdoff_steps`` window during which the policy may boost toward
nominal but may not undervolt again — the just-healed partition gets
time to prove itself clean before the loop leans on it.

Everything observable is published: ``railscale_level`` /
``railscale_target_volts{partition}`` gauges,
``railscale_transitions_total{direction}``, and a ``railscale_decision``
trace event per window into the flight recorder.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

from .clamp import GuardbandClamp
from .points import OperatingPointTable
from .policy import RailSignals, get_policy


class Autoscaler:
    """Closed-loop rail controller for one ``ServeEngine``.

    ``table``        — the operating-point ladder (level 0 = nominal).
    ``policy``       — name (``static``/``threshold``/``pid``) or a
                       ``RailPolicy`` instance.
    ``decide_every`` — decode steps per decision window.
    ``slo_ttft_s``   — TTFT SLO used to derive the headroom signal
                       (``None`` disables the SLO term).
    ``start_level``  — ladder level to snap the rails to at attach
                       (``None`` anchors at the level nearest the
                       device's current rails).
    """

    def __init__(self, table: OperatingPointTable, policy: Any = "threshold",
                 *, decide_every: int = 4, slo_ttft_s: Optional[float] = None,
                 start_level: Optional[int] = None,
                 max_step_v: float = 0.1, dwell_steps: int = 8,
                 heal_holdoff_steps: int = 16, **policy_kwargs: Any):
        if decide_every < 1:
            raise ValueError(f"decide_every must be >= 1, got {decide_every}")
        self.table = table
        self.policy = get_policy(policy, **policy_kwargs)
        self.decide_every = int(decide_every)
        self.slo_ttft_s = None if slo_ttft_s is None else float(slo_ttft_s)
        self.start_level = start_level
        self.heal_holdoff_steps = int(heal_holdoff_steps)
        self.clamp = GuardbandClamp(table.floor_v(), table.ceil_v(),
                                    max_step_v=max_step_v,
                                    dwell_steps=dwell_steps)
        self.level = 0
        self.session = None
        self._engine = None
        self._obs = None
        self._steps = 0
        self._decisions = 0
        self._transitions = {"up": 0, "down": 0}
        self._heal_preemptions = 0
        self._holdoff_until = -1
        self._recal_seen = 0
        # windowed-counter baselines (flags/calls and TTFT sum/count)
        self._prev_flags = 0.0
        self._prev_calls = 0.0
        self._prev_ttft_sum = 0.0
        self._prev_ttft_n = 0

    @property
    def is_static(self) -> bool:
        return getattr(self.policy, "name", None) == "static"

    # -- wiring ----------------------------------------------------------------

    def attach(self, engine) -> None:
        """Bind to a ``ServeEngine`` (called by the engine constructor).

        Non-static policies require the engine's ``HwLoopSession`` —
        that is the only sanctioned actuation path (its watchdog heals
        and the clamp share the same rails), and its partition count
        must match the table's."""
        if self._engine is not None:
            raise RuntimeError("Autoscaler is already attached to an engine; "
                               "build one Autoscaler per ServeEngine")
        session = getattr(engine, "hwloop", None)
        if session is None and not self.is_static:
            raise ValueError(
                f"the {self.policy.name!r} rail policy needs a hwloop "
                "session to actuate rails — construct the engine with "
                "ServeEngine(hwloop=HwLoopSession(...), ...)")
        if session is not None and (session.n_partitions
                                    != self.table.n_partitions):
            raise ValueError(
                f"operating-point table has {self.table.n_partitions} "
                f"partitions but the session device has "
                f"{session.n_partitions}")
        self._engine = engine
        self.session = session
        self._obs = engine.obs
        reg = self._obs.registry
        self._c_transitions = reg.counter(
            "railscale_transitions_total",
            "rail operating-point transitions by direction "
            "(down = deeper undervolt)", labels=("direction",))
        self._g_level = reg.gauge(
            "railscale_level",
            "current rail ladder level (0 = nominal rails)")
        self._g_target = reg.gauge(
            "railscale_target_volts",
            "autoscaler per-partition target rail voltage (V)",
            labels=("partition",))
        # engine-side metrics the signals sample (get-or-create: the
        # engine registered the real ones before attaching us)
        self._g_queue = reg.gauge(
            "serve_queue_depth", "requests waiting for a decode slot")
        self._g_active = reg.gauge(
            "serve_active_slots", "slots serving a live request")
        self._g_slots = reg.gauge("serve_slots", "configured decode slots")
        self._g_replay_rate = reg.gauge(
            "serve_replay_rate", "lifetime replays per GEMM call")
        self._g_energy = reg.gauge(
            "serve_energy_per_token_joules",
            "lifetime backend energy / tokens generated (J)")
        self._c_flags = reg.counter(
            "backend_flags_total", "Razor DETECTED flags raised")
        self._c_gemms = reg.counter(
            "backend_gemm_calls_total", "backend matmul invocations")
        self._h_ttft = reg.histogram(
            "serve_ttft_seconds", "submit to first emitted token (s)")
        if session is not None:
            self._recal_seen = int(session.recalibrations)
            if self.start_level is not None and not self.is_static:
                self.level = int(self.start_level)
                self.clamp.snap(session, self.table.rails(self.level))
            else:
                self.level = self.table.nearest_level(session.rails)
        self._publish_level()

    def _publish_level(self) -> None:
        self._g_level.set(float(self.level))
        for p, v in enumerate(self.table.rails(self.level)):
            self._g_target.set(float(v), partition=str(p))

    # -- sensing ---------------------------------------------------------------

    def read_signals(self) -> RailSignals:
        """Sample one decision window's control inputs off the registry.
        Counter-backed signals (flag rate, TTFT) are windowed deltas
        since the previous decision, so the policy reacts to *recent*
        behavior rather than lifetime averages."""
        flags = self._c_flags.value()
        calls = self._c_gemms.value()
        d_flags = flags - self._prev_flags
        d_calls = calls - self._prev_calls
        self._prev_flags, self._prev_calls = flags, calls
        flag_rate = d_flags / d_calls if d_calls > 0 else 0.0

        headroom: Optional[float] = None
        _, ttft_sum, ttft_n = self._h_ttft.snapshot()
        if self.slo_ttft_s and ttft_n > self._prev_ttft_n:
            recent = ((ttft_sum - self._prev_ttft_sum)
                      / (ttft_n - self._prev_ttft_n))
            headroom = 1.0 - recent / self.slo_ttft_s
        self._prev_ttft_sum, self._prev_ttft_n = ttft_sum, ttft_n

        slots = max(self._g_slots.value(), 1.0)
        energy = self._g_energy.value()
        return RailSignals(
            step=self._steps,
            queue_depth=self._g_queue.value(),
            active_frac=self._g_active.value() / slots,
            flag_rate=flag_rate,
            replay_rate=self._g_replay_rate.value(),
            energy_per_token_j=energy if energy > 0 else None,
            ttft_headroom=headroom)

    # -- the loop --------------------------------------------------------------

    def _check_heal(self) -> None:
        """A watchdog recalibration rewrote the rails: re-anchor at the
        healed level, preempt the dwell timer, and open the holdoff
        window that blocks immediate re-undervolting."""
        recals = int(self.session.recalibrations)
        if recals == self._recal_seen:
            return
        self._recal_seen = recals
        self._heal_preemptions += 1
        self._holdoff_until = self._steps + self.heal_holdoff_steps
        self.level = self.table.nearest_level(self.session.rails)
        self.clamp.notify_heal(self._steps)
        self._publish_level()
        self._obs.event("railscale_heal_preempt", step=self._steps,
                        level=self.level,
                        holdoff_until=self._holdoff_until)

    def on_decode_step(self) -> None:
        """Engine hook: called once per decode step, after that step's
        telemetry has been published."""
        self._steps += 1
        if self.is_static or self.session is None:
            return
        self._check_heal()
        if self._steps % self.decide_every:
            return
        signals = self.read_signals()
        self._decisions += 1
        target = int(self.policy.decide(signals, self.level, self.table))
        target = min(max(target, 0), len(self.table) - 1)
        held_off = target > self.level and self._steps < self._holdoff_until
        if held_off:
            target = self.level
        action = "hold"
        if target != self.level:
            boost = target < self.level   # toward nominal: urgent
            applied = self.clamp.apply(self.session,
                                       self.table.rails(target),
                                       self._steps, urgent=boost)
            if applied is None:
                action = "dwell"
            else:
                direction = "up" if boost else "down"
                self._transitions[direction] += 1
                self._c_transitions.inc(direction=direction)
                self.level = target
                self._publish_level()
                action = direction
        elif held_off:
            action = "holdoff"
        self._obs.event(
            "railscale_decision", step=self._steps, action=action,
            level=self.level, policy=self.policy.name,
            queue_depth=signals.queue_depth,
            active_frac=round(signals.active_frac, 4),
            flag_rate=round(signals.flag_rate, 6),
            ttft_headroom=(None if signals.ttft_headroom is None
                           else round(signals.ttft_headroom, 4)),
            rails_v=[float(v) for v in np.asarray(self.session.rails)])

    # -- telemetry -------------------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        """Plain-JSON lifetime summary for ``EngineStats.railscale``."""
        out: Dict[str, Any] = {
            "policy": getattr(self.policy, "name", "custom"),
            "levels": len(self.table),
            "level": self.level,
            "steps": self._steps,
            "decisions": self._decisions,
            "transitions": dict(self._transitions),
            "heal_preemptions": self._heal_preemptions,
            "slo_ttft_s": self.slo_ttft_s,
            "decide_every": self.decide_every,
        }
        if self.session is not None:
            out["rails_v"] = [float(v)
                              for v in np.asarray(self.session.rails)]
            out["target_rails_v"] = [float(v)
                                     for v in self.table.rails(self.level)]
        return out
