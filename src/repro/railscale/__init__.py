"""repro.railscale — closed-loop energy-aware rail autoscaling.

The paper's green-computing loop, operational: an operating-point table
distilled from the CAD flow (:mod:`repro.railscale.points`), pure rail
policies over ObsBus telemetry (:mod:`repro.railscale.policy`), a
guardband clamp that is the only sanctioned rail writer
(:mod:`repro.railscale.clamp`), and the :class:`Autoscaler` driver that
``ServeEngine(autoscaler=...)`` ticks once per decode step
(:mod:`repro.railscale.autoscaler`).
"""

from .autoscaler import Autoscaler
from .clamp import GuardbandClamp
from .points import (OperatingPoint, OperatingPointTable, load_tables,
                     save_tables)
from .policy import (PIDPolicy, POLICIES, RailPolicy, RailSignals,
                     StaticPolicy, ThresholdPolicy, get_policy)

__all__ = [
    "Autoscaler",
    "GuardbandClamp",
    "OperatingPoint",
    "OperatingPointTable",
    "PIDPolicy",
    "POLICIES",
    "RailPolicy",
    "RailSignals",
    "StaticPolicy",
    "ThresholdPolicy",
    "get_policy",
    "load_tables",
    "save_tables",
]
