"""Operating-point table: the autoscaler's menu of rail voltage vectors.

The closed loop needs a discrete ladder of operating points to move along:
level 0 is the nominal rails (today's static behavior, safest, most
expensive) and the deepest level is the calibrated near-threshold rails
from ``runtime_calibration`` (Algorithm 2) plus the session's guard
margin — the paper's green-computing target.  Intermediate levels
interpolate per partition, so low-slack partitions keep proportionally
more margin all the way down, exactly as the sweep()'s Pareto points do.

:meth:`OperatingPointTable.characterize` distills the table from a
:class:`~repro.flow.report.FlowReport`: each level is probed on a seeded
:class:`~repro.hwloop.device.EmulatedAccelerator` (same emulator the
serving backend runs on) to attach *measured* energy/token, flag rate,
replay rate, and a throughput proxy to the predicted voltages — the
reduced-voltage guardband characterization of Salami et al. (PAPERS.md),
in miniature.  Tables serialize to JSON (``flow`` CLI ``--points-out``)
so the serving policy can load them without rerunning the CAD flow.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One rung of the rail ladder, with its probed characteristics."""

    level: int                       # 0 = nominal (safest), higher = deeper undervolt
    rails_v: List[float]             # (P,) per-partition rail voltage
    energy_per_token_j: float        # probed on the emulator at these rails
    flag_rate: float                 # probe steps with >=1 DETECTED flag / steps
    replay_rate: float               # DETECTED replays per executed MAC
    throughput_scale: float          # probe throughput relative to level 0

    def to_dict(self) -> Dict[str, Any]:
        return {
            "level": self.level,
            "rails_v": [float(v) for v in self.rails_v],
            "energy_per_token_j": float(self.energy_per_token_j),
            "flag_rate": float(self.flag_rate),
            "replay_rate": float(self.replay_rate),
            "throughput_scale": float(self.throughput_scale),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "OperatingPoint":
        return cls(level=int(d["level"]),
                   rails_v=[float(v) for v in d["rails_v"]],
                   energy_per_token_j=float(d["energy_per_token_j"]),
                   flag_rate=float(d["flag_rate"]),
                   replay_rate=float(d["replay_rate"]),
                   throughput_scale=float(d["throughput_scale"]))


class OperatingPointTable:
    """Ordered ladder of operating points for one (tech, algo, array_n).

    ``points[0]`` is nominal rails; each successive level undervolts
    further toward the calibrated floor.  ``meta`` carries the flow
    coordinates the table was characterized at, so a multi-table file
    (one per sweep config) can be filtered on load.
    """

    def __init__(self, points: Sequence[OperatingPoint],
                 meta: Optional[Dict[str, Any]] = None):
        pts = sorted(points, key=lambda p: p.level)
        if not pts:
            raise ValueError("operating-point table needs at least one point")
        if [p.level for p in pts] != list(range(len(pts))):
            raise ValueError("operating-point levels must be 0..n-1 with no "
                             f"gaps, got {[p.level for p in pts]}")
        widths = {len(p.rails_v) for p in pts}
        if len(widths) != 1:
            raise ValueError(f"inconsistent partition counts across levels: "
                             f"{sorted(widths)}")
        means = [float(np.mean(p.rails_v)) for p in pts]
        if any(b > a + 1e-12 for a, b in zip(means, means[1:])):
            raise ValueError("mean rail voltage must be non-increasing with "
                             "level (level 0 is nominal, deeper = undervolt)")
        self.points: List[OperatingPoint] = list(pts)
        self.meta: Dict[str, Any] = dict(meta or {})

    # -- basic access ---------------------------------------------------------

    def __len__(self) -> int:
        return len(self.points)

    def __getitem__(self, level: int) -> OperatingPoint:
        return self.points[level]

    @property
    def n_partitions(self) -> int:
        return len(self.points[0].rails_v)

    def rails(self, level: int) -> np.ndarray:
        return np.asarray(self.points[level].rails_v, dtype=np.float64)

    def floor_v(self) -> np.ndarray:
        """(P,) per-partition lowest voltage anywhere in the table."""
        return np.min([p.rails_v for p in self.points], axis=0)

    def ceil_v(self) -> np.ndarray:
        """(P,) per-partition highest voltage anywhere in the table."""
        return np.max([p.rails_v for p in self.points], axis=0)

    def nearest_level(self, rails: Sequence[float]) -> int:
        """The level whose rail vector is closest (L2) to ``rails`` —
        used to re-anchor the policy after a watchdog heal rewrites the
        device rails underneath it."""
        rails = np.asarray(rails, dtype=np.float64)
        dists = [float(np.linalg.norm(rails - self.rails(lv)))
                 for lv in range(len(self))]
        return int(np.argmin(dists))

    # -- characterization from the CAD flow -----------------------------------

    @classmethod
    def characterize(cls, report, cfg, *, n_levels: int = 4,
                     probe_steps: int = 6, probe_rows: int = 16,
                     rail_margin: float = 0.02,
                     seed: int = 0) -> "OperatingPointTable":
        """Distill the ladder from one flow operating point.

        Levels interpolate per partition from nominal rails (level 0)
        down to the report's calibrated ``runtime_v`` plus
        ``rail_margin`` — the same guard band ``HwLoopSession`` applies,
        so the deepest level matches what a watchdog heal would restore.
        Each level runs ``probe_steps`` seeded probe matmuls on a fresh
        emulator to measure energy/token, flag rate, replay rate, and
        relative throughput.  Deterministic in (report, cfg, seed).
        """
        from ..hwloop.device import EmulatedAccelerator

        if n_levels < 1:
            raise ValueError(f"n_levels must be >= 1, got {n_levels}")
        if getattr(report, "runtime_v", None) is None:
            raise ValueError("report has no calibrated rails (runtime_v); "
                             "run the flow with calibrate=True to "
                             "characterize an operating-point ladder")
        node = cfg.node
        floor = np.asarray(report.runtime_v, dtype=np.float64) + rail_margin
        ceil = np.maximum(np.full_like(floor, float(node.v_nom)), floor)
        points: List[OperatingPoint] = []
        base_cycles: Optional[int] = None
        for level in range(n_levels):
            frac = level / max(n_levels - 1, 1)
            rails = (1.0 - frac) * ceil + frac * floor
            accel = EmulatedAccelerator.from_flow(report, cfg, rails=rails,
                                                  seed=seed)
            rng = np.random.default_rng(seed * 1_000_003 + level * 7919 + 11)
            n = accel.timing.n
            flagged_steps = 0
            for _ in range(probe_steps):
                a = rng.normal(size=(probe_rows, n))
                w = rng.normal(size=(n, n))
                _, tel = accel.matmul(a, w)
                if np.asarray(tel.partition_flags).any():
                    flagged_steps += 1
            accel.ledger.add_tokens(probe_steps)
            cycles = max(accel.ledger.cycles, 1)
            if base_cycles is None:
                base_cycles = cycles
            points.append(OperatingPoint(
                level=level,
                rails_v=[float(v) for v in rails],
                energy_per_token_j=float(accel.ledger.energy_per_token_j
                                         or 0.0),
                flag_rate=flagged_steps / max(probe_steps, 1),
                replay_rate=float(accel.ledger.replay_rate),
                throughput_scale=base_cycles / cycles))
        meta = {
            "tech": cfg.tech,
            "algo": cfg.algo,
            "array_n": int(cfg.array_n),
            "seed": int(seed),
            "rail_margin_v": float(rail_margin),
            "probe_steps": int(probe_steps),
            "probe_rows": int(probe_rows),
            "runtime_v": [float(v) for v in np.asarray(report.runtime_v)],
            "v_nom": float(node.v_nom),
            "v_th": float(node.v_th),
        }
        return cls(points, meta=meta)

    # -- serialization --------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {"meta": dict(self.meta),
                "points": [p.to_dict() for p in self.points]}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "OperatingPointTable":
        return cls([OperatingPoint.from_dict(p) for p in d["points"]],
                   meta=d.get("meta"))

    def save(self, path: str) -> None:
        save_tables(path, [self])

    @classmethod
    def load(cls, path: str, **selectors: Any) -> "OperatingPointTable":
        """Load one table from a ``--points-out`` file.  ``selectors``
        filter on ``meta`` keys (e.g. ``tech="vtr-22nm"``, ``algo=
        "dbscan"``, ``array_n=16``); exactly one table must match."""
        tables = load_tables(path)
        matches = [t for t in tables
                   if all(t.meta.get(k) == v for k, v in selectors.items())]
        if not matches:
            available = [{k: t.meta.get(k)
                          for k in ("tech", "algo", "array_n")}
                         for t in tables]
            raise KeyError(f"no operating-point table matches {selectors}; "
                           f"available: {available}")
        if len(matches) > 1:
            raise KeyError(f"{len(matches)} tables match {selectors}; "
                           "narrow with tech=/algo=/array_n=")
        return matches[0]


def save_tables(path: str, tables: Sequence[OperatingPointTable]) -> None:
    """Write one or more characterized tables as a versioned JSON file —
    the ``flow`` CLI's ``--points-out`` format (one table per sweep
    config)."""
    payload = {"version": SCHEMA_VERSION,
               "tables": [t.to_dict() for t in tables]}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")


def load_tables(path: str) -> List[OperatingPointTable]:
    with open(path) as fh:
        payload = json.load(fh)
    version = payload.get("version")
    if version != SCHEMA_VERSION:
        raise ValueError(f"unsupported operating-point file version "
                         f"{version!r} (expected {SCHEMA_VERSION})")
    return [OperatingPointTable.from_dict(d) for d in payload["tables"]]
