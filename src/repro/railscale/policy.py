"""Rail policies: pure decision functions from telemetry to a ladder level.

A policy sees one :class:`RailSignals` snapshot per decision window —
plain floats sampled off the ObsBus registry (queue depth, slot
occupancy, windowed flag/replay rates, energy/token, TTFT-SLO headroom).
No jax, no device handles, no clocks: ``decide()`` maps (signals,
current level, table) -> target level, deterministically.  Actuation,
rate limiting, and watchdog coordination live in
:class:`~repro.railscale.autoscaler.Autoscaler` +
:class:`~repro.railscale.clamp.GuardbandClamp`; a policy can *request*
any level and the clamp still bounds what reaches the device.

Three built-ins (select by name via :func:`get_policy`):

``static``     hold the current level forever — bit-compatible with
               today's fixed-rail serving path.
``threshold``  hysteresis bands: boost one level toward nominal under
               pressure (deep queue, flag rate above the ceiling, thin
               TTFT headroom), descend one level toward NTC only when
               *comfortably* idle — the gap between the boost and
               descend bands is the hysteresis that prevents flapping.
``pid``        proportional-integral controller on a scalar load/SLO
               pressure term: zero pressure converges to the deepest
               (greenest) level, sustained pressure drives the operating
               point continuously back toward nominal.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Type

try:  # Protocol is 3.8+; keep a runtime fallback for exotic interpreters
    from typing import Protocol
except ImportError:  # pragma: no cover
    Protocol = object  # type: ignore[assignment]


@dataclasses.dataclass(frozen=True)
class RailSignals:
    """One decision window's control inputs, all plain floats sampled
    from the ObsBus registry (never jax arrays)."""

    step: int                            # decode steps elapsed
    queue_depth: float                   # requests waiting for a slot
    active_frac: float                   # active slots / configured slots
    flag_rate: float                     # window flags per GEMM call
    replay_rate: float                   # lifetime replays per GEMM call
    energy_per_token_j: Optional[float]  # lifetime backend energy / tokens
    ttft_headroom: Optional[float]       # 1 - recent_ttft/SLO; None = no data


class RailPolicy(Protocol):
    """Anything with a ``name`` and a pure ``decide()`` is a policy."""

    name: str

    def decide(self, signals: RailSignals, level: int,
               table) -> int: ...


class StaticPolicy:
    """Hold whatever level the rails are at — today's behavior."""

    name = "static"

    def decide(self, signals: RailSignals, level: int, table) -> int:
        return level


class ThresholdPolicy:
    """Hysteresis bands on queue depth, flag rate, and TTFT headroom.

    Boost (one level toward nominal) when ANY pressure signal trips:
    ``queue_depth > queue_high``, ``flag_rate >= flag_high``, or TTFT
    headroom below ``headroom_low``.  Descend (one level deeper) only
    when EVERY idle condition holds: ``queue_depth <= queue_low``,
    flags clear, headroom at least ``2 * headroom_low`` (or no recent
    TTFT samples at all), and slot occupancy at most ``active_high``.
    Signals between the bands hold the current level — the hysteresis
    gap that keeps the rails from flapping on noisy load.
    """

    name = "threshold"

    def __init__(self, *, queue_low: float = 0.0,
                 queue_high: Optional[float] = None,
                 flag_high: float = 0.25,
                 headroom_low: float = 0.25,
                 active_high: float = 1.0):
        if queue_high is not None and queue_high < queue_low:
            raise ValueError(f"queue_high {queue_high} below queue_low "
                             f"{queue_low}: bands must not cross")
        self.queue_low = float(queue_low)
        self.queue_high = queue_high if queue_high is None else float(queue_high)
        self.flag_high = float(flag_high)
        self.headroom_low = float(headroom_low)
        self.active_high = float(active_high)

    def decide(self, signals: RailSignals, level: int, table) -> int:
        queue_high = (self.queue_high if self.queue_high is not None
                      else max(self.queue_low, 1.0))
        pressured = (signals.queue_depth > queue_high
                     or signals.flag_rate >= self.flag_high
                     or (signals.ttft_headroom is not None
                         and signals.ttft_headroom < self.headroom_low))
        if pressured:
            return max(level - 1, 0)
        idle = (signals.queue_depth <= self.queue_low
                and signals.flag_rate < self.flag_high
                and (signals.ttft_headroom is None
                     or signals.ttft_headroom >= 2 * self.headroom_low)
                and signals.active_frac <= self.active_high)
        if idle:
            return min(level + 1, len(table) - 1)
        return level


class PIDPolicy:
    """PI controller on a scalar pressure term.

    ``pressure = queue_depth/queue_ref + flag_rate/flag_ref +
    max(0, headroom_low - ttft_headroom)/headroom_low``.  The control
    output ``u = kp*(pressure - setpoint) + ki*integral`` maps linearly
    onto the ladder: ``u <= 0`` requests the deepest (greenest) level,
    ``u >= 1`` requests nominal.  The integral term (clamped to
    ``[0, i_max]``) accumulates sustained pressure so a persistent
    near-threshold queue eventually forces a boost even when no single
    window trips a threshold.
    """

    name = "pid"

    def __init__(self, *, kp: float = 1.0, ki: float = 0.25,
                 setpoint: float = 0.1, queue_ref: float = 4.0,
                 flag_ref: float = 0.25, headroom_low: float = 0.25,
                 i_max: float = 4.0):
        self.kp = float(kp)
        self.ki = float(ki)
        self.setpoint = float(setpoint)
        self.queue_ref = float(queue_ref)
        self.flag_ref = float(flag_ref)
        self.headroom_low = float(headroom_low)
        self.i_max = float(i_max)
        self._integral = 0.0

    def pressure(self, signals: RailSignals) -> float:
        p = (signals.queue_depth / self.queue_ref
             + signals.flag_rate / self.flag_ref)
        if signals.ttft_headroom is not None and self.headroom_low > 0:
            p += max(0.0, self.headroom_low
                     - signals.ttft_headroom) / self.headroom_low
        return p

    def decide(self, signals: RailSignals, level: int, table) -> int:
        error = self.pressure(signals) - self.setpoint
        self._integral = min(max(self._integral + error, 0.0), self.i_max)
        u = self.kp * error + self.ki * self._integral
        depth_frac = min(max(1.0 - u, 0.0), 1.0)
        return int(round(depth_frac * (len(table) - 1)))


POLICIES: Dict[str, Type] = {
    StaticPolicy.name: StaticPolicy,
    ThresholdPolicy.name: ThresholdPolicy,
    PIDPolicy.name: PIDPolicy,
}


def get_policy(policy: Any, **kwargs: Any):
    """Resolve a policy name (``static`` / ``threshold`` / ``pid``) or
    pass an instance through unchanged (kwargs then disallowed)."""
    if isinstance(policy, str):
        try:
            return POLICIES[policy](**kwargs)
        except KeyError:
            raise KeyError(f"unknown rail policy {policy!r}; available: "
                           f"{sorted(POLICIES)}") from None
    if kwargs:
        raise TypeError("kwargs only apply when selecting a policy by name")
    if not hasattr(policy, "decide"):
        raise TypeError(f"{policy!r} is not a RailPolicy (no .decide)")
    return policy
