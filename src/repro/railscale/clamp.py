"""Guardband clamp: the one sanctioned rail-write path in the scaler.

Every voltage the policy wants to apply passes through
:class:`GuardbandClamp`, which enforces the three safety properties the
watchdog relies on:

* **envelope** — each partition's voltage is clamped to the calibrated
  safe band ``[floor_v, ceil_v]`` (taken from the operating-point table,
  i.e. the Salami-et-al. guardband characterization); non-finite targets
  are rejected outright;
* **max step** — one transition moves each rail at most ``max_step_v``,
  so a misbehaving policy cannot slam a partition from nominal into the
  crash region in one decision;
* **dwell** — after a transition (or a watchdog heal, via
  :meth:`notify_heal`) no further transition lands for ``dwell_steps``
  decode steps, so the policy and the watchdog's heals never fight over
  the rails.

Lint rule RP009 flags any direct ``set_rails`` /
``set_partition_voltage`` call in ``railscale``/``serve`` scope outside
this module — the clamp is the only writer.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import numpy as np


class GuardbandClamp:
    """Envelope + rate-limit guard between a rail policy and the device."""

    def __init__(self, floor_v: Sequence[float], ceil_v: Sequence[float], *,
                 max_step_v: float = 0.1, dwell_steps: int = 8):
        self.floor_v = np.asarray(floor_v, dtype=np.float64).copy()
        self.ceil_v = np.asarray(ceil_v, dtype=np.float64).copy()
        if self.floor_v.shape != self.ceil_v.shape or self.floor_v.ndim != 1:
            raise ValueError(f"floor/ceil must be matching 1-D vectors, got "
                             f"{self.floor_v.shape} vs {self.ceil_v.shape}")
        if (not np.isfinite(self.floor_v).all()
                or not np.isfinite(self.ceil_v).all()):
            raise ValueError("guardband envelope must be finite")
        if (self.floor_v > self.ceil_v).any():
            raise ValueError("guardband floor above ceiling: "
                             f"{self.floor_v} > {self.ceil_v}")
        if not math.isfinite(max_step_v) or max_step_v <= 0:
            raise ValueError(f"max_step_v must be positive, got {max_step_v}")
        self.max_step_v = float(max_step_v)
        self.dwell_steps = int(dwell_steps)
        self._last_transition_step: Optional[int] = None

    @property
    def n_partitions(self) -> int:
        return int(self.floor_v.shape[0])

    # -- pure voltage math ----------------------------------------------------

    def clamp(self, rails: Sequence[float]) -> np.ndarray:
        """Bound a target rail vector to the calibrated envelope.  Raises
        on NaN/inf or shape mismatch — a policy emitting garbage must
        fail loudly, never reach the device."""
        rails = np.asarray(rails, dtype=np.float64)
        if rails.shape != self.floor_v.shape:
            raise ValueError(f"expected {self.n_partitions} rail voltages, "
                             f"got shape {rails.shape}")
        if not np.isfinite(rails).all():
            raise ValueError(f"non-finite rail target: {rails}")
        return np.clip(rails, self.floor_v, self.ceil_v)

    def dwell_active(self, step: int) -> bool:
        """True while the dwell timer blocks a new transition."""
        return (self._last_transition_step is not None
                and step - self._last_transition_step < self.dwell_steps)

    # -- actuation ------------------------------------------------------------

    def apply(self, session, target_v: Sequence[float], step: int, *,
              urgent: bool = False) -> Optional[np.ndarray]:
        """Move the session's rails toward ``target_v``, rate-limited.

        Returns the rails actually written, or ``None`` when nothing was
        (dwell timer active, or already at target).  ``urgent=True``
        bypasses the dwell timer — reserved for boosts toward nominal
        under error/SLO pressure; descents always respect it.
        """
        if not urgent and self.dwell_active(step):
            return None
        target = self.clamp(target_v)
        current = np.asarray(session.rails, dtype=np.float64)
        delta = np.clip(target - current, -self.max_step_v, self.max_step_v)
        new_rails = current + delta
        if np.allclose(new_rails, current, atol=1e-12):
            return None
        for p in range(self.n_partitions):
            if new_rails[p] != current[p]:
                # the clamp is the sanctioned writer
                session.set_partition_voltage(  # lint: allow=RP009 GuardbandClamp.apply IS the clamp helper every other rail write must route through
                    p, float(new_rails[p]))
        self._last_transition_step = int(step)
        return new_rails

    def snap(self, session, target_v: Sequence[float]) -> np.ndarray:
        """Envelope-clamped full jump, ignoring max-step and dwell —
        initialization only (anchoring a freshly attached engine onto a
        ladder level before traffic starts).  Steady-state transitions
        must go through :meth:`apply`."""
        target = self.clamp(target_v)
        current = np.asarray(session.rails, dtype=np.float64)
        for p in range(self.n_partitions):
            if target[p] != current[p]:
                session.set_partition_voltage(  # lint: allow=RP009 init-time snap inside the clamp helper itself
                    p, float(target[p]))
        return target

    def notify_heal(self, step: int) -> None:
        """A watchdog heal rewrote the rails underneath the policy: the
        heal preempts any pending dwell window (the policy re-evaluates
        from the healed rails immediately) and itself starts a fresh
        dwell, so the very next decision cannot push right back down."""
        self._last_transition_step = int(step)
