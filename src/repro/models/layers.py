"""Shared model building blocks: norms, RoPE, GQA attention (train / prefill /
cached decode, causal + sliding-window), SwiGLU/GELU MLPs, MoE (dense dispatch
and expert-parallel all-to-all), and sequence-chunked cross-entropy.

Numerics policy: params bf16 (norm scales f32), matmuls bf16 with f32 softmax/
normalization/loss.  All activation sharding goes through shardlib.shard so
the same code serves every mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..backend import current_backend
from ..backend import matmul as bmm
from ..configs.base import ModelConfig
from .shardlib import ParamSpec, current_rules, shard

Params = Dict[str, Any]

NEG_INF = -2.0 ** 30   # large-but-finite mask value (avoids NaN from inf-inf)


def scan_layers(body, carry, stacked, unroll: bool = False,
                collect: bool = False):
    """lax.scan over a stacked layer pytree, or a python unroll when the
    caller needs cost_analysis to see every repetition (roofline estimator).

    body(carry, layer_tree) -> carry  (collect=False)
    body(carry, layer_tree) -> (carry, out)  (collect=True; outs stacked)
    """
    if not unroll:
        if collect:
            return jax.lax.scan(body, carry, stacked)
        return jax.lax.scan(lambda c, lp: (body(c, lp), ()), carry, stacked)[0]
    n = jax.tree.leaves(stacked)[0].shape[0]
    outs = []
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], stacked)
        if collect:
            carry, out = body(carry, lp)
            outs.append(out)
        else:
            carry = body(carry, lp)
    if collect:
        stacked_out = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        return carry, stacked_out
    return carry


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), jnp.float32, (None,), init="ones")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, d_head); positions: (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, d/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention_param_specs(cfg: ModelConfig, layers: Optional[int] = None) -> Params:
    """Stacked (layers-first) projection weights for the attention block."""
    L = cfg.n_layers if layers is None else layers
    lead = (L,) if L else ()
    lax = ("layers",) if L else ()
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    bf = jnp.bfloat16
    specs = {
        "wq": ParamSpec(lead + (d, qd), bf, lax + ("fsdp", "tp")),
        "wk": ParamSpec(lead + (d, kvd), bf, lax + ("fsdp", "tp")),
        "wv": ParamSpec(lead + (d, kvd), bf, lax + ("fsdp", "tp")),
        "wo": ParamSpec(lead + (qd, d), bf, lax + ("tp", "fsdp")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec(lead + (qd,), bf, lax + ("tp",), init="zeros")
        specs["bk"] = ParamSpec(lead + (kvd,), bf, lax + ("tp",), init="zeros")
        specs["bv"] = ParamSpec(lead + (kvd,), bf, lax + ("tp",), init="zeros")
    return specs


def _qkv(x: jax.Array, p: Params, cfg: ModelConfig, positions: jax.Array):
    b, s, _ = x.shape
    q = bmm(x, p["wq"])
    k = bmm(x, p["wk"])
    v = bmm(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(b, s, kv, d) -> (b, s, heads, d) by group repetition."""
    b, s, kv, d = k.shape
    if kv == n_heads:
        return k
    rep = n_heads // kv
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, rep, d))
    return k.reshape(b, s, n_heads, d)


def _mask(q_pos: jax.Array, k_pos: jax.Array, window: Optional[int],
          causal: bool) -> jax.Array:
    """(q, k) boolean keep-mask."""
    if causal:
        keep = k_pos[None, :] <= q_pos[:, None]
    else:
        keep = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if window is not None:
        keep &= k_pos[None, :] > (q_pos[:, None] - window)
    return keep


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, keep: jax.Array,
          d_head: int, scores_f32: bool = True) -> jax.Array:
    """q:(b,qs,h,d) k,v:(b,ks,h,d) keep:(qs,ks) -> (b,qs,h,d).  f32 softmax."""
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d_head))
    scores = jnp.where(keep[None, None], scores, NEG_INF)
    if not scores_f32:
        # bf16 score pipeline: subtract the running max first so bf16's 8-bit
        # mantissa only ever sees bounded negatives (§Perf optimization)
        scores = (scores - jax.lax.stop_gradient(
            scores.max(-1, keepdims=True))).astype(jnp.bfloat16)
        w = jax.nn.softmax(scores.astype(jnp.bfloat16), axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def _sdpa_grouped(q: jax.Array, k: jax.Array, v: jax.Array, keep: jax.Array,
                  d_head: int, n_kv: int,
                  scores_f32: bool = True) -> jax.Array:
    """GQA without materializing repeated K/V: q reshaped (b, qs, kv, g, d)
    einsummed against the raw (b, ks, kv, d) K/V (§Perf: removes the
    heads/kv_heads-fold byte inflation of _repeat_kv)."""
    b, qs, h, d = q.shape
    g = h // n_kv
    qg = q.reshape(b, qs, n_kv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d_head))
    scores = jnp.where(keep[None, None, None], scores, NEG_INF)
    if not scores_f32:
        scores = (scores - jax.lax.stop_gradient(
            scores.max(-1, keepdims=True))).astype(jnp.bfloat16)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return o.reshape(b, qs, h, d)


def attention(x: jax.Array, p: Params, cfg: ModelConfig,
              causal: bool = True,
              positions: Optional[jax.Array] = None,
              return_kv: bool = False):
    """Training/prefill attention, q-chunked to bound the (q, k) score tensor.

    Full sequence K/V stay resident; queries are processed in cfg.attn_chunk
    blocks via lax.map, so peak score memory is (b, h, chunk, s) instead of
    (b, h, s, s).  ``return_kv`` also yields the pre-repeat K/V for prefill
    cache construction (avoids re-projecting).
    """
    b, s, _ = x.shape
    pos = jnp.arange(s) if positions is None else positions
    q, k, v = _qkv(x, p, cfg, jnp.broadcast_to(pos, (b, s)))
    k_raw, v_raw = k, v
    q = shard(q, "batch", None, "tp", None)
    if not cfg.gqa_grouped:
        k = _repeat_kv(k, cfg.n_heads)
        v = _repeat_kv(v, cfg.n_heads)
    k = shard(k, "batch", None, "tp", None)
    v = shard(v, "batch", None, "tp", None)

    ch = min(cfg.attn_chunk, s)
    if s % ch:
        ch = s  # fall back to single chunk on awkward sizes
    n_chunk = s // ch
    k_pos = pos

    def one_chunk(ci):
        qc = jax.lax.dynamic_slice_in_dim(q, ci * ch, ch, axis=1)
        q_pos = jax.lax.dynamic_slice_in_dim(k_pos, ci * ch, ch, axis=0)
        keep = _mask(q_pos, k_pos, cfg.sliding_window, causal)
        if cfg.gqa_grouped:
            return _sdpa_grouped(qc, k, v, keep, cfg.d_head, cfg.n_kv_heads,
                                 cfg.attn_scores_f32)
        return _sdpa(qc, k, v, keep, cfg.d_head, cfg.attn_scores_f32)

    if n_chunk == 1:
        o = one_chunk(0)
    elif cfg.unroll_layers:
        o = jnp.stack([one_chunk(ci) for ci in range(n_chunk)])
        o = jnp.moveaxis(o, 0, 1).reshape(b, s, cfg.n_heads, cfg.d_head)
    else:
        o = jax.lax.map(one_chunk, jnp.arange(n_chunk))       # (n, b, ch, h, d)
        o = jnp.moveaxis(o, 0, 1).reshape(b, s, cfg.n_heads, cfg.d_head)
    o = o.reshape(b, s, cfg.q_dim)
    out = bmm(o, p["wo"])
    if return_kv:
        return out, k_raw, v_raw
    return out


# -- cached decode -----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    """Decode-time KV cache layout: seq-sharded over the TP axis (flash-
    decoding style — XLA turns the softmax/output reductions over the sharded
    key axis into small all-reduces; see DESIGN.md Sec. 4).

    ``dtype_name='int8'`` stores symmetric-quantized K/V with per-(token,
    head) f32 scales — half the cache footprint/stream bytes (§Perf)."""

    layers: int
    batch: int
    max_len: int
    n_kv: int
    d_head: int
    dtype_name: str = "bf16"
    seq_axis: str = "seq_tp"

    def specs(self) -> Dict[str, ParamSpec]:
        shape = (self.layers, self.batch, self.max_len, self.n_kv, self.d_head)
        logical = ("layers", "batch", self.seq_axis, None, None)
        if self.dtype_name == "int8":
            sshape = shape[:-1] + (1,)
            return {
                "k": ParamSpec(shape, jnp.int8, logical, init="zeros"),
                "v": ParamSpec(shape, jnp.int8, logical, init="zeros"),
                "k_scale": ParamSpec(sshape, jnp.float32, logical,
                                     init="zeros"),
                "v_scale": ParamSpec(sshape, jnp.float32, logical,
                                     init="zeros"),
            }
        return {
            "k": ParamSpec(shape, jnp.bfloat16, logical, init="zeros"),
            "v": ParamSpec(shape, jnp.bfloat16, logical, init="zeros"),
        }


def _quant_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(..., dh) -> int8 payload + per-vector f32 scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def decode_attention(x: jax.Array, p: Params, cfg: ModelConfig,
                     kv: Dict[str, jax.Array],
                     index: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token attention against a cache.

    x: (b, 1, d); kv: {"k", "v"[, "k_scale", "v_scale"]} with k/v of shape
    (b, S, n_kv, dh); index: scalar position, or per-row (b,) positions —
    continuous batching runs every slot at its own offset, so each batch row
    writes its K/V at and masks against its own index.  Returns (out, new kv
    dict).
    """
    b = x.shape[0]
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32), (b,))
    pos = idx[:, None]
    q, k_new, v_new = _qkv(x, p, cfg, pos)
    int8 = "k_scale" in kv

    k_cache, v_cache = kv["k"], kv["v"]
    rows = jnp.arange(b)
    ring = (cfg.sliding_window is not None
            and k_cache.shape[1] <= cfg.sliding_window)
    slot = idx % k_cache.shape[1] if ring else idx   # ring buffer for SWA
    if int8:
        kq, ks = _quant_kv(k_new)
        vq, vs = _quant_kv(v_new)
        k_cache = k_cache.at[rows, slot].set(kq[:, 0])
        v_cache = v_cache.at[rows, slot].set(vq[:, 0])
        k_scale = kv["k_scale"].at[rows, slot].set(ks[:, 0])
        v_scale = kv["v_scale"].at[rows, slot].set(vs[:, 0])
        k_full = (k_cache.astype(jnp.float32) * k_scale).astype(jnp.bfloat16)
        v_full = (v_cache.astype(jnp.float32) * v_scale).astype(jnp.bfloat16)
        new_kv = {"k": k_cache, "v": v_cache,
                  "k_scale": k_scale, "v_scale": v_scale}
    else:
        k_cache = k_cache.at[rows, slot].set(k_new[:, 0])
        v_cache = v_cache.at[rows, slot].set(v_new[:, 0])
        k_full, v_full = k_cache, v_cache
        new_kv = {"k": k_cache, "v": v_cache}

    k = _repeat_kv(k_full, cfg.n_heads)
    v = _repeat_kv(v_full, cfg.n_heads)
    s = k.shape[1]
    k_pos = jnp.arange(s)
    if ring:
        # ring: everything valid once the row has wrapped
        valid = (k_pos[None, :] <= slot[:, None]) | (idx[:, None] >= s)
    else:
        valid = k_pos[None, :] <= idx[:, None]
        if cfg.sliding_window is not None:
            valid &= k_pos[None, :] > idx[:, None] - cfg.sliding_window
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(cfg.d_head))
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(b, 1, cfg.q_dim)
    return bmm(o, p["wo"]), new_kv


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_param_specs(cfg: ModelConfig, layers: Optional[int] = None,
                    d_ff: Optional[int] = None) -> Params:
    L = cfg.n_layers if layers is None else layers
    lead = (L,) if L else ()
    lax = ("layers",) if L else ()
    d = cfg.d_model
    ff = cfg.d_ff if d_ff is None else d_ff
    bf = jnp.bfloat16
    specs = {
        "w1": ParamSpec(lead + (d, ff), bf, lax + ("fsdp", "tp")),
        "w2": ParamSpec(lead + (ff, d), bf, lax + ("tp", "fsdp")),
    }
    if cfg.act == "swiglu":
        specs["wg"] = ParamSpec(lead + (d, ff), bf, lax + ("fsdp", "tp"))
    return specs


def mlp(x: jax.Array, p: Params, cfg: ModelConfig) -> jax.Array:
    if cfg.act == "swiglu":
        h = jax.nn.silu(bmm(x, p["wg"]).astype(jnp.float32)).astype(x.dtype)
        h = h * bmm(x, p["w1"])
    else:
        h = jax.nn.gelu(bmm(x, p["w1"]).astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", None, "tp")
    return bmm(h, p["w2"])


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def moe_param_specs(cfg: ModelConfig, layers: Optional[int] = None) -> Params:
    L = cfg.n_layers if layers is None else layers
    lead = (L,) if L else ()
    lax = ("layers",) if L else ()
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    bf = jnp.bfloat16
    if cfg.moe_shard == "expert":
        # experts over the TP axis (llama4: 16 experts == 16-way model axis)
        in_ax = lax + ("expert", "fsdp", None)
        out_ax = lax + ("expert", None, "fsdp")
    else:
        # experts replicated across TP, FFN hidden sharded (grok: 8 experts)
        in_ax = lax + (None, "fsdp", "tp")
        out_ax = lax + (None, "tp", "fsdp")
    specs = {
        "router": ParamSpec(lead + (d, e), jnp.float32, lax + ("fsdp", None)),
        "w1": ParamSpec(lead + (e, d, ff), bf, in_ax),
        "w2": ParamSpec(lead + (e, ff, d), bf, out_ax),
    }
    if cfg.act == "swiglu":
        specs["wg"] = ParamSpec(lead + (e, d, ff), bf, in_ax)
    return specs


def _router(x: jax.Array, p: Params, cfg: ModelConfig):
    """Top-k routing. Returns (weights (t, k), indices (t, k)) over flat tokens."""
    logits = bmm(x.astype(jnp.float32), p["router"])          # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx, probs


def moe_dense(x: jax.Array, p: Params, cfg: ModelConfig) -> jax.Array:
    """Dense dispatch: every expert computes every token, gated combine.

    Paper-faithful to 'dropless' MoE semantics; compute cost is E/top_k x the
    active-expert FLOPs — visible in the roofline MODEL_FLOPS ratio and the
    target of the ep_a2a hillclimb (EXPERIMENTS.md §Perf).
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    w, idx, _ = _router(xt, p, cfg)
    gates = jnp.zeros((t, cfg.n_experts), jnp.float32)
    gates = gates.at[jnp.arange(t)[:, None], idx].set(w)      # (t, E)
    if current_backend().is_ideal:
        # lint: allow=RP001 ideal-only fast path; non-ideal branch below bmm's
        up = lambda wkey: jnp.einsum("td,edf->etf", xt, p[wkey])
        down = lambda h: jnp.einsum("etf,efd->etd", h, p["w2"])  # lint: allow=RP001 ideal-only
    else:
        # per-expert GEMMs through the active backend (E dense matmuls)
        up = lambda wkey: jnp.stack(
            [bmm(xt, p[wkey][e]) for e in range(cfg.n_experts)])
        down = lambda h: jnp.stack(
            [bmm(h[e], p["w2"][e]) for e in range(cfg.n_experts)])
    if cfg.act == "swiglu":
        h = jax.nn.silu(up("wg").astype(jnp.float32)).astype(xt.dtype)
        h = h * up("w1")
    else:
        h = jax.nn.gelu(up("w1").astype(jnp.float32)).astype(xt.dtype)
    y = down(h)                                               # (E, t, d)
    out = jnp.einsum("etd,te->td", y, gates.astype(y.dtype))
    return out.reshape(b, s, d)


def _shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """shard_map with replication/vma checking off, across jax versions:
    jax >= 0.6 exports ``jax.shard_map`` (``check_vma=``), 0.4.x only has
    the experimental module (``check_rep=``)."""
    try:
        from jax import shard_map
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
    except ImportError:
        from jax.experimental.shard_map import shard_map
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def moe_ep_a2a(x: jax.Array, p: Params, cfg: ModelConfig) -> jax.Array:
    """Expert-parallel MoE with all-to-all dispatch (shard_map).

    Requires n_experts == size of the 'tp'/'expert' mesh axis.  Tokens are
    bucketed into per-expert capacity buffers locally, exchanged with a tiled
    all_to_all, processed by the resident expert, and returned.  Capacity
    C = ceil(T_local * top_k / E * capacity_factor); overflow tokens fall back
    to zero contribution (standard Switch-style dropping).
    """
    rules = current_rules()
    mesh = rules.mesh
    axis = rules.table.get("expert")
    if mesh is None or axis is None:
        return moe_dense(x, p, cfg)            # no mesh: smoke-test fallback
    e_axis = axis if isinstance(axis, str) else axis[0]
    esize = mesh.shape[e_axis]
    if cfg.n_experts != esize:
        raise ValueError(
            f"ep_a2a needs n_experts == mesh['{e_axis}'] ({cfg.n_experts} vs "
            f"{esize}); use moe_impl='dense'")

    b, s, d = x.shape
    batch_axes = rules.table["batch"]
    fsdp_axes = rules.table["fsdp"]

    def local(xl, router, wg, w1, w2):
        # xl: (b_local, s_local, d); expert weights: (1, d, ff) local shard
        bl, sl = xl.shape[0], xl.shape[1]
        t = bl * sl
        xt = xl.reshape(t, d)
        wgt, idx, _ = _router(xt, {"router": router}, cfg)
        cap = int(t * cfg.top_k / cfg.n_experts * cfg.capacity_factor + 1)
        # position of each (token, k) among its expert's claims
        onehot = jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.int32)  # (t,k,E)
        flat = onehot.reshape(t * cfg.top_k, cfg.n_experts)
        pos = jnp.cumsum(flat, axis=0) * flat - 1              # rank within expert
        pos_tk = pos.reshape(t, cfg.top_k, cfg.n_experts)
        expert_pos = (pos_tk * onehot).sum(-1)                 # (t, k)
        keep = expert_pos < cap
        # scatter tokens into (E, cap, d) send buffer
        buf = jnp.zeros((cfg.n_experts, cap, d), xl.dtype)
        e_idx = idx.reshape(-1)
        c_idx = jnp.where(keep, expert_pos, cap - 1).reshape(-1)
        src = jnp.repeat(xt, cfg.top_k, axis=0)
        src = jnp.where(keep.reshape(-1, 1), src, 0)
        buf = buf.at[e_idx, c_idx].add(src)
        # exchange: (E, cap, d) -> each device gets its expert's tokens from all
        recv = jax.lax.all_to_all(buf, e_axis, split_axis=0, concat_axis=0,
                                  tiled=True)                  # (E*cap, d) worth
        recv = recv.reshape(cfg.n_experts * cap, d)
        # resident expert FFN (weights arrive as (1, d, ff) shards)
        if cfg.act == "swiglu":
            h = jax.nn.silu(bmm(recv, wg[0]).astype(jnp.float32)).astype(recv.dtype)
            h = h * bmm(recv, w1[0])
        else:
            h = jax.nn.gelu(bmm(recv, w1[0]).astype(jnp.float32)).astype(recv.dtype)
        y = bmm(h, w2[0])
        y = y.reshape(cfg.n_experts, cap, d)
        back = jax.lax.all_to_all(y, e_axis, split_axis=0, concat_axis=0,
                                  tiled=True).reshape(cfg.n_experts, cap, d)
        # gather each (token, k) result and combine with router weights
        out_tk = back[e_idx, c_idx].reshape(t, cfg.top_k, d)
        out_tk = jnp.where(keep[..., None], out_tk, 0)
        out = (out_tk * wgt[..., None].astype(out_tk.dtype)).sum(1)
        return out.reshape(bl, sl, d)

    # tokens are partitioned over BOTH the batch (data) and sequence (expert/
    # model) axes before dispatch — otherwise every model-column would
    # redundantly dispatch and compute the same tokens (measured 16x waste;
    # EXPERIMENTS.md §Perf cell D)
    fn = _shard_map_unchecked(
        local, mesh=mesh,
        in_specs=(P(batch_axes, e_axis, None),
                  P(None, None),                 # router replicated locally
                  P(e_axis, None, None), P(e_axis, None, None),
                  P(e_axis, None, None)),
        out_specs=P(batch_axes, e_axis, None))
    wg = p.get("wg", p["w1"])
    return fn(x, p["router"], wg, p["w1"], p["w2"])


def moe(x: jax.Array, p: Params, cfg: ModelConfig) -> jax.Array:
    if cfg.moe_impl == "ep_a2a":
        return moe_ep_a2a(x, p, cfg)
    return moe_dense(x, p, cfg)


# ---------------------------------------------------------------------------
# Embedding / loss
# ---------------------------------------------------------------------------


def embed_param_specs(cfg: ModelConfig) -> Params:
    return {"embedding": ParamSpec((cfg.padded_vocab, cfg.d_model), jnp.bfloat16,
                                   ("tp", "fsdp"), init="embed")}


def embed(tokens: jax.Array, p: Params) -> jax.Array:
    x = jnp.take(p["embedding"], tokens, axis=0)
    return shard(x, "batch", None, None)


def chunked_softmax_xent(x: jax.Array, emb: jax.Array, labels: jax.Array,
                         chunk: int = 256, unroll: bool = False) -> jax.Array:
    """Sequence-chunked cross-entropy against the (tied) unembedding.

    Never materialises the full (b, s, V) logits: chunks of `chunk` positions
    produce (b, chunk, V) logits (vocab TP-sharded), reduce to scalar loss and
    are discarded inside the scan.  Measured on qwen1.5-110b this removes a
    ~40 GiB/device temp buffer (DESIGN.md Sec. 4)."""
    b, s, d = x.shape
    ch = min(chunk, s)
    if s % ch:
        ch = s
    n = s // ch

    def body(acc, ci):
        xc = jax.lax.dynamic_slice_in_dim(x, ci * ch, ch, axis=1)
        yc = jax.lax.dynamic_slice_in_dim(labels, ci * ch, ch, axis=1)
        logits = bmm(xc, emb.T).astype(jnp.float32)            # (b, ch, V)
        logits = shard(logits, "batch", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return acc + (lse - gold).sum(), ()

    if n == 1:
        loss, _ = body(jnp.float32(0), 0)
    elif unroll:
        loss = jnp.float32(0)
        for ci in range(n):
            loss, _ = body(loss, ci)
    else:
        loss, _ = jax.lax.scan(body, jnp.float32(0), jnp.arange(n))
    return loss / (b * s)


def logits_last(x_last: jax.Array, emb: jax.Array) -> jax.Array:
    """(b, 1, d) -> (b, V) logits for decode."""
    out = bmm(x_last[:, 0], emb.T).astype(jnp.float32)
    return shard(out, "batch", "tp")
