"""Model zoo: unified decoder LM (dense/MoE/VLM), SSM (RWKV6/Mamba2), hybrid
(Zamba2), encoder-decoder (Seamless) — all scan-stacked, logically sharded."""

from .api import BatchSpec, ModelAPI, model_api
from .shardlib import (ParamSpec, Rules, current_rules, init_param_tree,
                       multi_pod_rules, param_count, replicated_rules, shard,
                       single_pod_rules, spec_tree_to_pspecs,
                       spec_tree_to_shardings, spec_tree_to_structs, use_rules)

__all__ = [name for name in dir() if not name.startswith("_")]
