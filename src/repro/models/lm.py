"""Decoder-only transformer LM covering the dense, MoE and VLM-backbone
architectures (llava-next-mistral, grok-1, llama4-scout, granite, qwen1.5,
starcoder2, phi4-mini).

Layers are stacked on a leading L axis and driven by jax.lax.scan (compile
time O(1 layer) — DESIGN.md Sec. 4); remat policy per block from cfg.remat.

Every dense GEMM of this family (qkv/o projections, MLP, MoE experts,
unembedding logits/loss) routes through the active ``repro.backend`` — the
building blocks in :mod:`repro.models.layers` call ``backend.matmul``, so a
``ServeEngine(backend="emulated")`` decode runs this model's matmuls on the
voltage-scaled emulated array.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import (KVCacheSpec, attention, attention_param_specs, scan_layers,
                     chunked_softmax_xent, decode_attention, embed,
                     embed_param_specs, logits_last, mlp, mlp_param_specs, moe,
                     moe_param_specs, rmsnorm, rmsnorm_spec)
from .shardlib import ParamSpec, shard

Params = Dict[str, Any]


def _remat(fn, cfg: ModelConfig):
    if cfg.remat_save_attn:
        # keep full-remat memory behaviour EXCEPT the attention outputs: the
        # bwd pass then never re-runs the score/softmax pipeline (§Perf)
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out"))
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def param_specs(cfg: ModelConfig) -> Params:
    L = cfg.n_layers
    blocks: Params = {
        "norm_attn": ParamSpec((L, cfg.d_model), jnp.float32,
                               ("layers", None), init="ones"),
        "norm_mlp": ParamSpec((L, cfg.d_model), jnp.float32,
                              ("layers", None), init="ones"),
        "attn": attention_param_specs(cfg),
    }
    if cfg.n_experts:
        blocks["moe"] = moe_param_specs(cfg)
        if cfg.shared_expert:
            blocks["mlp"] = mlp_param_specs(cfg)
    else:
        blocks["mlp"] = mlp_param_specs(cfg)
    return {
        **embed_param_specs(cfg),
        "blocks": blocks,
        "final_norm": rmsnorm_spec(cfg.d_model),
    }


def _block(x: jax.Array, lp: Params, cfg: ModelConfig,
           positions: Optional[jax.Array] = None) -> jax.Array:
    h = rmsnorm(x, lp["norm_attn"])
    a = attention(h, lp["attn"], cfg, causal=True, positions=positions)
    if cfg.remat_save_attn:
        from jax.ad_checkpoint import checkpoint_name
        a = checkpoint_name(a, "attn_out")
    x = x + a
    h = rmsnorm(x, lp["norm_mlp"])
    if cfg.n_experts:
        y = moe(h, lp["moe"], cfg)
        if cfg.shared_expert:
            y = y + mlp(h, lp["mlp"], cfg)
    else:
        y = mlp(h, lp["mlp"], cfg)
    x = x + y
    return shard(x, "batch", None, None)


def backbone(params: Params, x: jax.Array, cfg: ModelConfig,
             positions: Optional[jax.Array] = None) -> jax.Array:
    """Embedding-space input -> final-norm output (scan over layer stack)."""
    block = _remat(functools.partial(_block, cfg=cfg, positions=positions), cfg)
    x = scan_layers(block, x, params["blocks"], unroll=cfg.unroll_layers)
    return rmsnorm(x, params["final_norm"])


def _inputs_to_embedding(params: Params, batch: Dict[str, jax.Array],
                         cfg: ModelConfig) -> Tuple[jax.Array, jax.Array, int]:
    """Returns (x, labels, n_prefix) where n_prefix positions carry no loss
    (VLM patch embeddings)."""
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(jnp.bfloat16)       # (b, p, d) stub
        tx = embed(batch["tokens"], params)
        x = jnp.concatenate([pe, tx], axis=1)
        return shard(x, "batch", None, None), batch["labels"], pe.shape[1]
    x = embed(batch["tokens"], params)
    return x, batch["labels"], 0


def loss_fn(params: Params, batch: Dict[str, jax.Array],
            cfg: ModelConfig) -> jax.Array:
    x, labels, n_prefix = _inputs_to_embedding(params, batch, cfg)
    y = backbone(params, x, cfg)
    if n_prefix:
        y = y[:, n_prefix:]
    return chunked_softmax_xent(y, params["embedding"], labels,
                                chunk=cfg.loss_chunk,
                                unroll=cfg.unroll_layers)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def kv_cache_spec(cfg: ModelConfig, batch: int, max_len: int,
                  long_context: bool = False) -> KVCacheSpec:
    eff_len = max_len
    if cfg.sliding_window is not None:
        eff_len = min(max_len, cfg.sliding_window)   # ring buffer (SWA)
    return KVCacheSpec(layers=cfg.n_layers, batch=batch, max_len=eff_len,
                       n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
                       dtype_name="int8" if cfg.kv_cache_dtype == "int8"
                       else "bf16",
                       seq_axis="seq_full" if long_context else "seq_tp")


def decode_state_specs(cfg: ModelConfig, batch: int, max_len: int,
                       long_context: bool = False) -> Params:
    # per-row index: continuous batching runs each slot at its own position
    return {"kv": kv_cache_spec(cfg, batch, max_len, long_context).specs(),
            "index": ParamSpec((batch,), jnp.int32, ("batch",), init="zeros")}


def _decode_block(x, lp, kv_l, index, cfg):
    h = rmsnorm(x, lp["norm_attn"])
    a, kv_new = decode_attention(h, lp["attn"], cfg, kv_l, index)
    x = x + a
    h = rmsnorm(x, lp["norm_mlp"])
    if cfg.n_experts:
        y = moe(h, lp["moe"], cfg)
        if cfg.shared_expert:
            y = y + mlp(h, lp["mlp"], cfg)
    else:
        y = mlp(h, lp["mlp"], cfg)
    return x + y, kv_new


def decode_step(params: Params, state: Params, tokens: jax.Array,
                cfg: ModelConfig) -> Tuple[jax.Array, Params]:
    """One decode step: tokens (b, 1) -> (logits (b, V), new state)."""
    x = embed(tokens, params)
    index = state["index"]

    def body(carry, layer_in):
        lp, kv_l = layer_in
        x = carry
        x, kv_new = _decode_block(x, lp, kv_l, index, cfg)
        return x, kv_new

    x, kv = scan_layers(body, x, (params["blocks"], state["kv"]),
                        unroll=cfg.unroll_layers, collect=True)
    x = rmsnorm(x, params["final_norm"])
    logits = logits_last(x, params["embedding"])
    return logits, {"kv": kv, "index": index + 1}


def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            max_len: Optional[int] = None) -> Tuple[jax.Array, Params]:
    """Process a full prompt, building the KV cache; returns (last-position
    logits, decode state)."""
    x, _, _ = _inputs_to_embedding(
        params, {**batch, "labels": batch.get("labels", batch["tokens"])}, cfg)
    b, s, _ = x.shape
    max_len = s if max_len is None else max_len
    cache_len = kv_cache_spec(cfg, b, max_len).max_len
    pos = jnp.arange(s)

    # run backbone while capturing per-layer K/V (recomputed projections —
    # prefill caches built inline to keep the scan carry small)
    def body(carry, lp):
        x = carry
        h = rmsnorm(x, lp["norm_attn"])
        a, k, v = attention(h, lp["attn"], cfg, causal=True, positions=pos,
                            return_kv=True)
        x = x + a
        h2 = rmsnorm(x, lp["norm_mlp"])
        if cfg.n_experts:
            y = moe(h2, lp["moe"], cfg)
            if cfg.shared_expert:
                y = y + mlp(h2, lp["mlp"], cfg)
        else:
            y = mlp(h2, lp["mlp"], cfg)
        x = x + y
        if cache_len < s:                       # SWA ring: keep the tail
            k = k[:, -cache_len:]
            v = v[:, -cache_len:]
        elif cache_len > s:
            pad = cache_len - s
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if cfg.kv_cache_dtype == "int8":
            from .layers import _quant_kv
            kq, ks = _quant_kv(k)
            vq, vs = _quant_kv(v)
            return x, {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        return x, {"k": k, "v": v}

    x, kv = scan_layers(body, x, params["blocks"],
                        unroll=cfg.unroll_layers, collect=True)
    x = rmsnorm(x, params["final_norm"])
    logits = logits_last(x[:, -1:], params["embedding"])
    state = {"kv": kv, "index": jnp.full((b,), s, jnp.int32)}
    return logits, state
