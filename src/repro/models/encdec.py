"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The speech frontend is a stub per the assignment: ``input_specs()`` supplies
precomputed frame embeddings (b, t_enc, d).  Encoder: bidirectional attention;
decoder: causal self-attention + cross-attention to the encoder output.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..backend import matmul as bmm
from ..configs.base import ModelConfig
from .layers import (KVCacheSpec, _mask, _qkv, _repeat_kv, _sdpa, attention, scan_layers,
                     attention_param_specs, chunked_softmax_xent,
                     decode_attention, embed, embed_param_specs, logits_last,
                     mlp, mlp_param_specs, rmsnorm, rmsnorm_spec)
from .shardlib import ParamSpec, shard

Params = Dict[str, Any]


def _remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat == "full" else fn


def cross_attention_param_specs(cfg: ModelConfig, layers: int) -> Params:
    return attention_param_specs(cfg, layers=layers)


def cross_attention(x: jax.Array, mem_k: jax.Array, mem_v: jax.Array,
                    p: Params, cfg: ModelConfig) -> jax.Array:
    """x: (b, s, d) queries; mem_k/mem_v: (b, t, h_kv, dh) projected memory."""
    b, s, _ = x.shape
    q = bmm(x, p["wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = _repeat_kv(mem_k, cfg.n_heads)
    v = _repeat_kv(mem_v, cfg.n_heads)
    keep = jnp.ones((s, k.shape[1]), bool)
    o = _sdpa(q, k, v, keep, cfg.d_head).reshape(b, s, cfg.q_dim)
    return bmm(o, p["wo"])


def project_memory(mem: jax.Array, p: Params, cfg: ModelConfig):
    b, t, _ = mem.shape
    k = bmm(mem, p["wk"]).reshape(b, t, cfg.n_kv_heads, cfg.d_head)
    v = bmm(mem, p["wv"]).reshape(b, t, cfg.n_kv_heads, cfg.d_head)
    return k, v


def param_specs(cfg: ModelConfig) -> Params:
    Le, Ld = cfg.n_enc_layers, cfg.n_layers
    enc = {
        "norm_attn": ParamSpec((Le, cfg.d_model), jnp.float32,
                               ("layers", None), init="ones"),
        "norm_mlp": ParamSpec((Le, cfg.d_model), jnp.float32,
                              ("layers", None), init="ones"),
        "attn": attention_param_specs(cfg, layers=Le),
        "mlp": mlp_param_specs(cfg, layers=Le),
    }
    dec = {
        "norm_self": ParamSpec((Ld, cfg.d_model), jnp.float32,
                               ("layers", None), init="ones"),
        "norm_cross": ParamSpec((Ld, cfg.d_model), jnp.float32,
                                ("layers", None), init="ones"),
        "norm_mlp": ParamSpec((Ld, cfg.d_model), jnp.float32,
                              ("layers", None), init="ones"),
        "self_attn": attention_param_specs(cfg, layers=Ld),
        "cross_attn": cross_attention_param_specs(cfg, layers=Ld),
        "mlp": mlp_param_specs(cfg, layers=Ld),
    }
    return {**embed_param_specs(cfg), "encoder": enc, "decoder": dec,
            "enc_norm": rmsnorm_spec(cfg.d_model),
            "final_norm": rmsnorm_spec(cfg.d_model)}


def encode(params: Params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = shard(frames.astype(jnp.bfloat16), "batch", None, None)

    def block(x, lp):
        h = rmsnorm(x, lp["norm_attn"])
        x = x + attention(h, lp["attn"], cfg, causal=False)
        h = rmsnorm(x, lp["norm_mlp"])
        return x + mlp(h, lp["mlp"], cfg)

    blk = _remat(block, cfg)
    x = scan_layers(blk, x, params["encoder"], unroll=cfg.unroll_layers)
    return rmsnorm(x, params["enc_norm"])


def _dec_block(x, mem, lp, cfg):
    h = rmsnorm(x, lp["norm_self"])
    x = x + attention(h, lp["self_attn"], cfg, causal=True)
    h = rmsnorm(x, lp["norm_cross"])
    mk, mv = project_memory(mem, lp["cross_attn"], cfg)
    x = x + cross_attention(h, mk, mv, lp["cross_attn"], cfg)
    h = rmsnorm(x, lp["norm_mlp"])
    return x + mlp(h, lp["mlp"], cfg)


def loss_fn(params: Params, batch: Dict[str, jax.Array],
            cfg: ModelConfig) -> jax.Array:
    mem = encode(params, batch["frames"], cfg)
    x = embed(batch["tokens"], params)
    blk = _remat(functools.partial(_dec_block, cfg=cfg), cfg)
    x = scan_layers(lambda c, lp: blk(c, mem, lp), x, params["decoder"],
                    unroll=cfg.unroll_layers)
    x = rmsnorm(x, params["final_norm"])
    return chunked_softmax_xent(x, params["embedding"], batch["labels"],
                                cfg.loss_chunk, unroll=cfg.unroll_layers)


# ---------------------------------------------------------------------------
# Serving: cross-attention memory K/V are computed once at prefill; decoder
# self-attention uses a standard KV cache.
# ---------------------------------------------------------------------------


def decode_state_specs(cfg: ModelConfig, batch: int, max_len: int,
                       t_enc: Optional[int] = None) -> Params:
    t_enc = max_len // cfg.enc_frames_ratio if t_enc is None else t_enc
    self_kv = KVCacheSpec(layers=cfg.n_layers, batch=batch, max_len=max_len,
                          n_kv=cfg.n_kv_heads, d_head=cfg.d_head).specs()
    mem_shape = (cfg.n_layers, batch, t_enc, cfg.n_kv_heads, cfg.d_head)
    mem_logical = ("layers", "batch", "seq_tp", None, None)
    return {
        "kv": self_kv,
        "mem_k": ParamSpec(mem_shape, jnp.bfloat16, mem_logical, init="zeros"),
        "mem_v": ParamSpec(mem_shape, jnp.bfloat16, mem_logical, init="zeros"),
        "index": ParamSpec((batch,), jnp.int32, ("batch",), init="zeros"),
    }


def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            max_len: Optional[int] = None):
    """Encode frames + run decoder prompt; returns (logits, state)."""
    mem = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_len = s if max_len is None else max_len
    x = embed(tokens, params)
    pos = jnp.arange(s)

    def body(carry, lp):
        x = carry
        h = rmsnorm(x, lp["norm_self"])
        _, k, v = _qkv(h, lp["self_attn"], cfg, jnp.broadcast_to(pos, (b, s)))
        x = x + attention(h, lp["self_attn"], cfg, causal=True)
        h = rmsnorm(x, lp["norm_cross"])
        mk, mv = project_memory(mem, lp["cross_attn"], cfg)
        x = x + cross_attention(h, mk, mv, lp["cross_attn"], cfg)
        h = rmsnorm(x, lp["norm_mlp"])
        x = x + mlp(h, lp["mlp"], cfg)
        if max_len > s:
            pad = max_len - s
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, {"k": k, "v": v, "mk": mk, "mv": mv}

    x, caches = scan_layers(body, x, params["decoder"],
                            unroll=cfg.unroll_layers, collect=True)
    x = rmsnorm(x, params["final_norm"])
    logits = logits_last(x[:, -1:], params["embedding"])
    state = {"kv": {"k": caches["k"], "v": caches["v"]},
             "mem_k": caches["mk"], "mem_v": caches["mv"],
             "index": jnp.full((b,), s, jnp.int32)}
    return logits, state


def decode_step(params: Params, state: Params, tokens: jax.Array,
                cfg: ModelConfig):
    x = embed(tokens, params)
    index = state["index"]

    def body(carry, layer):
        x = carry
        lp, kv_l, mk, mv = layer
        h = rmsnorm(x, lp["norm_self"])
        a, kv_new = decode_attention(h, lp["self_attn"], cfg, kv_l, index)
        x = x + a
        h = rmsnorm(x, lp["norm_cross"])
        x = x + cross_attention(h, mk, mv, lp["cross_attn"], cfg)
        h = rmsnorm(x, lp["norm_mlp"])
        x = x + mlp(h, lp["mlp"], cfg)
        return x, kv_new

    x, kv = scan_layers(body, x, (params["decoder"], state["kv"],
                                  state["mem_k"], state["mem_v"]),
                        unroll=cfg.unroll_layers, collect=True)
    x = rmsnorm(x, params["final_norm"])
    logits = logits_last(x, params["embedding"])
    return logits, {**state, "kv": kv, "index": index + 1}
