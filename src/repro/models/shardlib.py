"""Logical-axis sharding: one place that maps model-level axis names onto the
production mesh (DESIGN.md Sec. 4).

Params and activations carry *logical* axes ("fsdp", "tp", "batch", "seq_tp",
...).  ``Rules`` resolves them to mesh axes; the same model code then runs on
the single-pod (16,16) mesh, the multi-pod (2,16,16) mesh, the tiny CPU test
meshes, or no mesh at all (rules resolve to fully-replicated).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Rules:
    """Logical-axis -> mesh-axis mapping."""

    table: Mapping[str, MeshAxes]
    mesh: Optional[Mesh] = None

    def resolve(self, logical: Sequence[Optional[str]]) -> P:
        """Logical -> PartitionSpec, de-duplicating mesh axes (first dim that
        claims an axis wins — needed for layouts like tp2d where 'tp' spans
        every axis and would otherwise collide with 'batch')."""
        out = []
        used: set = set()
        for name in logical:
            if name is None:
                out.append(None)
                continue
            if name not in self.table:
                raise KeyError(f"unknown logical axis {name!r}")
            axes = self.table[name]
            if axes is None:
                out.append(None)
                continue
            tup = (axes,) if isinstance(axes, str) else tuple(axes)
            free = tuple(a for a in tup if a not in used)
            used.update(free)
            if not free:
                out.append(None)
            elif len(free) == 1:
                out.append(free[0])
            else:
                out.append(free)
        return P(*out)

    def sharding(self, logical: Sequence[Optional[str]]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.resolve(logical))


def single_pod_rules(mesh: Optional[Mesh] = None) -> Rules:
    """(16, 16) ("data", "model"): DP+FSDP over data, TP over model."""
    return Rules({
        "layers": None,
        "batch": "data",
        "fsdp": "data",            # ZeRO-style parameter/optimizer sharding
        "tp": "model",             # heads / ffn / vocab / experts
        "expert": "model",
        "seq_tp": "model",         # sequence-sharded KV caches (decode)
        "seq_full": ("data", "model"),  # long-context single-batch caches
        "none": None,
    }, mesh)


def multi_pod_rules(mesh: Optional[Mesh] = None) -> Rules:
    """(2, 16, 16) ("pod", "data", "model"): pod joins the data axis."""
    return Rules({
        "layers": None,
        "batch": ("pod", "data"),
        "fsdp": ("pod", "data"),
        "tp": "model",
        "expert": "model",
        "seq_tp": "model",
        "seq_full": ("pod", "data", "model"),
        "none": None,
    }, mesh)


def replicated_rules() -> Rules:
    """All logical axes resolve to replication — for CPU tests/smoke runs."""
    return Rules({k: None for k in ("layers", "batch", "fsdp", "tp", "expert",
                                    "seq_tp", "seq_full", "none")})


_STATE = threading.local()


def current_rules() -> Rules:
    return getattr(_STATE, "rules", None) or replicated_rules()


@contextlib.contextmanager
def use_rules(rules: Rules):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def _axes_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint against the active rules (no-op when the rules
    carry no mesh — keeps model code mesh-agnostic).

    Best-effort: dims whose size the mapped mesh axes do not divide are left
    unconstrained (XLA picks), so alternate layouts like 256-way tp2d can be
    applied to weights without invalidating every activation hint."""
    rules = current_rules()
    if rules.mesh is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"rank mismatch: {logical} vs {x.shape}")
    spec = rules.resolve(logical)
    fixed = []
    for dim, axes in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        size = _axes_size(rules.mesh, axes)
        fixed.append(axes if size and dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*fixed)))


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Shape/dtype + logical axes of one parameter tensor."""

    shape: Tuple[int, ...]
    dtype: Any
    logical: Tuple[Optional[str], ...]
    init: str = "normal"            # "normal" | "zeros" | "ones" | "embed"

    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def spec_tree_to_structs(tree):
    return jax.tree.map(lambda s: s.struct(), tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def spec_tree_to_shardings(tree, rules: Rules):
    return jax.tree.map(lambda s: rules.sharding(s.logical), tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def spec_tree_to_pspecs(tree, rules: Rules):
    return jax.tree.map(lambda s: rules.resolve(s.logical), tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def init_param(key: jax.Array, s: ParamSpec) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
    scale = 0.02 if s.init == "embed" else 1.0 / jnp.sqrt(jnp.float32(fan_in))
    return (jax.random.normal(key, s.shape, jnp.float32) * scale).astype(s.dtype)


def init_param_tree(key: jax.Array, tree):
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef,
                              [init_param(k, s) for k, s in zip(keys, leaves)])


def param_count(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    total = 0
    for leaf in leaves:
        shape = leaf.shape if isinstance(leaf, ParamSpec) else leaf.shape
        n = 1
        for d in shape:
            n *= d
        total += n
    return total
