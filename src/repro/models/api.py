"""Family dispatch: one uniform interface over every architecture family.

    api = model_api(cfg)
    api.param_specs() / api.init_params(key)
    api.loss(params, batch)
    api.prefill(params, batch) -> (logits, state)
    api.decode_step(params, state, tokens) -> (logits, state)
    api.input_specs(shape) -> batch of ShapeDtypeStructs (+ logical shardings)
    api.decode_state_specs(shape) -> decode-state ParamSpecs
    api.make_decode_state(shape) -> all-zeros decode state
    api.slot_slice / slot_update / slot_reset -> per-slot state surgery
        (continuous batching: one batch row is admitted/evicted without
        recomputing the rest of the batch)
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import encdec, lm, ssm
from .shardlib import ParamSpec, init_param_tree

Params = Dict[str, Any]

_is_spec = lambda x: isinstance(x, ParamSpec)


@dataclasses.dataclass(frozen=True)
class BatchSpec:
    """ShapeDtypeStruct + logical axes for one batch input."""

    shape: Tuple[int, ...]
    dtype: Any
    logical: Tuple[Optional[str], ...]

    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def _token_batch(b: int, s: int, with_labels: bool) -> Dict[str, BatchSpec]:
    out = {"tokens": BatchSpec((b, s), jnp.int32, ("batch", None))}
    if with_labels:
        out["labels"] = BatchSpec((b, s), jnp.int32, ("batch", None))
    return out


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig
    #: Execution backend for the model's dense GEMMs (a ``repro.backend``
    #: name or instance); ``None`` keeps the surrounding scope's backend
    #: (usually the zero-overhead ``ideal`` XLA path).
    backend: Any = None

    def __post_init__(self) -> None:
        if self.backend is not None:
            # resolve a name to ONE instance up front: per-call resolution
            # would rebuild the device every step and strand its telemetry
            from ..backend import get_backend
            self.backend = get_backend(self.backend)

    def _scope(self):
        """Active-backend scope for model steps.

        Entered per call so any (re)trace sees this API's backend.  Routing
        binds at trace time: jit wrappers must not be shared across APIs
        with different backends (each ``ServeEngine`` builds its own)."""
        if self.backend is None:
            return contextlib.nullcontext()
        from ..backend import use_backend
        return use_backend(self.backend)

    # ---- params --------------------------------------------------------------

    def param_specs(self) -> Params:
        f = self.cfg.family
        if f in ("dense", "moe", "vlm"):
            return lm.param_specs(self.cfg)
        if f == "ssm":
            return ssm.rwkv6_param_tree(self.cfg)
        if f == "hybrid":
            return ssm.zamba2_param_tree(self.cfg)
        if f == "encdec":
            return encdec.param_specs(self.cfg)
        raise ValueError(f"unknown family {f}")

    def init_params(self, key: jax.Array) -> Params:
        return init_param_tree(key, self.param_specs())

    # ---- steps ---------------------------------------------------------------

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        f = self.cfg.family
        with self._scope():
            if f in ("dense", "moe", "vlm"):
                return lm.loss_fn(params, batch, self.cfg)
            if f == "ssm":
                return ssm.rwkv6_loss(params, batch, self.cfg)
            if f == "hybrid":
                return ssm.zamba2_loss(params, batch, self.cfg)
            if f == "encdec":
                return encdec.loss_fn(params, batch, self.cfg)
        raise ValueError(f)

    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                max_len: Optional[int] = None):
        f = self.cfg.family
        with self._scope():
            if f in ("dense", "moe", "vlm"):
                return lm.prefill(params, batch, self.cfg, max_len)
            if f == "encdec":
                return encdec.prefill(params, batch, self.cfg, max_len)
        raise NotImplementedError(
            f"prefill for {f}: SSM/hybrid prompts are absorbed by running "
            "decode_step over the prompt (O(1) state)")

    def decode_step(self, params: Params, state: Params, tokens: jax.Array):
        f = self.cfg.family
        with self._scope():
            if f in ("dense", "moe", "vlm"):
                return lm.decode_step(params, state, tokens, self.cfg)
            if f == "ssm":
                return ssm.rwkv6_decode_step(params, state, tokens, self.cfg)
            if f == "hybrid":
                return ssm.zamba2_decode_step(params, state, tokens, self.cfg)
            if f == "encdec":
                return encdec.decode_step(params, state, tokens, self.cfg)
        raise ValueError(f)

    # ---- specs ---------------------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> Dict[str, BatchSpec]:
        """Batch stand-ins for one assigned (arch x shape) cell."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        f = cfg.family
        if shape.kind == "decode":
            return {"tokens": BatchSpec((b, 1), jnp.int32, ("batch", None))}
        with_labels = shape.is_train
        if f == "vlm":
            p = min(cfg.frontend_tokens, s // 2)
            batch = {
                "patch_embeds": BatchSpec((b, p, cfg.d_model), jnp.bfloat16,
                                          ("batch", None, None)),
                **_token_batch(b, s - p, with_labels),
            }
            return batch
        if f == "encdec":
            t_enc = max(s // cfg.enc_frames_ratio, 1)
            return {
                "frames": BatchSpec((b, t_enc, cfg.d_model), jnp.bfloat16,
                                    ("batch", None, None)),
                **_token_batch(b, s, with_labels),
            }
        return _token_batch(b, s, with_labels)

    def decode_state_specs(self, shape: ShapeConfig) -> Params:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        long_ctx = shape.name == "long_500k"
        f = cfg.family
        if f in ("dense", "moe", "vlm"):
            return lm.decode_state_specs(cfg, b, s, long_context=long_ctx)
        if f == "ssm":
            return ssm.rwkv6_state_specs(cfg, b)
        if f == "hybrid":
            return ssm.zamba2_state_specs(cfg, b, s, long_context=long_ctx)
        if f == "encdec":
            return encdec.decode_state_specs(cfg, b, s)
        raise ValueError(f)

    # ---- per-slot state surgery (continuous batching) ------------------------
    #
    # Every decode-state leaf carries its logical axes in the spec tree, so the
    # batch ("slot") axis can be located per leaf and one row sliced/scattered
    # with a dynamic_slice — no per-family knowledge, no batch recompute.

    def make_decode_state(self, shape: ShapeConfig) -> Params:
        """All-zeros decode state matching ``decode_state_specs(shape)``."""
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.decode_state_specs(shape), is_leaf=_is_spec)

    def slot_slice(self, shape: ShapeConfig, state: Params,
                   slot: jax.Array) -> Params:
        """Extract batch row ``slot`` of a decode state as a batch-1 state."""
        def take(spec, leaf):
            if "batch" not in spec.logical:
                return leaf
            ax = spec.logical.index("batch")
            return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=ax)
        return jax.tree.map(take, self.decode_state_specs(shape), state,
                            is_leaf=_is_spec)

    def slot_update(self, shape: ShapeConfig, state: Params, slot: jax.Array,
                    sub: Params) -> Params:
        """Scatter a batch-1 sub-state (e.g. a fresh prefill) into row
        ``slot``; every other slot's state is untouched."""
        def put(spec, leaf, s):
            if "batch" not in spec.logical:
                return leaf
            ax = spec.logical.index("batch")
            return jax.lax.dynamic_update_slice_in_dim(
                leaf, s.astype(leaf.dtype), slot, axis=ax)
        return jax.tree.map(put, self.decode_state_specs(shape), state, sub,
                            is_leaf=_is_spec)

    def slot_reset(self, shape: ShapeConfig, state: Params,
                   slot: jax.Array) -> Params:
        """Zero one slot's state (eviction) without recomputing the batch."""
        def zero(spec, leaf):
            if "batch" not in spec.logical:
                return leaf
            ax = spec.logical.index("batch")
            shape1 = leaf.shape[:ax] + (1,) + leaf.shape[ax + 1:]
            return jax.lax.dynamic_update_slice_in_dim(
                leaf, jnp.zeros(shape1, leaf.dtype), slot, axis=ax)
        return jax.tree.map(zero, self.decode_state_specs(shape), state,
                            is_leaf=_is_spec)


def model_api(cfg: ModelConfig, backend: Any = None) -> ModelAPI:
    return ModelAPI(cfg, backend=backend)
