"""State-space / linear-recurrence architectures:

* Mamba2 (SSD, chunked-parallel training form + recurrent decode) — the
  zamba2-2.7b building block [arXiv:2405.21060 / 2411.15242];
* RWKV6 "Finch" time-mix with data-dependent decay + channel-mix
  [arXiv:2404.05892];
* Zamba2 hybrid: stacked Mamba2 blocks with one *shared* attention+MLP block
  applied every ``shared_attn_period`` layers.

Training uses chunked matmul forms (MXU-friendly — these are also the Pallas
kernel targets in repro.kernels); decode uses O(1) recurrent state updates.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..backend import current_backend
from ..backend import matmul as bmm
from ..configs.base import ModelConfig
from .layers import (attention, attention_param_specs, chunked_softmax_xent, scan_layers,
                     decode_attention, embed, embed_param_specs, logits_last,
                     mlp, mlp_param_specs, rmsnorm, rmsnorm_spec)
from .shardlib import ParamSpec, shard

Params = Dict[str, Any]

EXP_CLAMP = 30.0


def _remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat == "full" else fn


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================


def mamba2_dims(cfg: ModelConfig) -> Dict[str, int]:
    d_inner = 2 * cfg.d_model
    n_heads = d_inner // cfg.ssm_d_head
    conv_dim = d_inner + 2 * cfg.ssm_state          # x, B, C share the conv
    in_dim = 2 * d_inner + 2 * cfg.ssm_state + n_heads
    return dict(d_inner=d_inner, n_heads=n_heads, conv_dim=conv_dim,
                in_dim=in_dim, d_state=cfg.ssm_state, p=cfg.ssm_d_head)


def mamba2_param_specs(cfg: ModelConfig, layers: int) -> Params:
    dims = mamba2_dims(cfg)
    L, d = layers, cfg.d_model
    bf = jnp.bfloat16
    return {
        "norm": ParamSpec((L, d), jnp.float32, ("layers", None), init="ones"),
        "in_proj": ParamSpec((L, d, dims["in_dim"]), bf,
                             ("layers", "fsdp", "tp")),
        "conv_w": ParamSpec((L, 4, dims["conv_dim"]), bf,
                            ("layers", None, "tp")),
        "A_log": ParamSpec((L, dims["n_heads"]), jnp.float32,
                           ("layers", None), init="zeros"),
        "D": ParamSpec((L, dims["n_heads"]), jnp.float32,
                       ("layers", None), init="ones"),
        "dt_bias": ParamSpec((L, dims["n_heads"]), jnp.float32,
                             ("layers", None), init="zeros"),
        "gate_norm": ParamSpec((L, dims["d_inner"]), jnp.float32,
                               ("layers", None), init="ones"),
        "out_proj": ParamSpec((L, dims["d_inner"], d), bf,
                              ("layers", "tp", "fsdp")),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv, kernel 4. x: (b, s, c), w: (4, c)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype)


def _split_zxbcdt(zxbcdt: jax.Array, dims: Dict[str, int]):
    z, xbc, dt = jnp.split(
        zxbcdt, [dims["d_inner"], dims["d_inner"] + dims["conv_dim"]], axis=-1)
    return z, xbc, dt


def mamba2_forward(x: jax.Array, lp: Params, cfg: ModelConfig,
                   ssm_state: Optional[jax.Array] = None,
                   conv_state: Optional[jax.Array] = None,
                   return_state: bool = False):
    """Chunked SSD forward. x: (b, s, d) -> (b, s, d) [+ final states].

    Chunk math (per head h, state size N, head dim P):
      da_t = dt_t * -exp(A_log_h); cum_t = cumsum(da) within chunk;
      intra: Y[t] += sum_{s<=t} (C_t . B_s) * exp(cum_t - cum_s) * dt_s x_s
      chunk state: S_c = sum_s exp(cum_last - cum_s) dt_s (B_s (x) x_s)
      carry: R_{c+1} = R_c * exp(cum_last) + S_c ; Y[t] += (C_t . R_c) exp(cum_t)
    """
    dims = mamba2_dims(cfg)
    b, s, _ = x.shape
    zxbcdt = bmm(x, lp["in_proj"])
    z, xbc, dt = _split_zxbcdt(zxbcdt, dims)
    xbc = _causal_conv(xbc, lp["conv_w"], conv_state)
    xs, B, C = jnp.split(xbc, [dims["d_inner"],
                               dims["d_inner"] + dims["d_state"]], axis=-1)
    h, p, n = dims["n_heads"], dims["p"], dims["d_state"]
    xh = xs.reshape(b, s, h, p).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])   # (b, s, h)
    a = -jnp.exp(lp["A_log"])                                      # (h,)
    da = dt * a                                                    # (b, s, h)

    ch = min(cfg.ssm_chunk, s)
    if s % ch:
        ch = s
    nc = s // ch
    Bf = B.astype(jnp.float32).reshape(b, nc, ch, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, ch, n)
    dac = da.reshape(b, nc, ch, h)
    xc = (xh * dt[..., None]).reshape(b, nc, ch, h, p)
    cum = jnp.cumsum(dac, axis=2)                                  # (b,nc,ch,h)

    scores = jnp.einsum("bctn,bcsn->bcts", Cf, Bf)                 # (b,nc,t,s)
    decay = jnp.exp(jnp.clip(cum[:, :, :, None] - cum[:, :, None, :],
                             -EXP_CLAMP, EXP_CLAMP))               # (b,nc,t,s,h)
    mask = jnp.tril(jnp.ones((ch, ch), bool))
    w = jnp.where(mask[None, None, :, :, None],
                  scores[..., None] * decay, 0.0)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", w, xc)

    # per-chunk boundary states
    tail = jnp.exp(jnp.clip(cum[:, :, -1:, :] - cum, -EXP_CLAMP, EXP_CLAMP))
    S_c = jnp.einsum("bcsn,bcsh,bcshp->bchnp", Bf, tail, xc)       # (b,nc,h,n,p)
    chunk_decay = jnp.exp(jnp.clip(cum[:, :, -1, :], -EXP_CLAMP, 0.0))

    R0 = (jnp.zeros((b, h, n, p), jnp.float32) if ssm_state is None
          else ssm_state.astype(jnp.float32))

    def carry_fn(R, inp):
        S, dec = inp
        out = R
        R = R * dec[:, :, None, None] + S
        return R, out

    S_t = jnp.moveaxis(S_c, 1, 0)
    dec_t = jnp.moveaxis(chunk_decay, 1, 0)
    R_final, R_before = jax.lax.scan(carry_fn, R0, (S_t, dec_t))
    R_before = jnp.moveaxis(R_before, 0, 1)                        # (b,nc,h,n,p)

    y_inter = jnp.einsum("bctn,bchnp->bcthp", Cf, R_before)
    y_inter = y_inter * jnp.exp(jnp.clip(cum, -EXP_CLAMP, 0.0))[..., None]
    y = (y_intra + y_inter).reshape(b, s, h, p)
    y = y + lp["D"][None, None, :, None] * xh
    y = y.reshape(b, s, dims["d_inner"])

    gated = y * jax.nn.silu(z.astype(jnp.float32))
    gated = rmsnorm(gated.astype(jnp.bfloat16), lp["gate_norm"])
    out = bmm(gated, lp["out_proj"])
    if return_state:
        conv_out = jnp.concatenate(
            [conv_state.astype(xbc.dtype) if conv_state is not None else
             jnp.zeros((b, 3, dims["conv_dim"]), xbc.dtype),
             # pre-activation conv input tail: slice the projection already
             # computed above (a second bmm would re-run the GEMM on the
             # host backend and double-count its MACs/energy)
             zxbcdt[:, :, dims["d_inner"]:dims["d_inner"] +
                    dims["conv_dim"]]], axis=1)[:, -3:]
        return out, R_final, conv_out
    return out


def mamba2_step(x: jax.Array, lp: Params, cfg: ModelConfig,
                ssm_state: jax.Array, conv_state: jax.Array):
    """Single-token recurrence. x: (b, 1, d); ssm_state: (b, h, n, p);
    conv_state: (b, 3, conv_dim) raw pre-conv inputs."""
    dims = mamba2_dims(cfg)
    b = x.shape[0]
    zxbcdt = bmm(x, lp["in_proj"])
    z, xbc_new, dt = _split_zxbcdt(zxbcdt, dims)
    window = jnp.concatenate([conv_state.astype(xbc_new.dtype), xbc_new], axis=1)
    conv_w = lp["conv_w"]
    xbc = sum(window[:, i] * conv_w[i][None] for i in range(4))
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)     # (b, conv)
    xs, B, C = jnp.split(xbc, [dims["d_inner"],
                               dims["d_inner"] + dims["d_state"]], axis=-1)
    h, p, n = dims["n_heads"], dims["p"], dims["d_state"]
    xh = xs.reshape(b, h, p).astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + lp["dt_bias"])  # (b,h)
    da = jnp.exp(jnp.clip(dt1 * -jnp.exp(lp["A_log"]), -EXP_CLAMP, 0.0))
    Bf = B.astype(jnp.float32)                                     # (b, n)
    Cf = C.astype(jnp.float32)
    new_state = (ssm_state * da[:, :, None, None]
                 + jnp.einsum("bn,bh,bhp->bhnp", Bf, dt1, xh))
    y = jnp.einsum("bn,bhnp->bhp", Cf, new_state) + lp["D"][None, :, None] * xh
    y = y.reshape(b, 1, dims["d_inner"])
    gated = y * jax.nn.silu(z.astype(jnp.float32))
    gated = rmsnorm(gated.astype(jnp.bfloat16), lp["gate_norm"])
    out = bmm(gated, lp["out_proj"])
    return out, new_state, window[:, -3:]


# ===========================================================================
# RWKV6 (Finch)
# ===========================================================================


def rwkv6_dims(cfg: ModelConfig) -> Dict[str, int]:
    return dict(h=cfg.n_heads, p=cfg.d_head, d=cfg.d_model,
                lora=max(32, cfg.d_model // 64))


def rwkv6_param_specs(cfg: ModelConfig) -> Params:
    dims = rwkv6_dims(cfg)
    L, d, lora = cfg.n_layers, cfg.d_model, dims["lora"]
    bf = jnp.bfloat16
    return {
        "norm_att": ParamSpec((L, d), jnp.float32, ("layers", None), init="ones"),
        "norm_ffn": ParamSpec((L, d), jnp.float32, ("layers", None), init="ones"),
        # time-mix interpolation coefficients for r,k,v,w,g
        "tmix_mu": ParamSpec((L, 5, d), jnp.float32, ("layers", None, None),
                        init="zeros"),
        "wr": ParamSpec((L, d, d), bf, ("layers", "fsdp", "tp")),
        "wk": ParamSpec((L, d, d), bf, ("layers", "fsdp", "tp")),
        "wv": ParamSpec((L, d, d), bf, ("layers", "fsdp", "tp")),
        "wg": ParamSpec((L, d, d), bf, ("layers", "fsdp", "tp")),
        "wo": ParamSpec((L, d, d), bf, ("layers", "tp", "fsdp")),
        # data-dependent decay: w = exp(-exp(base + tanh(x A) B))
        "w_base": ParamSpec((L, d), jnp.float32, ("layers", None), init="zeros"),
        "w_lora_a": ParamSpec((L, d, lora), bf, ("layers", "fsdp", None)),
        "w_lora_b": ParamSpec((L, lora, d), bf, ("layers", None, "tp")),
        "u": ParamSpec((L, dims["h"], dims["p"]), jnp.float32,
                       ("layers", None, None), init="zeros"),
        "ln_x": ParamSpec((L, d), jnp.float32, ("layers", None), init="ones"),
        # channel mix
        "cmix_mu": ParamSpec((L, 2, d), jnp.float32, ("layers", None, None),
                            init="zeros"),
        "ck": ParamSpec((L, d, cfg.d_ff), bf, ("layers", "fsdp", "tp")),
        "cv": ParamSpec((L, cfg.d_ff, d), bf, ("layers", "tp", "fsdp")),
        "cr": ParamSpec((L, d, d), bf, ("layers", "fsdp", "tp")),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """(b, s, d) -> previous-token tensor; `prev` seeds position 0 (decode)."""
    first = (jnp.zeros_like(x[:, :1]) if prev is None
             else prev[:, None].astype(x.dtype))
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def wkv6_chunked(r, k, v, w_log, u, state, chunk: int,
                 compute_dtype=jnp.float32):
    """Chunked WKV recurrence (shared by model fwd and kernels/ref).

      y_t = r_t . (S_{t-1} + (u (*) k_t) v_t^T) ; S_t = diag(w_t) S_{t-1} + k_t v_t^T

    r,k,v: (b, s, h, p) f32; w_log: (b, s, h, p) = log decay (<= 0);
    u: (h, p); state: (b, h, p, p).  Returns (y, final_state).
    """
    b, s, h, p = r.shape
    ch = min(chunk, s)
    if s % ch:
        ch = s
    nc = s // ch
    rc = r.reshape(b, nc, ch, h, p).astype(compute_dtype)
    kc = k.reshape(b, nc, ch, h, p).astype(compute_dtype)
    vc = v.reshape(b, nc, ch, h, p).astype(compute_dtype)
    lw = jnp.cumsum(w_log.reshape(b, nc, ch, h, p), axis=2)   # f32 cumsum

    # A[t, s] = sum_p r_t,p k_s,p exp(lw_{t-1,p} - lw_{s,p})  for s < t.
    # Exponents are centred at half the chunk's total decay so exp() stays in
    # f32 range for any chunk length (products telescope to <= 1).
    lw_prev = jnp.concatenate([jnp.zeros_like(lw[:, :, :1]), lw[:, :, :-1]],
                              axis=2)
    m = 0.5 * lw[:, :, -1:]
    rr = rc * jnp.exp(jnp.clip(lw_prev - m, -2 * EXP_CLAMP,
                               2 * EXP_CLAMP)).astype(compute_dtype)
    kk = kc * jnp.exp(jnp.clip(m - lw, -2 * EXP_CLAMP,
                               2 * EXP_CLAMP)).astype(compute_dtype)
    A = jnp.einsum("bcthp,bcshp->bchts", rr, kk).astype(compute_dtype)
    mask = jnp.tril(jnp.ones((ch, ch), bool), k=-1)
    A = jnp.where(mask[None, None, None], A, 0.0)
    diag = jnp.einsum("bcthp,hp,bcthp->bcth", rc, u, kc)
    y = jnp.einsum("bchts,bcshp->bcthp", A, vc)
    y = y + diag[..., None] * vc

    # inter-chunk: y += (r_t (*) exp(lw_{t-1})) . S_in ; state updates
    tail = jnp.exp(jnp.clip(lw[:, :, -1:] - lw, -EXP_CLAMP, EXP_CLAMP))
    k_tail = kc * tail                                          # decay to end
    S_c = jnp.einsum("bcshp,bcshq->bchpq", k_tail, vc)          # (b,nc,h,p,p)
    chunk_decay = jnp.exp(jnp.clip(lw[:, :, -1], -EXP_CLAMP, 0.0))  # (b,nc,h,p)

    def carry(S, inp):
        S_add, dec, r_blk, lwp_blk = inp
        # y_inter for this chunk uses S before update
        y_in = jnp.einsum("bthp,bhpq->bthq",
                          r_blk * jnp.exp(jnp.clip(lwp_blk, -EXP_CLAMP, 0.0)), S)
        S = S * dec[:, :, :, None] + S_add
        return S, y_in

    S_final, y_inter = jax.lax.scan(
        carry, state.astype(jnp.float32),
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0),
         jnp.moveaxis(rc, 1, 0), jnp.moveaxis(lw_prev, 1, 0)))
    y = y + jnp.moveaxis(y_inter, 0, 1)
    return y.reshape(b, s, h, p), S_final


def rwkv6_timemix(x, lp, cfg, state=None, prev=None, return_state=False):
    dims = rwkv6_dims(cfg)
    b, s, d = x.shape
    xs = _token_shift(x, prev)
    act = jnp.bfloat16 if cfg.ssm_bf16 else jnp.float32
    if cfg.fused_rwkv_proj:
        # y_i = x @ W_i + (mu_i*delta) @ W_i: read x and delta ONCE through a
        # stacked projection instead of 5 separate mixed-input matmuls (§Perf)
        delta = xs - x
        W = jnp.stack([lp["wr"], lp["wk"], lp["wv"], lp["wg"]])   # (4, d, d)
        mu = lp["tmix_mu"][:4].astype(jnp.float32)                # (4, d)
        W_mix = (mu[:, :, None] * W.astype(jnp.float32)).astype(W.dtype)
        if current_backend().is_ideal:
            base = jnp.einsum("bsd,idf->ibsf", x, W)
            mixp = jnp.einsum("bsd,idf->ibsf", delta, W_mix)
        else:
            base = jnp.stack([bmm(x, W[i]) for i in range(4)])
            mixp = jnp.stack([bmm(delta, W_mix[i]) for i in range(4)])
        rkvg = base + mixp
        r, k, v, gg = (rkvg[i].astype(act) for i in range(4))
        r = r.reshape(b, s, dims["h"], dims["p"])
        k = k.reshape(b, s, dims["h"], dims["p"])
        v = v.reshape(b, s, dims["h"], dims["p"])
        g = jax.nn.silu(gg.astype(jnp.float32)).astype(act)
        xw = x + lp["tmix_mu"][4][None, None].astype(x.dtype) * delta
    else:
        mix = lambda i: (x + lp["tmix_mu"][i][None, None].astype(x.dtype)
                         * (xs - x))
        xr, xk, xv, xw, xg = (mix(i) for i in range(5))
        r = bmm(xr, lp["wr"]).astype(act).reshape(b, s, dims["h"], dims["p"])
        k = bmm(xk, lp["wk"]).astype(act).reshape(b, s, dims["h"], dims["p"])
        v = bmm(xv, lp["wv"]).astype(act).reshape(b, s, dims["h"], dims["p"])
        g = jax.nn.silu(bmm(xg, lp["wg"]).astype(jnp.float32)).astype(act)
    w_log = -jnp.exp(lp["w_base"][None, None]
                     + bmm(jnp.tanh(bmm(xw, lp["w_lora_a"])
                                    .astype(jnp.float32)),
                           lp["w_lora_b"].astype(jnp.float32)))
    w_log = w_log.reshape(b, s, dims["h"], dims["p"])
    S0 = (jnp.zeros((b, dims["h"], dims["p"], dims["p"]), jnp.float32)
          if state is None else state)
    y, S = wkv6_chunked(r.astype(jnp.float32) if not cfg.ssm_bf16 else r,
                        k if cfg.ssm_bf16 else k.astype(jnp.float32),
                        v if cfg.ssm_bf16 else v.astype(jnp.float32),
                        w_log, lp["u"], S0, cfg.ssm_chunk or 64,
                        compute_dtype=act)
    y = y.reshape(b, s, d)
    y = rmsnorm(y.astype(jnp.bfloat16), lp["ln_x"]).astype(jnp.float32)
    out = bmm((y * g.astype(jnp.float32)).astype(jnp.bfloat16), lp["wo"])
    if return_state:
        return out, S, x[:, -1]
    return out


def rwkv6_channelmix(x, lp, prev=None, return_state=False):
    xs = _token_shift(x, prev)
    xk = x + lp["cmix_mu"][0][None, None].astype(x.dtype) * (xs - x)
    xr = x + lp["cmix_mu"][1][None, None].astype(x.dtype) * (xs - x)
    k = jnp.square(jax.nn.relu(bmm(xk, lp["ck"]).astype(jnp.float32)))
    kv = bmm(k.astype(jnp.bfloat16), lp["cv"])
    out = jax.nn.sigmoid(bmm(xr, lp["cr"]).astype(jnp.float32)).astype(kv.dtype) * kv
    if return_state:
        return out, x[:, -1]
    return out


def rwkv6_block(x, lp, cfg):
    h = rmsnorm(x, lp["norm_att"])
    x = x + rwkv6_timemix(h, lp, cfg)
    h = rmsnorm(x, lp["norm_ffn"])
    x = x + rwkv6_channelmix(h, lp)
    return shard(x, "batch", None, None)


def rwkv6_param_tree(cfg: ModelConfig) -> Params:
    return {**embed_param_specs(cfg),
            "blocks": rwkv6_param_specs(cfg),
            "final_norm": rmsnorm_spec(cfg.d_model)}


def rwkv6_loss(params, batch, cfg):
    x = embed(batch["tokens"], params)
    block = _remat(functools.partial(rwkv6_block, cfg=cfg), cfg)
    x = scan_layers(block, x, params["blocks"], unroll=cfg.unroll_layers)
    x = rmsnorm(x, params["final_norm"])
    return chunked_softmax_xent(x, params["embedding"], batch["labels"],
                                cfg.loss_chunk, unroll=cfg.unroll_layers)


def rwkv6_state_specs(cfg: ModelConfig, batch: int) -> Params:
    dims = rwkv6_dims(cfg)
    L = cfg.n_layers
    return {
        "wkv": ParamSpec((L, batch, dims["h"], dims["p"], dims["p"]),
                         jnp.float32, ("layers", "batch", "tp", None, None),
                         init="zeros"),
        "prev_att": ParamSpec((L, batch, cfg.d_model), jnp.bfloat16,
                              ("layers", "batch", None), init="zeros"),
        "prev_ffn": ParamSpec((L, batch, cfg.d_model), jnp.bfloat16,
                              ("layers", "batch", None), init="zeros"),
        "index": ParamSpec((batch,), jnp.int32, ("batch",), init="zeros"),
    }


def rwkv6_decode_step(params, state, tokens, cfg):
    x = embed(tokens, params)

    def body(carry, layer):
        x = carry
        lp, wkv, pa, pf = layer
        h = rmsnorm(x, lp["norm_att"])
        att, wkv_new, pa_new = rwkv6_timemix(h, lp, cfg, state=wkv, prev=pa,
                                             return_state=True)
        x = x + att
        h = rmsnorm(x, lp["norm_ffn"])
        ffn, pf_new = rwkv6_channelmix(h, lp, prev=pf, return_state=True)
        x = x + ffn
        return x, (wkv_new, pa_new, pf_new)

    x, (wkv, pa, pf) = scan_layers(
        body, x, (params["blocks"], state["wkv"], state["prev_att"],
                  state["prev_ffn"]), unroll=cfg.unroll_layers, collect=True)
    x = rmsnorm(x, params["final_norm"])
    logits = logits_last(x, params["embedding"])
    return logits, {"wkv": wkv, "prev_att": pa.astype(jnp.bfloat16),
                    "prev_ffn": pf.astype(jnp.bfloat16),
                    "index": state["index"] + 1}


# ===========================================================================
# Zamba2 hybrid
# ===========================================================================


def zamba2_param_tree(cfg: ModelConfig) -> Params:
    n_apps = cfg.n_layers // cfg.shared_attn_period
    shared = {
        "norm_attn": rmsnorm_spec(cfg.d_model),
        "norm_mlp": rmsnorm_spec(cfg.d_model),
        "attn": attention_param_specs(cfg, layers=0),
        "mlp": mlp_param_specs(cfg, layers=0),
        "down": ParamSpec((2 * cfg.d_model, cfg.d_model), jnp.bfloat16,
                          ("fsdp", "tp")),
    }
    return {**embed_param_specs(cfg),
            "mamba": mamba2_param_specs(cfg, cfg.n_layers),
            "shared": shared,
            "final_norm": rmsnorm_spec(cfg.d_model),
            }


def _zamba_shared_block(x, emb0, sp, cfg):
    """Shared attention block: concat(hidden, first-layer embedding) ->
    down-projection -> attn -> mlp (zamba2 concat re-use trick)."""
    cat = jnp.concatenate([x, emb0], axis=-1)
    h = bmm(cat, sp["down"])
    a = rmsnorm(h, sp["norm_attn"])
    h = h + attention(a, sp["attn"], cfg, causal=True)
    a = rmsnorm(h, sp["norm_mlp"])
    h = h + mlp(a, sp["mlp"], cfg)
    return x + h


def zamba2_loss(params, batch, cfg):
    x = embed(batch["tokens"], params)
    emb0 = x
    period = cfg.shared_attn_period
    n_groups = cfg.n_layers // period
    mamba = jax.tree.map(
        lambda a: a.reshape((n_groups, period) + a.shape[1:]), params["mamba"])

    def group(x, gp):
        inner_r = _remat(
            lambda c, lp: c + mamba2_forward(rmsnorm(c, lp["norm"]), lp, cfg),
            cfg)
        x = scan_layers(inner_r, x, gp, unroll=cfg.unroll_layers)
        x = _remat(lambda h: _zamba_shared_block(h, emb0, params["shared"],
                                                 cfg), cfg)(x)
        return x

    x = scan_layers(group, x, mamba, unroll=cfg.unroll_layers)
    x = rmsnorm(x, params["final_norm"])
    return chunked_softmax_xent(x, params["embedding"], batch["labels"],
                                cfg.loss_chunk, unroll=cfg.unroll_layers)


def zamba2_state_specs(cfg: ModelConfig, batch: int, max_len: int,
                       long_context: bool = False) -> Params:
    dims = mamba2_dims(cfg)
    L = cfg.n_layers
    n_apps = L // cfg.shared_attn_period
    seq_ax = "seq_full" if long_context else "seq_tp"
    return {
        "ssm": ParamSpec((L, batch, dims["n_heads"], dims["d_state"],
                          dims["p"]), jnp.float32,
                         ("layers", "batch", "tp", None, None), init="zeros"),
        "conv": ParamSpec((L, batch, 3, dims["conv_dim"]), jnp.bfloat16,
                          ("layers", "batch", None, "tp"), init="zeros"),
        "kv": {
            "k": ParamSpec((n_apps, batch, max_len, cfg.n_kv_heads, cfg.d_head),
                           jnp.bfloat16, ("layers", "batch", seq_ax, None, None),
                           init="zeros"),
            "v": ParamSpec((n_apps, batch, max_len, cfg.n_kv_heads, cfg.d_head),
                           jnp.bfloat16, ("layers", "batch", seq_ax, None, None),
                           init="zeros"),
        },
        "index": ParamSpec((batch,), jnp.int32, ("batch",), init="zeros"),
    }


def zamba2_decode_step(params, state, tokens, cfg):
    x = embed(tokens, params)
    emb0 = x
    period = cfg.shared_attn_period
    n_groups = cfg.n_layers // period
    regroup = lambda a: a.reshape((n_groups, period) + a.shape[1:])
    mamba = jax.tree.map(regroup, params["mamba"])
    ssm_g = regroup(state["ssm"])
    conv_g = regroup(state["conv"])
    index = state["index"]
    sp = params["shared"]

    def group(carry, inp):
        x = carry
        gp, ssm_s, conv_s, kv_l = inp

        def inner(c, layer):
            x = c
            lp, s1, c1 = layer
            y, s2, c2 = mamba2_step(rmsnorm(x, lp["norm"]), lp, cfg, s1, c1)
            return x + y, (s2, c2)

        x, (ssm_new, conv_new) = scan_layers(inner, x, (gp, ssm_s, conv_s),
                                             unroll=cfg.unroll_layers,
                                             collect=True)
        # shared attention with its per-application KV cache
        cat = jnp.concatenate([x, emb0], axis=-1)
        h = bmm(cat, sp["down"])
        a = rmsnorm(h, sp["norm_attn"])
        att, kv_new = decode_attention(a, sp["attn"], cfg, kv_l, index)
        h = h + att
        a = rmsnorm(h, sp["norm_mlp"])
        h = h + mlp(a, sp["mlp"], cfg)
        return x + h, (ssm_new, conv_new, kv_new)

    x, (ssm, conv, kv) = scan_layers(
        group, x, (mamba, ssm_g, conv_g, state["kv"]),
        unroll=cfg.unroll_layers, collect=True)
    flat = lambda a: a.reshape((-1,) + a.shape[2:])
    x = rmsnorm(x, params["final_norm"])
    logits = logits_last(x, params["embedding"])
    return logits, {"ssm": flat(ssm), "conv": flat(conv).astype(jnp.bfloat16),
                    "kv": kv, "index": index + 1}
