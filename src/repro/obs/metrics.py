"""Dependency-free metrics registry: counters, gauges, fixed-bucket
histograms, with label support and deterministic renderers.

Design constraints (they shape everything below):

* **Never touch jax.** Every operation is plain-python float arithmetic
  under a small lock, so the serve frontend can render a scrape on the
  asyncio thread while the pump thread is inside a jitted step.
* **Injectable clock.** The registry carries the same clock the engine
  uses (``time.monotonic`` in production, :class:`~repro.server.harness.
  VirtualClock` under the load harness), so latency histograms are
  replayable: two identical virtual-time runs produce *bit-identical*
  renders.
* **Deterministic renders.** No timestamps, no ids, no wall-clock leaks
  in the exposition output; metrics sort by name, children by label
  tuple, so ``render_prometheus()`` is a pure function of the recorded
  observations.

The exposition format follows the Prometheus text format (cumulative
``le`` buckets, ``+Inf``, ``_sum``/``_count`` series); ``render_json``
gives the same data as a plain dict for ``/v1/stats``.
"""

from __future__ import annotations

import math
import threading
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_LATENCY_BUCKETS",
]

# Latency buckets (seconds) sized for both virtual-time harness steps
# (tens of ms) and real TTFTs on the interpreter-speed emulated backend.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

_LabelKey = Tuple[Tuple[str, str], ...]


def _fmt(v: float) -> str:
    """Prometheus sample value: integers without a trailing ``.0``."""
    if v != v:  # NaN
        return "NaN"
    if v in (math.inf, -math.inf):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _fmt_labels(key: _LabelKey, extra: Optional[Tuple[str, str]] = None) -> str:
    pairs = list(key) + ([extra] if extra else [])
    if not pairs:
        return ""
    inner = ",".join(
        '%s="%s"' % (k, v.replace("\\", "\\\\").replace('"', '\\"'))
        for k, v in pairs)
    return "{" + inner + "}"


class _Metric:
    """Shared plumbing: name/help/label validation and child lookup."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Tuple[str, ...],
                 lock: threading.RLock) -> None:
        self.name = name
        self.help = help
        self.labelnames = labelnames
        self._lock = lock

    def _key(self, labels: Dict[str, str]) -> _LabelKey:
        if tuple(sorted(labels)) != tuple(sorted(self.labelnames)):
            raise ValueError(
                "metric %r expects labels %r, got %r"
                % (self.name, self.labelnames, tuple(labels)))
        return tuple((k, str(labels[k])) for k in self.labelnames)


class Counter(_Metric):
    """Monotonic counter. ``labels(**kv)`` returns a bound child."""

    kind = "counter"

    def __init__(self, name: str, help: str, labelnames: Tuple[str, ...],
                 lock: threading.RLock) -> None:
        super().__init__(name, help, labelnames, lock)
        self._values: Dict[_LabelKey, float] = {}

    def labels(self, **labels: str) -> "_BoundCounter":
        return _BoundCounter(self, self._key(labels))

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        if amount < 0:
            raise ValueError("counter %r cannot decrease" % self.name)
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def set(self, value: float, **labels: str) -> None:
        """Absolute set — used by stat views that assign snapshots
        (``stats.shed = scheduler.n_shed``). Still monotonic in spirit:
        callers own the invariant."""
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)

    def value(self, **labels: str) -> float:
        key = self._key(labels)
        with self._lock:
            return self._values.get(key, 0.0)

    def _samples(self) -> List[str]:
        return ["%s%s %s" % (self.name, _fmt_labels(k), _fmt(v))
                for k, v in sorted(self._values.items())]

    def _json(self):
        return [{"labels": dict(k), "value": v}
                for k, v in sorted(self._values.items())]


class _BoundCounter:
    __slots__ = ("_metric", "_key")

    def __init__(self, metric: Counter, key: _LabelKey) -> None:
        self._metric = metric
        self._key = key

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counter %r cannot decrease" % self._metric.name)
        with self._metric._lock:
            vals = self._metric._values
            vals[self._key] = vals.get(self._key, 0.0) + amount

    def set(self, value: float) -> None:
        with self._metric._lock:
            self._metric._values[self._key] = float(value)

    def value(self) -> float:
        with self._metric._lock:
            return self._metric._values.get(self._key, 0.0)


class Gauge(_Metric):
    """Settable instantaneous value (queue depth, rail volts, rates)."""

    kind = "gauge"

    def __init__(self, name: str, help: str, labelnames: Tuple[str, ...],
                 lock: threading.RLock) -> None:
        super().__init__(name, help, labelnames, lock)
        self._values: Dict[_LabelKey, float] = {}

    def labels(self, **labels: str) -> "_BoundGauge":
        return _BoundGauge(self, self._key(labels))

    def set(self, value: float, **labels: str) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels: str) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels: str) -> float:
        key = self._key(labels)
        with self._lock:
            return self._values.get(key, 0.0)

    _samples = Counter._samples
    _json = Counter._json


class _BoundGauge:
    __slots__ = ("_metric", "_key")

    def __init__(self, metric: Gauge, key: _LabelKey) -> None:
        self._metric = metric
        self._key = key

    def set(self, value: float) -> None:
        with self._metric._lock:
            self._metric._values[self._key] = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._metric._lock:
            vals = self._metric._values
            vals[self._key] = vals.get(self._key, 0.0) + amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    def value(self) -> float:
        with self._metric._lock:
            return self._metric._values.get(self._key, 0.0)


class Histogram(_Metric):
    """Fixed-bucket histogram with Prometheus ``le`` semantics: an
    observation lands in every bucket whose upper bound is >= the value
    (rendered cumulatively; stored per-bucket)."""

    kind = "histogram"

    def __init__(self, name: str, help: str, labelnames: Tuple[str, ...],
                 lock: threading.RLock,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS) -> None:
        super().__init__(name, help, labelnames, lock)
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds:
            raise ValueError("histogram %r needs at least one bucket" % name)
        if bounds[-1] != math.inf:
            bounds = bounds + (math.inf,)
        self.buckets = bounds
        # key -> (per-bucket counts, sum, count)
        self._values: Dict[_LabelKey, List] = {}

    def labels(self, **labels: str) -> "_BoundHistogram":
        return _BoundHistogram(self, self._key(labels))

    def _cell(self, key: _LabelKey):
        cell = self._values.get(key)
        if cell is None:
            cell = [[0] * len(self.buckets), 0.0, 0]
            self._values[key] = cell
        return cell

    def observe(self, value: float, **labels: str) -> None:
        key = self._key(labels)
        self._observe(key, value)

    def _observe(self, key: _LabelKey, value: float) -> None:
        v = float(value)
        with self._lock:
            counts, _, _ = cell = self._cell(key)
            for i, bound in enumerate(self.buckets):
                if v <= bound:
                    counts[i] += 1
                    break
            cell[1] += v
            cell[2] += 1

    def snapshot(self, **labels: str):
        """(cumulative bucket counts, sum, count) for tests/JSON."""
        key = self._key(labels)
        with self._lock:
            counts, total, n = self._cell(key)
            cum, acc = [], 0
            for c in counts:
                acc += c
                cum.append(acc)
            return list(zip(self.buckets, cum)), total, n

    def _samples(self) -> List[str]:
        out: List[str] = []
        with self._lock:
            for key, (counts, total, n) in sorted(self._values.items()):
                acc = 0
                for bound, c in zip(self.buckets, counts):
                    acc += c
                    le = "+Inf" if bound == math.inf else _fmt(bound)
                    out.append("%s_bucket%s %s" % (
                        self.name, _fmt_labels(key, ("le", le)), _fmt(acc)))
                out.append("%s_sum%s %s" % (self.name, _fmt_labels(key),
                                            _fmt(total)))
                out.append("%s_count%s %s" % (self.name, _fmt_labels(key),
                                              _fmt(n)))
        return out

    def _json(self):
        out = []
        with self._lock:
            for key, (counts, total, n) in sorted(self._values.items()):
                acc, cum = 0, {}
                for bound, c in zip(self.buckets, counts):
                    acc += c
                    le = "+Inf" if bound == math.inf else _fmt(bound)
                    cum[le] = acc
                out.append({"labels": dict(key), "buckets": cum,
                            "sum": total, "count": n})
        return out


class _BoundHistogram:
    __slots__ = ("_metric", "_key")

    def __init__(self, metric: Histogram, key: _LabelKey) -> None:
        self._metric = metric
        self._key = key

    def observe(self, value: float) -> None:
        self._metric._observe(self._key, value)


class MetricsRegistry:
    """Get-or-create registry of named metrics.

    ``counter``/``gauge``/``histogram`` return the existing metric when
    the name is already registered (kind/labelnames must match), so
    instrumentation sites never need to coordinate creation order.
    """

    def __init__(self, clock=time.monotonic) -> None:
        self.clock = clock
        self._lock = threading.RLock()
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, labels: Iterable[str],
             **kw) -> _Metric:
        labelnames = tuple(labels)
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise ValueError("metric %r already registered as %s"
                                     % (name, m.kind))
                if m.labelnames != labelnames and labelnames:
                    raise ValueError(
                        "metric %r labelnames mismatch: %r vs %r"
                        % (name, m.labelnames, labelnames))
                return m
            m = cls(name, help, labelnames, self._lock, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labels: Iterable[str] = ()) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Iterable[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Iterable[str] = (),
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS,
                  ) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def render_prometheus(self) -> str:
        """Prometheus text exposition. Deterministic: sorted by metric
        name, children by label tuple, no timestamps."""
        lines: List[str] = []
        with self._lock:
            for name in sorted(self._metrics):
                m = self._metrics[name]
                if m.help:
                    lines.append("# HELP %s %s" % (name, m.help))
                lines.append("# TYPE %s %s" % (name, m.kind))
                lines.extend(m._samples())
        return "\n".join(lines) + "\n" if lines else ""

    def render_json(self) -> Dict[str, Dict]:
        out: Dict[str, Dict] = {}
        with self._lock:
            for name in sorted(self._metrics):
                m = self._metrics[name]
                out[name] = {"type": m.kind, "help": m.help,
                             "values": m._json()}
        return out
