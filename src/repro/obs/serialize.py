"""The one telemetry serializer.

``EngineStats.to_dict``, ``BackendTelemetry.to_dict``, and
``bench_payload`` each used to hand-roll their numpy→python coercion,
which is how schema drift (and double-counted fields) creeps in. They
now all funnel through :func:`to_plain`, which converts any telemetry
value into plain JSON types — numpy scalars via ``.item()``, arrays via
``tolist()``, dataclasses field-by-field (preserving declaration
order), enums by name — and leaves bool/int/float/str/None untouched.

No jax import: this module runs on scrape paths that must never touch
the device runtime.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any

__all__ = ["to_plain"]


def to_plain(obj: Any) -> Any:
    """Recursively convert telemetry values to plain JSON types."""
    if obj is None or type(obj) in (bool, int, float, str):
        return obj
    if isinstance(obj, enum.Enum):
        return obj.name
    if isinstance(obj, dict):
        return {str(k): to_plain(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set, frozenset)):
        return [to_plain(v) for v in obj]
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: to_plain(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    # numpy scalars/arrays (and jax host arrays, which share the API)
    # without importing numpy here
    if hasattr(obj, "item") and getattr(obj, "ndim", None) == 0:
        return to_plain(obj.item())
    if hasattr(obj, "tolist"):
        return to_plain(obj.tolist())
    if hasattr(obj, "item"):  # 0-d-less numpy scalar types (np.float64)
        return to_plain(obj.item())
    # exotic builtin-scalar subclasses without a numpy API: downcast to
    # the plain base type so json output is schema-stable
    for base in (bool, int, float, str):
        if isinstance(obj, base):
            return base(obj)
    raise TypeError("to_plain: unsupported telemetry type %r" % type(obj))
