"""ObsBus: the facade that ties registry + tracer + flight recorder to
one clock, and the single object threaded through the serving stack.

Each :class:`~repro.serve.engine.ServeEngine` owns exactly one bus
(never a process-global singleton): determinism demands that two
identical virtual-time runs see identical metric state, which a shared
registry would break. The bus shares the engine's injectable clock, so
latency histograms replay bit-identically under the load harness.

``enabled=False`` turns off the *optional* instrumentation — tracer
events and flight recording — while keeping the registry live, because
``EngineStats`` is a view over the registry and must keep working. That
split is exactly what ``BENCH_obs.json`` measures: the marginal cost of
tracing on top of the always-on counters.
"""

from __future__ import annotations

import json
import time
from typing import IO, Optional

from .metrics import MetricsRegistry
from .recorder import FlightRecorder
from .trace import Tracer

__all__ = ["ObsBus"]


class ObsBus:
    def __init__(self, clock=time.monotonic, *, enabled: bool = True,
                 recorder_capacity: int = 256) -> None:
        self.clock = clock
        self.enabled = enabled
        self.registry = MetricsRegistry(clock=clock)
        self.recorder = FlightRecorder(capacity=recorder_capacity)
        self.tracer = Tracer(clock=clock, sinks=[self.recorder.record],
                             enabled=enabled)
        self._trace_file: Optional[IO[str]] = None

    # -- convenience passthroughs used by instrumentation sites --------
    def event(self, name: str, **attrs) -> None:
        self.tracer.event(name, **attrs)

    def span(self, name: str, **attrs):
        return self.tracer.span(name, **attrs)

    def render_prometheus(self) -> str:
        return self.registry.render_prometheus()

    def render_json(self):
        return self.registry.render_json()

    # -- NDJSON trace file sink (launch.serve --trace-out) -------------
    def attach_trace_file(self, path) -> None:
        """Stream every trace event to ``path`` as NDJSON."""
        if self._trace_file is not None:
            raise RuntimeError("trace file already attached")
        fh = open(path, "w")
        self._trace_file = fh

        def _write(event) -> None:
            fh.write(json.dumps(event, default=str) + "\n")

        self._trace_sink = _write
        self.tracer.add_sink(_write)

    def close_trace(self) -> None:
        if self._trace_file is None:
            return
        self.tracer.remove_sink(self._trace_sink)
        self._trace_file.close()
        self._trace_file = None
