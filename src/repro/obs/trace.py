"""Structured tracing: point events and timed spans over an injectable
clock, fanned out to sink callables.

The trace stream is a flat sequence of dict events — NDJSON-friendly,
one object per line when dumped:

* point event: ``{"kind": "event", "name": str, "t": float, ...attrs}``
* span:        ``{"kind": "span", "name": str, "t": float,
  "dur_s": float, ...attrs}`` (``t`` is the span start; the event is
  emitted at span end so the stream stays time-ordered by emission)

Sinks are plain callables ``sink(event: dict)`` — a
:class:`~repro.obs.recorder.FlightRecorder`'s ``record`` method, a file
writer, or a test list's ``append``. Emission is cheap when disabled:
``Tracer(enabled=False)`` short-circuits before building the event
dict, which is what the instrumentation-overhead benchmark toggles.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

__all__ = ["Span", "Tracer"]

Sink = Callable[[Dict], None]


class Span:
    """A timed section. Use via ``with tracer.span("prefill", uid=...)``;
    extra attributes can be attached mid-flight with :meth:`set`."""

    __slots__ = ("name", "t0", "attrs", "_tracer", "_done")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict) -> None:
        self._tracer = tracer
        self.name = name
        self.t0 = tracer.clock()
        self.attrs = attrs
        self._done = False

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def end(self) -> None:
        if self._done:
            return
        self._done = True
        t1 = self._tracer.clock()
        self._tracer._emit({"kind": "span", "name": self.name,
                            "t": self.t0, "dur_s": t1 - self.t0,
                            **self.attrs})

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self.end()


class _NullSpan:
    """Returned by a disabled tracer so ``with tracer.span(...)`` costs
    one attribute lookup and nothing else."""

    __slots__ = ()

    def set(self, **attrs) -> "_NullSpan":
        return self

    def end(self) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Tracer:
    def __init__(self, clock=time.monotonic,
                 sinks: Optional[List[Sink]] = None,
                 enabled: bool = True) -> None:
        self.clock = clock
        self.sinks: List[Sink] = list(sinks or [])
        self.enabled = enabled

    def add_sink(self, sink: Sink) -> None:
        self.sinks.append(sink)

    def remove_sink(self, sink: Sink) -> None:
        if sink in self.sinks:
            self.sinks.remove(sink)

    def event(self, name: str, **attrs) -> None:
        if not self.enabled:
            return
        self._emit({"kind": "event", "name": name, "t": self.clock(),
                    **attrs})

    def span(self, name: str, **attrs):
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, name, attrs)

    def _emit(self, event: Dict) -> None:
        for sink in self.sinks:
            sink(event)
