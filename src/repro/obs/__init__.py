"""repro.obs — unified, dependency-free observability for the serving
stack.

One :class:`ObsBus` per engine carries three planes over one injectable
clock:

* **Metrics** — :class:`MetricsRegistry` of counters, gauges, and
  fixed-bucket histograms (label support, Prometheus-text + JSON
  renderers). ``EngineStats`` is a *view* over this registry, so the
  stats the batch path prints and the ``/metrics`` scrape are one
  source of truth.
* **Tracing** — :class:`Tracer`/:class:`Span` events covering the
  request lifecycle (submit → admit/queue-wait → prefill → decode step
  → guard verify/correct → rail heal → finish), NDJSON-dumpable.
* **Flight recording** — :class:`FlightRecorder` ring buffer of the
  last N events, dumped on chaos failure or ``GuardError``.

Registry reads never touch jax and never block the pump thread: the
frontend scrapes from the asyncio thread while decode runs.
"""

from .bus import ObsBus
from .metrics import (Counter, DEFAULT_LATENCY_BUCKETS, Gauge, Histogram,
                      MetricsRegistry)
from .recorder import FlightRecorder
from .serialize import to_plain
from .trace import Span, Tracer

__all__ = [
    "Counter",
    "DEFAULT_LATENCY_BUCKETS",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ObsBus",
    "Span",
    "Tracer",
    "to_plain",
]
