"""Flight recorder: a bounded ring buffer of the most recent trace
events, dumped as NDJSON for post-mortems.

The recorder is the black box of the serving stack — it rides along as
a tracer sink, keeps only the last ``capacity`` events (decode steps,
guard escalations, rail heals), and is dumped when something goes
wrong: a chaos scenario turns red, or a :class:`~repro.resilience.
guard.GuardError` aborts a fail-closed serve. Recording is O(1)
(``deque`` append) and touches no jax, so it is safe from the decode
hot loop and from exception handlers.
"""

from __future__ import annotations

import json
import os
from collections import deque
from typing import Dict, IO, List, Union

from .serialize import to_plain

__all__ = ["FlightRecorder"]


class FlightRecorder:
    def __init__(self, capacity: int = 256) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)
        self.total_recorded = 0  # lifetime count, survives wraparound

    def record(self, event: Dict) -> None:
        """Tracer-sink compatible: append one event, evicting the oldest
        once the ring is full."""
        self._ring.append(event)
        self.total_recorded += 1

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def dropped(self) -> int:
        return self.total_recorded - len(self._ring)

    def to_list(self) -> List[Dict]:
        """Chronological (oldest-first) plain-JSON copy of the ring."""
        return [to_plain(ev) for ev in self._ring]

    def dump_ndjson(self, dest: Union[str, os.PathLike, IO[str]]) -> int:
        """Write the ring as NDJSON (one event per line, oldest first).
        Returns the number of events written."""
        events = self.to_list()
        if hasattr(dest, "write"):
            for ev in events:
                dest.write(json.dumps(ev) + "\n")
        else:
            with open(dest, "w") as fh:
                for ev in events:
                    fh.write(json.dumps(ev) + "\n")
        return len(events)

    def clear(self) -> None:
        self._ring.clear()
