"""Optimizers: sharded AdamW (+ fp32 master, int8 moments, clipping, schedules)."""

from .adamw import (AdamWConfig, Quantized, apply_updates, dequantize_i8,
                    global_norm, init_state, lr_at, quantize_i8, state_specs)

__all__ = [name for name in dir() if not name.startswith("_")]
