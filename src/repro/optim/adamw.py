"""AdamW with fp32 master weights, ZeRO-style sharded states, optional int8
moment compression, gradient clipping and LR schedules.

Optimizer state reuses each parameter's *logical axes*, so states shard
exactly like their parameters (fully 2-D sharded over data x model — the only
way 110B+ AdamW fits 16 GiB/chip; DESIGN.md Sec. 4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.shardlib import ParamSpec

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_fp32: bool = True
    int8_moments: bool = False        # gradient-compression trick: quantized mu/nu
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | constant


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        return cfg.lr * warm
    t = jnp.clip((s - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


# ---------------------------------------------------------------------------
# int8 moment compression
# ---------------------------------------------------------------------------


class Quantized(NamedTuple):
    q: jax.Array          # int8 payload
    scale: jax.Array      # f32 per-row (last-axis) scale


def quantize_i8(x: jax.Array) -> Quantized:
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return Quantized(q, scale.astype(jnp.float32))


def dequantize_i8(z: Quantized) -> jax.Array:
    return z.q.astype(jnp.float32) * z.scale


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------


def state_specs(param_specs: Pytree, cfg: AdamWConfig) -> Pytree:
    """ParamSpec tree for the optimizer state (mirrors parameter sharding)."""

    def leaf(s: ParamSpec) -> Dict[str, ParamSpec]:
        moment_dtype = jnp.int8 if cfg.int8_moments else jnp.float32
        out = {
            "mu": ParamSpec(s.shape, moment_dtype, s.logical, init="zeros"),
            "nu": ParamSpec(s.shape, moment_dtype, s.logical, init="zeros"),
        }
        if cfg.int8_moments:
            sshape = s.shape[:-1] + (1,)
            out["mu_scale"] = ParamSpec(sshape, jnp.float32,
                                        s.logical[:-1] + (None,), init="zeros")
            out["nu_scale"] = ParamSpec(sshape, jnp.float32,
                                        s.logical[:-1] + (None,), init="zeros")
        if cfg.master_fp32:
            out["master"] = ParamSpec(s.shape, jnp.float32, s.logical,
                                      init="zeros")
        return out

    tree = jax.tree.map(leaf, param_specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))
    return {"per_param": tree,
            "step": ParamSpec((), jnp.int32, (), init="zeros")}


def init_state(params: Pytree, cfg: AdamWConfig) -> Pytree:
    def leaf(p: jax.Array) -> Dict[str, jax.Array]:
        moment_dtype = jnp.int8 if cfg.int8_moments else jnp.float32
        out = {"mu": jnp.zeros(p.shape, moment_dtype),
               "nu": jnp.zeros(p.shape, moment_dtype)}
        if cfg.int8_moments:
            out["mu_scale"] = jnp.zeros(p.shape[:-1] + (1,), jnp.float32)
            out["nu_scale"] = jnp.zeros(p.shape[:-1] + (1,), jnp.float32)
        if cfg.master_fp32:
            # explicit copy: for f32 params astype() aliases the same buffer,
            # which breaks donation (same buffer donated via params AND state)
            out["master"] = jnp.array(p, dtype=jnp.float32, copy=True)
        return out

    return {"per_param": jax.tree.map(leaf, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_updates(params: Pytree, opt_state: Pytree, grads: Pytree,
                  cfg: AdamWConfig) -> Tuple[Pytree, Pytree]:
    """One AdamW step. Returns (new_params, new_state)."""
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)
    b1, b2 = jnp.float32(cfg.b1), jnp.float32(cfg.b2)
    corr1 = 1.0 - b1 ** step.astype(jnp.float32)
    corr2 = 1.0 - b2 ** step.astype(jnp.float32)

    state_keys = {"mu", "nu", "mu_scale", "nu_scale", "master"}
    is_state_leaf = (lambda x: isinstance(x, dict) and "mu" in x and "nu" in x
                     and set(x.keys()) <= state_keys)

    def leaf(p: jax.Array, s: Dict[str, jax.Array]):
        g = grads_lookup[id(s)]
        g = g.astype(jnp.float32) * clip
        if cfg.int8_moments:
            mu = dequantize_i8(Quantized(s["mu"], s["mu_scale"]))
            nu = dequantize_i8(Quantized(s["nu"], s["nu_scale"]))
        else:
            mu, nu = s["mu"], s["nu"]
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        update = (mu / corr1) / (jnp.sqrt(nu / corr2) + cfg.eps)
        base = s["master"] if cfg.master_fp32 else p.astype(jnp.float32)
        new = base - lr * (update + cfg.weight_decay * base)
        out = {}
        if cfg.int8_moments:
            qm, qn = quantize_i8(mu), quantize_i8(nu)
            out.update(mu=qm.q, mu_scale=qm.scale, nu=qn.q, nu_scale=qn.scale)
        else:
            out.update(mu=mu, nu=nu)
        if cfg.master_fp32:
            out["master"] = new
        return new.astype(p.dtype), out

    # pair grads with states by tree structure
    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_s = jax.tree.flatten(opt_state["per_param"], is_leaf=is_state_leaf)[0]
    grads_lookup = {id(s): g for s, g in zip(flat_s, flat_g)}
    new_p, new_s = [], []
    for p, s in zip(flat_p, flat_s):
        np_, ns_ = leaf(p, s)
        new_p.append(np_)
        new_s.append(ns_)
    params_out = jax.tree.unflatten(treedef, new_p)
    state_tree = jax.tree.unflatten(
        jax.tree.structure(opt_state["per_param"], is_leaf=is_state_leaf),
        new_s)
    return params_out, {"per_param": state_tree, "step": step}
