"""zamba2-2.7b [hybrid]: 54 Mamba2 layers d_model=2560 + one shared
attention block (32H MHA kv=32, d_head=80, d_ff=10240) applied every 6
layers; ssm_state=64; vocab=32000.  [arXiv:2411.15242; hf]
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_head=80,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_d_head=64, ssm_chunk=64, shared_attn_period=6,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
        d_ff=256, vocab_size=512, ssm_state=16, ssm_d_head=16, ssm_chunk=8,
        shared_attn_period=2, attn_chunk=32, loss_chunk=32)
