"""granite-20b [dense]: 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — llama-arch attention + 4x GELU MLP (20.0B with this MLP form), code.  [arXiv:2405.04324; hf]
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_head=128,
    d_ff=24576, vocab_size=49152,
    act="gelu",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, d_head=32,
        d_ff=256, vocab_size=512, attn_chunk=32, loss_chunk=32)
