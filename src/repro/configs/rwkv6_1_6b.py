"""rwkv6-1.6b "Finch" [ssm]: 24L d_model=2048 (attention-free, 32 heads of
64) d_ff=7168 vocab=65536 — data-dependent decay WKV.  [arXiv:2404.05892;
unverified]
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=7168, vocab_size=65536,
    ssm_chunk=64,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
        d_ff=256, vocab_size=512, ssm_chunk=8, attn_chunk=32, loss_chunk=32)
