"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE, GELU MLP.  [arXiv:2402.19173; hf]
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_head=128,
    d_ff=12288, vocab_size=49152,
    act="gelu",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=256, vocab_size=512, attn_chunk=32, loss_chunk=32)
