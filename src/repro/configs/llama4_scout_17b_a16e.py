"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert (early fusion backbone).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab_size=202048,
    n_experts=16, top_k=1, shared_expert=True, moe_impl="dense",
    moe_shard="expert",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=256, vocab_size=512, n_experts=4, top_k=1, shared_expert=True,
        attn_chunk=32, loss_chunk=32)
