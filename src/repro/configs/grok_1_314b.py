"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=32768, vocab_size=131072,
    n_experts=8, top_k=2, moe_impl="dense",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=256, vocab_size=512, n_experts=4, top_k=2,
        attn_chunk=32, loss_chunk=32)
