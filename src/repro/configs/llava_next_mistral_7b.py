"""llava-next-mistral-7b [vlm]: Mistral-7B backbone + anyres vision stub.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000; sliding-window 4096
attention (sub-quadratic -> long_500k cell runs; DESIGN.md Sec. 6).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=32000,
    sliding_window=4096, rope_theta=1_000_000.0,
    frontend="vision", frontend_tokens=2880,   # anyres: base 576 + 4 tiles
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=256, vocab_size=512, sliding_window=16, frontend_tokens=8,
        attn_chunk=32, loss_chunk=32)
