"""Config system: model, shape and run configurations for every assigned
architecture (DESIGN.md Sec. 3)."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

VOCAB_PAD_MULTIPLE = 256   # vocab padded so TP-16 sharding always divides


def pad_vocab(v: int, multiple: int = VOCAB_PAD_MULTIPLE) -> int:
    return (v + multiple - 1) // multiple * multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128
    # attention options
    qkv_bias: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 10_000.0
    attn_chunk: int = 1024           # q-chunk for memory-bounded attention
    # mlp
    act: str = "swiglu"              # swiglu | gelu
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_impl: str = "dense"          # dense | ep_a2a
    moe_shard: str = "ffn"           # which dim takes the TP axis:
    #   "expert": experts sharded over model axis (needs E % axis == 0)
    #   "ffn":    experts replicated, FFN hidden dim sharded (any E)
    capacity_factor: float = 1.25
    shared_expert: bool = False      # llama4-style always-on expert
    # ssm (mamba2 / rwkv6)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_d_head: int = 64
    ssm_chunk: int = 64
    # hybrid (zamba2): one shared attention block applied every k ssm layers
    shared_attn_period: int = 0
    # enc-dec
    n_enc_layers: int = 0
    enc_frames_ratio: int = 4        # encoder frames = seq_len // ratio
    # frontend stubs (vlm: patch embeddings, audio: frame embeddings)
    frontend: Optional[str] = None
    frontend_tokens: int = 0
    # training
    loss_chunk: int = 256            # sequence-chunked cross-entropy
    remat: str = "full"              # full | dots | none
    dtype: str = "bfloat16"
    # roofline instrumentation: python-unroll every repetition that lax.scan
    # would hide from cost_analysis (layer stack, CE chunks, attn chunks).
    # Compile-time O(L) — used only by the unroll-delta FLOP estimator.
    unroll_layers: bool = False
    # ---- performance knobs (EXPERIMENTS.md §Perf; defaults = paper-faithful
    # baseline, optimized variants flip them) -------------------------------
    gqa_grouped: bool = False        # grouped-GQA einsum (no K/V head repeat)
    attn_scores_f32: bool = True     # False: bf16 score pipeline (max-sub)
    remat_save_attn: bool = False    # checkpoint attention outputs (no bwd
    #                                  recompute of the score pipeline)
    kv_cache_dtype: str = "bf16"     # bf16 | int8 (quantized KV + f32 scales)
    serve_weight_layout: str = "fsdp_tp"  # fsdp_tp | tp2d (decode: weights
    #                                  stationary over data x model, psum acts)
    fused_rwkv_proj: bool = False    # single fused r/k/v/g/w projection
    ssm_bf16: bool = False           # bf16 recurrence internals (f32 decays)

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (DESIGN.md Sec. 6 skip policy)"""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """The 40-cell applicability matrix (DESIGN.md Sec. 6)."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, ("skip: pure full-attention arch — 500k decode needs "
                       "sub-quadratic attention")
    return True, ""
