"""phi4-mini-3.8b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA.  [arXiv:2412.08905; hf]
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab_size=200064,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=256, vocab_size=512, attn_chunk=32, loss_chunk=32)
