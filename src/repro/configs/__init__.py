"""Architecture registry: ``--arch <id>`` resolves here."""

from typing import Callable, Dict, Tuple

from . import (granite_20b, grok_1_314b, llama4_scout_17b_a16e,
               llava_next_mistral_7b, phi4_mini_3_8b, qwen1_5_110b,
               rwkv6_1_6b, seamless_m4t_medium, starcoder2_3b, zamba2_2_7b)
from .base import (SHAPES, ModelConfig, ShapeConfig, cell_is_runnable,
                   pad_vocab)

_MODULES = {
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "grok-1-314b": grok_1_314b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "granite-20b": granite_20b,
    "qwen1.5-110b": qwen1_5_110b,
    "starcoder2-3b": starcoder2_3b,
    "phi4-mini-3.8b": phi4_mini_3_8b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "zamba2-2.7b": zamba2_2_7b,
    "rwkv6-1.6b": rwkv6_1_6b,
}

ARCHS: Dict[str, ModelConfig] = {k: m.CONFIG for k, m in _MODULES.items()}
SMOKE: Dict[str, Callable[[], ModelConfig]] = {
    k: m.smoke_config for k, m in _MODULES.items()}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return SMOKE[arch]() if smoke else ARCHS[arch]


def all_cells():
    """Every (arch, shape) pair with its runnability verdict — 40 cells."""
    for arch, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            ok, why = cell_is_runnable(cfg, shape)
            yield arch, sname, ok, why


__all__ = ["ARCHS", "SMOKE", "SHAPES", "ModelConfig", "ShapeConfig",
           "get_config", "all_cells", "cell_is_runnable", "pad_vocab"]
