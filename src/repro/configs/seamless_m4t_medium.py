"""seamless-m4t-medium [audio/encdec]: 12+12L d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206 — enc-dec; speech frontend stubbed to precomputed
frame embeddings.  [arXiv:2308.11596; hf]
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_head=64, d_ff=4096, vocab_size=256206,
    frontend="audio", enc_frames_ratio=4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, n_enc_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_head=32, d_ff=256, vocab_size=512,
        attn_chunk=32, loss_chunk=32)
