"""Step builders: train / prefill / decode jitted functions with full
in/out shardings for any (arch x shape x mesh) cell.

Used by the dry-run, the trainer and the server; the same builders serve the
real CPU smoke runs (tiny configs) and the 512-device production lowering.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import optim
from ..configs import get_config
from ..configs.base import ModelConfig, ShapeConfig
from ..models import model_api
from ..models.api import BatchSpec, ModelAPI
from ..models.shardlib import (ParamSpec, Rules, spec_tree_to_shardings,
                               spec_tree_to_structs, use_rules)
from .mesh import rules_for_mesh

Pytree = Any


def _batch_structs(batch_specs: Dict[str, BatchSpec]):
    return {k: v.struct() for k, v in batch_specs.items()}


def _batch_shardings(batch_specs: Dict[str, BatchSpec], rules: Rules):
    return {k: rules.sharding(v.logical) for k, v in batch_specs.items()}


@dataclasses.dataclass
class BuiltStep:
    """A jitted step plus everything needed to lower it abstractly."""

    fn: Any                      # the jitted callable
    arg_structs: Tuple[Pytree, ...]
    kind: str                    # train | prefill | decode
    cfg: ModelConfig
    api: ModelAPI
    rules: Rules

    def lower(self):
        return self.fn.lower(*self.arg_structs)


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, rules: Rules,
                     opt_cfg: Optional[optim.AdamWConfig] = None,
                     donate: bool = True) -> BuiltStep:
    api = model_api(cfg)
    opt_cfg = opt_cfg or optim.AdamWConfig()
    pspecs = api.param_specs()
    ospecs = optim.state_specs(pspecs, opt_cfg)
    bspecs = api.input_specs(shape)

    def train_step(params, opt_state, batch):
        with use_rules(rules):
            loss, grads = jax.value_and_grad(api.loss)(params, batch)
            params, opt_state = optim.apply_updates(params, opt_state, grads,
                                                    opt_cfg)
        return params, opt_state, loss

    p_sh = spec_tree_to_shardings(pspecs, rules)
    o_sh = spec_tree_to_shardings(ospecs, rules)
    b_sh = _batch_shardings(bspecs, rules)
    loss_sh = rules.sharding(())
    with use_rules(rules):
        fn = jax.jit(train_step,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, loss_sh),
                     donate_argnums=(0, 1) if donate else ())
    args = (spec_tree_to_structs(pspecs), spec_tree_to_structs(ospecs),
            _batch_structs(bspecs))
    return BuiltStep(fn, args, "train", cfg, api, rules)


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig,
                       rules: Rules) -> BuiltStep:
    api = model_api(cfg)
    pspecs = api.param_specs()
    bspecs = api.input_specs(shape)
    sspecs = api.decode_state_specs(shape)

    def prefill_step(params, batch):
        with use_rules(rules):
            return api.prefill(params, batch, max_len=shape.seq_len)

    p_sh = spec_tree_to_shardings(pspecs, rules)
    b_sh = _batch_shardings(bspecs, rules)
    logits_sh = rules.sharding(("batch", "tp"))
    s_sh = spec_tree_to_shardings(sspecs, rules)
    with use_rules(rules):
        fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                     out_shardings=(logits_sh, s_sh))
    args = (spec_tree_to_structs(pspecs), _batch_structs(bspecs))
    return BuiltStep(fn, args, "prefill", cfg, api, rules)


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, rules: Rules,
                      donate: bool = True) -> BuiltStep:
    api = model_api(cfg)
    pspecs = api.param_specs()
    sspecs = api.decode_state_specs(shape)
    tokens = BatchSpec((shape.global_batch, 1), jnp.int32, ("batch", None))

    def decode_step(params, state, toks):
        with use_rules(rules):
            return api.decode_step(params, state, toks)

    p_sh = spec_tree_to_shardings(pspecs, rules)
    s_sh = spec_tree_to_shardings(sspecs, rules)
    logits_sh = rules.sharding(("batch", "tp"))
    with use_rules(rules):
        fn = jax.jit(decode_step,
                     in_shardings=(p_sh, s_sh, rules.sharding(tokens.logical)),
                     out_shardings=(logits_sh, s_sh),
                     donate_argnums=(1,) if donate else ())
    args = (spec_tree_to_structs(pspecs), spec_tree_to_structs(sspecs),
            tokens.struct())
    return BuiltStep(fn, args, "decode", cfg, api, rules)


def build_cell(arch: str, shape: ShapeConfig, mesh: jax.sharding.Mesh,
               smoke: bool = False,
               overrides: Optional[Dict[str, Any]] = None,
               opt_cfg: Optional[optim.AdamWConfig] = None) -> BuiltStep:
    """One (arch x shape) cell on a mesh: picks the right step kind."""
    cfg = get_config(arch, smoke=smoke)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    long_ctx = shape.name == "long_500k"
    rules = rules_for_mesh(mesh, long_context=long_ctx)
    if shape.kind != "train" and cfg.serve_weight_layout == "tp2d":
        from .mesh import tp2d_rules
        rules = tp2d_rules(mesh, long_context=long_ctx)
    if shape.kind == "train":
        return build_train_step(cfg, shape, rules, opt_cfg)
    if shape.kind == "prefill":
        if cfg.family in ("ssm", "hybrid"):
            # SSM prompts are absorbed via chunked forward = the train fwd;
            # lower the loss-forward as the prefill-compute proxy
            return build_forward_step(cfg, shape, rules)
        return build_prefill_step(cfg, shape, rules)
    return build_decode_step(cfg, shape, rules)


def build_forward_step(cfg: ModelConfig, shape: ShapeConfig,
                       rules: Rules) -> BuiltStep:
    """Forward-only (no grad) step — SSM/hybrid prefill proxy."""
    api = model_api(cfg)
    pspecs = api.param_specs()
    train_like = ShapeConfig(shape.name, shape.seq_len, shape.global_batch,
                             "train")
    bspecs = api.input_specs(train_like)

    def fwd(params, batch):
        with use_rules(rules):
            return api.loss(params, batch)

    p_sh = spec_tree_to_shardings(pspecs, rules)
    b_sh = _batch_shardings(bspecs, rules)
    with use_rules(rules):
        fn = jax.jit(fwd, in_shardings=(p_sh, b_sh),
                     out_shardings=rules.sharding(()))
    args = (spec_tree_to_structs(pspecs), _batch_structs(bspecs))
    return BuiltStep(fn, args, "prefill", cfg, api, rules)
