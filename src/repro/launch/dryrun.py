import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape x mesh) cell on the production meshes, print
memory_analysis() / cost_analysis(), extract the collective schedule, and
write one JSON artifact per cell for the roofline (deliverable g).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-110b \
        --shape train_4k [--multi-pod] [--set moe_impl=ep_a2a] [--tag name]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs import SHAPES, ARCHS, cell_is_runnable, get_config
from .mesh import chips, make_production_mesh
from .steps import build_cell

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _cost_dict(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and not k.startswith("utilization")}


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             overrides=None, tag: str = "", verbose: bool = True) -> dict:
    from ..roofline.hlo import parse_collectives, summarize_collectives, \
        total_collective_bytes

    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(get_config(arch), shape)
    mesh_kind = "multipod_2x16x16" if multi_pod else "pod_16x16"
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "overrides": overrides or {}, "tag": tag}
    if not ok:
        record.update(status="skipped", reason=why)
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {why}")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        step = build_cell(arch, shape, mesh, overrides=overrides)
        lowered = step.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        cost = _cost_dict(compiled)
        hlo = compiled.as_text()
        colls = parse_collectives(hlo)
        op_b, wire_b = total_collective_bytes(colls)
        record.update(
            status="ok", kind=step.kind, chips=chips(mesh),
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
            },
            cost=cost,
            collectives=summarize_collectives(colls),
            collective_operand_bytes=int(op_b),
            collective_wire_bytes=int(wire_b),
            hlo_bytes=len(hlo),
        )
        if verbose:
            args_gib = ma.argument_size_in_bytes / 2**30
            temp_gib = ma.temp_size_in_bytes / 2**30
            print(f"[ok]   {arch} x {shape_name} x {mesh_kind} ({step.kind}): "
                  f"args {args_gib:.2f} GiB/dev, temp {temp_gib:.2f} GiB/dev, "
                  f"flops/dev {cost.get('flops', 0):.3e}, "
                  f"colls {record['collectives']}, "
                  f"compile {t_compile:.1f}s")
    except Exception as e:                                  # noqa: BLE001
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} x {mesh_kind}: {e}")
    return record


def save(record: dict) -> Path:
    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    tag = f"_{record['tag']}" if record.get("tag") else ""
    name = f"{record['arch']}_{record['shape']}_{record['mesh']}{tag}.json"
    name = name.replace("/", "-")
    path = ARTIFACT_DIR / name
    path.write_text(json.dumps(record, indent=1))
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="every (arch x shape)")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. moe_impl=ep_a2a)")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, multi_pod=mp,
                           overrides=overrides or None, tag=args.tag)
            save(rec)
            n_fail += rec["status"] == "error"
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
