"""Production meshes (spec-mandated shapes) and mesh-aware sharding rules.

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from ..models.shardlib import Rules, multi_pod_rules, single_pod_rules

# TPU v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_LINK_BW = 50e9                # B/s per link
ICI_LINKS_PER_CHIP = 3            # usable torus links on a 16x16 slice


def _make_mesh(shape: Tuple[int, ...],
               axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    # jax >= 0.5 wants explicit axis_types (Auto keeps the pre-explicit
    # sharding semantics); 0.4.x predates jax.sharding.AxisType and rejects
    # the kwarg, so gate on the attribute
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")) -> jax.sharding.Mesh:
    """Small mesh over however many (fake) devices the test process has."""
    return _make_mesh(shape, axes)


def rules_for_mesh(mesh: jax.sharding.Mesh,
                   long_context: bool = False) -> Rules:
    """Sharding rules for a mesh; long_context drops batch sharding (batch=1)
    and spreads cache sequence dims across every axis."""
    multi = "pod" in mesh.axis_names
    rules = multi_pod_rules(mesh) if multi else single_pod_rules(mesh)
    if long_context:
        table = dict(rules.table)
        table["batch"] = None
        rules = Rules(table, mesh)
    return rules


def tp2d_rules(mesh: jax.sharding.Mesh, long_context: bool = False) -> Rules:
    """Serving weight layout: weights stationary, sharded over EVERY mesh
    axis (256/512-way "2D TP"); activations are small (one token/seq) and get
    psum'd instead of gathering gigabytes of weights per layer (§Perf,
    decode cells).  fsdp resolves to None, tp to the full axis tuple."""
    base = rules_for_mesh(mesh, long_context=long_context)
    table = dict(base.table)
    table["fsdp"] = None
    table["tp"] = tuple(mesh.axis_names)
    return Rules(table, mesh)


def chips(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
