"""Serving launcher: batched requests through the wave engine.

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --smoke --requests 6 --slots 2 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCHS, get_config
from ..models import model_api
from ..serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    api = model_api(cfg)
    params = api.init_params(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for uid in range(args.requests):
        plen = int(rng.integers(2, 8))
        prompt = rng.integers(3, cfg.vocab_size, plen).tolist()
        req = Request(uid=uid, prompt=prompt, max_new_tokens=args.max_new)
        reqs.append(req)
        engine.submit(req)

    t0 = time.time()
    stats = engine.run_until_drained()
    dt = time.time() - t0
    print(f"served {stats.completed} requests in {stats.waves} waves, "
          f"{stats.tokens_generated} tokens, {stats.decode_steps} decode "
          f"steps, {dt:.1f}s "
          f"({stats.tokens_generated / max(dt, 1e-9):.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: prompt {r.prompt} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
