"""Serving launcher: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --smoke --requests 6 --slots 2 --max-new 8

Flags:
    --engine {continuous,wave}   continuous (default) admits a request into
                                 any free slot mid-flight; wave is the legacy
                                 static batcher kept as a baseline
    --requests / --slots         workload size / decode slots
    --max-new                    max new tokens per request (randomized per
                                 request when --mixed is set)
    --max-len                    decode cache length
    --max-steps                  model-call budget for run_until_drained;
                                 exhaustion reports truncated/unserved counts
    --json-out PATH              dump full EngineStats telemetry as JSON
                                 (prefill/decode steps, TTFT, occupancy, ...)
    --backend {ideal,reference,simulated,emulated}
                                 execution backend for ALL model GEMMs
                                 (continuous engine only).  "emulated" runs
                                 the CAD flow first and serves every decode
                                 matmul on the calibrated voltage-scaled
                                 array — per-step Razor flags and
                                 energy/token land in EngineStats
    --hwloop                     attach a repro.hwloop session (continuous
                                 engine only).  Without --backend emulated:
                                 legacy probe traffic per decode step.  With
                                 it: thin watchdog adapter over the real
                                 GEMM flags (rails heal mid-serve)
    --hwloop-tech / --hwloop-array-n
                                 operating point of the emulated array /
                                 hwloop session
    --guard {off,freivalds,abft} wrap the execution backend in the ABFT
                                 GuardedBackend (repro.resilience): checksum
                                 verification, locate-and-correct, and the
                                 retry -> rail-heal -> policy escalation
                                 ladder on silent corruption
    --guard-policy {fail_open,fail_closed}
                                 what an unverifiable product does: return
                                 with telemetry (open) or raise (closed)
    --autoscale {static,threshold,pid}
                                 closed-loop energy-aware rail policy
                                 (repro.railscale).  "static" is today's
                                 fixed-rail path, bit-identical; the live
                                 policies need --backend emulated and attach
                                 a hwloop session automatically, undervolt
                                 toward the calibrated floor when load is
                                 low, and boost toward nominal under queue /
                                 flag / TTFT-SLO pressure
    --autoscale-points FILE      load the operating-point ladder from a
                                 ``flow --points-out`` JSON file instead of
                                 characterizing it at startup
    --slo-ttft S                 TTFT SLO (seconds) feeding the policy's
                                 headroom signal
    --autoscale-every N          decode steps per autoscaler decision
    --policy {fifo,priority}     scheduler admission policy; priority enables
                                 tiers + TTFT-deadline shedding
    --max-pending N              bounded admission queue (backpressure: a
                                 full queue sheds instead of buffering)
    --serve-http HOST:PORT       start the asyncio streaming frontend
                                 (repro.server) over the engine and serve
                                 until Ctrl-C, then drain gracefully
    --trace FILE                 replay a traffic trace (NDJSON, written by
                                 python -m repro.server.traffic) through the
                                 deterministic virtual-time load harness
                                 instead of the built-in random workload
    --step-cost S                virtual seconds per model call for --trace
    --metrics PATH               write a Prometheus-text snapshot of the
                                 engine's repro.obs registry at exit (the
                                 same exposition GET /metrics serves live
                                 under --serve-http)
    --trace-out PATH             stream every obs trace event (request
                                 lifecycle, decode steps, guard/rail
                                 events) to PATH as NDJSON
"""

from __future__ import annotations

import argparse
import json
import time

from ..backend import ensure_host_callback_capacity

ensure_host_callback_capacity()     # before jax builds its CPU client

import jax
import numpy as np

from ..configs import ARCHS, get_config
from ..models import model_api
from ..serve import Request, ServeEngine, WaveServeEngine


def _attach_obs_outputs(engine, args) -> None:
    if args.trace_out:
        engine.obs.attach_trace_file(args.trace_out)


def _finish_obs_outputs(engine, args) -> None:
    if args.metrics:
        with open(args.metrics, "w") as f:
            f.write(engine.obs.registry.render_prometheus())
        print(f"wrote {args.metrics}")
    if args.trace_out:
        engine.obs.close_trace()
        print(f"wrote {args.trace_out}")


def _serve_http(engine, hostport: str) -> None:
    """Run the asyncio streaming frontend until interrupted, then drain."""
    import asyncio

    from ..server import ServeFrontend

    host, _, port = hostport.rpartition(":")
    frontend = ServeFrontend(engine)

    async def run() -> None:
        bound = await frontend.start(host or "127.0.0.1", int(port))
        print(f"serving on http://{bound[0]}:{bound[1]} "
              f"(POST /v1/generate, GET /healthz /metrics /v1/stats); "
              f"Ctrl-C drains + exits")
        try:
            await frontend.serve_forever()
        except asyncio.CancelledError:
            pass
        finally:
            drained = await frontend.drain()
            await frontend.close()
            print(f"drained={drained}; served "
                  f"{engine.stats.completed} completed / "
                  f"{engine.stats.shed} shed / "
                  f"{engine.stats.tokens_generated} tokens")

    try:
        asyncio.run(run())
    except KeyboardInterrupt:
        pass


def _replay_trace(args, cfg, params, engine_kw) -> None:
    """Replay a saved traffic trace deterministically in virtual time."""
    from ..server import LoadHarness, VirtualClock, load_trace

    clock = VirtualClock()
    engine = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                         clock=clock, policy=args.policy,
                         max_pending=args.max_pending, **engine_kw)
    _attach_obs_outputs(engine, args)
    events = load_trace(args.trace)
    harness = LoadHarness(engine, clock, step_cost_s=args.step_cost)
    m = harness.replay(events)
    p50 = "n/a" if m.ttft_p50_s is None else f"{1e3 * m.ttft_p50_s:.0f}ms"
    p99 = "n/a" if m.ttft_p99_s is None else f"{1e3 * m.ttft_p99_s:.0f}ms"
    met = "n/a" if m.deadline_met_frac is None \
        else f"{100 * m.deadline_met_frac:.0f}%"
    print(f"[trace {args.trace}] {m.n_events} arrivals over "
          f"{m.elapsed_virtual_s:.2f} virtual s: {m.completed} completed / "
          f"{m.truncated} truncated / {m.shed} shed "
          f"(rate {m.shed_rate:.2f}, by tier {m.shed_by_priority}); "
          f"{m.tokens_per_s:.1f} tok/s, TTFT p50 {p50} p99 {p99}, "
          f"SLO met {met}; wall {m.wall_s:.1f}s")
    if args.json_out:
        payload = {"arch": args.arch, "trace": args.trace,
                   "slots": args.slots, "policy": args.policy,
                   "max_pending": args.max_pending,
                   "step_cost_s": args.step_cost, **m.to_dict()}
        if engine.autoscaler is not None:
            payload["railscale"] = engine.autoscaler.summary()
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json_out}")
    _finish_obs_outputs(engine, args)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=("continuous", "wave"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--mixed", action="store_true",
                    help="randomize max_new_tokens per request (1..max-new)")
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-steps", type=int, default=10_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", type=str, default=None)
    ap.add_argument("--backend", default="ideal",
                    choices=("ideal", "reference", "simulated", "emulated"))
    ap.add_argument("--guard", default="off",
                    choices=("off", "freivalds", "abft"))
    ap.add_argument("--guard-policy", default="fail_open",
                    choices=("fail_open", "fail_closed"))
    ap.add_argument("--hwloop", action="store_true")
    ap.add_argument("--hwloop-tech", default="vtr-22nm")
    ap.add_argument("--hwloop-array-n", type=int, default=8)
    ap.add_argument("--autoscale", default="static",
                    choices=("static", "threshold", "pid"))
    ap.add_argument("--autoscale-points", type=str, default=None,
                    metavar="FILE")
    ap.add_argument("--slo-ttft", type=float, default=None, metavar="S")
    ap.add_argument("--autoscale-every", type=int, default=4, metavar="N")
    ap.add_argument("--policy", choices=("fifo", "priority"), default="fifo")
    ap.add_argument("--max-pending", type=int, default=None)
    ap.add_argument("--serve-http", type=str, default=None,
                    metavar="HOST:PORT")
    ap.add_argument("--trace", type=str, default=None, metavar="FILE")
    ap.add_argument("--step-cost", type=float, default=0.02,
                    help="virtual seconds per model call under --trace")
    ap.add_argument("--metrics", type=str, default=None, metavar="PATH",
                    help="write a Prometheus-text registry snapshot at exit")
    ap.add_argument("--trace-out", type=str, default=None, metavar="PATH",
                    help="stream obs trace events to PATH as NDJSON")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    api = model_api(cfg)
    params = api.init_params(jax.random.PRNGKey(args.seed))
    engine_cls = ServeEngine if args.engine == "continuous" else WaveServeEngine
    engine_kw = {}
    fcfg, store = None, None
    if args.engine != "continuous" and (
            args.backend != "ideal" or args.hwloop or args.serve_http
            or args.trace or args.policy != "fifo"
            or args.max_pending is not None):
        ap.error("--backend/--hwloop/--serve-http/--trace/--policy/"
                 "--max-pending require the continuous engine")
    if args.serve_http and args.trace:
        ap.error("--serve-http and --trace are mutually exclusive")
    if args.autoscale != "static":
        if args.engine != "continuous":
            ap.error("--autoscale needs the continuous engine")
        if args.backend != "emulated":
            ap.error("--autoscale {threshold,pid} actuates the emulated "
                     "array's rails; pass --backend emulated")
        args.hwloop = True   # the session is the sanctioned actuation path
    if args.backend == "emulated" or args.hwloop:
        # only these two paths run the CAD flow; one artifact store shared
        # by the backend's flow run and the hwloop watchdog executes it once
        from ..flow import ArtifactStore, FlowConfig
        fcfg = FlowConfig(array_n=args.hwloop_array_n, tech=args.hwloop_tech,
                          max_trials=8, seed=2021)
        store = ArtifactStore()
    report = None
    if args.backend == "emulated":
        # CAD flow -> calibrated rails -> the serving execution target
        from ..backend import EmulatedBackend
        from ..flow import run as flow_run
        report = flow_run(fcfg, store=store)
        engine_kw["backend"] = EmulatedBackend.from_flow(report, fcfg)
    elif args.backend == "simulated":
        from ..backend import get_backend
        engine_kw["backend"] = get_backend(
            args.backend, array_n=args.hwloop_array_n, tech=args.hwloop_tech)
    elif args.backend != "ideal":
        from ..backend import get_backend
        engine_kw["backend"] = get_backend(args.backend)
    if args.guard != "off":
        if args.backend == "ideal":
            ap.error("--guard needs a non-ideal --backend to protect "
                     "(the ideal path never corrupts)")
        from ..resilience import GuardedBackend
        engine_kw["backend"] = GuardedBackend(
            engine_kw["backend"], mode=args.guard, policy=args.guard_policy)
    if args.hwloop:
        from ..hwloop import HwLoopSession
        engine_kw["hwloop"] = HwLoopSession(fcfg, probe_rows=8,
                                            rail_margin=0.02, store=store)
    if args.autoscale != "static":
        from ..railscale import Autoscaler, OperatingPointTable
        if args.autoscale_points:
            table = OperatingPointTable.load(
                args.autoscale_points, tech=args.hwloop_tech,
                array_n=args.hwloop_array_n)
        else:
            table = OperatingPointTable.characterize(report, fcfg,
                                                     seed=fcfg.seed)
        engine_kw["autoscaler"] = Autoscaler(
            table, args.autoscale, decide_every=args.autoscale_every,
            slo_ttft_s=args.slo_ttft, start_level=0)

    if args.trace:
        _replay_trace(args, cfg, params, engine_kw)
        return
    if args.engine == "continuous":
        engine_kw.update(policy=args.policy, max_pending=args.max_pending)
    engine = engine_cls(cfg, params, slots=args.slots, max_len=args.max_len,
                        **engine_kw)
    _attach_obs_outputs(engine, args)
    if args.serve_http:
        _serve_http(engine, args.serve_http)
        _finish_obs_outputs(engine, args)
        return

    rng = np.random.default_rng(args.seed)
    reqs = []
    for uid in range(args.requests):
        plen = int(rng.integers(2, 8))
        prompt = rng.integers(3, cfg.vocab_size, plen).tolist()
        max_new = (int(rng.integers(1, args.max_new + 1)) if args.mixed
                   else args.max_new)
        req = Request(uid=uid, prompt=prompt, max_new_tokens=max_new)
        reqs.append(req)
        engine.submit(req)

    t0 = time.time()
    stats = engine.run_until_drained(max_steps=args.max_steps)
    dt = time.time() - t0
    occ = ", ".join(f"{o:.2f}" for o in stats.occupancy())
    ttft = (f"{1e3 * sum(stats.ttft_s) / len(stats.ttft_s):.0f}ms"
            if stats.ttft_s else "n/a")
    print(f"[{args.engine}] served {stats.completed} completed / "
          f"{stats.truncated} truncated / {stats.unserved} unserved; "
          f"{stats.tokens_generated} tokens in {stats.prefill_steps} prefill "
          f"+ {stats.decode_steps} decode model steps, {dt:.1f}s "
          f"({stats.tokens_generated / max(dt, 1e-9):.1f} tok/s, "
          f"mean TTFT {ttft}, occupancy [{occ}])")
    for r in reqs[:3]:
        print(f"  req {r.uid}: prompt {r.prompt} -> {r.out_tokens}"
              f"{' (truncated)' if r.truncated else ''}")
    if stats.backend_telemetry:
        bt = stats.backend_telemetry
        e = bt.get("energy_per_token_j")
        print(f"[backend:{stats.backend}] {bt['calls']} GEMMs, "
              f"{bt['macs']} MACs, {bt['flags']} flags, "
              f"{bt['replays']} replays, "
              f"{'n/a' if e is None else f'{e:.3g}'} J/token")
    if stats.hwloop:
        hw = stats.hwloop
        rates = ", ".join(f"{x:.2f}" for x in hw["flag_rate"])
        e = hw["energy_per_token_j"]        # None when no decode step ran
        print(f"[hwloop] {hw['steps']} emulated steps, flag rates [{rates}], "
              f"{hw['recalibrations']} recalibrations, "
              f"{'n/a' if e is None else f'{e:.3g}'} J/token "
              f"(replay rate {hw['replay_rate']:.2e})")
    if stats.railscale:
        rs = stats.railscale
        rails = ", ".join(f"{v:.3f}" for v in rs.get("rails_v", []))
        print(f"[railscale:{rs['policy']}] level {rs['level']}/"
              f"{rs['levels'] - 1}, {rs['decisions']} decisions, "
              f"transitions {rs['transitions']}, "
              f"{rs['heal_preemptions']} heal preemptions, "
              f"rails [{rails}]")
    if args.json_out:
        payload = {"arch": args.arch, "engine": args.engine,
                   "slots": args.slots, "max_len": args.max_len,
                   "requests": args.requests, "wall_s": dt,
                   "tok_per_s": stats.tokens_generated / max(dt, 1e-9),
                   **stats.to_dict()}
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json_out}")
    _finish_obs_outputs(engine, args)


if __name__ == "__main__":
    main()
