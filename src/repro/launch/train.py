"""Training launcher.

CPU-real runs use reduced (smoke) configs:
    PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
        --smoke --steps 50 --batch 4 --seq 64

Full configs + the production mesh are exercised via the dry-run
(`repro.launch.dryrun`); this driver is the end-to-end loop (data ->
train_step -> checkpoints -> fault monitor) used by the examples.
"""

from __future__ import annotations

import argparse

from .. import optim
from ..configs import ARCHS, get_config
from ..configs.base import ShapeConfig
from ..runtime import HeartbeatMonitor
from ..train import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--int8-moments", action="store_true",
                    help="compressed optimizer state")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    tc = TrainConfig(steps=args.steps, checkpoint_dir=args.checkpoint_dir,
                     checkpoint_every=args.checkpoint_every)
    oc = optim.AdamWConfig(lr=args.lr, total_steps=args.steps,
                           warmup_steps=max(args.steps // 20, 1),
                           int8_moments=args.int8_moments)
    monitor = HeartbeatMonitor(num_hosts=1)
    res = train(cfg, shape, tc, oc, monitor=monitor, resume=args.resume)
    print(f"done: {res.steps_done} steps in {res.wall_s:.1f}s; "
          f"loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f}")


if __name__ == "__main__":
    main()
