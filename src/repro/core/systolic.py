"""Functional weight-stationary systolic-array simulator with voltage-dependent
timing-fault injection (paper Secs. II-E, III-B, V-B).

Computes C = A @ W on an N x N MAC grid.  MAC (i, j) multiplies the streamed
activation A[m, i] with the resident weight W[i, j] and adds the partial sum
flowing down from row i-1.  Each MAC runs at the voltage of its floorplan
partition; its effective path arrival time (data-dependent, Sec. II-E) is
classified by the Razor model into OK / DETECTED (flag + corrected, one replay
cycle) / SILENT (stale register value leaks through and propagates — the crash
region).

The simulator returns both the (possibly corrupted) product and per-partition
Razor statistics; the runtime scheme (Algorithm 2) calibrates voltages against
``trial_run``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from .partition import Floorplan
from .razor import (DETECTED, OK, SILENT, RazorConfig, classify_arrival,
                    effective_arrival, streamed_activity)
from .timing import TimingModel


@dataclasses.dataclass
class SimStats:
    detected: np.ndarray            # (n, n) replay counts per MAC
    silent: np.ndarray              # (n, n) silent-failure counts per MAC
    partition_fail: np.ndarray      # (P,) OR of detected flags per partition
    replay_cycles: int
    rel_error: float                # ||C_sim - C_true|| / ||C_true||

    def partition_detected(self, partition_of_mac: np.ndarray) -> np.ndarray:
        return _or_by_partition(self.detected.reshape(-1) > 0, partition_of_mac)


def _or_by_partition(mac_flags: np.ndarray, partition_of_mac: np.ndarray,
                     n_part: Optional[int] = None) -> np.ndarray:
    """(P,) any-of reduction of per-MAC booleans in one bincount pass."""
    n_part = int(partition_of_mac.max()) + 1 if n_part is None else n_part
    hits = np.bincount(partition_of_mac,
                       weights=np.asarray(mac_flags, dtype=np.float64),
                       minlength=n_part)
    return hits > 0


@dataclasses.dataclass
class SystolicSim:
    timing: TimingModel
    floorplan: Floorplan
    razor: RazorConfig = dataclasses.field(default_factory=RazorConfig)
    quant_bits: int = 16            # operand width for switching activity
    # "vectorized" (default): array-programming partial-sum propagation;
    # "reference": the original per-row / per-silent-element Python loops,
    # kept as the bit-exact oracle for tests and perf baselines
    impl: str = "vectorized"

    def __post_init__(self) -> None:
        if self.impl not in ("vectorized", "reference"):
            raise ValueError(f"unknown impl {self.impl!r}")
        # partition membership is fixed by the floorplan's structure (only the
        # rail voltages vary across trials), so resolve it once
        self._part = self.floorplan.partition_of_mac()
        self._n_part = int(self._part.max()) + 1

    def _arrival(self, v_map: np.ndarray, activity_m: np.ndarray) -> np.ndarray:
        """(M, n, n) effective arrival times: per-MAC nominal delay at its rail
        voltage, scaled by the per-cycle activation switching activity."""
        d = self.timing.delays_at(v_map)                      # (n, n)
        return effective_arrival(d[None, :, :],
                                 activity_m[:, :, None], self.razor)

    def _activity(self, a: np.ndarray) -> np.ndarray:
        """(M, n) per-cycle input toggle fraction on each row's activation bus."""
        return streamed_activity(a, self.quant_bits)

    def matmul(self, a: np.ndarray, w: np.ndarray,
               v_map: Optional[np.ndarray] = None) -> Tuple[np.ndarray, SimStats]:
        """Simulate C = a @ w with fault injection.

        a: (M, n) activations; w: (n, n) resident weights.
        """
        n = self.timing.n
        if a.shape[1] != n or w.shape != (n, n):
            raise ValueError(f"expected a:(M,{n}) w:({n},{n})")
        v_map = self.floorplan.voltage_map() if v_map is None else v_map
        act = self._activity(a)                               # (M, n)
        arrival = self._arrival(v_map, act)                   # (M, n, n)
        status = classify_arrival(arrival, self.razor)        # (M, n, n)

        c_true = a @ w
        if self.impl == "reference":
            c_sim, detected, silent = self._propagate_ref(a, w, status)
        else:
            c_sim, detected, silent = self._propagate_vec(a, w, status)

        det_flags = _or_by_partition(detected.reshape(-1) > 0, self._part,
                                     self._n_part)
        denom = float(np.linalg.norm(c_true)) or 1.0
        stats = SimStats(
            detected=detected, silent=silent, partition_fail=det_flags,
            replay_cycles=int(detected.sum()),
            rel_error=float(np.linalg.norm(c_sim - c_true)) / denom,
        )
        return c_sim, stats

    def _propagate_vec(self, a: np.ndarray, w: np.ndarray, status: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized partial-sum propagation, bit-identical to the reference.

        Silent failures re-emit the stale previous-cycle register value; the
        chained "silent rows inherit from the last clean row above" semantics
        of the reference's element loop is a per-column forward fill, done
        with ``np.maximum.accumulate`` over the last-clean row index.
        """
        n = self.timing.n
        m_rows = a.shape[0]
        detected = (status == DETECTED).sum(axis=0)           # (n, n)
        sil_all = status == SILENT                            # (M, n, n)
        silent = sil_all.sum(axis=0)
        terms = a[:, :, None] * w[None, :, :]                 # (M, n, n)
        if not sil_all.any():
            # cumsum matches the reference's sequential row accumulation order
            c_sim = terms.cumsum(axis=1)[:, -1, :]
            return c_sim, detected, silent
        row_ix = np.arange(m_rows)[:, None]
        out = np.zeros((m_rows, n), dtype=np.float64)
        for i in range(n):
            out = out + terms[:, i, :]
            sil = sil_all[:, i, :]                            # (M, n)
            if sil.any():
                last = np.maximum.accumulate(
                    np.where(sil, -1, row_ix), axis=0)        # last clean row
                filled = np.take_along_axis(out, np.maximum(last, 0), axis=0)
                out = np.where(sil, np.where(last >= 0, filled, 0.0), out)
        return out, detected, silent

    def _propagate_ref(self, a: np.ndarray, w: np.ndarray, status: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Original per-row / per-silent-element loop (the oracle)."""
        n = self.timing.n
        m_rows = a.shape[0]
        out_prev_rows = np.zeros((m_rows, n), dtype=np.float64)
        detected = np.zeros((n, n), dtype=np.int64)
        silent = np.zeros((n, n), dtype=np.int64)
        for i in range(n):
            term = a[:, i:i + 1] * w[i, :][None, :]           # (M, n)
            out = out_prev_rows + term
            st = status[:, i, :]                              # (M, n)
            detected[i] += (st == DETECTED).sum(axis=0)
            sil = st == SILENT
            silent[i] += sil.sum(axis=0)
            if sil.any():
                # stale register: MAC (i, j) re-emits its previous-cycle output
                for mi, j in zip(*np.nonzero(sil)):
                    out[mi, j] = out[mi - 1, j] if mi > 0 else 0.0
            out_prev_rows = out
        return out_prev_rows, detected, silent

    # -- runtime-scheme hook ---------------------------------------------------------

    def trial_run(self, partition_v: np.ndarray, seed: int = 0,
                  m_rows: int = 32, fail_on_silent: bool = True) -> np.ndarray:
        """One Algorithm-2 trial: random traffic at the given partition
        voltages; returns per-partition timing_fail flags.

        Razor can only *see* DETECTED errors; SILENT ones are invisible to the
        runtime scheme (crash region).  ``fail_on_silent=True`` folds them in
        only to let tests assert what an oracle would see.
        """
        rng = np.random.default_rng(seed)
        n = self.timing.n
        v_map = np.asarray(partition_v, dtype=np.float64)[self._part] \
            .reshape(n, n)
        a = rng.normal(size=(m_rows, n))
        w = rng.normal(size=(n, n))
        if self.impl == "reference":
            _, stats = self.matmul(a, w, v_map=v_map)
            flags = stats.partition_fail.copy()
            if fail_on_silent:
                flags |= _or_by_partition(stats.silent.reshape(-1) > 0,
                                          self._part, self._n_part)
            return flags
        # flags-only fast path: a trial consumes nothing but the Razor flags,
        # so skip the product/psum propagation entirely — classification of
        # the arrival tensor is all Algorithm 2 observes
        act = self._activity(a)
        status = classify_arrival(self._arrival(v_map, act), self.razor)
        fail = status == DETECTED
        if fail_on_silent:
            fail |= status == SILENT
        return _or_by_partition(fail.any(axis=0).reshape(-1), self._part,
                                self._n_part)


def fast_fault_matmul(a: np.ndarray, w: np.ndarray, fail_mask: np.ndarray,
                      mode: str = "drop") -> np.ndarray:
    """Vectorized large-array approximation: rank-1 terms of failing MACs are
    dropped ("drop") or halved ("attenuate").  Used for big sweeps where the
    cycle-level simulator is unnecessary."""
    n = w.shape[0]
    keep = (~fail_mask).astype(a.dtype) if mode == "drop" else (
        1.0 - 0.5 * fail_mask.astype(a.dtype))
    # C[m, j] = sum_i a[m, i] * w[i, j] * keep[i, j]
    return np.einsum("mi,ij->mj", a, w * keep)
