"""Precision-island controller: the TPU-native analogue of voltage islands
(DESIGN.md Sec. 2b, beyond-paper layer).

On a TPU the per-tile energy knob is numerics, not V_ccint.  The mapping:

    min-slack            -> quantization headroom of a weight tile
    V_ccint rail         -> precision tier (int4 < int8 < bf16 "voltage")
    Algorithm 1 (static) -> band the headroom range, assign tiers
    Razor shadow FF      -> shadow high-precision recompute + mismatch flag
                            (kernels/razor_matmul.py)
    Algorithm 2 (runtime)-> promote tile on mismatch, demote when clean

Energy per MAC by tier is anchored to the paper's PowerModel so the framework
reports a single consistent simulated-power number (roofline/power_report).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .voltage import RuntimeScheme

# precision tiers, ordered like ascending voltage: cheapest/most fragile first
TIERS: Tuple[str, ...] = ("int4", "int8", "bf16")

# relative energy per MAC (bf16 MXU pass = 1.0; int8 ~ 1/4 of bf16 multiply
# energy, int4 ~ 1/8 — standard accelerator energy ratios).  The ladder is
# monotone in BOTH energy and accuracy, mirroring the paper's voltage axis.
ENERGY_PER_MAC: Dict[str, float] = {"int4": 0.12, "int8": 0.25, "bf16": 1.00}


def tile_headroom(w: np.ndarray, tile: int = 128) -> np.ndarray:
    """Quantization headroom per (tile x tile) weight tile.

    Headroom = how well int8 quantization preserves the tile, measured as the
    negative log of relative quantization error — the 'min slack' analogue:
    larger headroom tolerates a cheaper tier.
    """
    r, c = w.shape
    tr, tc = (r + tile - 1) // tile, (c + tile - 1) // tile
    out = np.zeros((tr, tc))
    for i in range(tr):
        for j in range(tc):
            blk = w[i * tile:(i + 1) * tile, j * tile:(j + 1) * tile]
            scale = np.max(np.abs(blk)) or 1.0
            q = np.round(blk / scale * 127.0) / 127.0 * scale
            rel = float(np.linalg.norm(q - blk) / (np.linalg.norm(blk) or 1.0))
            out[i, j] = -np.log10(max(rel, 1e-12))
    return out


def static_tier_assignment(headroom: np.ndarray,
                           n_tiers: int = len(TIERS)) -> np.ndarray:
    """Algorithm-1 analogue: band the headroom range into ``n_tiers`` equal
    bands; highest-headroom band gets the cheapest tier (index 0 = int8)."""
    h = np.asarray(headroom, dtype=np.float64)
    lo, hi = float(h.min()), float(h.max())
    if hi - lo < 1e-12:
        return np.zeros(h.shape, dtype=np.int64)
    band = (hi - lo) / n_tiers
    # highest headroom -> tier 0 (cheapest); lowest -> tier n-1 (bf16)
    idx = np.clip(((hi - h) / band).astype(np.int64), 0, n_tiers - 1)
    return idx


@dataclasses.dataclass
class PrecisionController:
    """Algorithm-2 verbatim on tier indices instead of volts.

    ``step(tiers, mismatch)``: a tile whose shadow-recompute flag fired is
    promoted one tier (toward bf16); a clean tile is demoted one tier.
    """

    n_tiers: int = len(TIERS)
    history: List[np.ndarray] = dataclasses.field(default_factory=list)

    def step(self, tiers: np.ndarray, mismatch: np.ndarray) -> np.ndarray:
        t = np.asarray(tiers, dtype=np.int64)
        nt = np.where(np.asarray(mismatch, bool), t + 1, t - 1)
        nt = np.clip(nt, 0, self.n_tiers - 1)
        self.history.append(nt.copy())
        return nt

    def calibrate(self, tiers0: np.ndarray, trial, max_trials: int = 16) -> np.ndarray:
        """Anneal to the cheapest clean tier per tile; ``trial(tiers) ->
        mismatch flags``. Locks the lowest tier that ran clean."""
        t = np.asarray(tiers0, dtype=np.int64).copy()
        best_clean = np.full(t.shape, self.n_tiers - 1, dtype=np.int64)
        seen_clean = np.zeros(t.shape, dtype=bool)
        for _ in range(max_trials):
            flags = np.asarray(trial(t), bool)
            clean = ~flags
            best_clean = np.where(clean & (t < best_clean), t, best_clean)
            seen_clean |= clean
            t = self.step(t, flags)
            if seen_clean.all() and (t >= best_clean).all():
                break
        return np.where(seen_clean, best_clean, self.n_tiers - 1)


def energy_ratio(tiers: np.ndarray) -> float:
    """Mean per-MAC energy of a tier map relative to all-bf16."""
    t = np.asarray(tiers, dtype=np.int64)
    e = np.array([ENERGY_PER_MAC[TIERS[i]] for i in t.reshape(-1)])
    return float(e.mean())


def tier_names(tiers: np.ndarray) -> np.ndarray:
    return np.asarray(TIERS, dtype=object)[np.asarray(tiers, dtype=np.int64)]
