"""Synthetic-but-calibrated timing model of an N x N systolic MAC array.

Reproduces the *statistics* of the paper's synthesis timing reports (Sec. II,
Table I): per-path slack for every MAC output bit, with bottom rows (deeper
partial-sum accumulation) having the smallest minimum slack, and a per-bit
carry-chain gradient.  The Vivado/VTR timing engines are replaced by this
model (see DESIGN.md Sec. 2 "what did not transfer").

Calibration targets (16x16 array, 100 MHz clock, Artix-7-class logic):
  * worst paths: total delay 4.05-4.40 ns, logic 2.49-2.89 ns, net 1.47-1.57 ns
    => slack of worst paths ~ 5.3-5.8 ns   (paper Table I)
  * the row-band structure yields the multi-modal min-slack distribution that
    the paper's clustering figures (Figs. 11-14) show: ~4 natural groups.

Voltage -> delay uses the alpha-power law (near/sub-threshold behaviour):
    d(V) = d(Vnom) * ((Vnom - Vth) / (V - Vth)) ** alpha
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Technology nodes (paper Sec. V: Vivado Artix-7 28nm + VTR 22/45/130nm)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TechNode:
    """Electrical constants for one FPGA technology node."""

    name: str
    v_nom: float          # nominal core voltage (V)
    v_th: float           # threshold voltage (V) (paper Sec. V: 22nm 0.45, 45nm 0.5, 130nm 0.7)
    v_min: float          # top of the scaling range used by the paper
    v_crash: float        # voltage below which timing collapses (paper Fig. 7)
    alpha: float          # alpha-power-law exponent for delay(V)
    # power-law exponent P ~ (V/Vref)^k, least-squares fit to Table II (see power.py)
    power_k: float
    # baseline dynamic power (mW) of a 16x16 array at v_nom, 100MHz (Table II)
    p16_mw: float


#: Cross-instance cache of synthesized timing structure (see
#: ``TimingModel.__post_init__``); bounded, cleared wholesale when full.
_SYNTH_CACHE: Dict[tuple, tuple] = {}

TECH_NODES: Dict[str, TechNode] = {
    # Guard-band experiments use [0.95, 1.00] V exactly as the paper's Artix-7 run.
    "vivado-28nm": TechNode("vivado-28nm", v_nom=1.00, v_th=0.40, v_min=1.00,
                            v_crash=0.95, alpha=1.3, power_k=2.546, p16_mw=408.0),
    "vtr-22nm": TechNode("vtr-22nm", v_nom=1.00, v_th=0.45, v_min=1.20,
                         v_crash=0.50, alpha=1.3, power_k=0.713, p16_mw=269.0),
    "vtr-45nm": TechNode("vtr-45nm", v_nom=1.00, v_th=0.50, v_min=1.20,
                         v_crash=0.50, alpha=1.3, power_k=0.687, p16_mw=387.0),
    "vtr-130nm": TechNode("vtr-130nm", v_nom=1.30, v_th=0.70, v_min=1.30,
                          v_crash=0.70, alpha=1.3, power_k=0.280, p16_mw=1543.0),
}


def delay_scale(tech: TechNode, v: np.ndarray | float) -> np.ndarray | float:
    """Alpha-power-law delay multiplier relative to the nominal voltage.

    >= 1 for v < v_nom; diverges as v -> v_th (the crash region of Fig. 7).
    """
    v = np.asarray(v, dtype=np.float64)
    v_eff = np.maximum(v - tech.v_th, 1e-3)
    return ((tech.v_nom - tech.v_th) / v_eff) ** tech.alpha


# ---------------------------------------------------------------------------
# Timing report synthesis
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TimingPath:
    """One row of the synthesis timing report (paper Table I)."""

    name: str
    slack_ns: float
    levels: int
    high_fanout: int
    path_from: str
    path_to: str
    total_delay_ns: float
    logic_delay_ns: float
    net_delay_ns: float
    requirement_ns: float
    src_clock: str = "clk"
    dst_clock: str = "clk"


@dataclasses.dataclass
class TimingModel:
    """Deterministic per-path delay model for an ``n x n`` systolic array.

    Structure (physical rationale, calibrated to Table I):
      * per-bit carry gradient: higher accumulator bits close later;
      * row bands: partial sums ripple down rows, and every ``n//4`` rows the
        accumulation word grows / the P&R engine inserts longer nets, giving a
        step increase in delay -> the multi-modal min-slack structure that the
        paper clusters into ~4 groups;
      * per-MAC jitter: placement/LUT-mapping noise.
    """

    n: int = 16
    clock_ns: float = 10.0          # 100 MHz, as in the paper
    n_bits: int = 17                # accumulator output register bits (Table I shows bits 11..16)
    tech: TechNode = TECH_NODES["vivado-28nm"]
    seed: int = 2021

    # Calibrated against Table I (16x16 @ 100 MHz): worst total 4.41 vs paper
    # 4.40 ns, worst slack 5.34 vs 5.34, worst logic 2.93 vs 2.89, worst net
    # 1.51 vs 1.57; DBSCAN/mean-shift recover the 4 row bands of Figs. 11-14.
    base_logic_ns: float = 1.30
    carry_ns: float = 0.60          # full-swing per-bit carry contribution
    row_band_ns: float = 0.30       # step per row band (the cluster separation)
    row_slope_ns: float = 0.004     # small within-band gradient
    base_net_ns: float = 1.35
    net_spread_ns: float = 0.10
    jitter_ns: float = 0.03
    uncertainty_ns: float = 0.25    # clock uncertainty subtracted from slack

    def __post_init__(self) -> None:
        # The synthesized structure depends only on geometry, seed and the
        # calibration constants — NOT on the tech node or clock (those only
        # scale delays later).  Cache it so a sweep's 4 tech nodes share one
        # synthesis, and repeated models (tests, benchmarks) are free.
        key = (self.n, self.n_bits, self.seed, self.base_logic_ns,
               self.carry_ns, self.row_band_ns, self.row_slope_ns,
               self.base_net_ns, self.net_spread_ns, self.jitter_ns)
        hit = _SYNTH_CACHE.get(key)
        if hit is None:
            hit = self._synthesize()
            if len(_SYNTH_CACHE) >= 32:
                _SYNTH_CACHE.clear()
            _SYNTH_CACHE[key] = hit
        self._logic, self._net, self._fanout, self._levels, self._mac_delay \
            = hit

    def _synthesize(self):
        rng = np.random.default_rng(self.seed)
        n, b = self.n, self.n_bits
        bits = np.arange(b, dtype=np.float64)
        rows = np.arange(n, dtype=np.float64)

        n_bands = 4
        band = np.minimum(rows * n_bands // max(n, 1), n_bands - 1)  # (n,)

        logic = (
            self.base_logic_ns
            + self.carry_ns * (bits[None, None, :] / max(b - 1, 1))
            + self.row_band_ns * band[:, None, None]
            + self.row_slope_ns * rows[:, None, None]
            + rng.normal(0.0, self.jitter_ns, size=(n, n, b))
        )
        net = (
            self.base_net_ns
            + self.net_spread_ns * rng.random(size=(n, n, b))
            + 0.02 * band[:, None, None]
        )
        logic = np.maximum(logic, 0.1)            # (n, n, bits)
        net = np.maximum(net, 0.05)
        fanout = rng.integers(4, 12, size=(n, n))
        levels = 7 + (bits[None, None, :] // 6).astype(np.int64) \
            + np.zeros((n, n, b), np.int64)
        mac_delay = (logic + net).max(axis=-1)
        for arr in (logic, net, fanout, levels, mac_delay):
            arr.flags.writeable = False           # cached arrays are shared
        return logic, net, fanout, levels, mac_delay

    # -- nominal-voltage quantities ------------------------------------------------

    @property
    def path_delays_ns(self) -> np.ndarray:
        """(n, n, bits) total path delay at nominal voltage."""
        return self._logic + self._net

    @property
    def mac_delay_ns(self) -> np.ndarray:
        """(n, n) worst-path delay per MAC (precomputed — it is the base of
        every per-trial voltage scaling)."""
        return self._mac_delay

    @property
    def min_slack_ns(self) -> np.ndarray:
        """(n, n) minimum slack per MAC — the clustering feature (Sec. II-D)."""
        return self.clock_ns - self.uncertainty_ns - self.mac_delay_ns

    def min_slack_flat(self) -> np.ndarray:
        """(n*n,) min slack in row-major MAC order."""
        return self.min_slack_ns.reshape(-1)

    # -- voltage-dependent quantities ----------------------------------------------

    def delays_at(self, v: float | np.ndarray) -> np.ndarray:
        """(n, n) worst-path delay per MAC at per-MAC voltage ``v``.

        ``v`` may be a scalar or an (n, n) per-MAC voltage map (built from the
        partition voltages).
        """
        scale = delay_scale(self.tech, v)
        return self.mac_delay_ns * np.asarray(scale)

    def fails_at(self, v: float | np.ndarray, margin_ns: float = 0.0) -> np.ndarray:
        """(n, n) bool: worst path misses the clock at voltage ``v``."""
        return self.delays_at(v) > (self.clock_ns - margin_ns)

    def min_safe_voltage(self, lo: float | None = None, hi: float | None = None,
                         tol: float = 1e-4) -> np.ndarray:
        """(n, n) smallest voltage at which each MAC still meets timing (bisect)."""
        lo_v = self.tech.v_th + 1e-2 if lo is None else lo
        hi_v = max(self.tech.v_nom, self.tech.v_min) if hi is None else hi
        lo_a = np.full((self.n, self.n), lo_v)
        hi_a = np.full((self.n, self.n), hi_v)
        for _ in range(64):
            mid = 0.5 * (lo_a + hi_a)
            bad = self.fails_at(mid)
            lo_a = np.where(bad, mid, lo_a)
            hi_a = np.where(bad, hi_a, mid)
            if float(np.max(hi_a - lo_a)) < tol:
                break
        return hi_a

    # -- report rendering ------------------------------------------------------------

    def report(self, worst: int = 100) -> List[TimingPath]:
        """The ``worst`` setup paths, formatted like the paper's Table I.

        All numeric columns (indices, slacks, rounded delays) are produced as
        whole arrays; only the final dataclass packing walks the rows.
        """
        d = self.path_delays_ns
        flat = d.reshape(-1)
        order = np.argsort(-flat)[:worst]
        n, b = self.n, self.n_bits
        i_s, j_s, bits = np.unravel_index(order, (n, n, b))
        totals = flat[order]
        slacks = self.clock_ns - self.uncertainty_ns - totals
        levels = self._levels[i_s, j_s, bits]
        fanout = self._fanout[i_s, j_s]
        logic = self._logic[i_s, j_s, bits]
        net = self._net[i_s, j_s, bits]
        return [TimingPath(
            name=f"Path {rank + 1}",
            slack_ns=round(float(slacks[rank]), 2),
            levels=int(levels[rank]),
            high_fanout=int(fanout[rank]),
            path_from=f"GEN_REG_I[{max(i - 1, 0)}].GEN_REG_J[{j}].uut/prev_activ_reg[1]/C",
            path_to=f"GEN_REG_I[{i}].GEN_REG_J[{j}].uut/sig_mac_out_reg[{bit}]/D",
            total_delay_ns=round(float(totals[rank]), 2),
            logic_delay_ns=round(float(logic[rank]), 2),
            net_delay_ns=round(float(net[rank]), 2),
            requirement_ns=self.clock_ns,
        ) for rank, (i, j, bit) in enumerate(zip(i_s.tolist(), j_s.tolist(),
                                                 bits.tolist()))]

    def implementation_report(self, worst: int = 100, *, partitioned: bool = True,
                              seed: int = 7) -> np.ndarray:
        """Post-P&R delays for the ``worst`` synthesis paths (paper Figs. 4/5).

        Per Sec. II-D, clustering whole MACs keeps implementation delays close
        to synthesis delays; we model the residual P&R perturbation as a small
        multiplicative noise (larger if ``partitioned`` is False, mimicking the
        abandoned per-path flow whose critical path blew up ~2x).
        """
        d = np.sort(self.path_delays_ns.reshape(-1))[::-1][:worst]
        rng = np.random.default_rng(seed)
        if partitioned:
            return d * rng.normal(1.0, 0.015, size=d.shape)
        return d * rng.normal(1.9, 0.12, size=d.shape)


def render_report_table(paths: List[TimingPath]) -> str:
    """Text rendering mirroring Table I's columns."""
    hdr = ("Name, Slack, Levels, HighFanout, From, To, TotalDelay, LogicDelay, "
           "NetDelay, Requirement, SrcClk, DstClk")
    rows = [hdr]
    for p in paths:
        rows.append(
            f"{p.name}, {p.slack_ns:.2f}, {p.levels}, {p.high_fanout}, {p.path_from}, "
            f"{p.path_to}, {p.total_delay_ns:.2f}, {p.logic_delay_ns:.2f}, "
            f"{p.net_delay_ns:.2f}, {p.requirement_ns:.2f}, {p.src_clock}, {p.dst_clock}")
    return "\n".join(rows)
