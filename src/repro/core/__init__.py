"""Core reproduction of *Towards Power Efficient DNN Accelerator Design on
Reconfigurable Platform* — slack-clustered voltage-island partitioning of a
systolic-array TPU, with static (Algorithm 1) + Razor-runtime (Algorithm 2)
V_ccint calibration and the calibrated power model (Table II / Figs. 15-16)."""

from .cadflow import FlowReport, paper_table2_flow, run_flow
from .clustering import (cluster, dbscan, hierarchical, hierarchical_dendrogram,
                         kmeans, meanshift, relabel_by_feature_mean,
                         attach_noise_to_nearest, silhouette)
from .partition import (Floorplan, Partition, grid_floorplan, partition_min_slack,
                        quadrant_floorplan)
from .power import PAPER_TABLE2, PowerModel, fit_power_exponent, model_for, \
    validate_against_table2
from .precision import (ENERGY_PER_MAC, TIERS, PrecisionController, energy_ratio,
                        static_tier_assignment, tile_headroom)
from .razor import (DETECTED, OK, SILENT, RazorConfig, RazorMac, classify_arrival,
                    effective_arrival, streamed_activity, switching_activity)
from .systolic import SimStats, SystolicSim, fast_fault_matmul
from .timing import TECH_NODES, TechNode, TimingModel, TimingPath, delay_scale, \
    render_report_table
from .voltage import (CalibrationResult, RuntimeScheme,
                      assign_partition_voltages, runtime_voltage_scaling,
                      static_voltage_scaling)

__all__ = [name for name in dir() if not name.startswith("_")]
