"""DEPRECATED shim over :mod:`repro.flow` — the staged CAD-flow pipeline.

The paper's flow (Fig. 9: timing -> clustering -> floorplan -> static
Algorithm-1 voltages -> Razor runtime calibration -> power report) used to
live here as the monolithic ``run_flow()``.  It is now the composable
``repro.flow`` pipeline::

    from repro.flow import FlowConfig, run, sweep

    report = run(FlowConfig(array_n=16, tech="vivado-28nm", algo="dbscan"))
    result = sweep({"tech": ["vivado-28nm", "vtr-22nm"],
                    "algo": ["kmeans", "dbscan"]})

``run_flow()`` and ``FlowReport`` remain as thin, bit-for-bit-compatible
wrappers so existing callers keep working; new code should import from
``repro.flow`` (declarative ``FlowConfig``, pluggable stages, artifact
caching, multi-scenario sweeps).
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

# NOTE: only ..flow.report is imported at module scope — importing the full
# ..flow package here would be circular (repro.flow's stages import the core
# submodules, which triggers this module via repro.core.__init__).
from ..flow.report import FlowReport
from .power import model_for

__all__ = ["FlowReport", "run_flow", "paper_table2_flow"]


def _cluster(slack: np.ndarray, algo: str, n_clusters: Optional[int],
             seed: int) -> np.ndarray:
    """Deprecated alias of :func:`repro.flow.cluster_slack`."""
    from ..flow.stages import cluster_slack
    return cluster_slack(slack, algo, n_clusters, seed)


def run_flow(array_n: int = 16, tech: str = "vivado-28nm", algo: str = "dbscan",
             n_clusters: Optional[int] = 4, clock_ns: float = 10.0,
             seed: int = 2021, v_min: Optional[float] = None,
             v_crash: Optional[float] = None, freq_mhz: float = 100.0,
             calibrate: bool = True, max_trials: int = 48) -> FlowReport:
    """Execute the full flow of Fig. 9 and return every artifact.

    Deprecated: equivalent to ``repro.flow.run(FlowConfig(...))``, which also
    exposes stage composition, artifact caching and sweeps.
    """
    from ..flow import FlowConfig, run
    # legacy behaviour: falsy n_clusters (0/None) meant "use the default 4"
    return run(FlowConfig(
        array_n=array_n, tech=tech, algo=algo, n_clusters=n_clusters or None,
        clock_ns=clock_ns, seed=seed, v_min=v_min, v_crash=v_crash,
        freq_mhz=freq_mhz, calibrate=calibrate, max_trials=max_trials))


def paper_table2_flow(array_n: int, tech: str) -> Dict[str, float]:
    """The exact Table II configuration: 4 equal partitions at the paper's
    rounded voltages {0.96, 0.97, 0.98, 0.99} against a 1.0 V baseline."""
    pm = model_for(tech)
    v = np.array([0.96, 0.97, 0.98, 0.99])
    base = pm.baseline_mw(array_n, 1.0)
    scaled = pm.partitioned_mw(array_n, v, v_ref=1.0)
    return {"baseline_mw": base, "scaled_mw": scaled,
            "reduction_pct": 100.0 * (1 - scaled / base)}
