"""End-to-end CAD flow (paper Fig. 9): synthesis timing -> clustering ->
floorplan -> static voltages (Algorithm 1) -> runtime calibration
(Algorithm 2 + Razor trials) -> power report.

This is the paper's primary contribution as one composable entry point:

    report = run_flow(array_n=16, tech="vivado-28nm", algo="dbscan")

The returned FlowReport carries every intermediate artifact (timing report,
cluster labels, constraint files, voltages, power numbers) so benchmarks and
tests can interrogate any stage.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from . import clustering as cl
from .constraints import generate_sdc, generate_xdc
from .partition import Floorplan, grid_floorplan, partition_min_slack
from .power import PowerModel, model_for
from .razor import RazorConfig
from .systolic import SystolicSim
from .timing import TECH_NODES, TimingModel
from .voltage import RuntimeScheme, assign_partition_voltages, static_voltage_scaling


@dataclasses.dataclass
class FlowReport:
    array_n: int
    tech: str
    algo: str
    n_partitions: int
    labels: np.ndarray                   # (n*n,) cluster id per MAC
    min_slack: np.ndarray                # (n*n,)
    floorplan: Floorplan
    static_v: np.ndarray                 # (P,) Algorithm-1 voltages per partition
    runtime_v: np.ndarray                # (P,) after Algorithm-2 calibration
    baseline_mw: float
    static_mw: float
    runtime_mw: float
    static_reduction_pct: float
    runtime_reduction_pct: float
    xdc: str
    sdc: str
    razor_trials: int
    calibrated_fail_free: bool

    def summary(self) -> str:
        return (f"{self.array_n}x{self.array_n} {self.tech} {self.algo} "
                f"P={self.n_partitions} static {self.static_reduction_pct:.2f}% "
                f"runtime {self.runtime_reduction_pct:.2f}% "
                f"(baseline {self.baseline_mw:.0f} mW)")


def _cluster(slack: np.ndarray, algo: str, n_clusters: Optional[int],
             seed: int) -> np.ndarray:
    """Run the chosen algorithm with paper-consistent defaults and fold noise."""
    algo = algo.lower()
    spread = float(slack.max() - slack.min()) or 1.0
    if algo in ("kmeans", "k-means"):
        labels = cl.kmeans(slack, k=n_clusters or 4, seed=seed)
    elif algo in ("hierarchical", "hierarchy"):
        labels = cl.hierarchical(slack, n_clusters=n_clusters or 4)
    elif algo in ("meanshift", "mean-shift"):
        # the paper's radius 0.4 on its ~2.4 ns 16x16 slack spread, rescaled
        labels = cl.meanshift(slack, bandwidth=0.17 * spread)
    elif algo == "dbscan":
        labels = cl.dbscan(slack, eps=spread / 12.0,
                           min_pts=max(4, len(slack) // 64))
        labels = cl.attach_noise_to_nearest(slack, labels)
    else:
        raise ValueError(f"unknown algorithm {algo!r}")
    return cl.relabel_by_feature_mean(slack, labels)   # 0 = highest slack


def run_flow(array_n: int = 16, tech: str = "vivado-28nm", algo: str = "dbscan",
             n_clusters: Optional[int] = 4, clock_ns: float = 10.0,
             seed: int = 2021, v_min: Optional[float] = None,
             v_crash: Optional[float] = None, freq_mhz: float = 100.0,
             calibrate: bool = True, max_trials: int = 48) -> FlowReport:
    """Execute the full flow of Fig. 9 and return every artifact."""
    node = TECH_NODES[tech]
    v_min = node.v_min if v_min is None else v_min
    v_crash = node.v_crash if v_crash is None else v_crash

    # 1. synthesis timing (Sec. II-A/II-B)
    tm = TimingModel(n=array_n, clock_ns=clock_ns, tech=node, seed=seed)
    slack = tm.min_slack_flat()

    # 2. clustering (Sec. IV) + cluster 0 = highest slack
    labels = _cluster(slack, algo, n_clusters, seed)
    n_part = int(labels.max()) + 1

    # 3. floorplan + constraints (Sec. II-C)
    fp = grid_floorplan(labels, array_n)

    # 4. static scheme (Algorithm 1): ascending voltages; highest-slack
    #    cluster (=0) takes the lowest rail.
    v_bands = static_voltage_scaling(v_min, v_crash, n_part)
    part_slack = partition_min_slack(labels, slack)
    static_v = assign_partition_voltages(part_slack, v_bands)
    fp = fp.with_voltages(static_v)

    # 5. runtime scheme (Algorithm 2) with Razor trials
    sim = SystolicSim(tm, fp, RazorConfig(clock_ns=clock_ns))
    v_s = (v_min - v_crash) / n_part
    runtime_v = static_v.copy()
    trials = 0
    fail_free = True
    if calibrate:
        scheme = RuntimeScheme(v_s=v_s, v_floor=v_crash, v_ceil=max(v_min, node.v_nom))

        def trial(v: np.ndarray) -> np.ndarray:
            nonlocal trials
            trials += 1
            return sim.trial_run(v, seed=seed + trials)

        runtime_v = scheme.calibrate(static_v, trial, max_trials=max_trials)
        fail_free = not sim.trial_run(runtime_v, seed=seed + 10_000).any()

    # 6. power (Sec. V-C)
    pm = model_for(tech, freq_mhz=freq_mhz)
    frac = np.bincount(labels, minlength=n_part) / labels.size
    baseline = pm.baseline_mw(array_n, node.v_nom)
    static_mw = pm.partitioned_mw(array_n, static_v, frac, v_ref=node.v_nom)
    runtime_mw = pm.partitioned_mw(array_n, runtime_v, frac, v_ref=node.v_nom)

    return FlowReport(
        array_n=array_n, tech=tech, algo=algo, n_partitions=n_part,
        labels=labels, min_slack=slack, floorplan=fp.with_voltages(runtime_v),
        static_v=static_v, runtime_v=runtime_v,
        baseline_mw=baseline, static_mw=static_mw, runtime_mw=runtime_mw,
        static_reduction_pct=100.0 * (1 - static_mw / baseline),
        runtime_reduction_pct=100.0 * (1 - runtime_mw / baseline),
        xdc=generate_xdc(fp, clock_ns), sdc=generate_sdc(fp, clock_ns),
        razor_trials=trials, calibrated_fail_free=bool(fail_free),
    )


def paper_table2_flow(array_n: int, tech: str) -> Dict[str, float]:
    """The exact Table II configuration: 4 equal partitions at the paper's
    rounded voltages {0.96, 0.97, 0.98, 0.99} against a 1.0 V baseline."""
    pm = model_for(tech)
    v = np.array([0.96, 0.97, 0.98, 0.99])
    base = pm.baseline_mw(array_n, 1.0)
    scaled = pm.partitioned_mw(array_n, v, v_ref=1.0)
    return {"baseline_mw": base, "scaled_mw": scaled,
            "reduction_pct": 100.0 * (1 - scaled / base)}
