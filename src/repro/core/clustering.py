"""The paper's four clustering algorithms (Sec. IV), vectorized NumPy.

scikit-learn is not available in this container, so Hierarchical agglomerative,
K-means(++), Mean-shift and DBSCAN are implemented directly.  All operate on
1-D minimum-slack vectors (shape ``(n,)``) or general ``(n, d)`` features.

These are the array-programming rewrites of the original loop implementations,
which are preserved verbatim in :mod:`repro.core.clustering_ref` as bit-exact
oracles (``tests/core/test_clustering_equiv.py`` asserts label identity):

  * agglomerative keeps a per-row nearest-neighbour cache so each merge costs
    O(n) instead of an O(n^2) submatrix copy + argmin (the old
    ``np.ix_``/``alive.remove`` bookkeeping) — ~1000x at 64x64;
  * DBSCAN grows whole frontiers with boolean-matrix reachability instead of a
    per-point stack;
  * k-means updates all centroids in one ``np.bincount`` batch;
  * mean-shift merges modes one center-sweep at a time instead of per point;
  * the relabel/noise/silhouette helpers are single-pass ``np.bincount``.

Every function returns integer labels of shape ``(n,)``; DBSCAN additionally
uses ``-1`` for noise.  All are deterministic given ``seed``.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np


def _as2d(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, dtype=np.float64)
    return x[:, None] if x.ndim == 1 else x


def _pairwise_sq(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.maximum(
        (a * a).sum(-1)[:, None] + (b * b).sum(-1)[None, :] - 2.0 * a @ b.T, 0.0)


# ---------------------------------------------------------------------------
# Hierarchical agglomerative (Sec. IV-A)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Dendrogram:
    """Merge history: row i merges clusters ``left[i]``, ``right[i]`` at
    ``height[i]`` producing cluster ``n + i`` of size ``size[i]`` (scipy-like)."""

    left: np.ndarray
    right: np.ndarray
    height: np.ndarray
    size: np.ndarray

    def cut(self, n_clusters: int) -> np.ndarray:
        """Labels from cutting the tree to ``n_clusters``."""
        n = len(self.left) + 1
        parent = np.arange(n + len(self.left), dtype=np.int64)

        keep = len(self.left) - (n_clusters - 1)
        for m in range(max(keep, 0)):
            new = n + m
            for node in (int(self.left[m]), int(self.right[m])):
                while parent[node] != node:          # find with path halving
                    parent[node] = parent[parent[node]]
                    node = parent[node]
                parent[node] = new
        # vectorized path compression: pointer-jump every leaf to its root
        roots = parent[np.arange(n)]
        nxt = parent[roots]
        while (nxt != roots).any():
            roots = nxt
            nxt = parent[parent[roots]]
        # renumber sorted roots to 0..k-1 (same map as the reference dict)
        _, labels = np.unique(roots, return_inverse=True)
        return labels.astype(np.int64)


Linkage = Literal["single", "complete", "average"]


def hierarchical(x: np.ndarray, n_clusters: int = 4,
                 linkage: Linkage = "average") -> np.ndarray:
    """Agglomerative clustering; returns labels."""
    return hierarchical_dendrogram(x, linkage=linkage).cut(n_clusters)


def hierarchical_dendrogram(x: np.ndarray, linkage: Linkage = "average") -> Dendrogram:
    """Full merge history (the paper's Fig. 10 dendrogram).

    Nearest-neighbour-cached greedy merging: the full distance matrix is kept
    masked in place (dead rows/columns pinned at ``inf``) with a per-row
    (min, argmin) cache, so each merge is O(n) plus a batched re-scan of only
    the rows whose cached neighbour was invalidated.  Merge order, linkage
    updates and tie-breaking (row-major first occurrence) replicate
    :func:`repro.core.clustering_ref.hierarchical_dendrogram` bit for bit.
    """
    pts = _as2d(x)
    n = len(pts)
    dist = np.sqrt(_pairwise_sq(pts, pts))
    np.fill_diagonal(dist, np.inf)

    alive = np.ones(n, dtype=bool)
    active = np.arange(n, dtype=np.int64)        # slot -> cluster id
    slot_size = np.ones(n, dtype=np.int64)       # slot -> cluster size
    row_min = dist.min(axis=1)
    row_arg = dist.argmin(axis=1)

    left = np.empty(n - 1, dtype=np.int64)
    right = np.empty(n - 1, dtype=np.int64)
    height = np.empty(n - 1, dtype=np.float64)
    msize = np.empty(n - 1, dtype=np.int64)
    idx = np.arange(n)

    for m in range(n - 1):
        i_star = int(np.argmin(row_min))          # first row holding the min
        j_star = int(row_arg[i_star])             # first column in that row
        pa, pb = (i_star, j_star) if i_star < j_star else (j_star, i_star)
        h = float(dist[i_star, j_star])
        ca, cb = int(active[pa]), int(active[pb])
        sa, sb = int(slot_size[pa]), int(slot_size[pb])

        da, db = dist[pa], dist[pb]
        if linkage == "single":
            nd = np.minimum(da, db)
        elif linkage == "complete":
            nd = np.where(np.isinf(da) | np.isinf(db), np.inf, np.maximum(da, db))
        else:  # average
            nd = (sa * da + sb * db) / (sa + sb)
        dist[pa, :] = nd
        dist[:, pa] = nd
        dist[pa, pa] = np.inf
        dist[pb, :] = np.inf
        dist[:, pb] = np.inf
        alive[pb] = False
        row_min[pb] = np.inf

        left[m] = min(ca, cb)
        right[m] = max(ca, cb)
        height[m] = h
        msize[m] = sa + sb
        active[pa] = n + m
        slot_size[pa] = sa + sb

        # repair the row cache: rows whose cached neighbour was pa or pb must
        # re-scan; for the rest the only changed column is pa (distance nd)
        others = alive & (idx != pa)
        stale = others & ((row_arg == pa) | (row_arg == pb))
        stale[pa] = alive[pa]                     # pa's whole row changed
        fix = np.flatnonzero(stale)
        if fix.size:
            sub = dist[fix]
            args = sub.argmin(axis=1)
            row_arg[fix] = args
            row_min[fix] = sub[np.arange(fix.size), args]
        fresh = others & ~stale
        npa = nd[fresh]
        better = npa < row_min[fresh]
        tie = npa == row_min[fresh]
        fresh_ix = np.flatnonzero(fresh)
        row_min[fresh_ix[better]] = npa[better]
        row_arg[fresh_ix[better]] = pa
        # an exact tie moves the first occurrence only if pa is earlier
        row_arg[fresh_ix[tie]] = np.minimum(row_arg[fresh_ix[tie]], pa)

    return Dendrogram(left, right, height, msize)


# ---------------------------------------------------------------------------
# K-means++ (Sec. IV-B)
# ---------------------------------------------------------------------------


def kmeans(x: np.ndarray, k: int = 4, seed: int = 0, iters: int = 100,
           return_centers: bool = False):
    """Lloyd's algorithm with k-means++ seeding [Arthur & Vassilvitskii 2007].

    Centroid updates are batched over all clusters with ``np.bincount``; the
    empty-cluster re-seed walks clusters in index order exactly like the
    reference (each re-seed sees the centers updated so far).  Note the
    bincount sums accumulate sequentially while the reference's ``mean(0)``
    sums pairwise — centroids can differ in the last ulp, which changes
    labels only if a point sits within ~1 ulp of equidistant between two
    centroids (never observed on the flow's slack data; the equivalence
    suite pins it across seeds and sizes).
    """
    pts = _as2d(x)
    n, d = pts.shape
    if k >= n:
        labels = np.arange(n, dtype=np.int64) % max(k, 1)
        return (labels, pts.copy()) if return_centers else labels
    rng = np.random.default_rng(seed)
    centers = np.empty((k, d))
    centers[0] = pts[rng.integers(n)]
    d2 = _pairwise_sq(pts, centers[:1]).min(-1)
    for c in range(1, k):
        tot = d2.sum()
        probs = d2 / tot if tot > 0 else np.full(n, 1.0 / n)
        centers[c] = pts[rng.choice(n, p=probs)]
        d2 = np.minimum(d2, _pairwise_sq(pts, centers[c:c + 1]).min(-1))
    labels = np.zeros(n, dtype=np.int64)
    for it in range(iters):
        newl = np.argmin(_pairwise_sq(pts, centers), axis=-1)
        if np.array_equal(newl, labels) and it > 0:
            break
        labels = newl
        counts = np.bincount(labels, minlength=k)
        sums = np.stack([np.bincount(labels, weights=pts[:, j], minlength=k)
                         for j in range(d)], axis=1)
        means = sums / np.maximum(counts, 1)[:, None]
        nonempty = counts > 0
        if nonempty.all():
            centers = means
        else:
            for c in range(k):                    # reference re-seed order
                if nonempty[c]:
                    centers[c] = means[c]
                else:
                    centers[c] = pts[int(np.argmax(
                        _pairwise_sq(pts, centers).min(-1)))]
    return (labels, centers) if return_centers else labels


# ---------------------------------------------------------------------------
# Mean-shift (Sec. IV-C)
# ---------------------------------------------------------------------------


def meanshift(x: np.ndarray, bandwidth: float = 0.4, iters: int = 200,
              tol: float = 1e-6, kernel: str = "flat") -> np.ndarray:
    """Mean-shift clustering; the paper sets the window radius to 0.4 for the
    16x16 array's slacks (Sec. IV-C).  ``kernel='flat'`` is the classic
    fixed-radius window whose radius matches the paper's usage; 'gaussian'
    (RBF) is also provided."""
    pts = _as2d(x)
    modes = pts.copy()
    for _ in range(iters):
        d2 = _pairwise_sq(modes, pts)
        if kernel == "flat":
            w = (d2 <= bandwidth * bandwidth).astype(np.float64)
        else:
            w = np.exp(-0.5 * d2 / (bandwidth ** 2))
        new = (w @ pts) / np.maximum(w.sum(-1, keepdims=True), 1e-300)
        shift = np.abs(new - modes).max()
        modes = new
        if shift < tol:
            break
    # merge modes closer than bandwidth/2: sweep one center at a time — the
    # earliest unassigned mode founds the next center and claims every
    # unassigned mode within bandwidth/2, which is exactly the reference's
    # "join the first close-enough center" order
    n = len(pts)
    labels = -np.ones(n, dtype=np.int64)
    unassigned = np.ones(n, dtype=bool)
    cid = 0
    while unassigned.any():
        i = int(np.argmax(unassigned))
        ctr = modes[i]
        close = np.sqrt(((modes - ctr) ** 2).sum(-1)) < bandwidth / 2
        members = unassigned & close
        members[i] = True
        labels[members] = cid
        unassigned &= ~members
        cid += 1
    return labels


# ---------------------------------------------------------------------------
# DBSCAN (Sec. IV-D) — the paper's preferred algorithm
# ---------------------------------------------------------------------------


def dbscan(x: np.ndarray, eps: float = 0.12, min_pts: int = 8) -> np.ndarray:
    """Density-based clustering; label -1 marks noise/outlier MACs.

    Region growth expands whole frontiers at once: each step ORs together the
    neighbourhood rows of every core point on the frontier instead of popping
    points off a stack.  Cluster ids still appear in ascending order of each
    component's smallest core index, and a border point in reach of several
    clusters keeps the earliest id — the reference's DFS semantics.
    """
    pts = _as2d(x)
    n = len(pts)
    d2 = _pairwise_sq(pts, pts)
    neigh = d2 <= eps * eps
    core = neigh.sum(-1) >= min_pts          # self-inclusive, as sklearn
    labels = np.full(n, -1, dtype=np.int64)
    cid = 0
    unvisited_core = core.copy()
    while unvisited_core.any():
        seed = int(np.argmax(unvisited_core))
        members = np.zeros(n, dtype=bool)
        members[seed] = True
        frontier = members.copy()            # frontier always core-only
        while frontier.any():
            reached = neigh[frontier].any(axis=0)
            new = reached & ~members & (labels == -1)
            members |= new
            frontier = new & core
        labels[members] = cid
        unvisited_core &= ~members
        cid += 1
    return labels


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def cluster(x: np.ndarray, algo: str = "dbscan", **kw) -> np.ndarray:
    """Dispatch by algorithm name (paper's 'Choice of Clustering Algorithms')."""
    algo = algo.lower()
    if algo in ("hierarchical", "hierarchy"):
        return hierarchical(x, **kw)
    if algo in ("kmeans", "k-means", "k_means"):
        return kmeans(x, **kw)
    if algo in ("meanshift", "mean-shift", "mean_shift"):
        return meanshift(x, **kw)
    if algo == "dbscan":
        return dbscan(x, **kw)
    raise ValueError(f"unknown clustering algorithm: {algo!r}")


def relabel_by_feature_mean(x: np.ndarray, labels: np.ndarray,
                            descending: bool = True) -> np.ndarray:
    """Renumber clusters so cluster 0 has the highest (default) mean feature.

    With slack as the feature this makes cluster 0 the *highest-slack* group,
    which the paper places in the *lowest-voltage* partition. Noise (-1) stays.

    A ``np.bincount`` presence pass replaces the ``np.unique`` sort and one
    array gather replaces the old per-cluster remap rescans.  The k cluster
    means deciding the *ordering* deliberately use the oracle's exact
    arithmetic (``x[labels == c].mean()``, pairwise summation): a
    bincount-accumulated sum rounds differently in the last ulp, and a
    near-tie between cluster means must never permute labels between the
    vectorized and reference paths (the flow benchmark gates on bit-identical
    reports).
    """
    x = np.asarray(x, dtype=np.float64).reshape(len(labels), -1).mean(-1)
    mask = labels != -1
    if not mask.any():
        return labels.copy()
    ids = np.flatnonzero(np.bincount(labels[mask]))
    means = np.array([x[labels == c].mean() for c in ids])
    # stable sort keeps ascending id order on exact ties, like sorted()
    order = ids[np.argsort(-means if descending else means, kind="stable")]
    remap = np.empty(int(labels.max()) + 1, dtype=np.int64)
    remap[order] = np.arange(order.size)
    out = labels.copy()
    out[mask] = remap[labels[mask]]
    return out


def attach_noise_to_nearest(x: np.ndarray, labels: np.ndarray) -> np.ndarray:
    """Assign DBSCAN noise points to the nearest cluster centroid.

    The paper treats outlier MACs as noise at clustering time, but *every* MAC
    must live in some voltage partition, so noise is folded into its nearest
    cluster before floorplanning.  Centroids keep the oracle's exact
    per-cluster ``mean(0)`` (see :func:`relabel_by_feature_mean` for why);
    the noise-to-centroid assignment is the batched part.
    """
    pts = _as2d(x)
    mask = labels != -1
    if not mask.any():
        return np.zeros(len(labels), dtype=np.int64)
    ids = np.flatnonzero(np.bincount(labels[mask]))
    cents = np.stack([pts[labels == c].mean(0) for c in ids])
    out = labels.copy()
    noise = ~mask
    if noise.any():
        nearest = np.argmin(_pairwise_sq(pts[noise], cents), axis=-1)
        out[noise] = ids[nearest]
    return out


def silhouette(x: np.ndarray, labels: np.ndarray) -> float:
    """Mean silhouette coefficient (used by tests/benchmarks to sanity-check
    cluster quality across the four algorithms)."""
    pts = _as2d(x)
    labels = np.asarray(labels)
    mask = labels != -1
    counts = np.bincount(labels[mask]) if mask.any() else np.zeros(0, np.int64)
    ids = np.flatnonzero(counts)
    if ids.size < 2:
        return 0.0
    d = np.sqrt(_pairwise_sq(pts, pts))
    onehot = np.zeros((len(pts), int(labels.max()) + 1))
    onehot[mask, labels[mask]] = 1.0
    sums = d @ onehot                                  # (n, max_id+1)
    valid = mask & (counts[np.maximum(labels, 0)] > 1) & (labels >= 0)
    li = labels[valid]
    a = sums[valid, li] / (counts[li] - 1)             # d[i, i] = 0, excluded
    mean_to = sums[valid][:, ids] / counts[ids][None, :]
    own_col = np.searchsorted(ids, li)
    mean_to[np.arange(len(li)), own_col] = np.inf      # exclude own cluster
    b = mean_to.min(axis=1)
    if a.size == 0:
        return 0.0
    return float(np.mean((b - a) / np.maximum(a, b)))
