"""Floorplanning: clusters of MACs -> rectangular FPGA voltage islands.

Implements the paper's 'Cluster Generation' -> partition-placement step
(Sec. II-C / Fig. 8): each cluster of MACs becomes one FPGA partition bounded
by slice coordinates (X0,Y0)-(X1,Y1); the partition's V_ccint rail feeds every
MAC inside it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Partition:
    """One voltage island on the FPGA floor."""

    index: int
    mac_ids: Tuple[int, ...]            # row-major MAC indices in this island
    x0: int
    y0: int
    x1: int
    y1: int
    v_ccint: float = float("nan")

    @property
    def n_macs(self) -> int:
        return len(self.mac_ids)

    def slice_range(self) -> str:
        """Xilinx-style slice range for the XDC pblock."""
        return f"SLICE_X{self.x0}Y{self.y0}:SLICE_X{self.x1}Y{self.y1}"


@dataclasses.dataclass
class Floorplan:
    array_n: int
    partitions: List[Partition]

    def partition_of_mac(self) -> np.ndarray:
        """(n*n,) partition index per MAC (row-major)."""
        out = np.full(self.array_n * self.array_n, -1, dtype=np.int64)
        for p in self.partitions:
            out[list(p.mac_ids)] = p.index
        if (out < 0).any():
            raise ValueError("floorplan does not cover every MAC")
        return out

    def voltage_map(self) -> np.ndarray:
        """(n, n) per-MAC voltage from partition rails."""
        part = self.partition_of_mac()
        v = np.array([p.v_ccint for p in self.partitions])
        return v[part].reshape(self.array_n, self.array_n)

    def with_voltages(self, v: Sequence[float]) -> "Floorplan":
        ps = [dataclasses.replace(p, v_ccint=float(v[p.index]))
              for p in self.partitions]
        return Floorplan(self.array_n, ps)


def grid_floorplan(labels: np.ndarray, array_n: int,
                   slices_per_mac: int = 4) -> Floorplan:
    """Place clusters on the floor as horizontal slabs of rows.

    The paper observes (Sec. V-C) that min-slack is strongly row-correlated
    (partial sums ripple toward the bottom rows), so clusters map naturally to
    contiguous row bands; the 16x16 example in Fig. 8 uses quadrants, which is
    the special case of 4 equal slabs re-split in x when cluster sizes allow.

    ``labels`` is the (n*n,) cluster id per MAC (no -1 allowed here).  MACs are
    *re-ordered* into their cluster's slab; the mac_ids of each partition
    record which logical MACs live there, exactly like the paper's XDC flow
    pins clustered MACs into a pblock.
    """
    labels = np.asarray(labels)
    if labels.min() < 0:
        raise ValueError("attach noise points to clusters before floorplanning")
    n_part = int(labels.max()) + 1
    total = array_n * array_n
    if len(labels) != total:
        raise ValueError("labels must cover the full array")

    # rows of the floor are dealt out proportionally to cluster sizes
    sizes = np.bincount(labels, minlength=n_part)
    rows = np.maximum(1, np.round(sizes / total * array_n).astype(int))
    while rows.sum() > array_n:
        rows[int(np.argmax(rows))] -= 1
    while rows.sum() < array_n:
        rows[int(np.argmin(rows))] += 1

    parts: List[Partition] = []
    y = 0
    for c in range(n_part):
        ids = tuple(int(i) for i in np.flatnonzero(labels == c))
        y1 = y + int(rows[c]) * slices_per_mac - 1
        parts.append(Partition(
            index=c, mac_ids=ids,
            x0=0, y0=y * 1,
            x1=array_n * slices_per_mac - 1, y1=y1,
        ))
        y = y1 + 1
    return Floorplan(array_n, parts)


def quadrant_floorplan(array_n: int) -> Floorplan:
    """The paper's simplified Fig. 8 layout: 4 equal quadrants (n/2 x n/2),
    partition order: top-left, top-right, bottom-left, bottom-right."""
    h = array_n // 2
    s = 4  # slices per MAC edge
    quads = [(0, 0), (0, h), (h, 0), (h, h)]   # (row0, col0)
    parts = []
    for idx, (r0, c0) in enumerate(quads):
        ids = tuple(int((r0 + r) * array_n + (c0 + c))
                    for r in range(h) for c in range(h))
        parts.append(Partition(
            index=idx, mac_ids=ids,
            x0=c0 * s, y0=(array_n - (r0 + h)) * s,
            x1=(c0 + h) * s - 1, y1=(array_n - r0) * s - 1,
        ))
    return Floorplan(array_n, parts)


def partition_min_slack(labels: np.ndarray, min_slack_flat: np.ndarray) -> np.ndarray:
    """Representative (minimum) slack per cluster — drives voltage assignment."""
    n_part = int(labels.max()) + 1
    return np.array([min_slack_flat[labels == c].min() for c in range(n_part)])
