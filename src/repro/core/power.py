"""Dynamic-power model calibrated to the paper's Table II / Figs. 15-16.

Physics: P_dyn = a * C * V^2 * f.  The paper's *reported* reductions do not
track a pure V^2 law (tool power models mix voltage-scalable logic power with
rail-independent interconnect/clock components, plus leakage that shrinks
super-quadratically at 28 nm), so per technology node we fit a single exponent

    P(V) = P_ref * (V / V_ref) ** k

by least squares over the paper's own reduction rows, then *hold it fixed*
for every prediction (array sizes, Fig. 15/16 variants).  See DESIGN.md Sec. 9.

All paper numbers live in PAPER_TABLE2 so benchmarks/tests print model vs
paper side by side and flag |delta|.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .timing import TECH_NODES, TechNode

# ---------------------------------------------------------------------------
# Paper data (Table II).  Garbled OCR cells are reconstructed from the
# self-consistent columns: scaled = baseline * (1 - reduction); see DESIGN.md.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Table2Row:
    tech: str
    array: int                      # systolic array dimension (16/32/64)
    baseline_v: float               # unpartitioned V_ccint
    baseline_mw: float
    partition_v: Tuple[float, ...]  # the 4 partition voltages
    reduction_pct: float            # paper's reported % reduction


PAPER_TABLE2: List[Table2Row] = [
    # --- guard-band experiments: baseline 1.00 V, partitions {0.96,0.97,0.98,0.99}
    Table2Row("vivado-28nm", 16, 1.00, 408.0, (0.96, 0.97, 0.98, 0.99), 6.37),
    Table2Row("vivado-28nm", 32, 1.00, 1538.0, (0.96, 0.97, 0.98, 0.99), 6.76),
    Table2Row("vivado-28nm", 64, 1.00, 5920.0, (0.96, 0.97, 0.98, 0.99), 6.52),
    Table2Row("vtr-22nm", 16, 1.00, 269.0, (0.96, 0.97, 0.98, 0.99), 1.86),
    Table2Row("vtr-22nm", 32, 1.00, 1072.0, (0.96, 0.97, 0.98, 0.99), 1.95),
    Table2Row("vtr-22nm", 64, 1.00, 4284.0, (0.96, 0.97, 0.98, 0.99), 1.84),
    Table2Row("vtr-45nm", 16, 1.00, 387.0, (0.96, 0.97, 0.98, 0.99), 1.80),
    Table2Row("vtr-45nm", 32, 1.00, 1549.0, (0.96, 0.97, 0.98, 0.99), 1.87),
    Table2Row("vtr-45nm", 64, 1.00, 6200.0, (0.96, 0.97, 0.98, 0.99), 1.77),
    Table2Row("vtr-130nm", 16, 1.00, 1543.0, (0.96, 0.97, 0.98, 0.99), 0.70),
    Table2Row("vtr-130nm", 32, 1.00, 6172.0, (0.96, 0.97, 0.98, 0.99), 0.76),
    Table2Row("vtr-130nm", 64, 1.00, 24693.0, (0.96, 0.97, 0.98, 0.99), 0.77),
    # --- critical-region experiment (4th instant): baseline 0.9 V
    Table2Row("vtr-22nm", 64, 0.90, 3965.0, (0.70, 0.80, 0.90, 1.00), 3.70),
    Table2Row("vtr-45nm", 64, 0.90, 5798.0, (0.70, 0.80, 0.90, 1.00), 2.40),
    Table2Row("vtr-130nm", 64, 0.90, 23961.0, (0.70, 0.80, 0.90, 1.00), 1.37),
]


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def fit_power_exponent(tech: str) -> float:
    """Least-squares fit of k in P ~ V^k over the tech's Table II rows.

    Each row with equal-size partitions at voltages v_i and baseline V_ref
    predicts reduction r(k) = 1 - mean_i (v_i / V_ref)^k ; we minimise
    sum (r(k) - r_paper)^2 by golden-section search on k in [0.05, 4].

    The fit is cached per tech — ``PAPER_TABLE2`` is a constant, so the
    exponent is too.  Previously this re-ran ~2.5k interpreted ``loss``
    evaluations on every ``PowerStage`` execution of a sweep.  The loss body
    is kept operation-for-operation identical to
    :func:`fit_power_exponent_ref` so both produce the same bits (Python
    ``**`` and NumPy power round differently in the last ulp, which the
    golden-section bracketing would amplify into a different exponent).
    """
    rows = [r for r in PAPER_TABLE2 if r.tech == tech]
    if not rows:
        raise ValueError(f"no Table II rows for {tech}")

    def loss(k: float) -> float:
        tot = 0.0
        for r in rows:
            pred = 1.0 - np.mean([(v / r.baseline_v) ** k for v in r.partition_v])
            tot += (pred - r.reduction_pct / 100.0) ** 2
        return tot

    lo, hi = 0.05, 4.0
    phi = (np.sqrt(5.0) - 1.0) / 2.0
    a, b = lo, hi
    c, d = b - phi * (b - a), a + phi * (b - a)
    for _ in range(80):
        if loss(c) < loss(d):
            b = d
        else:
            a = c
        c, d = b - phi * (b - a), a + phi * (b - a)
    return 0.5 * (a + b)


def fit_power_exponent_ref(tech: str) -> float:
    """The original per-row interpreted fit, uncached — bit-identical result
    to :func:`fit_power_exponent`; kept as the ``impl="reference"`` perf
    baseline (the seed paid ~2.5k Python ``loss`` evaluations per sweep)."""
    rows = [r for r in PAPER_TABLE2 if r.tech == tech]
    if not rows:
        raise ValueError(f"no Table II rows for {tech}")

    def loss(k: float) -> float:
        tot = 0.0
        for r in rows:
            pred = 1.0 - np.mean([(v / r.baseline_v) ** k for v in r.partition_v])
            tot += (pred - r.reduction_pct / 100.0) ** 2
        return tot

    lo, hi = 0.05, 4.0
    phi = (np.sqrt(5.0) - 1.0) / 2.0
    a, b = lo, hi
    c, d = b - phi * (b - a), a + phi * (b - a)
    for _ in range(80):
        if loss(c) < loss(d):
            b = d
        else:
            a = c
        c, d = b - phi * (b - a), a + phi * (b - a)
    return 0.5 * (a + b)


@dataclasses.dataclass
class PowerModel:
    """Per-technology dynamic power with partitioned voltage scaling."""

    tech: TechNode
    k: Optional[float] = None                # power-law exponent; fit if None
    freq_mhz: float = 100.0
    activity: float = 0.5                    # toggle rate alpha

    def __post_init__(self) -> None:
        if self.k is None:
            self.k = fit_power_exponent(self.tech.name)

    # -- baselines -----------------------------------------------------------------

    def baseline_mw(self, array: int, v: Optional[float] = None) -> float:
        """Unpartitioned array power, anchored to the tech's 16x16 Table II cell
        and scaled by MAC count, frequency and activity."""
        v = self.tech.v_nom if v is None else v
        p16 = self.tech.p16_mw
        scale = (array / 16.0) ** 2
        f = self.freq_mhz / 100.0
        a = self.activity / 0.5
        return p16 * scale * f * a * (v / self.tech.v_nom) ** self.k

    # -- partitioned ----------------------------------------------------------------

    def partitioned_mw(self, array: int, partition_v: Sequence[float],
                       partition_frac: Optional[Sequence[float]] = None,
                       v_ref: Optional[float] = None) -> float:
        """Power with per-partition voltages.

        ``partition_frac[i]`` — fraction of MACs in partition i (defaults to
        equal, matching the paper's 'same partition size' simplification).
        ``v_ref`` — the unpartitioned baseline voltage this config is compared
        against (paper uses 1.0 in guard-band rows, 0.9 in the critical row).
        """
        v = np.asarray(partition_v, dtype=np.float64)
        frac = (np.full(len(v), 1.0 / len(v)) if partition_frac is None
                else np.asarray(partition_frac, dtype=np.float64))
        frac = frac / frac.sum()
        v_ref = self.tech.v_nom if v_ref is None else v_ref
        base = self.baseline_mw(array, v_ref)
        return float(base * np.sum(frac * (v / v_ref) ** self.k))

    def reduction_pct(self, array: int, partition_v: Sequence[float],
                      v_ref: Optional[float] = None,
                      partition_frac: Optional[Sequence[float]] = None) -> float:
        v_ref = self.tech.v_nom if v_ref is None else v_ref
        base = self.baseline_mw(array, v_ref)
        part = self.partitioned_mw(array, partition_v, partition_frac, v_ref)
        return 100.0 * (1.0 - part / base)

    # -- energy for the TPU integration (DESIGN.md Sec. 2c) --------------------------

    def energy_per_mac_pj(self, v: float) -> float:
        """Energy of one MAC op at voltage v, derived from the 16x16 anchor:
        P = N_mac * E_mac * f  =>  E_mac(V_nom) = P16 / (256 * f)."""
        e_nom_pj = (self.tech.p16_mw * 1e-3) / (256 * self.freq_mhz * 1e6) * 1e12
        return e_nom_pj * (v / self.tech.v_nom) ** self.k

    def macs_energy_j(self, n_macs: float, partition_v: Sequence[float],
                      partition_frac: Optional[Sequence[float]] = None) -> float:
        """Total energy for ``n_macs`` MAC ops spread over voltage partitions."""
        v = np.asarray(partition_v, dtype=np.float64)
        frac = (np.full(len(v), 1.0 / len(v)) if partition_frac is None
                else np.asarray(partition_frac, dtype=np.float64))
        frac = frac / frac.sum()
        e = np.array([self.energy_per_mac_pj(float(x)) for x in v]) * 1e-12
        return float(n_macs * np.sum(frac * e))


def model_for(tech_name: str, **kw) -> PowerModel:
    return PowerModel(tech=TECH_NODES[tech_name], **kw)


def validate_against_table2(max_rows: Optional[int] = None) -> List[Dict]:
    """Model-vs-paper comparison over every Table II row (used by tests and the
    table2 benchmark)."""
    out = []
    models = {t: model_for(t) for t in TECH_NODES}
    rows = PAPER_TABLE2[:max_rows] if max_rows else PAPER_TABLE2
    for r in rows:
        m = models[r.tech]
        pred = m.reduction_pct(r.array, r.partition_v, v_ref=r.baseline_v)
        scaled_paper = r.baseline_mw * (1 - r.reduction_pct / 100.0)
        scaled_model = r.baseline_mw * (1 - pred / 100.0)
        out.append({
            "tech": r.tech, "array": r.array, "v_ref": r.baseline_v,
            "paper_reduction_pct": r.reduction_pct,
            "model_reduction_pct": round(pred, 3),
            "delta_pp": round(pred - r.reduction_pct, 3),
            "paper_scaled_mw": round(scaled_paper, 1),
            "model_scaled_mw": round(scaled_model, 1),
        })
    return out
