"""Reference (loop) implementations of the paper's four clustering algorithms.

These are the original interpreted-Python implementations, kept verbatim as
bit-exact oracles for the vectorized rewrites in :mod:`repro.core.clustering`.
They are intentionally slow (hierarchical is O(n^3) with per-merge submatrix
copies) — use them only for equivalence testing and the ``impl="reference"``
benchmark baseline, never on a hot path.

Every function returns integer labels of shape ``(n,)``; DBSCAN additionally
uses ``-1`` for noise.  All are deterministic given ``seed``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Literal, Optional, Tuple

import numpy as np


def _as2d(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, dtype=np.float64)
    return x[:, None] if x.ndim == 1 else x


def _pairwise_sq(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.maximum(
        (a * a).sum(-1)[:, None] + (b * b).sum(-1)[None, :] - 2.0 * a @ b.T, 0.0)


# ---------------------------------------------------------------------------
# Hierarchical agglomerative (Sec. IV-A)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Dendrogram:
    """Merge history: row i merges clusters ``left[i]``, ``right[i]`` at
    ``height[i]`` producing cluster ``n + i`` of size ``size[i]`` (scipy-like)."""

    left: np.ndarray
    right: np.ndarray
    height: np.ndarray
    size: np.ndarray

    def cut(self, n_clusters: int) -> np.ndarray:
        """Labels from cutting the tree to ``n_clusters``."""
        n = len(self.left) + 1
        parent = list(range(n + len(self.left)))

        def find(a: int) -> int:
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        keep = len(self.left) - (n_clusters - 1)
        for m in range(max(keep, 0)):
            new = n + m
            parent[find(int(self.left[m]))] = new
            parent[find(int(self.right[m]))] = new
        roots = {find(i) for i in range(n)}
        remap = {r: k for k, r in enumerate(sorted(roots))}
        return np.array([remap[find(i)] for i in range(n)], dtype=np.int64)


Linkage = Literal["single", "complete", "average"]


def hierarchical(x: np.ndarray, n_clusters: int = 4,
                 linkage: Linkage = "average") -> np.ndarray:
    """Agglomerative clustering; returns labels."""
    return hierarchical_dendrogram(x, linkage=linkage).cut(n_clusters)


def hierarchical_dendrogram(x: np.ndarray, linkage: Linkage = "average") -> Dendrogram:
    """Full merge history (the paper's Fig. 10 dendrogram). O(n^3) worst case —
    fine for the <= 4096 MACs of a 64x64 array."""
    pts = _as2d(x)
    n = len(pts)
    d = np.sqrt(_pairwise_sq(pts, pts))
    np.fill_diagonal(d, np.inf)
    active = {i: i for i in range(n)}          # position -> cluster id
    sizes = {i: 1 for i in range(n)}
    alive = list(range(n))
    left: List[int] = []
    right: List[int] = []
    height: List[float] = []
    msize: List[int] = []
    next_id = n
    dist = d.copy()
    for _ in range(n - 1):
        sub = dist[np.ix_(alive, alive)]
        k = int(np.argmin(sub))
        ai, bi = divmod(k, len(alive))
        if ai > bi:
            ai, bi = bi, ai
        pa, pb = alive[ai], alive[bi]
        h = float(sub[ai, bi])
        ca, cb = active[pa], active[pb]
        sa, sb = sizes[ca], sizes[cb]
        # update distances from merged cluster (stored at slot pa) to the rest
        da, db = dist[pa], dist[pb]
        if linkage == "single":
            nd = np.minimum(da, db)
        elif linkage == "complete":
            nd = np.where(np.isinf(da) | np.isinf(db), np.inf, np.maximum(da, db))
        else:  # average
            nd = (sa * da + sb * db) / (sa + sb)
        dist[pa, :] = nd
        dist[:, pa] = nd
        dist[pa, pa] = np.inf
        dist[pb, :] = np.inf
        dist[:, pb] = np.inf
        alive.remove(pb)
        left.append(min(ca, cb))
        right.append(max(ca, cb))
        height.append(h)
        msize.append(sa + sb)
        active[pa] = next_id
        sizes[next_id] = sa + sb
        next_id += 1
    return Dendrogram(np.array(left), np.array(right), np.array(height),
                      np.array(msize))


# ---------------------------------------------------------------------------
# K-means++ (Sec. IV-B)
# ---------------------------------------------------------------------------


def kmeans(x: np.ndarray, k: int = 4, seed: int = 0, iters: int = 100,
           return_centers: bool = False):
    """Lloyd's algorithm with k-means++ seeding [Arthur & Vassilvitskii 2007]."""
    pts = _as2d(x)
    n = len(pts)
    if k >= n:
        labels = np.arange(n, dtype=np.int64) % max(k, 1)
        return (labels, pts.copy()) if return_centers else labels
    rng = np.random.default_rng(seed)
    centers = np.empty((k, pts.shape[1]))
    centers[0] = pts[rng.integers(n)]
    d2 = _pairwise_sq(pts, centers[:1]).min(-1)
    for c in range(1, k):
        tot = d2.sum()
        probs = d2 / tot if tot > 0 else np.full(n, 1.0 / n)
        centers[c] = pts[rng.choice(n, p=probs)]
        d2 = np.minimum(d2, _pairwise_sq(pts, centers[c:c + 1]).min(-1))
    labels = np.zeros(n, dtype=np.int64)
    for _ in range(iters):
        newl = np.argmin(_pairwise_sq(pts, centers), axis=-1)
        if np.array_equal(newl, labels) and _ > 0:
            break
        labels = newl
        for c in range(k):
            m = labels == c
            if m.any():
                centers[c] = pts[m].mean(0)
            else:  # re-seed empty cluster at the farthest point
                centers[c] = pts[int(np.argmax(_pairwise_sq(pts, centers).min(-1)))]
    return (labels, centers) if return_centers else labels


# ---------------------------------------------------------------------------
# Mean-shift (Sec. IV-C)
# ---------------------------------------------------------------------------


def meanshift(x: np.ndarray, bandwidth: float = 0.4, iters: int = 200,
              tol: float = 1e-6, kernel: str = "flat") -> np.ndarray:
    """Mean-shift clustering; the paper sets the window radius to 0.4 for the
    16x16 array's slacks (Sec. IV-C).  ``kernel='flat'`` is the classic
    fixed-radius window whose radius matches the paper's usage; 'gaussian'
    (RBF) is also provided."""
    pts = _as2d(x)
    modes = pts.copy()
    for _ in range(iters):
        d2 = _pairwise_sq(modes, pts)
        if kernel == "flat":
            w = (d2 <= bandwidth * bandwidth).astype(np.float64)
        else:
            w = np.exp(-0.5 * d2 / (bandwidth ** 2))
        new = (w @ pts) / np.maximum(w.sum(-1, keepdims=True), 1e-300)
        shift = np.abs(new - modes).max()
        modes = new
        if shift < tol:
            break
    # merge modes closer than bandwidth/2
    labels = -np.ones(len(pts), dtype=np.int64)
    centers: List[np.ndarray] = []
    for i, m in enumerate(modes):
        for c, ctr in enumerate(centers):
            if np.linalg.norm(m - ctr) < bandwidth / 2:
                labels[i] = c
                break
        else:
            centers.append(m)
            labels[i] = len(centers) - 1
    return labels


# ---------------------------------------------------------------------------
# DBSCAN (Sec. IV-D) — the paper's preferred algorithm
# ---------------------------------------------------------------------------


def dbscan(x: np.ndarray, eps: float = 0.12, min_pts: int = 8) -> np.ndarray:
    """Density-based clustering; label -1 marks noise/outlier MACs."""
    pts = _as2d(x)
    n = len(pts)
    d2 = _pairwise_sq(pts, pts)
    neigh = d2 <= eps * eps
    core = neigh.sum(-1) >= min_pts          # self-inclusive, as sklearn
    labels = np.full(n, -1, dtype=np.int64)
    cid = 0
    for i in range(n):
        if labels[i] != -1 or not core[i]:
            continue
        # BFS over density-reachable points
        stack = [i]
        labels[i] = cid
        while stack:
            p = stack.pop()
            if not core[p]:
                continue
            for q in np.flatnonzero(neigh[p]):
                if labels[q] == -1:
                    labels[q] = cid
                    stack.append(int(q))
        cid += 1
    return labels


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def cluster(x: np.ndarray, algo: str = "dbscan", **kw) -> np.ndarray:
    """Dispatch by algorithm name (paper's 'Choice of Clustering Algorithms')."""
    algo = algo.lower()
    if algo in ("hierarchical", "hierarchy"):
        return hierarchical(x, **kw)
    if algo in ("kmeans", "k-means", "k_means"):
        return kmeans(x, **kw)
    if algo in ("meanshift", "mean-shift", "mean_shift"):
        return meanshift(x, **kw)
    if algo == "dbscan":
        return dbscan(x, **kw)
    raise ValueError(f"unknown clustering algorithm: {algo!r}")


def relabel_by_feature_mean(x: np.ndarray, labels: np.ndarray,
                            descending: bool = True) -> np.ndarray:
    """Renumber clusters so cluster 0 has the highest (default) mean feature.

    With slack as the feature this makes cluster 0 the *highest-slack* group,
    which the paper places in the *lowest-voltage* partition. Noise (-1) stays.
    """
    x = np.asarray(x, dtype=np.float64).reshape(len(labels), -1).mean(-1)
    ids = [c for c in np.unique(labels) if c != -1]
    means = {c: x[labels == c].mean() for c in ids}
    order = sorted(ids, key=lambda c: means[c], reverse=descending)
    remap = {c: r for r, c in enumerate(order)}
    out = labels.copy()
    for c, r in remap.items():
        out[labels == c] = r
    return out


def attach_noise_to_nearest(x: np.ndarray, labels: np.ndarray) -> np.ndarray:
    """Assign DBSCAN noise points to the nearest cluster centroid.

    The paper treats outlier MACs as noise at clustering time, but *every* MAC
    must live in some voltage partition, so noise is folded into its nearest
    cluster before floorplanning.
    """
    pts = _as2d(x)
    ids = [c for c in np.unique(labels) if c != -1]
    if not ids:
        return np.zeros(len(labels), dtype=np.int64)
    cents = np.stack([pts[labels == c].mean(0) for c in ids])
    out = labels.copy()
    noise = labels == -1
    if noise.any():
        nearest = np.argmin(_pairwise_sq(pts[noise], cents), axis=-1)
        out[noise] = np.array(ids)[nearest]
    return out


def silhouette(x: np.ndarray, labels: np.ndarray) -> float:
    """Mean silhouette coefficient (used by tests/benchmarks to sanity-check
    cluster quality across the four algorithms)."""
    pts = _as2d(x)
    ids = [c for c in np.unique(labels) if c != -1]
    if len(ids) < 2:
        return 0.0
    d = np.sqrt(_pairwise_sq(pts, pts))
    vals = []
    for i in range(len(pts)):
        li = labels[i]
        if li == -1:
            continue
        own = labels == li
        own[i] = False
        if not own.any():
            continue
        a = d[i][own].mean()
        b = min(d[i][labels == c].mean() for c in ids if c != li)
        vals.append((b - a) / max(a, b))
    return float(np.mean(vals)) if vals else 0.0
