"""Static and runtime voltage-scaling schemes (paper Sec. III, Algorithms 1-2).

Algorithm 1 (static): split the critical range [V_crash, V_min] into n bands,
partition i gets the band midpoint (ascending).  The paper's n=4 Artix-7
example [0.95, 1.00] yields 0.95625/0.96875/0.98125/0.99375 — printed in the
paper (rounded) as 0.96/0.97/0.98/0.99.

Algorithm 2 (runtime): per trial run, a partition whose Razor flag fired steps
its V_ccint up by V_s, otherwise down by V_s.  We add the convergence wrapper
("trial run" loop of Sec. III-B): anneal until every partition oscillates
around its lowest safe voltage, then lock the upper rail of the oscillation.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np


def static_voltage_scaling(v_min: float, v_crash: float, n: int) -> np.ndarray:
    """Algorithm 1, verbatim. Returns ascending V_ccint_i (partition 0 lowest).

    V_s = (V_min - V_crash) / n ; V_ccint_i = midpoint of band i.
    """
    if n <= 0:
        raise ValueError("need at least one partition")
    if v_min <= v_crash:
        raise ValueError("V_min must exceed V_crash")
    v_s = (v_min - v_crash) / n
    v_l = v_crash
    out = []
    for _ in range(n):
        out.append((v_l + v_l + v_s) / 2.0)
        v_l += v_s
    return np.asarray(out)


def assign_partition_voltages(cluster_mean_slack: Sequence[float],
                              voltages_ascending: np.ndarray) -> np.ndarray:
    """Map clusters to voltages: higher min-slack -> lower V_ccint (Sec. I).

    ``cluster_mean_slack[c]`` is the representative (mean or min) slack of
    cluster ``c``; returns ``v[c]`` per cluster.
    """
    slack = np.asarray(cluster_mean_slack, dtype=np.float64)
    if len(slack) != len(voltages_ascending):
        raise ValueError("one voltage per cluster required")
    order = np.argsort(-slack)           # highest slack first
    v = np.empty_like(slack)
    v[order] = np.sort(np.asarray(voltages_ascending))
    return v


class CalibrationResult(np.ndarray):
    """Calibrated per-partition voltages with an explicit convergence flag.

    Behaves exactly like the ``(P,)`` float array of voltages (it *is* one),
    plus ``converged``: a ``(P,)`` bool array that is False for partitions
    that never produced a clean trial run within ``max_trials`` — those rails
    are pinned at ``v_ceil`` as a safe fallback, and callers should treat
    them as uncalibrated rather than trusting the substituted value.
    """

    converged: np.ndarray

    @classmethod
    def wrap(cls, v: np.ndarray, converged: np.ndarray) -> "CalibrationResult":
        out = np.asarray(v, dtype=np.float64).view(cls)
        out.converged = np.asarray(converged, dtype=bool)
        return out

    def __array_finalize__(self, obj) -> None:
        if obj is None:
            return
        self.converged = getattr(obj, "converged", None)

    @property
    def all_converged(self) -> bool:
        return bool(np.all(self.converged))


@dataclasses.dataclass
class RuntimeScheme:
    """Algorithm 2 with the trial-run convergence wrapper.

    ``flag_reduce`` — the paper's text is self-contradictory ("ANDed value of
    all error detection flags" vs "if any timing failure flag ... is high");
    Algorithm 2's semantics require OR, which is the default.  AND is kept as
    an option; tests show it fails to protect individual MACs.
    """

    v_s: float
    v_floor: float
    v_ceil: float
    flag_reduce: str = "or"              # "or" | "and"
    history: List[np.ndarray] = dataclasses.field(default_factory=list)

    def partition_flags(self, mac_flags: np.ndarray,
                        partition_of_mac: np.ndarray) -> np.ndarray:
        """Reduce per-MAC Razor flags to per-partition timing_fail flags.

        One ``np.bincount`` pass instead of a per-partition mask scan; empty
        partitions reduce to False under both semantics.  Flags are
        binarized first so integer inputs (e.g. per-MAC detected *counts*)
        keep the original truthiness semantics of ``any()``/``all()``.
        """
        part = np.asarray(partition_of_mac)
        n_part = int(part.max()) + 1
        truthy = np.asarray(mac_flags).astype(bool)
        hits = np.bincount(part, weights=truthy.astype(np.float64),
                           minlength=n_part)
        if self.flag_reduce == "or":
            return hits > 0
        size = np.bincount(part, minlength=n_part)
        return (size > 0) & (hits == size)

    def step(self, v: np.ndarray, fail_flags: np.ndarray) -> np.ndarray:
        """One Algorithm-2 update: +V_s on failure else -V_s, clamped."""
        v = np.asarray(v, dtype=np.float64)
        nv = np.where(fail_flags, v + self.v_s, v - self.v_s)
        nv = np.clip(nv, self.v_floor, self.v_ceil)
        self.history.append(nv.copy())
        return nv

    def calibrate(self, v0: np.ndarray,
                  trial: Callable[[np.ndarray], np.ndarray],
                  max_trials: int = 64) -> CalibrationResult:
        """Run trial runs until each partition oscillates (paper's pre-run
        tuning).  ``trial(v) -> per-partition fail flags``.

        Locks each partition at the upper rail of its final oscillation, i.e.
        the lowest voltage that produced a clean run.  Returns a
        :class:`CalibrationResult` — an ndarray of voltages whose
        ``converged`` attribute is False for partitions that never saw a
        clean trial (their rail is pinned at ``v_ceil``, explicitly flagged
        instead of silently substituted).
        """
        v = np.asarray(v0, dtype=np.float64).copy()
        last_clean = np.full(len(v), np.nan)
        seen_fail = np.zeros(len(v), dtype=bool)
        for _ in range(max_trials):
            flags = trial(v)
            seen_fail |= flags
            last_clean = np.where(~flags & (np.isnan(last_clean) | (v < last_clean)),
                                  v, last_clean)
            # converged once every partition has a clean voltage and has either
            # bounced off a failing one or sits clean on the floor
            at_floor_clean = (~flags) & (v <= self.v_floor + 1e-12)
            if np.all((~np.isnan(last_clean)) & (seen_fail | at_floor_clean)):
                break
            v = self.step(v, flags)
        converged = ~np.isnan(last_clean)
        out = np.where(np.isnan(last_clean), self.v_ceil, last_clean)
        return CalibrationResult.wrap(out, converged)

    def calibrate_bisect(self, v0: np.ndarray,
                         trial: Callable[[np.ndarray], np.ndarray],
                         max_trials: int = 16,
                         tol: float = 1e-3) -> CalibrationResult:
        """Batched bisection alternative to the Algorithm-2 anneal.

        The whole rail vector converges in one loop: every trial evaluates all
        partitions at once, each partition halving its own [failing, clean]
        bracket.  ~log2(range/tol) trials instead of the anneal's walk — use
        it when only the converged rails matter, not the paper-faithful
        oscillation trajectory.  Partitions that fail even at ``v_ceil`` are
        reported unconverged and pinned there, like :meth:`calibrate`.
        ``v0`` only fixes the rail count (the bracket is [v_floor, v_ceil]).
        """
        p = len(np.asarray(v0, dtype=np.float64))
        if max_trials <= 0:                    # no trial budget: like anneal,
            return CalibrationResult.wrap(     # pin at ceil, unconverged
                np.full(p, self.v_ceil), np.zeros(p, dtype=bool))
        lo = np.full(p, self.v_floor)
        hi = np.full(p, self.v_ceil)
        converged = ~trial(hi.copy())          # clean at the ceiling?
        for _ in range(max(max_trials - 1, 0)):
            if float(np.max(hi - lo)) <= tol:
                break
            mid = 0.5 * (lo + hi)
            flags = trial(mid)
            lo = np.where(flags, mid, lo)
            hi = np.where(flags, hi, mid)
        out = np.where(converged, hi, self.v_ceil)
        return CalibrationResult.wrap(out, converged)


def runtime_voltage_scaling(v: np.ndarray, fail_flags: np.ndarray, v_s: float,
                            v_floor: float = 0.0, v_ceil: float = np.inf) -> np.ndarray:
    """Stateless single step of Algorithm 2 (verbatim form)."""
    scheme = RuntimeScheme(v_s=v_s, v_floor=v_floor, v_ceil=v_ceil)
    return scheme.step(np.asarray(v, dtype=np.float64), np.asarray(fail_flags, bool))
