"""Behavioural Razor flip-flop model (paper Sec. II-E, Fig. 6; Ernst et al. [5]).

A main register R samples at the rising edge of CLK (period T); a shadow
register S samples the same data on DCLK, lagging by T_del.  Data arriving

  * before T              -> both agree: no error;
  * in (T, T + T_del]     -> R caught stale data, S the fresh value: the error
                             flag F fires and S's value *corrects* R (one-cycle
                             replay penalty);
  * after T + T_del       -> both stale: a *silent* failure (the crash region
                             of Fig. 7 — undetectable, accuracy collapses).

The paper notes input-bit fluctuation raises NTC failure probability; we model
the effective arrival time as the nominal path delay scaled by a
switching-activity term computed from the data actually flowing through the
MAC.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

OK = 0
DETECTED = 1       # Razor flag fires; value is corrected, one replay cycle
SILENT = 2         # arrival beyond the shadow window: undetected corruption


@dataclasses.dataclass(frozen=True)
class RazorConfig:
    clock_ns: float = 10.0
    t_del_ns: float = 2.5          # shadow-clock lag (detection window)
    beta: float = 0.25             # delay sensitivity to switching activity


def classify_arrival(arrival_ns: np.ndarray, cfg: RazorConfig) -> np.ndarray:
    """Elementwise OK / DETECTED / SILENT for arrival times."""
    a = np.asarray(arrival_ns, dtype=np.float64)
    out = np.zeros(a.shape, dtype=np.int64)
    out[a > cfg.clock_ns] = DETECTED
    out[a > cfg.clock_ns + cfg.t_del_ns] = SILENT
    return out


def switching_activity(prev_bits: np.ndarray, cur_bits: np.ndarray,
                       n_bits: int = 16) -> np.ndarray:
    """Fraction of input bits that toggled between consecutive operands.

    Operates on integer operands; the paper's observation is that high
    fluctuation of input bits raises timing-failure probability at NTC.
    """
    prev = np.asarray(prev_bits).astype(np.int64)
    cur = np.asarray(cur_bits).astype(np.int64)
    mask = (1 << n_bits) - 1
    x = (prev ^ cur) & mask
    # popcount via per-byte lookup
    cnt = np.zeros(x.shape, dtype=np.int64)
    for shift in range(0, n_bits, 8):
        cnt += POPCOUNT8[(x >> shift) & 0xFF]
    return cnt / float(n_bits)


POPCOUNT8 = np.array([bin(i).count("1") for i in range(256)], dtype=np.int64)


def streamed_activity(a: np.ndarray, n_bits: int = 16) -> np.ndarray:
    """(M, K) per-cycle toggle fraction of streamed real-valued activations.

    Full-scale quantization to ``n_bits`` signed ints, then consecutive-row
    :func:`switching_activity`.  The single definition shared by
    ``SystolicSim`` and the hwloop emulator — their data-dependent delay
    terms must stay bit-identical.
    """
    a = np.asarray(a)
    scale = np.max(np.abs(a)) or 1.0
    q = np.clip((a / scale) * (2 ** (n_bits - 1) - 1),
                -(2 ** (n_bits - 1)), 2 ** (n_bits - 1) - 1).astype(np.int64)
    prev = np.vstack([q[:1], q[:-1]])
    return switching_activity(prev, q, n_bits)


def effective_arrival(nominal_delay_ns: np.ndarray, activity: np.ndarray,
                      cfg: RazorConfig) -> np.ndarray:
    """Arrival time after data-dependent slowdown: d * (1 + beta * activity)."""
    return np.asarray(nominal_delay_ns) * (1.0 + cfg.beta * np.asarray(activity))


@dataclasses.dataclass
class RazorMac:
    """A MAC wrapped with a Razor FF: produces (value, status) per cycle.

    ``delay_ns`` is the MAC's worst-path delay at its partition voltage (from
    ``TimingModel.delays_at``).  On DETECTED the corrected (true) value is
    returned and the replay counter increments; on SILENT the *stale* previous
    register value leaks through — exactly the paper's failure semantics.
    """

    delay_ns: float
    cfg: RazorConfig = dataclasses.field(default_factory=RazorConfig)
    _reg: float = 0.0
    replays: int = 0
    silent_failures: int = 0

    def cycle(self, a: float, b: float, acc: float, activity: float) -> Tuple[float, int]:
        true_val = acc + a * b
        arrival = float(effective_arrival(np.float64(self.delay_ns), activity, self.cfg))
        status = int(classify_arrival(np.float64(arrival), self.cfg))
        if status == OK:
            self._reg = true_val
        elif status == DETECTED:
            self.replays += 1            # shadow FF corrects R next cycle
            self._reg = true_val
        else:
            self.silent_failures += 1    # R keeps stale data; corruption propagates
        return self._reg, status
