"""Constraint-file generation (paper Sec. II-C step 3).

Emits the XDC (Vivado) and SDC-style (VTR/VPR) artifacts the paper's Python
environment writes: one pblock per voltage island with its slice range, the
clustered MAC cells pinned inside, and the clock constraint.  There is no P&R
engine in this container to consume them — they are produced as textual
artifacts exactly as the paper's flow hands them to the vendor tool.
"""

from __future__ import annotations

from typing import List

from .partition import Floorplan


def mac_cell_name(mac_id: int, array_n: int) -> str:
    i, j = divmod(mac_id, array_n)
    return f"GEN_REG_I[{i}].GEN_REG_J[{j}].uut"


def generate_xdc(fp: Floorplan, clock_ns: float = 10.0,
                 design: str = "systolic_array") -> str:
    """Vivado XDC: create_pblock / resize_pblock / add_cells_to_pblock."""
    lines: List[str] = [
        f"# auto-generated voltage-island constraints for {design} "
        f"({fp.array_n}x{fp.array_n})",
        f"create_clock -period {clock_ns:.3f} -name clk [get_ports clk]",
    ]
    for p in fp.partitions:
        name = f"pblock_vccint_{p.index + 1}"
        lines.append(f"create_pblock {name}")
        lines.append(f"resize_pblock {name} -add {{{p.slice_range()}}}")
        cells = " ".join(mac_cell_name(m, fp.array_n) for m in p.mac_ids)
        lines.append(f"add_cells_to_pblock {name} [get_cells {{{cells}}}]")
        if p.v_ccint == p.v_ccint:  # not NaN
            lines.append(f"# V_CCINT rail for partition {p.index + 1}: "
                         f"{p.v_ccint:.4f} V")
    return "\n".join(lines) + "\n"


def generate_sdc(fp: Floorplan, clock_ns: float = 10.0) -> str:
    """VTR/VPR SDC: clock + per-partition placement region comments (VPR takes
    placement regions via its own constraint syntax; we mirror the paper's
    script output)."""
    lines = [f"create_clock -period {clock_ns:.3f} clk"]
    for p in fp.partitions:
        cells = ", ".join(mac_cell_name(m, fp.array_n) for m in p.mac_ids[:4])
        more = "" if p.n_macs <= 4 else f", ... ({p.n_macs} MACs)"
        lines.append(f"# region partition-{p.index + 1} "
                     f"x[{p.x0}:{p.x1}] y[{p.y0}:{p.y1}] "
                     f"vccint={p.v_ccint:.4f} cells: {cells}{more}")
    return "\n".join(lines) + "\n"
