"""repro: voltage-scaled partitioned DNN accelerators (Paul et al., 2021)
reproduced + generalized as a multi-pod JAX training/serving framework."""

__version__ = "1.0.0"
