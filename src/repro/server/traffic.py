"""Seeded traffic-trace workload generator for the serving frontend.

Datacenter serving workloads (the regime the TPU paper's "millions of
users" economics lives in) are bursty and heavy-tailed, not the uniform
request lists the engine tests use.  This module generates *replayable*
traces with the three canonical properties:

- **Poisson arrivals** — exponential inter-arrival gaps at ``rate_rps``,
  optionally modulated by a **diurnal burst envelope**
  (``rate(t) = rate_rps * (1 + amplitude * sin(2*pi*t / period))``,
  realized by Lewis thinning so the process stays an exact
  inhomogeneous Poisson process under one seed).
- **Heavy-tailed lengths** — prompt and generation lengths drawn from
  clipped lognormals, so a few large requests dominate token demand.
- **QoS mix** — each request lands in a ``Priority`` tier with a
  per-tier TTFT SLO (``deadline_s``), the knobs the priority scheduler
  and load shedder act on.

Everything is deterministic under ``TrafficConfig.seed``; traces round-trip
through NDJSON files (``save_trace``/``load_trace``) so a measured envelope
can be replayed bit-for-bit across backends and scheduler policies.

CLI (writes a trace file for ``repro.launch.serve --trace``):

    PYTHONPATH=src python -m repro.server.traffic --out trace.ndjson \
        --rate 8 --duration 5 --seed 0
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import IO, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..serve import Priority, Request


@dataclasses.dataclass
class TrafficConfig:
    """Knobs for one synthetic traffic trace (all draws seeded)."""
    rate_rps: float = 4.0            # mean arrival rate (requests/second)
    duration_s: float = 10.0         # trace horizon
    seed: int = 0
    # clipped-lognormal length distributions (ln-space mean / sigma)
    prompt_len_log_mean: float = 1.1
    prompt_len_log_sigma: float = 0.6
    gen_len_log_mean: float = 1.4
    gen_len_log_sigma: float = 0.6
    max_prompt_len: int = 24
    max_gen_len: int = 24
    # diurnal burst envelope: 0 disables; 0.8 swings the rate +-80%
    diurnal_amplitude: float = 0.0
    diurnal_period_s: float = 60.0
    # QoS mix: P(LOW), P(NORMAL), P(HIGH) and per-tier TTFT SLO seconds
    # (None = no deadline for that tier), indexed by int(Priority)
    priority_weights: Tuple[float, float, float] = (0.25, 0.5, 0.25)
    deadline_s: Tuple[Optional[float], Optional[float], Optional[float]] = \
        (None, 2.0, 0.75)
    vocab_size: int = 256            # prompt tokens drawn from [3, vocab)

    def __post_init__(self):
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {self.rate_rps}")
        if self.duration_s <= 0:
            raise ValueError(f"duration_s must be > 0, got {self.duration_s}")
        if not 0 <= self.diurnal_amplitude <= 1:
            raise ValueError("diurnal_amplitude must be in [0, 1], got "
                             f"{self.diurnal_amplitude}")
        if len(self.priority_weights) != 3 or \
                not math.isclose(sum(self.priority_weights), 1.0,
                                 rel_tol=1e-6):
            raise ValueError("priority_weights must be 3 probabilities "
                             f"summing to 1, got {self.priority_weights}")

    def mean_tokens_per_request(self) -> float:
        """Expected generated tokens per request (un-clipped lognormal mean;
        close enough for capacity planning)."""
        return math.exp(self.gen_len_log_mean
                        + 0.5 * self.gen_len_log_sigma ** 2)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TraceEvent:
    """One request arrival in a traffic trace."""
    t_s: float                       # arrival time from trace start
    uid: int
    prompt: List[int]
    max_new_tokens: int
    priority: Priority = Priority.NORMAL
    deadline_s: Optional[float] = None

    def to_request(self) -> Request:
        return Request(uid=self.uid, prompt=list(self.prompt),
                       max_new_tokens=self.max_new_tokens,
                       priority=self.priority, deadline_s=self.deadline_s)

    def to_dict(self) -> dict:
        return {"t_s": self.t_s, "uid": self.uid, "prompt": self.prompt,
                "max_new_tokens": self.max_new_tokens,
                "priority": self.priority.name, "deadline_s": self.deadline_s}

    @classmethod
    def from_dict(cls, d: dict) -> "TraceEvent":
        return cls(t_s=float(d["t_s"]), uid=int(d["uid"]),
                   prompt=[int(t) for t in d["prompt"]],
                   max_new_tokens=int(d["max_new_tokens"]),
                   priority=Priority[d.get("priority", "NORMAL")],
                   deadline_s=(None if d.get("deadline_s") is None
                               else float(d["deadline_s"])))


class TrafficGenerator:
    """Deterministic trace generation from a ``TrafficConfig``."""

    def __init__(self, cfg: TrafficConfig):
        self.cfg = cfg

    def _length(self, rng: np.random.Generator, log_mean: float,
                log_sigma: float, max_len: int) -> int:
        raw = rng.lognormal(mean=log_mean, sigma=log_sigma)
        return int(np.clip(round(raw), 1, max_len))

    def rate_at(self, t_s: float) -> float:
        """Instantaneous arrival rate under the diurnal envelope."""
        c = self.cfg
        return c.rate_rps * (1.0 + c.diurnal_amplitude
                             * math.sin(2.0 * math.pi * t_s
                                        / c.diurnal_period_s))

    def events(self) -> List[TraceEvent]:
        c = self.cfg
        rng = np.random.default_rng(c.seed)
        # Lewis thinning: draw a homogeneous process at the envelope's peak
        # rate, keep each arrival with probability rate(t) / rate_max
        rate_max = c.rate_rps * (1.0 + c.diurnal_amplitude)
        out: List[TraceEvent] = []
        t, uid = 0.0, 0
        while True:
            t += float(rng.exponential(1.0 / rate_max))
            if t >= c.duration_s:
                break
            if float(rng.random()) * rate_max > self.rate_at(t):
                continue
            plen = self._length(rng, c.prompt_len_log_mean,
                                c.prompt_len_log_sigma, c.max_prompt_len)
            glen = self._length(rng, c.gen_len_log_mean,
                                c.gen_len_log_sigma, c.max_gen_len)
            prio = Priority(int(rng.choice(3, p=c.priority_weights)))
            prompt = rng.integers(3, c.vocab_size, plen).tolist()
            out.append(TraceEvent(t_s=t, uid=uid, prompt=prompt,
                                  max_new_tokens=glen, priority=prio,
                                  deadline_s=c.deadline_s[int(prio)]))
            uid += 1
        return out


# ---- trace files (NDJSON: one event per line) -------------------------------

def save_trace(events: Sequence[TraceEvent],
               path_or_file: Union[str, IO[str]]) -> None:
    def _write(f: IO[str]) -> None:
        for ev in events:
            f.write(json.dumps(ev.to_dict()) + "\n")

    if isinstance(path_or_file, str):
        with open(path_or_file, "w") as f:
            _write(f)
    else:
        _write(path_or_file)


def load_trace(path_or_file: Union[str, IO[str]]) -> List[TraceEvent]:
    def _read(f: IO[str]) -> List[TraceEvent]:
        return [TraceEvent.from_dict(json.loads(line))
                for line in f if line.strip()]

    if isinstance(path_or_file, str):
        with open(path_or_file) as f:
            return _read(f)
    return _read(path_or_file)


def _main() -> None:
    import argparse
    ap = argparse.ArgumentParser(
        description="Generate a replayable serving traffic trace (NDJSON).")
    ap.add_argument("--out", required=True, help="trace file to write")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="mean arrival rate, requests/s")
    ap.add_argument("--duration", type=float, default=10.0,
                    help="trace horizon, seconds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--burst", type=float, default=0.0,
                    help="diurnal envelope amplitude in [0, 1]")
    ap.add_argument("--burst-period", type=float, default=60.0)
    ap.add_argument("--max-prompt-len", type=int, default=24)
    ap.add_argument("--max-gen-len", type=int, default=24)
    ap.add_argument("--vocab-size", type=int, default=256)
    args = ap.parse_args()
    cfg = TrafficConfig(rate_rps=args.rate, duration_s=args.duration,
                        seed=args.seed, diurnal_amplitude=args.burst,
                        diurnal_period_s=args.burst_period,
                        max_prompt_len=args.max_prompt_len,
                        max_gen_len=args.max_gen_len,
                        vocab_size=args.vocab_size)
    events = TrafficGenerator(cfg).events()
    save_trace(events, args.out)
    by_prio = {p.name: sum(1 for e in events if e.priority is p)
               for p in Priority}
    # lint: allow=RP008 CLI entry point owns stdout; one-shot summary line
    print(f"wrote {len(events)} events over {args.duration}s to {args.out} "
          f"(priorities {by_prio})")


if __name__ == "__main__":
    _main()
