"""``python -m repro.server``: generate a replayable traffic trace.

Thin alias for the ``repro.server.traffic`` CLI (same flags) that avoids
runpy's package-reimport warning; see that module for the trace format.
"""
from .traffic import _main

if __name__ == "__main__":
    _main()
