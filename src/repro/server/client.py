"""Minimal asyncio streaming client for the ``repro.server`` frontend.

Stdlib-only HTTP/1.1 with chunked-transfer decoding, shared by the
end-to-end tests and the overload example so neither hand-rolls the wire
format.  ``stream_generate`` consumes the NDJSON token stream as it arrives
and returns the full transcript; ``get_json`` fetches a JSON endpoint
(``/healthz``).
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import random
from typing import (Any, Awaitable, Callable, Dict, List, Optional, Sequence,
                    Tuple)

#: Transport failures worth retrying (the server went away mid-exchange or
#: never answered) — as opposed to protocol errors, which never heal.
RETRYABLE_ERRORS = (ConnectionError, asyncio.IncompleteReadError,
                    asyncio.TimeoutError, OSError)


@dataclasses.dataclass
class RetryPolicy:
    """Exponential backoff with seeded jitter for ``stream_generate``.

    Attempt ``k`` (0-based) sleeps ``backoff_s * multiplier**k``, scaled by a
    uniform jitter in ``[1 - jitter, 1 + jitter]`` drawn from a private
    ``random.Random(seed)`` — deterministic per policy instance, and spread
    out across instances seeded differently so a shed thundering herd
    doesn't re-arrive in lockstep.  A 503's ``Retry-After`` header, when
    longer, takes precedence over the computed backoff."""

    max_retries: int = 3
    backoff_s: float = 0.05
    multiplier: float = 2.0
    jitter: float = 0.1              # fraction of the delay, uniform +/-
    seed: int = 0
    retry_statuses: Tuple[int, ...] = (503,)

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(f"jitter must be in [0, 1), got {self.jitter}")
        self._rng = random.Random(self.seed)

    def delay_s(self, attempt: int,
                retry_after_s: Optional[float] = None) -> float:
        base = self.backoff_s * self.multiplier ** attempt
        base *= 1.0 + self.jitter * self._rng.uniform(-1.0, 1.0)
        if retry_after_s is not None:
            base = max(base, retry_after_s)
        return base


@dataclasses.dataclass
class GenerateResult:
    """Outcome of one streamed /v1/generate call."""
    http_status: int
    tokens: List[int]
    summary: Dict[str, Any]          # final NDJSON line (or the error body)
    headers: Dict[str, str] = dataclasses.field(default_factory=dict)
    attempts: int = 1                # 1 = first try succeeded / no retry

    @property
    def status(self) -> str:
        return str(self.summary.get("status", "error"))

    @property
    def ok(self) -> bool:
        return self.http_status == 200


async def _read_headers(reader: asyncio.StreamReader):
    status_line = (await reader.readline()).decode("latin-1").strip()
    parts = status_line.split()
    if len(parts) < 2 or not parts[1].isdigit():
        # the server died (or reset) before answering: surface it as the
        # retryable incomplete-read it is, not a parse crash
        raise asyncio.IncompleteReadError(status_line.encode("latin-1"), None)
    http_status = int(parts[1])
    headers: Dict[str, str] = {}
    while True:
        raw = await reader.readline()
        if raw in (b"\r\n", b"\n", b""):
            break
        key, _, val = raw.decode("latin-1").partition(":")
        headers[key.strip().lower()] = val.strip()
    return http_status, headers


async def _read_body(reader: asyncio.StreamReader,
                     headers: Dict[str, str]) -> bytes:
    if headers.get("transfer-encoding", "").lower() == "chunked":
        out = b""
        while True:
            size = int((await reader.readline()).strip() or b"0", 16)
            if size == 0:
                await reader.readline()       # trailing CRLF
                return out
            out += await reader.readexactly(size)
            await reader.readexactly(2)       # chunk CRLF
    n = int(headers.get("content-length", "0") or 0)
    return await reader.readexactly(n) if n else b""


async def _request(host: str, port: int, method: str, path: str,
                   body: Optional[bytes] = None):
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = body or b""
        head = (f"{method} {path} HTTP/1.1\r\nHost: {host}:{port}\r\n"
                f"Content-Type: application/json\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: close\r\n\r\n")
        writer.write(head.encode() + body)
        await writer.drain()
        http_status, headers = await _read_headers(reader)
        payload = await _read_body(reader, headers)
        return http_status, headers, payload
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass


async def get_json(host: str, port: int, path: str) -> Dict[str, Any]:
    status, _, payload = await _request(host, port, "GET", path)
    out = json.loads(payload or b"{}")
    out["_http_status"] = status
    return out


def _parse_retry_after(headers: Dict[str, str]) -> Optional[float]:
    raw = headers.get("retry-after")
    try:
        return None if raw is None else float(raw)
    except ValueError:
        return None


async def stream_generate(host: str, port: int, prompt: Sequence[int],
                          max_new_tokens: int = 8,
                          priority: str = "normal",
                          deadline_s: Optional[float] = None,
                          timeout_s: float = 120.0,
                          retry: Optional[RetryPolicy] = None,
                          sleep: Callable[[float], Awaitable[None]]
                          = asyncio.sleep) -> GenerateResult:
    """One /v1/generate stream, optionally retried under ``retry``.

    Retries fire on retryable transport errors and on the policy's
    ``retry_statuses`` (503 overload by default), honouring the server's
    ``Retry-After``.  ``sleep`` is injectable so tests assert the backoff
    schedule without waiting it out.  With ``retry=None`` a transport error
    propagates, as before."""
    body = json.dumps({
        "prompt": list(prompt), "max_new_tokens": max_new_tokens,
        "priority": priority, "deadline_s": deadline_s,
    }).encode()
    max_attempts = 1 + (retry.max_retries if retry is not None else 0)
    attempt = 0
    while True:
        try:
            status, headers, payload = await asyncio.wait_for(
                _request(host, port, "POST", "/v1/generate", body), timeout_s)
        except RETRYABLE_ERRORS:
            if retry is None or attempt + 1 >= max_attempts:
                raise
            await sleep(retry.delay_s(attempt))
            attempt += 1
            continue
        if retry is not None and status in retry.retry_statuses \
                and attempt + 1 < max_attempts:
            await sleep(retry.delay_s(attempt, _parse_retry_after(headers)))
            attempt += 1
            continue
        break
    tokens: List[int] = []
    summary: Dict[str, Any] = {}
    for line in payload.decode().splitlines():
        if not line.strip():
            continue
        obj = json.loads(line)
        if "token" in obj:
            tokens.append(int(obj["token"]))
        else:
            summary = obj
    return GenerateResult(http_status=status, tokens=tokens, summary=summary,
                          headers=dict(headers), attempts=attempt + 1)
