"""Minimal asyncio streaming client for the ``repro.server`` frontend.

Stdlib-only HTTP/1.1 with chunked-transfer decoding, shared by the
end-to-end tests and the overload example so neither hand-rolls the wire
format.  ``stream_generate`` consumes the NDJSON token stream as it arrives
and returns the full transcript; ``get_json`` fetches a JSON endpoint
(``/healthz``).
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence


@dataclasses.dataclass
class GenerateResult:
    """Outcome of one streamed /v1/generate call."""
    http_status: int
    tokens: List[int]
    summary: Dict[str, Any]          # final NDJSON line (or the error body)

    @property
    def status(self) -> str:
        return str(self.summary.get("status", "error"))

    @property
    def ok(self) -> bool:
        return self.http_status == 200


async def _read_headers(reader: asyncio.StreamReader):
    status_line = (await reader.readline()).decode("latin-1").strip()
    http_status = int(status_line.split()[1])
    headers: Dict[str, str] = {}
    while True:
        raw = await reader.readline()
        if raw in (b"\r\n", b"\n", b""):
            break
        key, _, val = raw.decode("latin-1").partition(":")
        headers[key.strip().lower()] = val.strip()
    return http_status, headers


async def _read_body(reader: asyncio.StreamReader,
                     headers: Dict[str, str]) -> bytes:
    if headers.get("transfer-encoding", "").lower() == "chunked":
        out = b""
        while True:
            size = int((await reader.readline()).strip() or b"0", 16)
            if size == 0:
                await reader.readline()       # trailing CRLF
                return out
            out += await reader.readexactly(size)
            await reader.readexactly(2)       # chunk CRLF
    n = int(headers.get("content-length", "0") or 0)
    return await reader.readexactly(n) if n else b""


async def _request(host: str, port: int, method: str, path: str,
                   body: Optional[bytes] = None):
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = body or b""
        head = (f"{method} {path} HTTP/1.1\r\nHost: {host}:{port}\r\n"
                f"Content-Type: application/json\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: close\r\n\r\n")
        writer.write(head.encode() + body)
        await writer.drain()
        http_status, headers = await _read_headers(reader)
        payload = await _read_body(reader, headers)
        return http_status, payload
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass


async def get_json(host: str, port: int, path: str) -> Dict[str, Any]:
    status, payload = await _request(host, port, "GET", path)
    out = json.loads(payload or b"{}")
    out["_http_status"] = status
    return out


async def stream_generate(host: str, port: int, prompt: Sequence[int],
                          max_new_tokens: int = 8,
                          priority: str = "normal",
                          deadline_s: Optional[float] = None,
                          timeout_s: float = 120.0) -> GenerateResult:
    body = json.dumps({
        "prompt": list(prompt), "max_new_tokens": max_new_tokens,
        "priority": priority, "deadline_s": deadline_s,
    }).encode()
    status, payload = await asyncio.wait_for(
        _request(host, port, "POST", "/v1/generate", body), timeout_s)
    tokens: List[int] = []
    summary: Dict[str, Any] = {}
    for line in payload.decode().splitlines():
        if not line.strip():
            continue
        obj = json.loads(line)
        if "token" in obj:
            tokens.append(int(obj["token"]))
        else:
            summary = obj
    return GenerateResult(http_status=status, tokens=tokens, summary=summary)
