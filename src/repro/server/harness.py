"""Virtual-time load harness: deterministic trace replay over ``ServeEngine``.

Real-socket serving (``repro.server.frontend``) measures the wall clock and
is therefore noisy; the harness instead replays a traffic trace in *virtual
time*.  The engine is constructed with an injected ``VirtualClock``, every
model call advances that clock by a fixed ``step_cost_s``, and arrivals are
injected exactly when the virtual clock crosses their trace timestamps.
Queueing delay, TTFT percentiles, deadline misses, and shed rates then
depend only on (trace seed, scheduler policy, step cost) — bit-reproducible
across machines, which is what lets ``BENCH_traffic.json`` gate overload
behaviour in CI.

The service capacity of the modelled deployment is ``slots / step_cost_s``
tokens/s; ``overload_rate_rps`` converts that into the arrival rate that
offers ``factor``x the sustainable token load, so "2x overload" means the
same thing for every engine configuration.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..serve import Priority, Request
from .traffic import TraceEvent, TrafficConfig


class VirtualClock:
    """A monotonically advancing fake clock (callable like
    ``time.monotonic``); the harness — or a test — owns its arrow of time."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance time backwards by {dt}")
        self.now += dt
        return self.now


def overload_rate_rps(factor: float, slots: int, step_cost_s: float,
                      cfg: TrafficConfig) -> float:
    """Arrival rate offering ``factor``x the deployment's token capacity.

    Capacity ~= slots tokens per decode call at full occupancy; each request
    demands ~(mean generated tokens + 1 prefill call) model-call equivalents.
    """
    capacity_tok_s = slots / step_cost_s
    per_request = cfg.mean_tokens_per_request() + 1.0
    return factor * capacity_tok_s / per_request


@dataclasses.dataclass
class TrafficMetrics:
    """Envelope measured by one trace replay (virtual-time unless noted)."""
    n_events: int = 0
    admitted: int = 0
    completed: int = 0
    truncated: int = 0
    shed: int = 0
    shed_by_reason: Dict[str, int] = dataclasses.field(default_factory=dict)
    shed_by_priority: Dict[str, int] = dataclasses.field(default_factory=dict)
    tokens_generated: int = 0
    elapsed_virtual_s: float = 0.0
    tokens_per_s: float = 0.0        # virtual-time serving throughput
    ttft_p50_s: Optional[float] = None
    ttft_p99_s: Optional[float] = None
    shed_rate: float = 0.0           # shed / submitted
    deadline_met_frac: Optional[float] = None   # over SLO-carrying, non-shed
    model_steps: int = 0
    wall_s: float = 0.0              # real wall time spent replaying

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class LoadHarness:
    """Replays a trace through an engine that reads the harness's clock.

    The engine MUST have been constructed with ``clock=`` the same
    ``VirtualClock`` instance, or latency telemetry will mix time bases.
    """

    #: default registry gauges captured by the sampling timeline — the
    #: load/energy/rail signals a railscale closed-loop run is judged on
    SAMPLE_GAUGES = ("serve_queue_depth", "serve_active_slots",
                     "serve_energy_per_token_joules", "railscale_level")

    def __init__(self, engine, clock: VirtualClock,
                 step_cost_s: float = 0.02,
                 wall_clock: Callable[[], float] = time.perf_counter,
                 sample_every_s: Optional[float] = None,
                 sample_gauges: Sequence[str] = SAMPLE_GAUGES):
        if getattr(engine, "_clock", None) is not clock:
            raise ValueError("engine was not built with this harness clock; "
                             "pass ServeEngine(..., clock=clock)")
        if step_cost_s <= 0:
            raise ValueError(f"step_cost_s must be > 0, got {step_cost_s}")
        if sample_every_s is not None and sample_every_s <= 0:
            raise ValueError(f"sample_every_s must be > 0, "
                             f"got {sample_every_s}")
        self.engine = engine
        self.clock = clock
        self.step_cost_s = step_cost_s
        # wall_s telemetry (replay cost, not a serving metric) reads this
        # injectable second clock so tests can pin it too
        self.wall_clock = wall_clock
        self.requests: List[Request] = []
        # opt-in virtual-time gauge timeline: every ``sample_every_s``
        # virtual seconds one row of registry gauge values is appended to
        # ``self.samples`` — the deterministic load/energy/level traces
        # behind BENCH_railscale.json.  Default off (bit-identical replay).
        self.sample_every_s = sample_every_s
        self.sample_gauges = tuple(sample_gauges)
        self.samples: List[Dict[str, float]] = []
        self._next_sample_t = 0.0

    def replay(self, events: Sequence[TraceEvent],
               max_steps: int = 1_000_000) -> TrafficMetrics:
        wall0 = self.wall_clock()
        eng, clock = self.engine, self.clock
        events = sorted(events, key=lambda e: e.t_s)
        i, n = 0, len(events)
        steps = 0
        while (i < n or not eng.scheduler.drained()) and steps < max_steps:
            while i < n and events[i].t_s <= clock.now + 1e-12:
                req = events[i].to_request()
                self.requests.append(req)
                eng.submit(req)
                i += 1
            if eng.scheduler.drained():
                if i >= n:
                    break
                clock.now = events[i].t_s   # idle: jump to the next arrival
                continue
            used = eng.step()
            steps += max(used, 1)
            # every model call costs fixed virtual time; a zero-cost
            # iteration (nothing admissible ran) still advances one tick so
            # queued deadlines keep aging and the loop cannot spin
            clock.advance(max(used, 1) * self.step_cost_s)
            self._maybe_sample()
        return self._metrics(events, self.wall_clock() - wall0, steps)

    def _maybe_sample(self) -> None:
        if self.sample_every_s is None or self.clock.now < self._next_sample_t:
            return
        reg = self.engine.obs.registry
        row: Dict[str, float] = {"t_s": float(self.clock.now)}
        for name in self.sample_gauges:
            # get-or-create: a gauge the engine never published reads 0.0
            row[name] = float(reg.gauge(name).value())
        self.samples.append(row)
        # schedule strictly past ``now`` even when the clock idled/jumped
        missed = (self.clock.now - self._next_sample_t) // self.sample_every_s
        self._next_sample_t += (missed + 1) * self.sample_every_s

    def _metrics(self, events: Sequence[TraceEvent], wall_s: float,
                 steps: int) -> TrafficMetrics:
        stats = self.engine.stats
        reqs = self.requests
        shed = [r for r in reqs if r.shed]
        ttfts = np.asarray(sorted(stats.ttft_s), float)
        slo = [r for r in reqs if r.deadline_s is not None and not r.shed
               and r.done]
        met = [r for r in slo if r.deadline_met()]
        elapsed = max(self.clock.now, self.step_cost_s)
        m = TrafficMetrics(
            n_events=len(events),
            admitted=stats.admitted,
            completed=stats.completed,
            truncated=stats.truncated,
            shed=len(shed),
            shed_by_reason={
                reason: sum(1 for r in shed if r.shed_reason == reason)
                for reason in sorted({r.shed_reason for r in shed
                                      if r.shed_reason})},
            shed_by_priority={
                p.name: sum(1 for r in shed if r.priority is p)
                for p in Priority},
            tokens_generated=stats.tokens_generated,
            elapsed_virtual_s=elapsed,
            tokens_per_s=stats.tokens_generated / elapsed,
            ttft_p50_s=(float(np.percentile(ttfts, 50)) if ttfts.size
                        else None),
            ttft_p99_s=(float(np.percentile(ttfts, 99)) if ttfts.size
                        else None),
            shed_rate=len(shed) / max(len(reqs), 1),
            deadline_met_frac=(len(met) / len(slo) if slo else None),
            model_steps=stats.model_steps,
            wall_s=wall_s,
        )
        return m
