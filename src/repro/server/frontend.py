"""Async streaming HTTP frontend over the continuous-batching ``ServeEngine``.

Stdlib-only (asyncio + a hand-rolled HTTP/1.1 layer — no Flask/aiohttp
dependency), built for throughput: the asyncio event loop only parses
requests and shuttles bytes, while ONE pump thread owns every jax call and
drives ``ServeEngine.step()`` continuously.  Handlers talk to the engine
through the scheduler's admission queue; per-token streaming rides the
``Request.on_token``/``on_finish`` callbacks, which hop thread -> event loop
via ``loop.call_soon_threadsafe`` into a per-request ``asyncio.Queue``.

Endpoints:

``POST /v1/generate``
    Body ``{"prompt": [ints], "max_new_tokens": n, "priority":
    "low|normal|high", "deadline_s": s}``.  Streams newline-delimited JSON
    (chunked transfer encoding): one ``{"token": t}`` line per generated
    token, then a final ``{"done": true, "status": ..., "n_tokens": ...,
    "ttft_s": ...}`` summary line.  Headers are deferred until the first
    engine event, so a request shed *after* admission (deadline expiry,
    displaced by a higher tier) still gets a clean ``503`` instead of an
    empty 200 stream.

``GET /healthz``
    Queue depth, shed/admission counters, and drain state as JSON — the
    load-balancer view of backpressure.

``GET /metrics``
    Prometheus text exposition of the engine's ``repro.obs`` registry
    (TTFT/queue-wait histograms, queue depth, flag/replay rates,
    energy/token, guard events).  Lock-free: a scrape never blocks the
    pump thread and never touches jax.

``GET /v1/stats``
    The same registry as JSON, plus the full ``EngineStats`` view and
    health payload — what the PR-10 autoscaler polls.

Overload behaviour is the scheduler's: with ``ServeEngine(policy="priority",
max_pending=N)`` a full queue sheds (HTTP 503 with shed telemetry) rather
than buffering unboundedly, and expired TTFT SLOs shed queued requests
before they waste decode slots.  ``drain()`` stops admission (503
``draining``) but finishes every already-admitted stream before ``close()``
tears the pump down — a rolling-restart never clips a live response.
"""

from __future__ import annotations

import asyncio
import itertools
import json
import threading
from typing import Any, Dict, Optional, Tuple

from ..serve import Priority, Request

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            503: "Service Unavailable"}


#: 503 responses advertise this via ``Retry-After`` so well-behaved clients
#: (``repro.server.client.RetryPolicy`` honours it) back off together.
RETRY_AFTER_S = 1


def _json_response(status: int, obj: Dict[str, Any],
                   headers: Optional[Dict[str, str]] = None) -> bytes:
    body = json.dumps(obj).encode()
    extra = "".join(f"{k}: {v}\r\n" for k, v in (headers or {}).items())
    return (f"HTTP/1.1 {status} {_REASONS.get(status, 'OK')}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"{extra}Connection: close\r\n\r\n").encode() + body


def _unavailable(obj: Dict[str, Any]) -> bytes:
    """503 with the backpressure header every shed/overload path shares."""
    return _json_response(503, obj,
                          headers={"Retry-After": str(RETRY_AFTER_S)})


def _text_response(status: int, text: str,
                   content_type: str = "text/plain; charset=utf-8") -> bytes:
    body = text.encode()
    return (f"HTTP/1.1 {status} {_REASONS.get(status, 'OK')}\r\n"
            f"Content-Type: {content_type}\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n").encode() + body


#: Content type of the Prometheus text exposition format.
PROMETHEUS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class ServeFrontend:
    """Asyncio HTTP server wrapping one ``ServeEngine``."""

    def __init__(self, engine, pump_idle_s: float = 0.005,
                 request_timeout_s: Optional[float] = None):
        self.engine = engine
        self._pump_idle_s = pump_idle_s
        # wall-clock budget per /v1/generate request (None: unbounded).  On
        # expiry the request is cancelled — the engine reaps its slot — and
        # the client sees a 503 (pre-stream) or a terminal "cancelled" line.
        self._request_timeout_s = request_timeout_s
        # one lock serializes scheduler mutation (handler submits) against
        # the pump's engine.step(); the pump holds it per step, so handler
        # submission latency is bounded by one model call
        self._lock = threading.Lock()
        self._work = threading.Event()
        self._stop = threading.Event()
        self._pump_error: Optional[BaseException] = None
        self._draining = False
        self._uids = itertools.count()
        self._thread: Optional[threading.Thread] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self.address: Optional[Tuple[str, int]] = None

    # ---- engine pump (the only thread that touches jax) ----------------------

    def _pump(self) -> None:
        try:
            while not self._stop.is_set():
                with self._lock:
                    busy = not self.engine.scheduler.drained()
                    if busy:
                        self.engine.step()
                if not busy:
                    self._work.wait(self._pump_idle_s)
                    self._work.clear()
        except BaseException as e:            # surface, never die silently
            self._pump_error = e
            self._fail_open()

    def _fail_open(self) -> None:
        """The pump died: terminate every live stream cleanly instead of
        leaving clients blocked on an events queue that will never fill.
        ``fire_finish`` is idempotent, so this cannot double-deliver."""
        sched = self.engine.scheduler
        for req in list(sched.active.values()) + list(sched.pending):
            if not req.done:
                req.done = req.truncated = True
            req.fire_finish()

    # ---- lifecycle -----------------------------------------------------------

    async def start(self, host: str = "127.0.0.1",
                    port: int = 0) -> Tuple[str, int]:
        """Bind and start serving; returns the (host, port) actually bound
        (port 0 picks an ephemeral port)."""
        self._loop = asyncio.get_running_loop()
        self._thread = threading.Thread(target=self._pump, daemon=True,
                                        name="serve-engine-pump")
        self._thread.start()
        self._server = await asyncio.start_server(self._handle, host, port)
        self.address = self._server.sockets[0].getsockname()[:2]
        return self.address

    async def drain(self, timeout_s: float = 60.0) -> bool:
        """Graceful drain: stop admitting, finish every in-flight request.
        Returns True when the engine fully drained within the timeout."""
        self._draining = True
        self._work.set()
        loop = asyncio.get_running_loop()
        deadline = loop.time() + timeout_s
        # racy read by design: drained() only inspects container emptiness,
        # and taking the lock here would stall the event loop on a jax step
        while not self.engine.scheduler.drained():
            if self._pump_error is not None or loop.time() > deadline:
                return False
            await asyncio.sleep(0.005)
        return True

    async def close(self) -> None:
        """Stop the pump and the listener (call ``drain()`` first for a
        graceful shutdown)."""
        self._stop.set()
        self._work.set()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if self._thread is not None:
            await asyncio.get_running_loop().run_in_executor(
                None, self._thread.join)
        if self._pump_error is not None:
            raise self._pump_error

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        await self._server.serve_forever()

    # ---- telemetry -----------------------------------------------------------

    def health(self) -> Dict[str, Any]:
        s, sched = self.engine.stats, self.engine.scheduler
        return {
            "status": "draining" if self._draining else "ok",
            "pending": sched.n_pending,
            "active": sched.n_active,
            "slots": sched.slots,
            "policy": sched.policy,
            "max_pending": sched.max_pending,
            "admitted": s.admitted,
            "completed": s.completed,
            "truncated": s.truncated,
            "shed": sched.n_shed,
            "cancelled": s.cancelled,
            "pump_alive": self._pump_error is None,
            "shed_rate": sched.n_shed / max(s.admitted + sched.n_shed
                                            + sched.n_pending, 1),
            "tokens_generated": s.tokens_generated,
            "decode_steps": s.decode_steps,
            "backend": s.backend,
        }

    def metrics_text(self) -> str:
        """Prometheus text exposition of the engine's ObsBus registry.

        Runs on the asyncio thread WITHOUT ``self._lock`` — racy read by
        design, same as ``drain()``: registry cells are plain floats
        behind the registry's own fine-grained lock (never held across a
        jax call), so a scrape can never stall the pump mid-step."""
        obs = getattr(self.engine, "obs", None)
        if obs is None:
            return ""
        return obs.registry.render_prometheus()

    def stats_json(self) -> Dict[str, Any]:
        """JSON twin of ``/metrics``: health + the full EngineStats view +
        the raw registry.  Lock-free for the same reason as
        :meth:`metrics_text`; ``to_dict`` only reads python lists and
        registry counters, never jax state."""
        obs = getattr(self.engine, "obs", None)
        return {
            "health": self.health(),
            "engine": self.engine.stats.to_dict(),
            "metrics": obs.registry.render_json() if obs is not None else {},
        }

    # ---- HTTP plumbing -------------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            method, path, headers, body = await self._read_request(reader)
            if method == "GET" and path == "/healthz":
                writer.write(_json_response(200, self.health()))
            elif method == "GET" and path == "/metrics":
                writer.write(_text_response(200, self.metrics_text(),
                                            PROMETHEUS_CONTENT_TYPE))
            elif method == "GET" and path == "/v1/stats":
                writer.write(_json_response(200, self.stats_json()))
            elif method == "POST" and path == "/v1/generate":
                await self._generate(writer, body)
            else:
                writer.write(_json_response(
                    404, {"error": f"no route {method} {path}"}))
            await writer.drain()
        except (asyncio.IncompleteReadError, ConnectionResetError,
                BrokenPipeError):
            pass                               # client went away mid-stream
        except ValueError as e:
            try:
                writer.write(_json_response(400, {"error": str(e)}))
                await writer.drain()
            except (ConnectionResetError, BrokenPipeError):
                pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    @staticmethod
    async def _read_request(reader: asyncio.StreamReader):
        line = (await reader.readline()).decode("latin-1").strip()
        if not line:
            raise asyncio.IncompleteReadError(b"", None)
        try:
            method, path, _ = line.split(None, 2)
        except ValueError:
            raise ValueError(f"malformed request line {line!r}")
        headers: Dict[str, str] = {}
        while True:
            raw = await reader.readline()
            if raw in (b"\r\n", b"\n", b""):
                break
            key, _, val = raw.decode("latin-1").partition(":")
            headers[key.strip().lower()] = val.strip()
        n = int(headers.get("content-length", "0") or 0)
        body = await reader.readexactly(n) if n else b""
        return method, path, headers, body

    # ---- streaming generation ------------------------------------------------

    def _parse_generate(self, body: bytes) -> Request:
        try:
            payload = json.loads(body or b"{}")
        except json.JSONDecodeError as e:
            raise ValueError(f"invalid JSON body: {e}")
        prompt = payload.get("prompt", [])
        if not isinstance(prompt, list) or \
                not all(isinstance(t, int) for t in prompt):
            raise ValueError("prompt must be a list of token ids")
        try:
            priority = Priority[str(payload.get("priority", "normal")).upper()]
        except KeyError:
            raise ValueError(f"unknown priority {payload.get('priority')!r}")
        deadline = payload.get("deadline_s")
        return Request(
            uid=next(self._uids), prompt=prompt,
            max_new_tokens=int(payload.get("max_new_tokens", 8)),
            priority=priority,
            deadline_s=None if deadline is None else float(deadline))

    async def _next_event(self, events: asyncio.Queue, deadline: Optional[float],
                          loop: asyncio.AbstractEventLoop):
        if deadline is None:
            return await events.get()
        return await asyncio.wait_for(events.get(),
                                      max(deadline - loop.time(), 0.0))

    async def _generate(self, writer: asyncio.StreamWriter,
                        body: bytes) -> None:
        req = self._parse_generate(body)
        if self._draining:
            writer.write(_unavailable({"error": "draining", "uid": req.uid}))
            return
        loop = asyncio.get_running_loop()
        deadline = (None if self._request_timeout_s is None
                    else loop.time() + self._request_timeout_s)
        events: asyncio.Queue = asyncio.Queue()
        req.on_token = lambda r, tok: loop.call_soon_threadsafe(
            events.put_nowait, ("token", tok))
        req.on_finish = lambda r: loop.call_soon_threadsafe(
            events.put_nowait, ("finish", None))
        with self._lock:
            accepted = self.engine.submit(req)
        self._work.set()
        if not accepted:
            writer.write(_unavailable(self._shed_payload(req)))
            return
        # defer the status line until the engine says something: a request
        # shed from the queue gets a 503, not an empty 200 stream
        try:
            kind, tok = await self._next_event(events, deadline, loop)
        except asyncio.TimeoutError:
            req.cancelled = True               # engine reaps the slot/queue
            self._work.set()
            writer.write(_unavailable(
                {"error": "timeout", "uid": req.uid, "status": "cancelled",
                 "timeout_s": self._request_timeout_s}))
            return
        if kind == "finish" and req.shed:
            writer.write(_unavailable(self._shed_payload(req)))
            return
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: application/x-ndjson\r\n"
                     b"Transfer-Encoding: chunked\r\n"
                     b"Connection: close\r\n\r\n")
        try:
            while True:
                if kind == "token":
                    await self._chunk(writer, {"token": tok})
                elif kind == "finish":
                    await self._chunk(writer, {
                        "done": True, "uid": req.uid, "status": req.status,
                        "n_tokens": len(req.out_tokens),
                        "ttft_s": req.ttft_s,
                        "deadline_met": req.deadline_met(),
                    })
                    break
                try:
                    kind, tok = await self._next_event(events, deadline, loop)
                except asyncio.TimeoutError:
                    req.cancelled = True
                    self._work.set()
                    await self._chunk(writer, {
                        "done": True, "uid": req.uid, "status": "cancelled",
                        "n_tokens": len(req.out_tokens),
                        "error": "timeout",
                        "timeout_s": self._request_timeout_s})
                    break
        except (ConnectionResetError, BrokenPipeError):
            # client went away mid-stream: release the decode slot
            req.cancelled = True
            self._work.set()
            raise
        writer.write(b"0\r\n\r\n")             # chunked stream terminator

    def _shed_payload(self, req: Request) -> Dict[str, Any]:
        return {"error": "overloaded", "uid": req.uid, "status": "shed",
                "reason": req.shed_reason,
                "shed_rate": self.health()["shed_rate"]}

    @staticmethod
    async def _chunk(writer: asyncio.StreamWriter, obj: Dict[str, Any]) -> None:
        data = (json.dumps(obj) + "\n").encode()
        writer.write(f"{len(data):x}\r\n".encode() + data + b"\r\n")
        await writer.drain()
