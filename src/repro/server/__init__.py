"""Production serving frontend over the continuous-batching engine.

- ``frontend``: stdlib-asyncio HTTP layer — admission queue into
  ``ServeEngine``, per-token NDJSON streaming, SLO/priority admission,
  bounded-queue load shedding (503s), graceful drain.
- ``traffic``: seeded traffic-trace generator (Poisson arrivals,
  heavy-tailed lognormal lengths, diurnal burst envelopes) with replayable
  NDJSON trace files.
- ``harness``: deterministic virtual-time trace replay producing the
  p50/p99 TTFT / tokens-per-s / shed-rate envelope (``BENCH_traffic.json``).
- ``client``: minimal streaming HTTP client for tests and examples.
"""
from .client import GenerateResult, RetryPolicy, get_json, stream_generate
from .frontend import ServeFrontend
from .harness import (LoadHarness, TrafficMetrics, VirtualClock,
                      overload_rate_rps)
from .traffic import (TraceEvent, TrafficConfig, TrafficGenerator,
                      load_trace, save_trace)

__all__ = [
    "GenerateResult", "LoadHarness", "RetryPolicy", "ServeFrontend",
    "TraceEvent",
    "TrafficConfig", "TrafficGenerator", "TrafficMetrics", "VirtualClock",
    "get_json", "load_trace", "overload_rate_rps", "save_trace",
    "stream_generate",
]
