import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Unroll-delta cost estimator (DESIGN.md Sec. 5).

lax.scan hides its trip count from cost_analysis (loop body counted once —
verified empirically), so the honest per-cell totals come from compiling the
cell with 1 and 2 *python-unrolled* layer units and extrapolating

    total(L) = fixed + L * per_unit,   per_unit = c(2) - c(1)

Layer units: 1 layer (LM/SSM/enc-dec pairs) or one shared-attention group
(zamba2).  Remat recompute IS visible to this estimate (the unrolled graphs
contain the checkpointed recompute), so HLO/MODEL flops ratios stay honest.

Usage:
    PYTHONPATH=src python -m repro.roofline.estimate --arch X --shape Y
    PYTHONPATH=src python -m repro.roofline.estimate --all
"""

import argparse
import json
import time
import traceback
from pathlib import Path
from typing import Dict, Optional

from ..configs import ARCHS, SHAPES, cell_is_runnable, get_config

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "roofline"


def _unit(cfg) -> Dict[str, int]:
    """Layer-unit size and the config overrides for k units."""
    if cfg.family == "hybrid":
        return {"unit_layers": cfg.shared_attn_period,
                "units": cfg.n_layers // cfg.shared_attn_period}
    return {"unit_layers": 1, "units": cfg.n_layers}


def _overrides_for_units(cfg, k: int) -> Dict[str, int]:
    u = _unit(cfg)
    ov = {"n_layers": k * u["unit_layers"], "unroll_layers": True}
    if cfg.family == "encdec":
        ov["n_enc_layers"] = k            # unit = (1 dec + 1 enc) pair
    return ov


def _collect_costs(arch: str, shape_name: str, multi_pod: bool,
                   overrides: Dict) -> Dict[str, float]:
    from ..launch.dryrun import run_cell
    rec = run_cell(arch, shape_name, multi_pod=multi_pod, overrides=overrides,
                   verbose=False)
    if rec["status"] != "ok":
        raise RuntimeError(rec.get("error", rec.get("reason", "failed")))
    out = {
        "flops": rec["cost"].get("flops", 0.0),
        "bytes": rec["cost"].get("bytes accessed", 0.0),
        "coll_operand": float(rec["collective_operand_bytes"]),
        "coll_wire": float(rec["collective_wire_bytes"]),
    }
    return out


def estimate_cell(arch: str, shape_name: str, multi_pod: bool = False,
                  extra_overrides: Optional[Dict] = None,
                  tag: str = "") -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    mesh_kind = "multipod_2x16x16" if multi_pod else "pod_16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    try:
        t0 = time.time()
        ov1 = _overrides_for_units(cfg, 1)
        ov2 = _overrides_for_units(cfg, 2)
        if extra_overrides:
            ov1.update(extra_overrides)
            ov2.update(extra_overrides)
        c1 = _collect_costs(arch, shape_name, multi_pod, ov1)
        c2 = _collect_costs(arch, shape_name, multi_pod, ov2)
        units = _unit(cfg)["units"]
        est = {}
        for k in c1:
            per_unit = max(c2[k] - c1[k], 0.0)
            fixed = max(c1[k] - per_unit, 0.0)
            est[k] = fixed + units * per_unit
            est[k + "_per_unit"] = per_unit
            est[k + "_fixed"] = fixed
        rec.update(status="ok", estimate=est, units=units,
                   l1_raw=c1, l2_raw=c2, wall_s=round(time.time() - t0, 1))
    except Exception as e:                                 # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-1500:])
    return rec


def save(rec: Dict) -> Path:
    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    tag = f"_{rec['tag']}" if rec.get("tag") else ""
    name = (f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{tag}.json"
            .replace("/", "-"))
    path = ARTIFACT_DIR / name
    path.write_text(json.dumps(rec, indent=1))
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS))
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    cells = ([(a, s) for a in ARCHS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    fails = 0
    for arch, shape in cells:
        rec = estimate_cell(arch, shape, multi_pod=args.multi_pod,
                            extra_overrides=overrides or None, tag=args.tag)
        save(rec)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f"flops {rec['estimate']['flops']:.3e}/dev "
                     f"wire {rec['estimate']['coll_wire']:.3e} "
                     f"({rec['wall_s']}s)")
        elif status == "error":
            extra = rec["error"][:120]
            fails += 1
        print(f"[{status}] {arch} x {shape}: {extra}", flush=True)
    raise SystemExit(1 if fails else 0)


if __name__ == "__main__":
    main()
