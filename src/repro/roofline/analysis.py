"""Three-term roofline per (arch x shape x mesh) from the dry-run artifacts
(deliverable g).

    compute term    = HLO_FLOPs / (chips * peak)     [= per-dev flops / peak]
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * ICI)

cost_analysis numbers are per-device (verified), so each term is simply the
per-device quantity over the per-chip capability.  FLOPs/bytes come from the
unroll-delta estimate (scan hides trip counts); the collective term uses the
ring-modeled wire bytes over the chip's aggregate ICI (3 links x 50 GB/s),
with the spec-literal operand-byte variant reported alongside.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional

from ..configs import ARCHS, SHAPES, get_config
from ..launch.mesh import (HBM_BW, ICI_LINK_BW, ICI_LINKS_PER_CHIP,
                           PEAK_FLOPS_BF16)
from .analytic import hbm_bytes_per_device, model_flops

ART = Path(__file__).resolve().parents[3] / "artifacts"


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    kind: str
    chips: int
    status: str
    reason: str = ""
    # per-device totals
    hlo_flops: float = 0.0
    hlo_bytes: float = 0.0
    coll_wire: float = 0.0
    coll_operand: float = 0.0
    # terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0            # spec-literal: cost_analysis bytes (unfused UB)
    t_memory_fused: float = 0.0      # analytic fused lower bound
    t_collective: float = 0.0
    t_collective_spec: float = 0.0       # operand-bytes / single-link variant
    dominant: str = ""
    model_flops_global: float = 0.0
    hlo_over_model: float = 0.0
    roofline_fraction: float = 0.0       # useful-compute / dominant term
    args_gib: float = 0.0
    temp_gib: float = 0.0
    note: str = ""


def _load(path: Path) -> Optional[Dict]:
    if not path.exists():
        return None
    return json.loads(path.read_text())


def _note(row: RooflineRow) -> str:
    if row.dominant == "collective":
        return ("collective-bound: overlap/shrink the per-layer all-reduce "
                "(reduce-scatter + all-gather fusion, or larger per-device "
                "batch to amortize)")
    if row.dominant == "memory":
        if row.kind == "decode":
            return ("memory-bound (KV/weight streaming): int8 KV cache or "
                    "wider batch to re-use streamed weights")
        return ("memory-bound: fuse elementwise chains / raise arithmetic "
                "intensity (bigger per-chip tiles)")
    if row.hlo_over_model > 2.0:
        return (f"compute-bound but {row.hlo_over_model:.1f}x model flops: "
                "cut remat recompute or dispatch waste (MoE dense -> EP)")
    return "compute-bound near useful flops: increase per-chip utilization"


def build_row(arch: str, shape: str, mesh: str) -> RooflineRow:
    cell = _load(ART / "dryrun" / f"{arch}_{shape}_{mesh}.json")
    est = _load(ART / "roofline" / f"{arch}_{shape}_{mesh}.json")
    cfg = get_config(arch)
    sh = SHAPES[shape]
    if cell is None:
        return RooflineRow(arch, shape, mesh, sh.kind, 0, "missing")
    if cell.get("status") == "skipped":
        return RooflineRow(arch, shape, mesh, sh.kind, 0, "skipped",
                           reason=cell.get("reason", ""))
    if cell.get("status") != "ok":
        return RooflineRow(arch, shape, mesh, sh.kind, 0, "error",
                           reason=cell.get("error", "?"))

    chips = cell["chips"]
    if est and est.get("status") == "ok":
        flops = est["estimate"]["flops"]
        bytes_ = est["estimate"]["bytes"]
        wire = est["estimate"]["coll_wire"]
        operand = est["estimate"]["coll_operand"]
        src = "unroll-delta"
    else:  # fall back to raw scanned numbers (undercounted; flagged)
        flops = cell["cost"].get("flops", 0.0)
        bytes_ = cell["cost"].get("bytes accessed", 0.0)
        wire = cell["collective_wire_bytes"]
        operand = cell["collective_operand_bytes"]
        src = "scan-raw (undercounted)"

    t_c = flops / PEAK_FLOPS_BF16
    t_m = bytes_ / HBM_BW
    t_mf = hbm_bytes_per_device(cfg, sh, chips) / HBM_BW
    t_x = wire / (ICI_LINKS_PER_CHIP * ICI_LINK_BW)
    t_x_spec = operand / ICI_LINK_BW
    # dominance judged with the fused memory bound (the spec-literal unfused
    # bytes are reported alongside; see analytic.hbm_bytes_per_device)
    dominant = max(("compute", t_c), ("memory", t_mf), ("collective", t_x),
                   key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, sh)
    useful_t = mf / chips / PEAK_FLOPS_BF16
    dom_t = max(t_c, t_mf, t_x)
    row = RooflineRow(
        arch=arch, shape=shape, mesh=mesh, kind=cell.get("kind", sh.kind),
        chips=chips, status="ok",
        hlo_flops=flops, hlo_bytes=bytes_, coll_wire=wire,
        coll_operand=operand,
        t_compute=t_c, t_memory=t_m, t_memory_fused=t_mf, t_collective=t_x,
        t_collective_spec=t_x_spec, dominant=dominant,
        model_flops_global=mf,
        hlo_over_model=(flops * chips / mf) if mf else 0.0,
        roofline_fraction=useful_t / dom_t if dom_t else 0.0,
        args_gib=cell["memory"]["argument_bytes"] / 2**30,
        temp_gib=cell["memory"]["temp_bytes"] / 2**30,
        reason=src,
    )
    row.note = _note(row)
    return row


def all_rows(mesh: str = "pod_16x16") -> List[RooflineRow]:
    return [build_row(a, s, mesh) for a in ARCHS for s in SHAPES]


def render_markdown(rows: List[RooflineRow]) -> str:
    hdr = ("| arch | shape | kind | compute s | mem s (UB) | mem s (fused) | "
           "collective s | dominant | HLO/model | roofline frac | note |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    for r in rows:
        if r.status != "ok":
            out.append(f"| {r.arch} | {r.shape} | {r.kind} | - | - | - | - | "
                       f"{r.status} | - | - | {r.reason[:70]} |")
            continue
        out.append(
            f"| {r.arch} | {r.shape} | {r.kind} | {r.t_compute:.2e} | "
            f"{r.t_memory:.2e} | {r.t_memory_fused:.2e} | "
            f"{r.t_collective:.2e} | **{r.dominant}** | "
            f"{r.hlo_over_model:.2f}x | {r.roofline_fraction:.1%} | "
            f"{r.note[:80]} |")
    return "\n".join(out)


def main() -> None:
    rows = all_rows()
    print(render_markdown(rows))
    ok = [r for r in rows if r.status == "ok"]
    print(f"\n{len(ok)} cells analysed; dominants: " + ", ".join(
        f"{d}={sum(r.dominant == d for r in ok)}"
        for d in ("compute", "memory", "collective")))


if __name__ == "__main__":
    main()
