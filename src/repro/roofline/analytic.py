"""Analytic MODEL_FLOPS per (arch x shape): the useful matmul work, counted
from the architecture dimensions (fwd 2MNK per matmul; train = 3x fwd; no
remat, no dispatch waste).  The roofline reports HLO_FLOPs / MODEL_FLOPS to
expose recompute/redundancy (spec: 'catches remat/redundancy waste').
"""

from __future__ import annotations

from ..configs.base import ModelConfig, ShapeConfig
from ..models.ssm import mamba2_dims, rwkv6_dims


def _attn_proj_flops_per_tok(cfg: ModelConfig) -> float:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return 2 * d * (qd + 2 * kvd) + 2 * qd * d


def _mlp_flops_per_tok(cfg: ModelConfig, d_ff=None) -> float:
    ff = cfg.d_ff if d_ff is None else d_ff
    mats = 3 if cfg.act == "swiglu" else 2
    return 2 * cfg.d_model * ff * mats


def _moe_flops_per_tok(cfg: ModelConfig) -> float:
    route = 2 * cfg.d_model * cfg.n_experts
    active = cfg.top_k * _mlp_flops_per_tok(cfg)
    shared = _mlp_flops_per_tok(cfg) if cfg.shared_expert else 0
    return route + active + shared


def _attn_score_flops(cfg: ModelConfig, s: int, causal: bool = True,
                      kv_len=None) -> float:
    """Per-sequence attention einsum flops (qk + av)."""
    kv = s if kv_len is None else kv_len
    if cfg.sliding_window is not None:
        kv = min(kv, cfg.sliding_window)
    pairs = s * kv * (0.5 if (causal and kv_len is None) else 1.0)
    return 2 * 2 * pairs * cfg.q_dim


def _mamba_flops_per_tok(cfg: ModelConfig) -> float:
    dims = mamba2_dims(cfg)
    proj = 2 * cfg.d_model * dims["in_dim"] + 2 * dims["d_inner"] * cfg.d_model
    conv = 2 * 4 * dims["conv_dim"]
    # state recurrence: update + readout ~ 4*h*n*p per token
    ssm = 4 * dims["n_heads"] * dims["d_state"] * dims["p"]
    return proj + conv + ssm


def _rwkv_flops_per_tok(cfg: ModelConfig) -> float:
    d = cfg.d_model
    dims = rwkv6_dims(cfg)
    tm = 5 * 2 * d * d + 2 * d * d            # r,k,v,g,w projections + out
    lora = 2 * d * dims["lora"] * 2
    wkv = 4 * dims["h"] * dims["p"] * dims["p"]
    cm = 2 * d * cfg.d_ff * 2 + 2 * d * d     # channel mix
    return tm + lora + wkv + cm


def _head_flops_per_tok(cfg: ModelConfig) -> float:
    return 2 * cfg.d_model * cfg.padded_vocab


def forward_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """One forward pass over the full batch for this cell's step kind."""
    b, s = shape.global_batch, shape.seq_len
    f = cfg.family
    if shape.kind == "decode":
        toks = b                                   # one new token per seq
        ctx = s
    else:
        toks = b * s
        ctx = s

    if f in ("dense", "moe", "vlm"):
        per_tok = _attn_proj_flops_per_tok(cfg)
        per_tok += _moe_flops_per_tok(cfg) if cfg.n_experts else \
            _mlp_flops_per_tok(cfg)
        total = cfg.n_layers * per_tok * toks
        if shape.kind == "decode":
            kv = ctx if cfg.sliding_window is None else min(
                ctx, cfg.sliding_window)
            total += cfg.n_layers * b * 2 * 2 * kv * cfg.q_dim
        else:
            total += cfg.n_layers * b * _attn_score_flops(cfg, s)
        total += toks * _head_flops_per_tok(cfg) if shape.kind != "decode" \
            else b * _head_flops_per_tok(cfg)
        return total

    if f == "encdec":
        t_enc = max(s // cfg.enc_frames_ratio, 1)
        enc_tok = b * t_enc if shape.kind != "decode" else 0
        enc = cfg.n_enc_layers * (enc_tok * (_attn_proj_flops_per_tok(cfg)
                                             + _mlp_flops_per_tok(cfg))
                                  + (b * _attn_score_flops(cfg, t_enc,
                                                           causal=False)
                                     if enc_tok else 0))
        dec_tok = toks
        dec = cfg.n_layers * dec_tok * (2 * _attn_proj_flops_per_tok(cfg)
                                        + _mlp_flops_per_tok(cfg))
        if shape.kind == "decode":
            dec += cfg.n_layers * b * 2 * 2 * (ctx + t_enc) * cfg.q_dim
        else:
            dec += cfg.n_layers * b * (_attn_score_flops(cfg, s)
                                       + 2 * 2 * s * t_enc * cfg.q_dim)
        head = (toks if shape.kind != "decode" else b) * _head_flops_per_tok(cfg)
        return enc + dec + head

    if f == "ssm":
        total = cfg.n_layers * toks * _rwkv_flops_per_tok(cfg)
        total += (toks if shape.kind != "decode" else b) * \
            _head_flops_per_tok(cfg)
        return total

    if f == "hybrid":
        total = cfg.n_layers * toks * _mamba_flops_per_tok(cfg)
        n_apps = cfg.n_layers // cfg.shared_attn_period
        shared_per_tok = (2 * (2 * cfg.d_model) * cfg.d_model     # down proj
                          + _attn_proj_flops_per_tok(cfg)
                          + _mlp_flops_per_tok(cfg))
        total += n_apps * toks * shared_per_tok
        if shape.kind == "decode":
            total += n_apps * b * 2 * 2 * ctx * cfg.q_dim
        else:
            total += n_apps * b * _attn_score_flops(cfg, s)
        total += (toks if shape.kind != "decode" else b) * \
            _head_flops_per_tok(cfg)
        return total

    raise ValueError(f)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS for the cell's step: train = 3x forward (fwd + 2x bwd),
    prefill/decode = forward only."""
    fwd = forward_flops(cfg, shape)
    return 3 * fwd if shape.is_train else fwd


def active_params(cfg: ModelConfig) -> float:
    """Per-token active parameter count (MoE counts top_k + shared)."""
    from ..models import model_api, param_count
    total = param_count(model_api(cfg).param_specs())
    if not cfg.n_experts:
        return total
    # replace expert banks with the active subset
    ff_mats = 3 if cfg.act == "swiglu" else 2
    expert_params = cfg.n_layers * cfg.n_experts * ff_mats * \
        cfg.d_model * cfg.d_ff
    active_experts = cfg.n_layers * cfg.top_k * ff_mats * \
        cfg.d_model * cfg.d_ff
    return total - expert_params + active_experts


# ---------------------------------------------------------------------------
# Analytic HBM traffic (fused lower bound)
# ---------------------------------------------------------------------------

def hbm_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig,
                         chips: int, tp: int = 16) -> float:
    """Per-device HBM traffic assuming perfect elementwise fusion — the
    irreducible streams: weights touched per pass, layer-boundary activation
    checkpoints, KV/recurrent state, loss logits, optimizer state.

    cost_analysis' 'bytes accessed' on XLA:CPU counts every unfused op
    (converts/adds dominate: measured 3.6 TB of converts vs 46 GB of dot
    bytes per qwen layer), so the §Roofline table reports BOTH that
    spec-literal upper bound and this fused lower bound; truth on a real TPU
    lies between, much nearer this bound.
    """
    from ..models import model_api, param_count
    b, s = shape.global_batch, shape.seq_len
    params_b = param_count(model_api(cfg).param_specs()) * 2     # bf16
    d = cfg.d_model
    kv_bytes_tok = (1 if cfg.kv_cache_dtype == "int8" else 2)

    if shape.kind == "train":
        b_loc = max(b // (chips // tp), 1)
        passes = 3 + (1 if cfg.remat in ("full",) else 0)        # fwd+bwd+remat
        weights = passes * params_b / tp                          # gathered/TP
        layers = cfg.n_layers + cfg.n_enc_layers
        acts = 2 * layers * b_loc * s * d * 2                     # ckpt in+out
        logits = 2 * b_loc * s * cfg.padded_vocab * 4 / tp        # CE chunks
        opt = 2 * param_count(model_api(cfg).param_specs()) * 12 / chips
        return weights + acts + logits + opt
    if shape.kind == "prefill":
        b_loc = max(b // (chips // tp), 1)
        weights = params_b / tp
        layers = cfg.n_layers + cfg.n_enc_layers
        acts = 2 * layers * b_loc * s * d * 2
        cache = cfg.n_layers * b_loc * min(
            s, cfg.sliding_window or s) * cfg.kv_dim * 2 * kv_bytes_tok
        return weights + acts + cache
    # decode: stream resident weights + the KV/state working set
    weights = params_b / tp / max(chips // tp, 1) if False else params_b / tp
    b_loc = max(b // (chips // tp), 1)
    if cfg.family in ("ssm",):
        state = cfg.n_layers * b_loc * cfg.n_heads * cfg.d_head ** 2 * 4 * 2
        return weights / max(chips // tp, 1) * (chips // tp) / (chips // tp) \
            + state if False else weights + state
    eff = min(s, cfg.sliding_window or s)
    kv = cfg.n_layers * b_loc * eff * cfg.kv_dim * 2 * kv_bytes_tok
    if cfg.family == "hybrid":
        kv = (cfg.n_layers // max(cfg.shared_attn_period, 1)) * b_loc * eff \
            * cfg.kv_dim * 2 * kv_bytes_tok
    return weights + kv
