"""Post-SPMD HLO parsing: extract every collective op, its per-device operand
bytes, replica-group size and modeled wire traffic (ring schedules).

cost_analysis() does not report collective traffic, so the roofline's
collective term comes from here (spec: "parse as_text() and sum operand sizes
of every all-gather/all-reduce/reduce-scatter/all-to-all/collective-permute").
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+(\(?[^)=]*?\)?)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_txt: str) -> int:
    """Bytes of one 'f32[8,16]' result; tuple types sum their elements."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(first), 1)
    return 1


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int            # per-device result size
    group: int                   # replica-group participants
    line: str

    @property
    def operand_bytes(self) -> int:
        """Per-device operand (input) size."""
        if self.kind == "all-gather":
            return max(self.result_bytes // max(self.group, 1), 1)
        if self.kind == "reduce-scatter":
            return self.result_bytes * self.group
        return self.result_bytes

    @property
    def wire_bytes(self) -> int:
        """Ring-schedule traffic in/out of one chip."""
        g = max(self.group, 1)
        if self.kind == "all-reduce":
            return int(2 * self.result_bytes * (g - 1) / g)
        if self.kind == "all-gather":
            return int(self.result_bytes * (g - 1) / g)
        if self.kind == "reduce-scatter":
            return int(self.operand_bytes * (g - 1) / g)
        if self.kind == "all-to-all":
            return int(self.result_bytes * (g - 1) / g)
        return self.result_bytes     # collective-permute: one hop


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    out: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(2).replace("-start", "")
        res = _shape_bytes(m.group(1))
        out.append(CollectiveOp(kind=kind, result_bytes=res,
                                group=_group_size(line), line=line.strip()))
    return out


def summarize_collectives(ops: List[CollectiveOp]) -> Dict[str, Dict[str, int]]:
    summary: Dict[str, Dict[str, int]] = {}
    for op in ops:
        s = summary.setdefault(op.kind, {"count": 0, "operand_bytes": 0,
                                         "wire_bytes": 0})
        s["count"] += 1
        s["operand_bytes"] += op.operand_bytes
        s["wire_bytes"] += op.wire_bytes
    return summary


def total_collective_bytes(ops: List[CollectiveOp]) -> Tuple[int, int]:
    """(sum of per-device operand bytes, sum of modeled wire bytes)."""
    return (sum(o.operand_bytes for o in ops),
            sum(o.wire_bytes for o in ops))
