"""Paper-technique power report for every dry-run cell (DESIGN.md Sec. 2c).

The dry-run's MODEL_FLOPS are converted to MAC counts and 'executed' on the
paper's virtual partitioned systolic arrays: a v5e chip is modeled as
4 x (128 x 128) MAC grids; the paper's flow (slack model -> DBSCAN clusters
-> Algorithm 1 -> Algorithm 2 calibration) assigns per-partition rail
voltages, and the calibrated PowerModel turns MAC counts into energy — with
and without voltage scaling, plus the beyond-paper precision-island variant.

CLI (the report lands next to the other ``BENCH_*`` artifacts):

    PYTHONPATH=src python -m repro.roofline.power_report \
        [--tech vtr-22nm] [--json-out BENCH_power_report.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from ..configs import ARCHS, SHAPES, cell_is_runnable, get_config
from ..core import model_for
from ..core.precision import ENERGY_PER_MAC, TIERS
from ..core.timing import TECH_NODES
from ..flow import ArtifactStore, FlowConfig, FlowReport, Pipeline, run
from .analytic import model_flops

ART = Path(__file__).resolve().parents[3] / "artifacts"

MXU_GRIDS_PER_CHIP = 4
MXU_N = 128


@dataclasses.dataclass
class PowerRow:
    arch: str
    shape: str
    macs: float
    baseline_j: float                 # all partitions at nominal V
    static_j: float                   # Algorithm-1 voltages
    runtime_j: float                  # Algorithm-2 calibrated voltages
    precision_j: float                # beyond-paper int4/int8/bf16 islands
    static_saving_pct: float
    runtime_saving_pct: float
    precision_saving_pct: float


# Shared artifact store + pipeline: repeated power_row() calls (any tech)
# reuse every cached stage output instead of re-running the Fig. 9 flow per
# call, and the content-addressed cluster/floorplan stages are computed once
# and shared across tech nodes (the slack structure is tech-independent —
# the same sharing PR 3's sweep caching exploits).
_STORE = ArtifactStore()
_PIPELINE = Pipeline()


def _flow(tech: str = "vtr-22nm") -> FlowReport:
    # one 128x128 virtual array per MXU; paper flow with DBSCAN
    return run(FlowConfig(array_n=64, tech=tech, algo="dbscan",
                          seed=2021, max_trials=24),
               pipeline=_PIPELINE, store=_STORE)


def power_row(arch: str, shape_name: str, tech: str = "vtr-22nm") -> PowerRow:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    macs = model_flops(cfg, shape) / 2.0
    flow = _flow(tech)
    pm = model_for(tech)
    n_part = flow.n_partitions
    frac = np.bincount(flow.labels, minlength=n_part) / flow.labels.size

    nominal_v = [pm.tech.v_nom] * n_part
    base = pm.macs_energy_j(macs, nominal_v, frac)
    static = pm.macs_energy_j(macs, flow.static_v, frac)
    runtime = pm.macs_energy_j(macs, flow.runtime_v, frac)
    # beyond-paper: precision islands using the same cluster fractions;
    # cheapest tier on the highest-slack cluster
    tier_energy = np.array([ENERGY_PER_MAC[TIERS[min(i, len(TIERS) - 1)]]
                            for i in range(n_part)])
    precision = float(base * np.sum(frac * tier_energy))
    return PowerRow(
        arch=arch, shape=shape_name, macs=macs,
        baseline_j=base, static_j=static, runtime_j=runtime,
        precision_j=precision,
        static_saving_pct=100 * (1 - static / base),
        runtime_saving_pct=100 * (1 - runtime / base),
        precision_saving_pct=100 * (1 - precision / base),
    )


def all_rows(tech: str = "vtr-22nm") -> List[PowerRow]:
    out = []
    for arch in ARCHS:
        for shape_name, shape in SHAPES.items():
            ok, _ = cell_is_runnable(get_config(arch), shape)
            if ok:
                out.append(power_row(arch, shape_name, tech))
    return out


def render_markdown(rows: List[PowerRow]) -> str:
    hdr = ("| arch | shape | MACs | baseline J | static J | runtime J | "
           "precision J | runtime saving | precision saving |")
    out = [hdr, "|" + "---|" * 9]
    for r in rows:
        out.append(f"| {r.arch} | {r.shape} | {r.macs:.2e} | "
                   f"{r.baseline_j:.3g} | {r.static_j:.3g} | "
                   f"{r.runtime_j:.3g} | {r.precision_j:.3g} | "
                   f"{r.runtime_saving_pct:.1f}% | "
                   f"{r.precision_saving_pct:.1f}% |")
    return "\n".join(out)


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tech", default="vtr-22nm", choices=sorted(TECH_NODES),
                    help="technology node for the virtual arrays")
    ap.add_argument("--json-out", default=None,
                    help="also write the rows as a JSON artifact "
                         "(e.g. BENCH_power_report.json, next to the other "
                         "BENCH_* files)")
    args = ap.parse_args(argv)
    rows = all_rows(args.tech)
    print(render_markdown(rows))
    if args.json_out:
        payload = {
            "tech": args.tech,
            "rows": [dataclasses.asdict(r) for r in rows],
            "flow_cache": {
                "timing_stage_runs": _STORE.runs_of("timing"),
                "cluster_stage_runs": _STORE.runs_of("cluster"),
            },
        }
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
