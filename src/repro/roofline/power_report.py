"""Paper-technique power report for every dry-run cell (DESIGN.md Sec. 2c).

The dry-run's MODEL_FLOPS are converted to MAC counts and 'executed' on the
paper's virtual partitioned systolic arrays: a v5e chip is modeled as
4 x (128 x 128) MAC grids; the paper's flow (slack model -> DBSCAN clusters
-> Algorithm 1 -> Algorithm 2 calibration) assigns per-partition rail
voltages, and the calibrated PowerModel turns MAC counts into energy — with
and without voltage scaling, plus the beyond-paper precision-island variant.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List

import numpy as np

from ..configs import ARCHS, SHAPES, cell_is_runnable, get_config
from ..core import model_for
from ..core.precision import ENERGY_PER_MAC, TIERS
from ..flow import ArtifactStore, FlowConfig, FlowReport, run
from .analytic import model_flops

ART = Path(__file__).resolve().parents[3] / "artifacts"

MXU_GRIDS_PER_CHIP = 4
MXU_N = 128


@dataclasses.dataclass
class PowerRow:
    arch: str
    shape: str
    macs: float
    baseline_j: float                 # all partitions at nominal V
    static_j: float                   # Algorithm-1 voltages
    runtime_j: float                  # Algorithm-2 calibrated voltages
    precision_j: float                # beyond-paper int4/int8/bf16 islands
    static_saving_pct: float
    runtime_saving_pct: float
    precision_saving_pct: float


# Shared artifact store: repeated power_row() calls (any tech) reuse every
# cached stage output instead of re-running the Fig. 9 flow per call.
_STORE = ArtifactStore()


def _flow(tech: str = "vtr-22nm") -> FlowReport:
    # one 128x128 virtual array per MXU; paper flow with DBSCAN
    return run(FlowConfig(array_n=64, tech=tech, algo="dbscan",
                          seed=2021, max_trials=24), store=_STORE)


def power_row(arch: str, shape_name: str, tech: str = "vtr-22nm") -> PowerRow:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    macs = model_flops(cfg, shape) / 2.0
    flow = _flow(tech)
    pm = model_for(tech)
    n_part = flow.n_partitions
    frac = np.bincount(flow.labels, minlength=n_part) / flow.labels.size

    nominal_v = [pm.tech.v_nom] * n_part
    base = pm.macs_energy_j(macs, nominal_v, frac)
    static = pm.macs_energy_j(macs, flow.static_v, frac)
    runtime = pm.macs_energy_j(macs, flow.runtime_v, frac)
    # beyond-paper: precision islands using the same cluster fractions;
    # cheapest tier on the highest-slack cluster
    tier_energy = np.array([ENERGY_PER_MAC[TIERS[min(i, len(TIERS) - 1)]]
                            for i in range(n_part)])
    precision = float(base * np.sum(frac * tier_energy))
    return PowerRow(
        arch=arch, shape=shape_name, macs=macs,
        baseline_j=base, static_j=static, runtime_j=runtime,
        precision_j=precision,
        static_saving_pct=100 * (1 - static / base),
        runtime_saving_pct=100 * (1 - runtime / base),
        precision_saving_pct=100 * (1 - precision / base),
    )


def all_rows(tech: str = "vtr-22nm") -> List[PowerRow]:
    out = []
    for arch in ARCHS:
        for shape_name, shape in SHAPES.items():
            ok, _ = cell_is_runnable(get_config(arch), shape)
            if ok:
                out.append(power_row(arch, shape_name, tech))
    return out


def render_markdown(rows: List[PowerRow]) -> str:
    hdr = ("| arch | shape | MACs | baseline J | static J | runtime J | "
           "precision J | runtime saving | precision saving |")
    out = [hdr, "|" + "---|" * 9]
    for r in rows:
        out.append(f"| {r.arch} | {r.shape} | {r.macs:.2e} | "
                   f"{r.baseline_j:.3g} | {r.static_j:.3g} | "
                   f"{r.runtime_j:.3g} | {r.precision_j:.3g} | "
                   f"{r.runtime_saving_pct:.1f}% | "
                   f"{r.precision_saving_pct:.1f}% |")
    return "\n".join(out)


def main() -> None:
    rows = all_rows()
    print(render_markdown(rows))


if __name__ == "__main__":
    main()
