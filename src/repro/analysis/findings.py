"""Finding record, inline suppressions, and the checked-in baseline.

A finding is identified for baseline purposes by ``(code, path,
line_text)`` — the stripped source text of the offending line — so the
baseline survives unrelated edits that shift line numbers.  Identical
entries are counted: a file may legitimately carry two baselined findings
with the same source text, and a third appearance is *new*.

Inline suppression syntax (preferred over baselining; forces a written
reason next to the exemption)::

    x = jnp.einsum("td,edf->etf", xt, p[k])  # lint: allow=RP001 ideal-only

The marker may sit on the offending line or on the line directly above
(for lines too long to annotate in place).
"""

from __future__ import annotations

import json
import re
from collections import Counter
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Tuple

_ALLOW_RE = re.compile(r"#\s*lint:\s*allow=([A-Z0-9,]+)")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    code: str            # e.g. "RP001"
    path: str            # repo-relative, forward slashes
    line: int            # 1-based
    col: int             # 0-based
    message: str
    fix_hint: str
    line_text: str = ""  # stripped source of the offending line

    def key(self) -> Tuple[str, str, str]:
        return (self.code, self.path, self.line_text)

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col + 1}: "
                f"{self.code} {self.message}\n    hint: {self.fix_hint}")


def parse_suppressions(source: str) -> Dict[int, List[str]]:
    """Map line number -> list of rule codes allowed on that line.

    A marker on line N suppresses findings on lines N and N+1, so a
    comment can ride above a long statement.
    """
    allowed: Dict[int, List[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(text)
        if not m:
            continue
        codes = [c for c in m.group(1).split(",") if c]
        allowed.setdefault(i, []).extend(codes)
        allowed.setdefault(i + 1, []).extend(codes)
    return allowed


def suppressed(finding: Finding, allowed: Dict[int, List[str]]) -> bool:
    return finding.code in allowed.get(finding.line, ())


# ---- baseline ---------------------------------------------------------------


def write_baseline(findings: Iterable[Finding], path: Path) -> None:
    """Serialize findings (deduped with counts) as the suppression baseline."""
    counts = Counter(f.key() for f in findings)
    entries = [
        {"code": code, "path": p, "line_text": text, "count": n}
        for (code, p, text), n in sorted(counts.items())
    ]
    path.write_text(json.dumps({"version": 1, "findings": entries},
                               indent=2, sort_keys=True) + "\n")


def load_baseline(path: Path) -> Counter:
    """Baseline as a Counter over finding keys; empty if the file is absent."""
    if not path.is_file():
        return Counter()
    payload = json.loads(path.read_text())
    counts: Counter = Counter()
    for e in payload.get("findings", []):
        counts[(e["code"], e["path"], e.get("line_text", ""))] = \
            int(e.get("count", 1))
    return counts


def apply_baseline(findings: List[Finding],
                   baseline: Counter) -> List[Finding]:
    """Return findings not absorbed by the baseline (order preserved)."""
    budget = Counter(baseline)
    fresh: List[Finding] = []
    for f in findings:
        if budget[f.key()] > 0:
            budget[f.key()] -= 1
        else:
            fresh.append(f)
    return fresh
