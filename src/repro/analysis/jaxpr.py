"""jaxpr census: inventory every host round-trip on the serving hot path.

Traces each config's ``ModelAPI`` prefill/decode exactly the way
``ServeEngine`` jits them (same batch shapes, same ``use_backend`` scope),
then walks the closed jaxpr — recursing through ``pjit``/``scan``/custom-vjp
sub-jaxprs, multiplying by scan trip counts — and reports per config:

* ``pure_callbacks`` — host round-trips per model call (the exact worklist
  for ROADMAP item 1: every one of these pins serve throughput to
  interpreter speed and blocks sharding);
* ``dots``/``flops`` — dot-op count and a flop estimate from
  ``dot_general`` contraction shapes;
* ``dot_dtypes`` — dtype histogram of dot outputs (precision flow).

``*_static`` variants count jaxpr equations without scan weighting.

The pinned reference counts live in ``census_baseline.json``; CI fails when
any config's callback count rises above its pin, so a new host round-trip
can never land silently.
"""

from __future__ import annotations

import json
import math
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Tuple

# Small smoke config per family: the census is about *structure* (callback
# and dot counts per model call), which smoke shapes share with full ones.
CENSUS_ARCHS: Tuple[str, ...] = (
    "starcoder2-3b",          # dense
    "llama4-scout-17b-a16e",  # moe (shared expert + top-k router)
    "llava-next-mistral-7b",  # vlm
    "rwkv6-1.6b",             # ssm (decode-only prompt absorption)
    "zamba2-2.7b",            # hybrid
    "seamless-m4t-medium",    # encdec
)

PROMPT_LEN = 8
SLOTS = 2
MAX_LEN = 32


# ---- jaxpr walking ----------------------------------------------------------


def _sub_jaxprs(params: Dict[str, Any]):
    """Yield every Jaxpr/ClosedJaxpr buried in an eqn's params."""
    import jax.core as jcore

    def visit(v):
        if isinstance(v, (jcore.Jaxpr, jcore.ClosedJaxpr)):
            yield v
        elif isinstance(v, (tuple, list)):
            for item in v:
                yield from visit(item)
    for v in params.values():
        yield from visit(v)


def _walk(jaxpr, counts: Dict[str, Any], weight: int = 1) -> None:
    import jax.core as jcore

    if isinstance(jaxpr, jcore.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        counts["eqns"] += 1
        if prim == "pure_callback":
            counts["pure_callbacks"] += weight
            counts["pure_callbacks_static"] += 1
        elif prim in ("dot_general", "dot"):
            counts["dots"] += weight
            counts["dots_static"] += 1
            counts["flops"] += weight * _dot_flops(eqn)
            dt = str(eqn.outvars[0].aval.dtype)
            counts["dot_dtypes"][dt] = counts["dot_dtypes"].get(dt, 0) + weight
        sub_weight = weight
        if prim == "scan":
            length = eqn.params.get("length")
            if isinstance(length, int) and length > 0:
                sub_weight = weight * length
        for sub in _sub_jaxprs(eqn.params):
            _walk(sub, counts, sub_weight)


def _dot_flops(eqn) -> int:
    """2 * prod(out shape) * prod(contracting dims) for one dot_general."""
    out_shape = tuple(eqn.outvars[0].aval.shape)
    dnums = eqn.params.get("dimension_numbers")
    contract = 1
    if dnums is not None:
        (lhs_c, _), _ = dnums
        lhs_shape = tuple(eqn.invars[0].aval.shape)
        for ax in lhs_c:
            contract *= lhs_shape[ax]
    return 2 * math.prod(out_shape) * contract


def trace_counts(fn, *args) -> Dict[str, Any]:
    """Counts for one traced callable (args may be ShapeDtypeStructs)."""
    import jax

    closed = jax.make_jaxpr(fn)(*args)
    counts: Dict[str, Any] = {
        "eqns": 0, "pure_callbacks": 0, "pure_callbacks_static": 0,
        "dots": 0, "dots_static": 0, "flops": 0, "dot_dtypes": {},
    }
    _walk(closed, counts)
    counts["dot_dtypes"] = dict(sorted(counts["dot_dtypes"].items()))
    return counts


# ---- per-config tracing -----------------------------------------------------


def census_config(arch: str, backend: str = "reference", *,
                  smoke: bool = True, prompt_len: int = PROMPT_LEN,
                  slots: int = SLOTS, max_len: int = MAX_LEN
                  ) -> Dict[str, Any]:
    """Trace one config's prefill + decode the way ``ServeEngine`` runs them."""
    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..configs.base import ShapeConfig
    from ..models.api import model_api
    from ..models.shardlib import spec_tree_to_structs

    cfg = get_config(arch, smoke=smoke)
    api = model_api(cfg, backend=backend)
    shape = ShapeConfig("census", max_len, slots, "decode")

    params = spec_tree_to_structs(api.param_specs())
    state = spec_tree_to_structs(api.decode_state_specs(shape))
    tokens = jax.ShapeDtypeStruct((slots, 1), jnp.int32)

    report: Dict[str, Any] = {
        "arch": arch, "family": cfg.family, "backend": backend,
        "decode": trace_counts(api.decode_step, params, state, tokens),
    }

    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        batch: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((1, prompt_len), jnp.int32)}
        if cfg.family == "encdec":
            t_enc = max_len // cfg.enc_frames_ratio
            batch["frames"] = jax.ShapeDtypeStruct(
                (1, t_enc, cfg.d_model), jnp.bfloat16)
        report["prefill"] = trace_counts(
            lambda p, b: api.prefill(p, b, max_len=max_len), params, batch)
    else:
        report["prefill"] = None  # SSM/hybrid absorb prompts via decode_step
    return report


def census(archs: Iterable[str] = CENSUS_ARCHS,
           backend: str = "reference", **kw) -> Dict[str, Any]:
    return {
        "version": 1,
        "backend": backend,
        "slots": kw.get("slots", SLOTS),
        "max_len": kw.get("max_len", MAX_LEN),
        "prompt_len": kw.get("prompt_len", PROMPT_LEN),
        "configs": {a: census_config(a, backend, **kw) for a in archs},
    }


# ---- CI gate ----------------------------------------------------------------


def check_census(current: Dict[str, Any],
                 baseline: Dict[str, Any]) -> List[str]:
    """Violations (empty list = gate passes).

    The gate is one-sided: callback counts may only *fall* relative to the
    baseline (ROADMAP item 1 is about driving them to zero); a drop is
    reported as stale-baseline advice, not a failure.  Dot counts are pinned
    exactly — a changed dot census means the model graph changed and the
    baseline must be regenerated deliberately.
    """
    problems: List[str] = []
    for arch, base_cfg in baseline.get("configs", {}).items():
        cur_cfg = current.get("configs", {}).get(arch)
        if cur_cfg is None:
            problems.append(f"{arch}: missing from current census")
            continue
        for phase in ("prefill", "decode"):
            base = base_cfg.get(phase)
            cur = cur_cfg.get(phase)
            if base is None and cur is None:
                continue
            if (base is None) != (cur is None):
                problems.append(f"{arch}.{phase}: presence changed "
                                f"(baseline={base is not None}, "
                                f"current={cur is not None})")
                continue
            if cur["pure_callbacks"] > base["pure_callbacks"]:
                problems.append(
                    f"{arch}.{phase}: pure_callbacks rose "
                    f"{base['pure_callbacks']} -> {cur['pure_callbacks']} — "
                    f"a new host round-trip landed on the hot path")
            if cur["dots"] != base["dots"]:
                problems.append(
                    f"{arch}.{phase}: dot count changed "
                    f"{base['dots']} -> {cur['dots']} — regenerate the "
                    f"baseline if the model graph change is intentional")
    return problems


def load_census(path: Path) -> Dict[str, Any]:
    return json.loads(Path(path).read_text())


def write_census(report: Dict[str, Any], path: Path) -> None:
    Path(path).write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
