"""CLI for the static-analysis subsystem.

::

    python -m repro.analysis lint src/ [--baseline lint_baseline.json]
    python -m repro.analysis lint src/ --write-baseline   # absorb current
    python -m repro.analysis rules                        # list rule codes
    python -m repro.analysis census [--json out.json] [--check baseline]
    python -m repro.analysis census --write-baseline      # repin counts

``lint`` exits 1 on any finding not covered by an inline
``# lint: allow=RPxxx`` marker or the baseline.  ``census --check`` exits 1
when any config's ``pure_callback`` count rose above its pin (or its dot
census drifted without a deliberate repin).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .checker import DEFAULT_BASELINE, lint_paths
from .findings import write_baseline
from .jaxpr import (CENSUS_ARCHS, census, check_census, load_census,
                    write_census)
from .rules import RULES

CENSUS_BASELINE = "census_baseline.json"


def _repo_root() -> Path:
    # src/repro/analysis/__main__.py -> repo root is three levels above src/
    return Path(__file__).resolve().parents[3]


def _cmd_lint(args: argparse.Namespace) -> int:
    root = _repo_root()
    paths = [Path(p) for p in args.paths] or [root / "src"]
    baseline = None if args.no_baseline else Path(args.baseline)
    if args.write_baseline:
        all_findings, _ = lint_paths(paths, root=root, baseline_path=None)
        write_baseline(all_findings, Path(args.baseline))
        print(f"wrote {len(all_findings)} finding(s) to {args.baseline}")
        return 0
    fresh, absorbed = lint_paths(paths, root=root, baseline_path=baseline)
    for f in fresh:
        print(f.format())
    tail = f" ({absorbed} baselined)" if absorbed else ""
    if fresh:
        print(f"\n{len(fresh)} finding(s){tail}")
        return 1
    print(f"clean{tail}")
    return 0


def _cmd_rules(_args: argparse.Namespace) -> int:
    for r in RULES:
        scope = "/".join(r.scopes) or "src"
        print(f"{r.code}  [{scope}]  {r.description}\n    fix: {r.fix_hint}")
    return 0


def _cmd_census(args: argparse.Namespace) -> int:
    archs = args.arch or list(CENSUS_ARCHS)
    report = census(archs, backend=args.backend)
    if args.json:
        write_census(report, Path(args.json))
        print(f"census written to {args.json}")
    if args.write_baseline:
        write_census(report, Path(args.baseline))
        print(f"baseline repinned at {args.baseline}")
        return 0
    for arch, cfg in report["configs"].items():
        for phase in ("prefill", "decode"):
            c = cfg.get(phase)
            if c is None:
                continue
            print(f"{arch:24s} {phase:7s} callbacks={c['pure_callbacks']:5d} "
                  f"dots={c['dots']:5d} flops={c['flops']:.3e} "
                  f"dtypes={c['dot_dtypes']}")
    if args.check:
        problems = check_census(report, load_census(Path(args.check)))
        for p in problems:
            print(f"CENSUS GATE: {p}")
        if problems:
            return 1
        print("census gate: OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    lint = sub.add_parser("lint", help="run the invariant linter")
    lint.add_argument("paths", nargs="*", help="files or trees (default src/)")
    lint.add_argument("--baseline", default=str(_repo_root() / DEFAULT_BASELINE))
    lint.add_argument("--no-baseline", action="store_true")
    lint.add_argument("--write-baseline", action="store_true",
                      help="absorb every current finding into the baseline")
    lint.set_defaults(fn=_cmd_lint)

    rules = sub.add_parser("rules", help="list rule codes and fix hints")
    rules.set_defaults(fn=_cmd_rules)

    cen = sub.add_parser("census", help="jaxpr host-round-trip census")
    cen.add_argument("--arch", action="append",
                     help="config name (repeatable; default: one per family)")
    cen.add_argument("--backend", default="reference",
                     help="backend scope to trace under (default reference — "
                          "the host-callback path the census inventories)")
    cen.add_argument("--json", help="write the full census report here")
    cen.add_argument("--check", help="baseline to gate against")
    cen.add_argument("--baseline",
                     default=str(_repo_root() / CENSUS_BASELINE))
    cen.add_argument("--write-baseline", action="store_true")
    cen.set_defaults(fn=_cmd_census)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
