"""repro.analysis — static guards for the repo's cross-cutting invariants.

Two layers:

* **Invariant linter** (:mod:`.rules` + :mod:`.checker`): AST-based,
  repo-specific rules (RP001..RP006) that pin the load-bearing conventions
  established by earlier PRs — every dense GEMM routes through
  ``backend.matmul``, one pump thread owns every jax call in the server,
  wall-clock reads go through injectable ``clock=``, Pallas block shapes
  come from ``kernels.tuning`` tables.  Violations carry a fix-hint and can
  be silenced either inline (``# lint: allow=RP001 <reason>``) or via a
  checked-in JSON baseline.

* **jaxpr census** (:mod:`.jaxpr`): traces each config's ``ModelAPI``
  prefill/decode closed jaxpr and inventories ``pure_callback`` host
  round-trips, dot ops, flop estimates and dtype flow per decode step —
  the ground-truth worklist for ROADMAP item 1 (device-resident fault
  injection), pinned by CI so new host round-trips fail loudly.

CLI: ``python -m repro.analysis lint src/`` and
``python -m repro.analysis census``.
"""

from .findings import Finding, load_baseline, write_baseline  # noqa: F401
from .checker import lint_file, lint_paths  # noqa: F401
from .rules import RULES, rule_codes  # noqa: F401
from .jaxpr import (  # noqa: F401
    CENSUS_ARCHS,
    census,
    census_config,
    check_census,
    trace_counts,
)
