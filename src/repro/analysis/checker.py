"""File walker: parse, run scope-matched rules, apply suppressions/baseline.

``lint_paths`` is the programmatic entry point used by both the CLI and CI:
it returns ``(fresh, suppressed_count)`` where *fresh* are findings not
absorbed by an inline ``# lint: allow=`` marker or the checked-in baseline.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Tuple

from .findings import (Finding, apply_baseline, load_baseline,
                       parse_suppressions, suppressed)
from .rules import RULES, Rule, RuleContext

DEFAULT_BASELINE = "lint_baseline.json"


def repo_relative(path: Path, root: Optional[Path] = None) -> str:
    """Posix path relative to *root* (or its best-effort anchor).

    Falls back to the segment chain after a recognizable anchor
    (``src`` or ``tests``) so fixture trees resolve rule scopes the same
    way the real tree does.
    """
    p = path.resolve()
    if root is not None:
        try:
            return p.relative_to(root.resolve()).as_posix()
        except ValueError:
            pass
    parts = p.parts
    for anchor in ("src", "tests"):
        if anchor in parts:
            return Path(*parts[parts.index(anchor):]).as_posix()
    return p.name


def lint_source(source: str, rel_path: str,
                rules: Sequence[Rule] = RULES) -> List[Finding]:
    """All findings for one in-memory source blob (suppressions applied,
    baseline not)."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(code="RP000", path=rel_path, line=e.lineno or 1,
                        col=(e.offset or 1) - 1,
                        message=f"syntax error: {e.msg}",
                        fix_hint="fix the parse error before linting",
                        line_text="")]
    from .rules import build_import_table
    ctx = RuleContext(path=rel_path, tree=tree,
                      imports=build_import_table(tree),
                      lines=source.splitlines())
    allowed = parse_suppressions(source)
    out: List[Finding] = []
    for rule in rules:
        if not rule.applies_to(ctx):
            continue
        for f in rule.check(ctx):
            if not suppressed(f, allowed):
                out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return out


def lint_file(path: Path, root: Optional[Path] = None,
              rules: Sequence[Rule] = RULES) -> List[Finding]:
    return lint_source(path.read_text(), repo_relative(path, root), rules)


def iter_python_files(paths: Iterable[Path]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(q for q in p.rglob("*.py")
                                if "__pycache__" not in q.parts))
        elif p.suffix == ".py":
            files.append(p)
    return files


def lint_paths(paths: Sequence[Path], root: Optional[Path] = None,
               baseline_path: Optional[Path] = None,
               rules: Sequence[Rule] = RULES,
               ) -> Tuple[List[Finding], int]:
    """Lint files/trees; returns (fresh findings, baselined count)."""
    findings: List[Finding] = []
    for f in iter_python_files(paths):
        findings.extend(lint_file(f, root, rules))
    if baseline_path is None:
        return findings, 0
    baseline = load_baseline(baseline_path)
    fresh = apply_baseline(findings, baseline)
    return fresh, len(findings) - len(fresh)
