"""Repo-specific invariant rules (RP001..RP008).

Each rule pins a convention an earlier PR made load-bearing:

========  ====================================================================
RP001     Dense GEMMs in ``models/`` must route through ``backend.matmul``
          (PR 5) — a direct ``jnp.dot``/``@``/``lax.dot_general``/weight
          ``einsum`` silently runs ideal and skips fault injection.
RP002     Only the pump thread may touch jax in ``server/`` (PR 6) — jax
          calls inside ``async def`` handlers run on the event loop and
          deadlock or stall streaming.
RP003     Wall-clock reads in ``serve/``/``server/``/``hwloop/`` must go
          through the injectable ``clock=`` seam — direct ``time.*()`` calls
          break the virtual-time ``LoadHarness``.
RP004     No unseeded global ``np.random`` — deterministic harness/oracle
          paths must thread an explicit ``np.random.default_rng(seed)``.
RP005     No mutable default arguments.
RP006     Pallas block/chunk shapes in ``kernels/`` come from
          ``tuning.BLOCK_TABLE``/``CHUNK_TABLE`` (literal defaults bypass
          the tables and break divisibility on off-table shapes).
RP007     No swallowed exceptions in ``serve/``/``server/``/``hwloop/``
          (PR 8) — a bare ``except:`` or a pass-only ``except Exception:``
          hides pump deaths and silent-corruption escalation; the
          resilience contract requires faults to surface or be handled.
RP008     No bare ``print()`` in ``serve``/``server``/``hwloop``/
          ``resilience``/``obs`` (PR 9) — runtime output must flow through
          the ``repro.obs`` event/metric path (or an explicit CLI sink) so
          the flight recorder and ``/metrics`` see it; stray prints corrupt
          NDJSON trace streams piped to stdout.
RP009     Rail writes in ``railscale``/``serve`` go through
          ``GuardbandClamp`` (PR 10) — a direct ``set_rails``/
          ``set_partition_voltage`` call skips the envelope bound, dwell
          timer, and max-step limit, so a policy bug can push a partition
          below its calibrated floor or fight the watchdog's heals.
========  ====================================================================

Rules are conservative by design: the RP001 einsum check only fires when an
operand is a subscript expression (``p["w1"]`` — a parameter leaf), so
activation-activation contractions (attention scores, SSM scans) pass
without annotation.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .findings import Finding

# ---- shared AST helpers -----------------------------------------------------


def build_import_table(tree: ast.AST) -> Dict[str, str]:
    """Map local alias -> canonical dotted origin.

    ``import jax.numpy as jnp``       -> {"jnp": "jax.numpy", "jax": "jax"}
    ``import time as _time``          -> {"_time": "time"}
    ``from time import perf_counter`` -> {"perf_counter": "time.perf_counter"}
    ``from jax import lax``           -> {"lax": "jax.lax"}
    """
    table: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                local = alias.asname or alias.name.split(".")[0]
                table[local] = alias.name if alias.asname \
                    else alias.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for alias in node.names:
                if alias.name == "*":
                    continue
                table[alias.asname or alias.name] = \
                    f"{node.module}.{alias.name}"
    return table


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` attribute chain as a dotted string, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def canonical(node: ast.AST, imports: Dict[str, str]) -> Optional[str]:
    """Dotted name with its head resolved through the import table."""
    name = dotted_name(node)
    if name is None:
        return None
    head, _, rest = name.partition(".")
    origin = imports.get(head, head)
    return f"{origin}.{rest}" if rest else origin


@dataclass
class RuleContext:
    """Per-file state shared by every rule."""

    path: str                       # repo-relative, posix
    tree: ast.AST
    imports: Dict[str, str]
    lines: Sequence[str]            # raw source lines (0-based)
    segments: Tuple[str, ...] = ()  # path split on "/"

    def __post_init__(self) -> None:
        self.segments = tuple(self.path.split("/"))

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""


@dataclass(frozen=True)
class Rule:
    code: str
    name: str
    scopes: Tuple[str, ...]         # path segments; empty = everywhere
    fix_hint: str
    description: str
    check: Callable[[RuleContext], List[Finding]]

    def applies_to(self, ctx: RuleContext) -> bool:
        return not self.scopes or any(s in ctx.segments for s in self.scopes)


def _finding(rule: "Rule", ctx: RuleContext, node: ast.AST,
             message: str) -> Finding:
    line = getattr(node, "lineno", 1)
    return Finding(code=rule.code, path=ctx.path, line=line,
                   col=getattr(node, "col_offset", 0), message=message,
                   fix_hint=rule.fix_hint, line_text=ctx.line_text(line))


# ---- RP001: dense GEMM bypassing backend.matmul ----------------------------

_GEMM_CALLS = {
    "jax.numpy.dot", "jax.numpy.matmul", "jax.numpy.tensordot",
    "numpy.dot", "numpy.matmul", "numpy.tensordot",
    "jax.lax.dot", "jax.lax.dot_general", "jax.lax.batch_matmul",
}
_EINSUM_CALLS = {"jax.numpy.einsum", "numpy.einsum"}


def _check_rp001(ctx: RuleContext) -> List[Finding]:
    rule = RP001
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.MatMult):
            out.append(_finding(rule, ctx, node,
                                "matrix product via `@` bypasses the "
                                "backend router"))
        elif isinstance(node, ast.Call):
            name = canonical(node.func, ctx.imports)
            if name in _GEMM_CALLS:
                out.append(_finding(
                    rule, ctx, node,
                    f"direct `{name}` bypasses the backend router"))
            elif name in _EINSUM_CALLS:
                # weight GEMM heuristic: an operand that *is or contains* a
                # subscript (p["w1"]) is a parameter leaf — contraction
                # against it is a dense GEMM; activation einsums pass
                operands = node.args[1:] if node.args else []
                if any(isinstance(sub, ast.Subscript)
                       for arg in operands for sub in ast.walk(arg)):
                    out.append(_finding(
                        rule, ctx, node,
                        "einsum contracts a parameter leaf (subscripted "
                        "operand) outside the backend router"))
    return out


RP001 = Rule(
    code="RP001", name="gemm-bypasses-backend", scopes=("models",),
    fix_hint="route through repro.backend.matmul (`from ..backend import "
             "matmul as bmm`) so non-ideal backends see this GEMM; "
             "ideal-only branches need `# lint: allow=RP001 <reason>`",
    description="dense GEMM in models/ bypassing backend.matmul",
    check=_check_rp001,
)


# ---- RP002: jax calls inside asyncio handlers ------------------------------


def _check_rp002(ctx: RuleContext) -> List[Finding]:
    rule = RP002
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.AsyncFunctionDef):
            continue
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                name = canonical(sub.func, ctx.imports)
                if name and (name == "jax" or name.startswith("jax.")):
                    out.append(_finding(
                        rule, ctx, sub,
                        f"`{name}` called inside async handler "
                        f"`{node.name}` — jax belongs to the pump thread"))
    return out


RP002 = Rule(
    code="RP002", name="jax-in-async-handler", scopes=("server",),
    fix_hint="hand work to the pump thread via the scheduler queue "
             "(Request callbacks + loop.call_soon_threadsafe); the event "
             "loop must only parse and stream",
    description="jax/jnp call reachable from an asyncio handler in server/",
    check=_check_rp002,
)


# ---- RP003: direct wall-clock reads ----------------------------------------

_CLOCK_CALLS = {"time.time", "time.monotonic", "time.perf_counter",
                "time.process_time"}


def _check_rp003(ctx: RuleContext) -> List[Finding]:
    rule = RP003
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            name = canonical(node.func, ctx.imports)
            if name in _CLOCK_CALLS:
                out.append(_finding(
                    rule, ctx, node,
                    f"direct `{name}()` read bypasses the injectable "
                    f"clock seam"))
    return out


RP003 = Rule(
    code="RP003", name="uninjected-wall-clock",
    scopes=("serve", "server", "hwloop"),
    fix_hint="accept `clock=time.monotonic` (a reference, not a call) as a "
             "parameter and read `self._clock()` so VirtualClock/LoadHarness "
             "can substitute virtual time",
    description="direct time.time/monotonic/perf_counter call in timed paths",
    check=_check_rp003,
)


# ---- RP004: unseeded global np.random --------------------------------------

_SEEDED_FACTORIES = {"default_rng", "Generator", "RandomState",
                     "SeedSequence", "PCG64", "Philox"}


def _check_rp004(ctx: RuleContext) -> List[Finding]:
    rule = RP004
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            name = canonical(node.func, ctx.imports)
            if name and name.startswith("numpy.random.") \
                    and name.rsplit(".", 1)[1] not in _SEEDED_FACTORIES:
                out.append(_finding(
                    rule, ctx, node,
                    f"global `{name}()` draws from hidden process-wide "
                    f"state"))
    return out


RP004 = Rule(
    code="RP004", name="unseeded-global-random", scopes=(),
    fix_hint="thread an explicit `np.random.default_rng(seed)` Generator "
             "through the call path (harness/oracle runs must replay "
             "bit-exactly)",
    description="unseeded global np.random call",
    check=_check_rp004,
)


# ---- RP005: mutable default arguments --------------------------------------

_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                     ast.DictComp, ast.SetComp)
_MUTABLE_CALLS = {"list", "dict", "set", "bytearray",
                  "collections.defaultdict", "collections.deque",
                  "collections.Counter", "collections.OrderedDict"}


def _is_mutable_default(node: ast.AST, imports: Dict[str, str]) -> bool:
    if isinstance(node, _MUTABLE_LITERALS):
        return True
    if isinstance(node, ast.Call):
        name = canonical(node.func, imports)
        return name in _MUTABLE_CALLS
    return False


def _check_rp005(ctx: RuleContext) -> List[Finding]:
    rule = RP005
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        a = node.args
        pos = list(a.posonlyargs) + list(a.args)
        for arg, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
            if _is_mutable_default(default, ctx.imports):
                out.append(_finding(
                    rule, ctx, default,
                    f"mutable default for `{arg.arg}` in `{node.name}` is "
                    f"shared across calls"))
        for arg, default in zip(a.kwonlyargs, a.kw_defaults):
            if default is not None and \
                    _is_mutable_default(default, ctx.imports):
                out.append(_finding(
                    rule, ctx, default,
                    f"mutable default for `{arg.arg}` in `{node.name}` is "
                    f"shared across calls"))
    return out


RP005 = Rule(
    code="RP005", name="mutable-default-arg", scopes=(),
    fix_hint="default to None and materialize inside the function body",
    description="mutable default argument",
    check=_check_rp005,
)


# ---- RP006: hard-coded Pallas block/chunk shapes ---------------------------

_TUNED_PARAMS = {"block_m", "block_n", "block_k", "block", "chunk",
                 "chunk_q", "chunk_k"}
_BLOCKSPEC = {"jax.experimental.pallas.BlockSpec"}


def _literal_over_one(elt: ast.AST) -> bool:
    return isinstance(elt, ast.Constant) and isinstance(elt.value, int) \
        and not isinstance(elt.value, bool) and elt.value > 1


def _check_rp006(ctx: RuleContext) -> List[Finding]:
    rule = RP006
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = node.args
            pos = list(a.posonlyargs) + list(a.args)
            pairs = list(zip(pos[len(pos) - len(a.defaults):], a.defaults))
            pairs += [(arg, d) for arg, d in zip(a.kwonlyargs, a.kw_defaults)
                      if d is not None]
            for arg, default in pairs:
                if arg.arg in _TUNED_PARAMS and \
                        isinstance(default, ast.Constant) and \
                        isinstance(default.value, int) and \
                        not isinstance(default.value, bool):
                    out.append(_finding(
                        rule, ctx, default,
                        f"`{node.name}` pins `{arg.arg}={default.value}` — "
                        f"a literal default bypasses the tuning tables and "
                        f"breaks divisibility on off-table shapes"))
        elif isinstance(node, ast.Call):
            name = canonical(node.func, ctx.imports)
            if name in _BLOCKSPEC and node.args:
                shape = node.args[0]
                if isinstance(shape, (ast.Tuple, ast.List)) and \
                        any(_literal_over_one(e) for e in shape.elts):
                    out.append(_finding(
                        rule, ctx, node,
                        "BlockSpec hard-codes a block edge > 1 — take it "
                        "from tuning.select_blocks/select_chunk (scalar "
                        "`(1, 1)` accumulator tiles are fine)"))
    return out


RP006 = Rule(
    code="RP006", name="hardcoded-pallas-blocks", scopes=("kernels",),
    fix_hint="default block/chunk params to None and resolve via "
             "tuning.select_blocks/select_chunk (BLOCK_TABLE/CHUNK_TABLE), "
             "then assert divisibility with tuning.assert_divides",
    description="Pallas BlockSpec/grid shape bypassing tuning tables",
    check=_check_rp006,
)


# ---- RP007: swallowed exceptions in the serving/hardware path ---------------

_BROAD_EXC = {"Exception", "BaseException"}


def _swallows(body: Sequence[ast.stmt]) -> bool:
    """A handler body that only `pass`es (or `...`s) discards the fault."""
    for stmt in body:
        if isinstance(stmt, ast.Pass):
            continue
        if isinstance(stmt, ast.Expr) and \
                isinstance(stmt.value, ast.Constant) and \
                stmt.value.value is Ellipsis:
            continue
        return False
    return True


def _check_rp007(ctx: RuleContext) -> List[Finding]:
    rule = RP007
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            out.append(_finding(
                rule, ctx, node,
                "bare `except:` catches everything (KeyboardInterrupt, "
                "SystemExit, pump shutdown) — faults vanish silently"))
            continue
        types = node.type.elts if isinstance(node.type, ast.Tuple) \
            else [node.type]
        broad = [canonical(t, ctx.imports) for t in types
                 if canonical(t, ctx.imports) in _BROAD_EXC]
        if broad and _swallows(node.body):
            out.append(_finding(
                rule, ctx, node,
                f"`except {', '.join(broad)}` with a pass-only body "
                f"swallows the fault instead of surfacing or handling it"))
    return out


RP007 = Rule(
    code="RP007", name="swallowed-exception",
    scopes=("serve", "server", "hwloop"),
    fix_hint="catch the narrowest exception type the contract allows "
             "(narrow-typed `except ...: pass` is fine), or handle the "
             "fault and surface it through telemetry/re-raise; intentional "
             "broad catches need `# lint: allow=RP007 <reason>`",
    description="bare or pass-only broad except in serve/server/hwloop",
    check=_check_rp007,
)


def _check_rp008(ctx: RuleContext) -> List[Finding]:
    rule = RP008
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Name) and node.func.id == "print":
            out.append(_finding(
                rule, ctx, node,
                "bare `print()` bypasses the obs event/metric path — it is "
                "invisible to the flight recorder and corrupts NDJSON trace "
                "streams on stdout"))
    return out


RP008 = Rule(
    code="RP008", name="bare-print",
    scopes=("serve", "server", "hwloop", "resilience", "obs"),
    fix_hint="emit through `obs.event(...)`/a registry metric, or return the "
             "payload to the CLI layer (`repro.launch`) which owns stdout; "
             "intentional CLI prints need `# lint: allow=RP008 <reason>`",
    description="bare print() in serve/server/hwloop/resilience/obs",
    check=_check_rp008,
)


# ---- RP009: rail writes bypassing the guardband clamp ----------------------

_RAIL_SETTERS = {"set_rails", "set_partition_voltage"}


def _check_rp009(ctx: RuleContext) -> List[Finding]:
    rule = RP009
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in _RAIL_SETTERS:
            out.append(_finding(
                rule, ctx, node,
                f"direct `.{node.func.attr}()` skips the guardband clamp "
                f"(envelope bound, dwell timer, max step) — the autoscaler "
                f"and watchdog can end up fighting over the rails"))
    return out


RP009 = Rule(
    code="RP009", name="unclamped-rail-write",
    scopes=("railscale", "serve"),
    fix_hint="actuate through repro.railscale.GuardbandClamp "
             "(`clamp.apply(session, target_v, step)` / `clamp.snap`) so "
             "every rail write is envelope-bounded, dwell-limited, and "
             "step-limited; the clamp's own writes carry "
             "`# lint: allow=RP009 <reason>`",
    description="direct set_rails/set_partition_voltage in railscale/serve",
    check=_check_rp009,
)


RULES: Tuple[Rule, ...] = (RP001, RP002, RP003, RP004, RP005, RP006, RP007,
                           RP008, RP009)


def rule_codes() -> List[str]:
    return [r.code for r in RULES]
