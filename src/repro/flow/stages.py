"""The pluggable stages of the paper's Fig. 9 flow.

Each stage is a pure ``(Artifacts, FlowConfig) -> Artifacts`` step that only
*adds* named artifacts; ``requires``/``provides`` declare its dataflow and
``config_keys`` names the config fields that can change its output (the
basis of artifact-prefix caching — see :mod:`repro.flow.pipeline`).

The default stage chain reproduces ``repro.core.cadflow.run_flow`` bit for
bit: TimingStage -> ClusterStage -> FloorplanStage -> StaticVoltageStage ->
RuntimeCalibrationStage -> PowerStage -> ConstraintsStage.  Users may
replace, insert or skip stages via :class:`repro.flow.Pipeline`.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple, Type

import numpy as np

from ..core import clustering as cl
from ..core import clustering_ref as cl_ref
from ..core.constraints import generate_sdc, generate_xdc
from ..core.partition import grid_floorplan, partition_min_slack
from ..core.power import model_for
from ..core.razor import RazorConfig
from ..core.systolic import SystolicSim
from ..core.timing import TimingModel
from ..core.voltage import (RuntimeScheme, assign_partition_voltages,
                            static_voltage_scaling)
from .artifacts import Artifacts
from .config import FlowConfig


class Stage:
    """Base class: a named, pure pipeline step.

    Subclasses set the class attributes and implement :meth:`run`.  A stage
    must only read artifacts named in ``requires`` and config fields named in
    ``config_keys`` — the caching layer relies on those declarations.
    """

    name: str = "stage"
    requires: Tuple[str, ...] = ()
    provides: Tuple[str, ...] = ()
    config_keys: Tuple[str, ...] = ()
    # opt-in: cache this stage's output on the *values* of its required
    # artifacts (+ its own config fields) instead of the upstream config
    # prefix — sound exactly because of the requires/config_keys contract
    # above.  See Pipeline._store_key.
    content_cache: bool = False

    def run(self, art: Artifacts, cfg: FlowConfig) -> Artifacts:
        raise NotImplementedError

    def __call__(self, art: Artifacts, cfg: FlowConfig) -> Artifacts:
        return self.run(art, cfg)

    def cache_token(self) -> str:
        """Identity of this stage *implementation* for artifact caching.

        Two stages sharing a name but differing in behaviour (e.g. the
        default ``cluster`` vs a user replacement) must not share cached
        outputs; the token is folded into the store key of this stage and
        every stage downstream of it."""
        return f"{type(self).__module__}.{type(self).__qualname__}"

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


class FunctionStage(Stage):
    """Wrap a plain ``(Artifacts, config) -> Artifacts`` function as a stage —
    the one-liner way to inject custom behaviour into a pipeline."""

    def __init__(self, name: str, fn: Callable[[Artifacts, Any], Artifacts],
                 requires: Tuple[str, ...] = (),
                 provides: Tuple[str, ...] = (),
                 config_keys: Tuple[str, ...] = ()):
        self.name = name
        self._fn = fn
        self.requires = tuple(requires)
        self.provides = tuple(provides)
        self.config_keys = tuple(config_keys)

    def run(self, art: Artifacts, cfg: Any) -> Artifacts:
        return self._fn(art, cfg)

    def cache_token(self) -> str:
        # qualnames collide for distinct lambdas, so pin the exact function
        # object; an id() is only unique within this process, which matches
        # the in-memory lifetime of an ArtifactStore
        fn = self._fn
        return f"{fn.__module__}.{fn.__qualname__}@{id(fn)}"


# ---------------------------------------------------------------------------
# Stage registry
# ---------------------------------------------------------------------------

STAGE_REGISTRY: Dict[str, Type[Stage]] = {}


def register_stage(cls: Type[Stage]) -> Type[Stage]:
    """Class decorator: make a stage constructible by name via
    :func:`get_stage` (and hence from the CLI / saved configs)."""
    STAGE_REGISTRY[cls.name] = cls
    return cls


def get_stage(name: str) -> Stage:
    try:
        return STAGE_REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown stage {name!r}; registered: "
                       f"{sorted(STAGE_REGISTRY)}") from None


# ---------------------------------------------------------------------------
# Default stages (paper Fig. 9, in order)
# ---------------------------------------------------------------------------


@register_stage
class TimingStage(Stage):
    """Synthesis timing (Sec. II-A/II-B): build the slack model."""

    name = "timing"
    provides = ("timing_model", "slack")
    config_keys = ("array_n", "tech", "clock_ns", "seed")

    def run(self, art: Artifacts, cfg: FlowConfig) -> Artifacts:
        tm = TimingModel(n=cfg.array_n, clock_ns=cfg.clock_ns, tech=cfg.node,
                         seed=cfg.seed)
        return art.with_(timing_model=tm, slack=tm.min_slack_flat())


def cluster_slack(slack: np.ndarray, algo: str, n_clusters: Optional[int],
                  seed: int, params: Optional[Dict[str, Any]] = None,
                  impl: str = "vectorized") -> np.ndarray:
    """Run the chosen algorithm with paper-consistent defaults and fold noise.

    ``params`` overrides the defaults (bandwidth / eps / min_pts / linkage /
    k).  Labels are relabelled so cluster 0 has the highest slack.
    ``impl`` selects the vectorized implementations (default) or the loop
    oracles in :mod:`repro.core.clustering_ref` — bit-identical labels,
    orders of magnitude apart in wall clock.
    """
    mod = cl if impl == "vectorized" else cl_ref
    algo = algo.lower()
    params = dict(params or {})
    spread = float(slack.max() - slack.min()) or 1.0
    if algo in ("kmeans", "k-means"):
        labels = mod.kmeans(slack, k=params.pop("k", n_clusters or 4),
                            seed=params.pop("seed", seed), **params)
    elif algo in ("hierarchical", "hierarchy"):
        labels = mod.hierarchical(slack, n_clusters=params.pop("k", n_clusters or 4),
                                  **params)
    elif algo in ("meanshift", "mean-shift"):
        # the paper's radius 0.4 on its ~2.4 ns 16x16 slack spread, rescaled
        labels = mod.meanshift(slack,
                               bandwidth=params.pop("bandwidth", 0.17 * spread),
                               **params)
    elif algo == "dbscan":
        labels = mod.dbscan(slack, eps=params.pop("eps", spread / 12.0),
                            min_pts=params.pop("min_pts",
                                               max(4, len(slack) // 64)),
                            **params)
        labels = mod.attach_noise_to_nearest(slack, labels)
    else:
        raise ValueError(f"unknown algorithm {algo!r}")
    return mod.relabel_by_feature_mean(slack, labels)   # 0 = highest slack


@register_stage
class ClusterStage(Stage):
    """Min-slack clustering (Sec. IV).  Density-based algorithms (mean-shift,
    DBSCAN) choose their own partition count, so the stage reports both the
    *requested* count (``n_partitions_requested`` — what the config asked
    for, possibly None) and the *actual* one (``n_partitions``) instead of
    silently diverging."""

    name = "cluster"
    requires = ("slack",)
    provides = ("labels", "n_partitions", "n_partitions_requested")
    config_keys = ("algo", "n_clusters", "seed", "algo_params", "impl")
    # the synthesized slack structure is tech-independent, so content keying
    # shares one clustering per algorithm across every tech node of a sweep
    content_cache = True

    def run(self, art: Artifacts, cfg: FlowConfig) -> Artifacts:
        labels = cluster_slack(art.slack, cfg.algo, cfg.n_clusters, cfg.seed,
                               dict(cfg.algo_params), impl=cfg.impl)
        return art.with_(labels=labels,
                         n_partitions=int(labels.max()) + 1,
                         n_partitions_requested=cfg.n_clusters)


@register_stage
class FloorplanStage(Stage):
    """Cluster -> voltage-island placement (Sec. II-C, Fig. 8)."""

    name = "floorplan"
    requires = ("labels",)
    provides = ("floorplan",)
    config_keys = ("array_n",)
    content_cache = True                 # same labels -> same floorplan

    def run(self, art: Artifacts, cfg: FlowConfig) -> Artifacts:
        return art.with_(floorplan=grid_floorplan(art.labels, cfg.array_n))


@register_stage
class StaticVoltageStage(Stage):
    """Algorithm 1: ascending band-midpoint voltages; the highest-slack
    cluster (label 0) takes the lowest rail."""

    name = "static_voltage"
    requires = ("slack", "labels", "n_partitions", "floorplan")
    provides = ("static_v", "partition_slack", "floorplan_static")
    config_keys = ("tech", "v_min", "v_crash")

    def run(self, art: Artifacts, cfg: FlowConfig) -> Artifacts:
        v_bands = static_voltage_scaling(cfg.resolved_v_min(),
                                         cfg.resolved_v_crash(),
                                         art.n_partitions)
        part_slack = partition_min_slack(art.labels, art.slack)
        static_v = assign_partition_voltages(part_slack, v_bands)
        return art.with_(static_v=static_v, partition_slack=part_slack,
                         floorplan_static=art.floorplan.with_voltages(static_v))


@register_stage
class RuntimeCalibrationStage(Stage):
    """Algorithm 2 + Razor trial runs on the fault-injecting simulator.

    Adds ``calibration_converged`` (per-partition bool: False where no clean
    trial was ever observed and the rail was pinned at V_ceil) alongside the
    calibrated ``runtime_v``.  With ``calibrate=False`` the stage passes the
    static voltages through unchanged (zero trials).
    """

    name = "runtime_calibration"
    requires = ("timing_model", "static_v", "n_partitions", "floorplan_static")
    provides = ("runtime_v", "razor_trials", "calibrated_fail_free",
                "calibration_converged", "floorplan_runtime")
    config_keys = ("tech", "v_min", "v_crash", "clock_ns", "seed",
                   "calibration_seed", "calibrate", "max_trials",
                   "flag_reduce", "impl", "calibration_method")

    def run(self, art: Artifacts, cfg: FlowConfig) -> Artifacts:
        v_min, v_crash = cfg.resolved_v_min(), cfg.resolved_v_crash()
        cal_seed = cfg.resolved_calibration_seed()
        sim = SystolicSim(art.timing_model, art.floorplan_static,
                          RazorConfig(clock_ns=cfg.clock_ns), impl=cfg.impl)
        static_v = art.static_v
        runtime_v = static_v.copy()
        converged = np.ones(art.n_partitions, dtype=bool)
        trials = 0
        fail_free = True
        if cfg.calibrate:
            scheme = RuntimeScheme(
                v_s=(v_min - v_crash) / art.n_partitions,
                v_floor=v_crash, v_ceil=max(v_min, cfg.node.v_nom),
                flag_reduce=cfg.flag_reduce)

            def trial(v: np.ndarray) -> np.ndarray:
                nonlocal trials
                trials += 1
                return sim.trial_run(v, seed=cal_seed + trials)

            if cfg.calibration_method == "bisect":
                result = scheme.calibrate_bisect(static_v, trial,
                                                 max_trials=cfg.max_trials)
            else:
                result = scheme.calibrate(static_v, trial,
                                          max_trials=cfg.max_trials)
            runtime_v = np.asarray(result)
            converged = result.converged
            fail_free = not sim.trial_run(runtime_v,
                                          seed=cal_seed + 10_000).any()
        return art.with_(
            runtime_v=runtime_v, razor_trials=trials,
            calibrated_fail_free=bool(fail_free),
            calibration_converged=converged,
            floorplan_runtime=art.floorplan.with_voltages(runtime_v))


@register_stage
class PowerStage(Stage):
    """Calibrated power model (Sec. V-C / Table II): baseline vs static vs
    runtime.  When the calibration stage was skipped, the runtime numbers
    fall back to the static voltages."""

    name = "power"
    requires = ("labels", "n_partitions", "static_v")
    provides = ("baseline_mw", "static_mw", "runtime_mw",
                "static_reduction_pct", "runtime_reduction_pct")
    config_keys = ("array_n", "tech", "freq_mhz", "activity", "impl")

    def run(self, art: Artifacts, cfg: FlowConfig) -> Artifacts:
        if cfg.impl == "reference":
            # seed-faithful baseline: per-run interpreted exponent fit
            from ..core.power import fit_power_exponent_ref
            pm = model_for(cfg.tech, k=fit_power_exponent_ref(cfg.tech),
                           freq_mhz=cfg.freq_mhz, activity=cfg.activity)
        else:
            pm = model_for(cfg.tech, freq_mhz=cfg.freq_mhz,
                           activity=cfg.activity)
        runtime_v = art.get("runtime_v", art.static_v)
        frac = np.bincount(art.labels, minlength=art.n_partitions) / art.labels.size
        baseline = pm.baseline_mw(cfg.array_n, cfg.node.v_nom)
        static_mw = pm.partitioned_mw(cfg.array_n, art.static_v, frac,
                                      v_ref=cfg.node.v_nom)
        runtime_mw = pm.partitioned_mw(cfg.array_n, runtime_v, frac,
                                       v_ref=cfg.node.v_nom)
        return art.with_(
            baseline_mw=baseline, static_mw=static_mw, runtime_mw=runtime_mw,
            static_reduction_pct=100.0 * (1 - static_mw / baseline),
            runtime_reduction_pct=100.0 * (1 - runtime_mw / baseline))


@register_stage
class ConstraintsStage(Stage):
    """Constraint-file artifacts (Sec. II-C step 3).  Matches the monolith:
    XDC/SDC are rendered from the *static*-voltage floorplan (the files the
    flow hands to the vendor tool before runtime tuning exists)."""

    name = "constraints"
    requires = ("floorplan_static",)
    provides = ("xdc", "sdc")
    config_keys = ("clock_ns",)

    def run(self, art: Artifacts, cfg: FlowConfig) -> Artifacts:
        return art.with_(xdc=generate_xdc(art.floorplan_static, cfg.clock_ns),
                         sdc=generate_sdc(art.floorplan_static, cfg.clock_ns))


@register_stage
class HwLoopStage(Stage):
    """Hardware-in-the-loop emulation: execute probe inference traffic on
    the calibrated voltage islands through the ``repro.backend`` execution
    protocol, yielding the voltage→(accuracy-proxy, energy/token,
    replay-rate) observables that close the loop between the CAD flow and
    real inference.

    ``cfg.backend`` selects the execution target: ``"emulated"`` (default)
    is the fault-injecting accelerator with the energy ledger;
    ``"simulated"`` runs the cycle-level :class:`SystolicSim` at the same
    calibrated rails (flags/silent observables, no energy model);
    ``"ideal"``/``"reference"`` are the exact baselines (zero flags).

    Opt-in: not part of :data:`DEFAULT_STAGE_NAMES`; insert it after
    ``power`` (``repro.hwloop.hwloop_pipeline()`` does exactly that) so
    ``sweep()`` produces Pareto tables across tech nodes.
    """

    name = "hwloop"
    requires = ("timing_model", "floorplan_runtime", "n_partitions")
    provides = ("hwloop_energy_per_token_j", "hwloop_energy_per_mac_j",
                "hwloop_replay_rate", "hwloop_flag_rate",
                "hwloop_silent_rate", "hwloop_rel_error")
    config_keys = ("array_n", "tech", "clock_ns", "freq_mhz", "activity",
                   "seed", "calibration_seed", "hwloop_steps", "hwloop_rows",
                   "hwloop_corruption", "backend")

    def _backend(self, art: Artifacts, cfg: FlowConfig):
        # imported lazily: repro.backend's emulated impl reaches into
        # repro.hwloop, which imports repro.flow at package level
        from ..backend import get_backend
        from ..backend.impls import EmulatedBackend, SimulatedBackend
        if cfg.backend == "emulated":
            from ..hwloop.device import EmulatedAccelerator
            return EmulatedBackend(EmulatedAccelerator(
                art.timing_model, art.floorplan_runtime,
                razor=RazorConfig(clock_ns=cfg.clock_ns),
                power=model_for(cfg.tech, freq_mhz=cfg.freq_mhz,
                                activity=cfg.activity),
                corruption=cfg.hwloop_corruption))
        if cfg.backend == "simulated":
            return SimulatedBackend(SystolicSim(
                art.timing_model, art.floorplan_runtime,
                RazorConfig(clock_ns=cfg.clock_ns)))
        return get_backend(cfg.backend)

    def run(self, art: Artifacts, cfg: FlowConfig) -> Artifacts:
        be = self._backend(art, cfg)
        rng = np.random.default_rng(cfg.resolved_calibration_seed() + 99_991)
        n = cfg.array_n
        flags = np.zeros(art.n_partitions, dtype=np.float64)
        silent = 0
        rel_errors = []
        for _ in range(cfg.hwloop_steps):
            a = rng.normal(size=(cfg.hwloop_rows, n))
            w = rng.normal(size=(n, n))
            _, tel = be.matmul(a, w)
            if tel.partition_flags is not None:
                flags += np.asarray(tel.partition_flags, dtype=np.float64)
            silent += tel.silent
            rel_errors.append(tel.rel_error)
        be.add_tokens(cfg.hwloop_steps)  # one probe step ~ one served token
        led = getattr(be, "ledger", None)
        total_macs = max(be.total.macs, 1)
        return art.with_(
            hwloop_energy_per_token_j=(led.energy_per_token_j
                                       if led is not None else None),
            hwloop_energy_per_mac_j=(led.energy_per_mac_j
                                     if led is not None else None),
            hwloop_replay_rate=(led.replay_rate if led is not None
                                else be.total.replays / total_macs),
            hwloop_flag_rate=(flags / cfg.hwloop_steps).tolist(),
            hwloop_silent_rate=silent / total_macs,
            hwloop_rel_error=float(np.mean(rel_errors)))


#: Canonical stage order of the paper's flow.
DEFAULT_STAGE_NAMES: Tuple[str, ...] = (
    "timing", "cluster", "floorplan", "static_voltage",
    "runtime_calibration", "power", "constraints")


def default_stages() -> Tuple[Stage, ...]:
    """Fresh instances of the canonical Fig. 9 stage chain."""
    return tuple(get_stage(n) for n in DEFAULT_STAGE_NAMES)
