"""Flat report view over the pipeline's artifacts.

``FlowReport`` is the stable result object callers have always received from
``repro.core.cadflow.run_flow``; it now lives here and is assembled from a
:class:`~repro.flow.artifacts.Artifacts` value via :func:`report_from`.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

import numpy as np

from ..core.partition import Floorplan
from .artifacts import Artifacts

if TYPE_CHECKING:  # avoid a circular import with repro.core.cadflow's shim
    from .config import FlowConfig


@dataclasses.dataclass
class FlowReport:
    array_n: int
    tech: str
    algo: str
    n_partitions: int
    labels: np.ndarray                   # (n*n,) cluster id per MAC
    min_slack: np.ndarray                # (n*n,)
    floorplan: Floorplan
    static_v: np.ndarray                 # (P,) Algorithm-1 voltages per partition
    runtime_v: np.ndarray                # (P,) after Algorithm-2 calibration
    baseline_mw: float
    static_mw: float
    runtime_mw: float
    static_reduction_pct: float
    runtime_reduction_pct: float
    xdc: str
    sdc: str
    razor_trials: int
    calibrated_fail_free: bool
    # requested cluster count (None when the algorithm picks its own — the
    # density-based ones) vs the actual n_partitions above
    n_partitions_requested: Optional[int] = None
    # (P,) bool — False where Algorithm-2 never saw a clean trial and the
    # rail was pinned at V_ceil (see voltage.CalibrationResult)
    calibration_converged: Optional[np.ndarray] = None
    # hardware-in-the-loop emulation observables (the opt-in "hwloop" stage;
    # None when the stage did not run)
    hwloop_energy_per_token_j: Optional[float] = None
    hwloop_energy_per_mac_j: Optional[float] = None
    hwloop_replay_rate: Optional[float] = None
    hwloop_flag_rate: Optional[list] = None          # (P,) per-partition
    hwloop_silent_rate: Optional[float] = None
    hwloop_rel_error: Optional[float] = None         # accuracy proxy

    def summary(self) -> str:
        part = (f"P={self.n_partitions}"
                if self.n_partitions_requested in (None, self.n_partitions)
                else f"P={self.n_partitions}"
                     f"(req {self.n_partitions_requested})")
        return (f"{self.array_n}x{self.array_n} {self.tech} {self.algo} "
                f"{part} static {self.static_reduction_pct:.2f}% "
                f"runtime {self.runtime_reduction_pct:.2f}% "
                f"(baseline {self.baseline_mw:.0f} mW)")


def report_from(art: Artifacts, cfg: "FlowConfig") -> FlowReport:
    """Assemble the flat report from pipeline artifacts.

    Tolerates skipped stages: without the calibration stage, runtime numbers
    mirror the static scheme; without the constraints stage, ``xdc``/``sdc``
    are empty strings.
    """
    static_v = art.static_v
    runtime_v = art.get("runtime_v", static_v)
    fp = art.get("floorplan_runtime",
                 art.get("floorplan_static", art.floorplan))
    return FlowReport(
        array_n=cfg.array_n, tech=cfg.tech, algo=cfg.algo,
        n_partitions=art.n_partitions,
        labels=art.labels, min_slack=art.slack, floorplan=fp,
        static_v=static_v, runtime_v=runtime_v,
        baseline_mw=art.baseline_mw, static_mw=art.static_mw,
        runtime_mw=art.runtime_mw,
        static_reduction_pct=art.static_reduction_pct,
        runtime_reduction_pct=art.runtime_reduction_pct,
        xdc=art.get("xdc", ""), sdc=art.get("sdc", ""),
        razor_trials=art.get("razor_trials", 0),
        calibrated_fail_free=art.get("calibrated_fail_free", True),
        n_partitions_requested=art.get("n_partitions_requested"),
        calibration_converged=art.get("calibration_converged"),
        hwloop_energy_per_token_j=art.get("hwloop_energy_per_token_j"),
        hwloop_energy_per_mac_j=art.get("hwloop_energy_per_mac_j"),
        hwloop_replay_rate=art.get("hwloop_replay_rate"),
        hwloop_flag_rate=art.get("hwloop_flag_rate"),
        hwloop_silent_rate=art.get("hwloop_silent_rate"),
        hwloop_rel_error=art.get("hwloop_rel_error"),
    )
