"""CLI for the staged CAD flow.

    PYTHONPATH=src python -m repro.flow run [--tech vivado-28nm] [--algo dbscan]
    PYTHONPATH=src python -m repro.flow sweep --tech vivado-28nm,vtr-22nm \
        --algo kmeans,dbscan --array-n 16

``run`` executes one config and prints the report (summary, voltages,
power); ``sweep`` fans a grid through the shared-cache pipeline and prints
the tidy comparison table plus cache statistics.  ``--config file.json``
loads a serialized ``FlowConfig`` (CLI flags override it).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional

import numpy as np

from . import FlowConfig, run, sweep
from .config import KNOWN_ALGOS
from ..core.timing import TECH_NODES


def _csv(kind):
    def parse(s: str) -> List:
        return [kind(x) for x in s.split(",") if x]
    return parse


def _add_config_flags(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--config", type=str, default=None,
                    help="JSON file with a serialized FlowConfig")
    ap.add_argument("--clock-ns", type=float, default=None)
    ap.add_argument("--n-clusters", type=int, default=None)
    ap.add_argument("--max-trials", type=int, default=None)
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the Razor runtime-calibration stage")
    ap.add_argument("--points-out", type=str, default=None, metavar="FILE",
                    help="distill each report into a railscale operating-"
                         "point table (nominal down to calibrated rails) "
                         "and write the JSON ladder file here")
    ap.add_argument("--points-levels", type=int, default=4,
                    help="rungs per operating-point ladder (default 4)")
    ap.add_argument("--points-probe-steps", type=int, default=6,
                    help="probe matmuls per rung when characterizing "
                         "energy/flag rates (default 6)")


def _base_config(args: argparse.Namespace,
                 extra: Optional[Dict[str, Any]] = None) -> FlowConfig:
    d: Dict[str, Any] = {}
    if args.config:
        with open(args.config) as f:
            d.update(json.load(f))
    for field, flag in (("clock_ns", "clock_ns"), ("n_clusters", "n_clusters"),
                        ("max_trials", "max_trials")):
        v = getattr(args, flag)
        if v is not None:
            d[field] = v
    if args.no_calibrate:
        d["calibrate"] = False
    d.update(extra or {})
    return FlowConfig.from_dict(d)


def _cmd_run(args: argparse.Namespace) -> int:
    cfg = _base_config(args, {"array_n": args.array_n, "tech": args.tech,
                              "algo": args.algo, "seed": args.seed})
    rep = run(cfg)
    print(rep.summary())
    req = rep.n_partitions_requested
    print(f"partitions: {rep.n_partitions}"
          + ("" if req in (None, rep.n_partitions) else f" (requested {req})"))
    print("static  V_ccint:", np.round(rep.static_v, 4).tolist())
    print("runtime V_ccint:", np.round(rep.runtime_v, 4).tolist())
    if rep.calibration_converged is not None:
        print("converged:      ", rep.calibration_converged.tolist())
    print(f"razor trials: {rep.razor_trials}  "
          f"fail-free: {rep.calibrated_fail_free}")
    print(f"power: baseline {rep.baseline_mw:.1f} mW  "
          f"static {rep.static_mw:.1f} mW ({rep.static_reduction_pct:.2f}%)  "
          f"runtime {rep.runtime_mw:.1f} mW ({rep.runtime_reduction_pct:.2f}%)")
    if args.emit_xdc:
        print(rep.xdc)
    if args.points_out:
        _write_points(args, [(cfg, rep)])
    return 0


def _write_points(args: argparse.Namespace, runs) -> None:
    """Distill (config, report) pairs into serialized operating-point
    ladders — the ``repro.railscale`` policies load these instead of
    rerunning the CAD flow."""
    from ..railscale import OperatingPointTable, save_tables

    tables = [OperatingPointTable.characterize(
        rep, cfg, n_levels=args.points_levels,
        probe_steps=args.points_probe_steps, seed=cfg.seed)
        for cfg, rep in runs]
    save_tables(args.points_out, tables)
    print(f"# wrote {len(tables)} operating-point table"
          f"{'s' if len(tables) != 1 else ''} "
          f"({args.points_levels} levels each) -> {args.points_out}")


def _cmd_sweep(args: argparse.Namespace) -> int:
    base = _base_config(args, {"seed": args.seed})
    grid = {"tech": args.tech, "array_n": args.array_n, "algo": args.algo}
    result = sweep(grid, base)
    print(result.table())
    print()
    print(f"# {len(result.configs)} configs; timing stage executed "
          f"{result.timing_stage_runs()}x; cache: {result.store.summary()}")
    best = result.best()
    print(f"# best runtime reduction: {best['tech']} {best['algo']} "
          f"{best['array_n']}x{best['array_n']} "
          f"-> {best['runtime_reduction_pct']:.2f}%")
    if args.points_out:
        _write_points(args, list(zip(result.configs, result.reports)))
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.flow",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_run = sub.add_parser("run", help="execute one flow config")
    p_run.add_argument("--array-n", type=int, default=16)
    p_run.add_argument("--tech", choices=sorted(TECH_NODES), default="vivado-28nm")
    p_run.add_argument("--algo", choices=KNOWN_ALGOS, default="dbscan")
    p_run.add_argument("--seed", type=int, default=2021)
    p_run.add_argument("--emit-xdc", action="store_true",
                       help="print the generated XDC constraints")
    _add_config_flags(p_run)
    p_run.set_defaults(fn=_cmd_run)

    p_sweep = sub.add_parser("sweep", help="fan a config grid through the "
                                           "pipeline with shared caching")
    p_sweep.add_argument("--tech", type=_csv(str),
                         default=list(sorted(TECH_NODES)))
    p_sweep.add_argument("--algo", type=_csv(str), default=list(KNOWN_ALGOS))
    p_sweep.add_argument("--array-n", type=_csv(int), default=[16])
    p_sweep.add_argument("--seed", type=int, default=2021)
    _add_config_flags(p_sweep)
    p_sweep.set_defaults(fn=_cmd_sweep)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except BrokenPipeError:        # e.g. `... | head` closed the pipe
        return 0


if __name__ == "__main__":
    sys.exit(main())
