"""Multi-scenario sweep driver.

``sweep()`` fans a grid of :class:`~repro.flow.config.FlowConfig` operating
points (tech node x clustering algorithm x array size x ...) through one
pipeline with a *shared* artifact store, so expensive prefixes — above all
the timing stage — are computed once per distinct ``(tech, array_n,
clock_ns, seed)`` and reused by every config that shares them.  The result
is a tidy comparison table (list-of-dicts + text rendering).
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Union

from .artifacts import ArtifactStore
from .config import FlowConfig
from .pipeline import Pipeline
from .report import FlowReport, report_from

#: The tidy columns every sweep row carries.
ROW_COLUMNS = ("tech", "algo", "array_n", "seed", "n_partitions",
               "n_partitions_requested", "baseline_mw", "static_mw",
               "runtime_mw", "static_reduction_pct", "runtime_reduction_pct",
               "razor_trials", "calibrated_fail_free")

#: Extra columns added when the opt-in ``hwloop`` emulation stage ran — the
#: voltage→(energy/token, replay-rate, accuracy-proxy) Pareto observables.
HWLOOP_COLUMNS = ("hwloop_energy_per_token_j", "hwloop_replay_rate",
                  "hwloop_flag_rate", "hwloop_silent_rate",
                  "hwloop_rel_error")


def expand_grid(grid: Mapping[str, Sequence[Any]],
                base: Optional[FlowConfig] = None) -> List[FlowConfig]:
    """Cartesian product of ``{config_field: [values...]}`` over ``base``.

    Axis insertion order is preserved; the *last* axis varies fastest — put
    cheap-to-vary fields (algo) after expensive ones (tech, array_n) so
    consecutive runs share cached prefixes.
    """
    base = base or FlowConfig()
    axes = [(k, list(v)) for k, v in grid.items()]
    for k, vals in axes:
        if not hasattr(base, k):
            raise ValueError(f"unknown FlowConfig field {k!r} in sweep grid")
        if not vals:
            raise ValueError(f"sweep axis {k!r} is empty")
    out = []
    for combo in itertools.product(*(v for _, v in axes)):
        out.append(base.replace(**dict(zip((k for k, _ in axes), combo))))
    return out


@dataclasses.dataclass
class SweepResult:
    configs: List[FlowConfig]
    reports: List[FlowReport]
    store: ArtifactStore
    # wall-clock seconds per config, in ``configs`` order (cache hits show up
    # as near-zero entries) — the raw data behind benchmarks' BENCH_flow.json
    elapsed_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def total_elapsed_s(self) -> float:
        return float(sum(self.elapsed_s))

    def _has_hwloop(self) -> bool:
        return any(r.hwloop_energy_per_token_j is not None
                   for r in self.reports)

    def rows(self) -> List[Dict[str, Any]]:
        """Tidy comparison rows, one per config (stable column set; the
        hwloop columns join when the emulation stage ran)."""
        out = []
        hwloop = self._has_hwloop()
        for cfg, rep in zip(self.configs, self.reports):
            row = {
                "tech": rep.tech, "algo": rep.algo, "array_n": rep.array_n,
                "seed": cfg.seed, "n_partitions": rep.n_partitions,
                "n_partitions_requested": rep.n_partitions_requested,
                "baseline_mw": rep.baseline_mw, "static_mw": rep.static_mw,
                "runtime_mw": rep.runtime_mw,
                "static_reduction_pct": rep.static_reduction_pct,
                "runtime_reduction_pct": rep.runtime_reduction_pct,
                "razor_trials": rep.razor_trials,
                "calibrated_fail_free": rep.calibrated_fail_free,
            }
            if hwloop:
                for c in HWLOOP_COLUMNS:
                    row[c] = getattr(rep, c)
            out.append(row)
        return out

    def best(self, key: str = "runtime_reduction_pct") -> Dict[str, Any]:
        return max(self.rows(), key=lambda r: r[key])

    def table(self, columns: Optional[Sequence[str]] = None) -> str:
        """Fixed-width text table of the tidy rows (hwloop columns appear
        automatically when the emulation stage ran)."""
        if columns is None:
            columns = ROW_COLUMNS + (HWLOOP_COLUMNS if self._has_hwloop()
                                     else ())
        rows = self.rows()
        cells = [[_fmt(r[c]) for c in columns] for r in rows]
        widths = [max(len(c), *(len(row[i]) for row in cells)) if cells
                  else len(c) for i, c in enumerate(columns)]
        lines = ["  ".join(c.ljust(w) for c, w in zip(columns, widths))]
        lines.append("  ".join("-" * w for w in widths))
        for row in cells:
            lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)))
        return "\n".join(lines)

    def timing_stage_runs(self) -> int:
        """How many times the timing stage actually executed across the sweep
        (== number of distinct (tech, array_n, clock_ns, seed) prefixes)."""
        return self.store.runs_of("timing")


def sweep(grid: Union[Mapping[str, Sequence[Any]], Iterable[FlowConfig]],
          base: Optional[FlowConfig] = None, *,
          pipeline: Optional[Pipeline] = None,
          store: Optional[ArtifactStore] = None) -> SweepResult:
    """Run every config of ``grid`` through the pipeline with shared caching.

    ``grid`` is either ``{field: [values...]}`` (expanded as a cartesian
    product over ``base``) or an explicit iterable of ``FlowConfig``s.
    """
    if isinstance(grid, Mapping):
        configs = expand_grid(grid, base)
    else:
        configs = list(grid)
        if base is not None:
            raise ValueError("base is only meaningful with a grid mapping")
    pipeline = pipeline or Pipeline()
    store = store or ArtifactStore()
    reports = []
    elapsed: List[float] = []
    for cfg in configs:
        t0 = time.perf_counter()
        art = pipeline.run(cfg, store=store)
        reports.append(report_from(art, cfg))
        elapsed.append(time.perf_counter() - t0)
    return SweepResult(configs=configs, reports=reports, store=store,
                       elapsed_s=elapsed)


def _fmt(v: Any) -> str:
    if isinstance(v, bool) or v is None:
        return str(v)
    if isinstance(v, float):
        # sub-centi values (energies in joules, rates) need sig-figs, not 0.00
        return f"{v:.3g}" if 0.0 < abs(v) < 0.01 else f"{v:.2f}"
    if isinstance(v, (list, tuple)):
        return "[" + ",".join(_fmt(float(x)) for x in v) + "]"
    return str(v)
