"""Declarative, serializable configuration for the staged CAD flow.

``FlowConfig`` captures every knob of the paper's Fig. 9 pipeline — array
size, technology node, clustering algorithm + parameters, voltage scheme
bounds, Razor/runtime calibration settings and the power model — as one
validated, hashable-by-value dataclass with ``to_dict``/``from_dict``
round-tripping, so configs can be stored, diffed and swept.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Mapping, Optional, Tuple

from ..core.timing import TECH_NODES, TechNode

#: Clustering algorithms the paper evaluates (Sec. IV), canonical spellings.
KNOWN_ALGOS: Tuple[str, ...] = ("kmeans", "hierarchical", "meanshift", "dbscan")

_ALGO_ALIASES = {
    "k-means": "kmeans", "kmeans": "kmeans",
    "hierarchy": "hierarchical", "hierarchical": "hierarchical",
    "mean-shift": "meanshift", "meanshift": "meanshift",
    "dbscan": "dbscan",
}


@dataclasses.dataclass(frozen=True)
class FlowConfig:
    """One operating point of the Fig. 9 flow.

    ``v_min``/``v_crash`` default to the tech node's values when ``None``
    (use :meth:`resolved_v_min`/:meth:`resolved_v_crash` for the effective
    numbers).  ``algo_params`` overrides the paper-consistent clustering
    defaults (e.g. ``{"bandwidth": 0.3}`` for mean-shift, ``{"eps": 0.2,
    "min_pts": 8}`` for DBSCAN, ``{"linkage": "complete"}`` for
    hierarchical).
    """

    array_n: int = 16
    tech: str = "vivado-28nm"
    algo: str = "dbscan"
    n_clusters: Optional[int] = 4
    clock_ns: float = 10.0
    seed: int = 2021
    v_min: Optional[float] = None
    v_crash: Optional[float] = None
    freq_mhz: float = 100.0
    calibrate: bool = True
    max_trials: int = 48
    # Razor trial-run RNG seed; None -> use ``seed``.  Kept separate so a
    # production recalibration can re-roll the trials without invalidating
    # the cached timing/clustering prefix (which keys on ``seed``).
    calibration_seed: Optional[int] = None
    flag_reduce: str = "or"              # Razor per-partition flag reduction
    activity: float = 0.5                # power-model toggle rate
    algo_params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    # Hot-path implementation: "vectorized" (default) uses the array-programming
    # clustering + simulator; "reference" runs the bit-exact loop oracles
    # (clustering_ref / SystolicSim reference propagation) — the perf baseline
    # of benchmarks/run.py's ``flow`` scenario.
    impl: str = "vectorized"
    # Razor calibration: "anneal" = the paper's Algorithm-2 trial-run walk;
    # "bisect" = batched per-partition bisection (fewer trials, same rails up
    # to the step/tolerance difference)
    calibration_method: str = "anneal"
    # hwloop emulation stage (repro.hwloop, opt-in via the "hwloop" stage):
    # probe-traffic steps, streamed activation rows per step, and the
    # silent-failure corruption model (see repro.hwloop.inject)
    hwloop_steps: int = 8
    hwloop_rows: int = 32
    hwloop_corruption: str = "stale"
    # execution backend (repro.backend registry) the hwloop stage runs its
    # inference traffic on: "emulated" (default — the calibrated
    # fault-injecting accelerator with energy accounting), "simulated"
    # (cycle-level SystolicSim at the calibrated rails), or
    # "ideal"/"reference" (exact baselines: zero flags, no energy model)
    backend: str = "emulated"

    def __post_init__(self) -> None:
        object.__setattr__(self, "algo",
                           _ALGO_ALIASES.get(str(self.algo).lower(),
                                             str(self.algo).lower()))
        # freeze algo_params into a plain dict copy so the config is stable
        object.__setattr__(self, "algo_params", dict(self.algo_params))
        self.validate()

    # -- validation ----------------------------------------------------------

    def validate(self) -> None:
        if self.tech not in TECH_NODES:
            raise ValueError(f"unknown tech node {self.tech!r}; "
                             f"known: {sorted(TECH_NODES)}")
        if self.algo not in KNOWN_ALGOS:
            raise ValueError(f"unknown clustering algorithm {self.algo!r}; "
                             f"known: {KNOWN_ALGOS}")
        if self.array_n <= 0:
            raise ValueError("array_n must be positive")
        if self.n_clusters is not None and self.n_clusters <= 0:
            raise ValueError("n_clusters must be positive (or None)")
        if self.clock_ns <= 0:
            raise ValueError("clock_ns must be positive")
        if self.freq_mhz <= 0:
            raise ValueError("freq_mhz must be positive")
        if self.max_trials < 0:
            raise ValueError("max_trials must be >= 0")
        if self.flag_reduce not in ("or", "and"):
            raise ValueError("flag_reduce must be 'or' or 'and'")
        if self.impl not in ("vectorized", "reference"):
            raise ValueError("impl must be 'vectorized' or 'reference'")
        if self.calibration_method not in ("anneal", "bisect"):
            raise ValueError("calibration_method must be 'anneal' or 'bisect'")
        if not 0.0 < self.activity <= 1.0:
            raise ValueError("activity must be in (0, 1]")
        if self.hwloop_steps <= 0:
            raise ValueError("hwloop_steps must be positive")
        if self.hwloop_rows <= 0:
            raise ValueError("hwloop_rows must be positive")
        if self.backend not in ("ideal", "reference", "simulated", "emulated"):
            # user backends registered in repro.backend are accepted too;
            # the import is deferred (repro.backend is a heavier package)
            try:
                from ..backend import available_backends
                known = available_backends()
            except ImportError:  # pragma: no cover - mid-import edge only
                known = ["ideal", "reference", "simulated", "emulated"]
            if self.backend not in known:
                raise ValueError(f"unknown backend {self.backend!r}; "
                                 f"known: {known}")
        if self.hwloop_corruption not in ("stale", "tedrop", "bitflip"):
            # beyond the built-ins, accept anything in the repro.hwloop
            # registry (user models added via register_corruption).  The
            # import is deferred to here — never at module scope — because
            # repro.hwloop itself imports repro.flow.
            try:
                from ..hwloop.inject import CORRUPTION_MODELS
                known = sorted(CORRUPTION_MODELS)
            except ImportError:  # pragma: no cover - mid-import edge only
                known = ["stale", "tedrop", "bitflip"]
            if self.hwloop_corruption not in known:
                raise ValueError(f"unknown hwloop_corruption "
                                 f"{self.hwloop_corruption!r}; known: {known}")
        if self.resolved_v_min() <= self.resolved_v_crash():
            raise ValueError("V_min must exceed V_crash")

    # -- derived -------------------------------------------------------------

    @property
    def node(self) -> TechNode:
        return TECH_NODES[self.tech]

    def resolved_v_min(self) -> float:
        return self.node.v_min if self.v_min is None else float(self.v_min)

    def resolved_v_crash(self) -> float:
        return self.node.v_crash if self.v_crash is None else float(self.v_crash)

    def resolved_calibration_seed(self) -> int:
        return self.seed if self.calibration_seed is None else int(self.calibration_seed)

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        """Plain-JSON-serializable dict (round-trips via :meth:`from_dict`)."""
        out = dataclasses.asdict(self)
        out["algo_params"] = dict(self.algo_params)
        return out

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "FlowConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown FlowConfig fields: {sorted(unknown)}")
        return cls(**dict(d))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "FlowConfig":
        return cls.from_dict(json.loads(s))

    def replace(self, **changes: Any) -> "FlowConfig":
        """A copy with ``changes`` applied (re-validated)."""
        return dataclasses.replace(self, **changes)

    # -- cache fingerprinting ------------------------------------------------

    def fingerprint(self, keys: Tuple[str, ...]) -> Tuple[Tuple[str, str], ...]:
        """Stable, hashable digest of the named fields — the artifact-store
        cache key component (see :mod:`repro.flow.pipeline`)."""
        out = []
        for k in sorted(keys):
            v = getattr(self, k)
            if isinstance(v, Mapping):
                v = json.dumps({str(a): v[a] for a in sorted(v)}, sort_keys=True)
            out.append((k, repr(v)))
        return tuple(out)
