"""Immutable artifact store for the staged flow.

``Artifacts`` is the value that moves through the pipeline: a named,
append-only mapping of every intermediate the Fig. 9 flow produces (timing
model, slack vector, cluster labels, floorplan, voltages, constraint files,
power numbers).  Stages never mutate it — they return a new ``Artifacts``
with their outputs added — which is what makes stage outputs cacheable.

``ArtifactStore`` is the cross-run cache: it maps ``(stage name, config
fingerprint)`` keys to the artifact *delta* a stage produced, so a pipeline
re-run (or a :func:`repro.flow.sweep`) can short-circuit any prefix of
stages whose relevant config fields did not change — e.g. the timing stage
runs once per ``(tech, array_n, clock_ns, seed)`` no matter how many
clustering algorithms are swept on top of it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Mapping, Optional, Tuple


class Artifacts(Mapping[str, Any]):
    """Read-only mapping of named flow intermediates with attribute access."""

    __slots__ = ("_data",)

    def __init__(self, data: Optional[Mapping[str, Any]] = None):
        object.__setattr__(self, "_data", dict(data or {}))

    # -- mapping protocol ----------------------------------------------------

    def __getitem__(self, name: str) -> Any:
        try:
            return self._data[name]
        except KeyError:
            raise KeyError(
                f"artifact {name!r} not produced yet; available: "
                f"{sorted(self._data)}") from None

    def __iter__(self) -> Iterator[str]:
        return iter(self._data)

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, name: object) -> bool:
        return name in self._data

    def __getattr__(self, name: str) -> Any:
        if name.startswith("_"):
            raise AttributeError(name)
        try:
            return self._data[name]
        except KeyError:
            raise AttributeError(
                f"artifact {name!r} not produced yet; available: "
                f"{sorted(self._data)}") from None

    def __repr__(self) -> str:
        return f"Artifacts({sorted(self._data)})"

    # -- functional updates --------------------------------------------------

    def with_(self, **named: Any) -> "Artifacts":
        """A new ``Artifacts`` with the given artifacts added/replaced."""
        data = dict(self._data)
        data.update(named)
        return Artifacts(data)

    def merged(self, other: Mapping[str, Any]) -> "Artifacts":
        return self.with_(**dict(other))

    def delta_from(self, base: "Artifacts") -> Dict[str, Any]:
        """Artifacts added or replaced relative to ``base`` (what a stage
        produced — the unit the :class:`ArtifactStore` caches)."""
        return {k: v for k, v in self._data.items()
                if k not in base._data or base._data[k] is not v}

    def asdict(self) -> Dict[str, Any]:
        return dict(self._data)


#: Cache key: (stage name, (upstream stage-implementation chain, fingerprint
#: of every config field that can affect the stage output, including all
#: upstream stages' fields)).  Hashable; built by Pipeline.run.
StoreKey = Tuple[str, Tuple[Tuple[str, ...], Tuple[Tuple[str, str], ...]]]


@dataclasses.dataclass
class StoreStats:
    hits: int = 0
    misses: int = 0

    def __str__(self) -> str:
        return f"hits={self.hits} misses={self.misses}"


class ArtifactStore:
    """Cross-run cache of per-stage artifact deltas (see module docstring)."""

    def __init__(self) -> None:
        self._cache: Dict[StoreKey, Dict[str, Any]] = {}
        self.stats: Dict[str, StoreStats] = {}

    def _stat(self, stage: str) -> StoreStats:
        return self.stats.setdefault(stage, StoreStats())

    def get(self, key: StoreKey) -> Optional[Dict[str, Any]]:
        delta = self._cache.get(key)
        if delta is None:
            self._stat(key[0]).misses += 1
            return None
        self._stat(key[0]).hits += 1
        return delta

    def put(self, key: StoreKey, delta: Dict[str, Any]) -> None:
        self._cache[key] = delta

    def __len__(self) -> int:
        return len(self._cache)

    def runs_of(self, stage: str) -> int:
        """How many distinct times ``stage`` actually executed (cache misses)."""
        return self._stat(stage).misses

    def summary(self) -> str:
        return ", ".join(f"{name}: {s}" for name, s in sorted(self.stats.items()))
