"""repro.flow — the paper's Fig. 9 CAD flow as a composable stage pipeline.

Quickstart::

    from repro.flow import FlowConfig, run, sweep

    report = run(FlowConfig(array_n=16, tech="vivado-28nm", algo="dbscan"))
    print(report.summary())

    result = sweep({"tech": ["vivado-28nm", "vtr-22nm"],
                    "algo": ["kmeans", "dbscan"]})
    print(result.table())

Layers:

* :class:`FlowConfig` — declarative, validated, serializable operating point.
* :class:`Stage` subclasses + :data:`STAGE_REGISTRY` — pluggable pipeline
  steps, each a pure ``(Artifacts, FlowConfig) -> Artifacts`` function.
* :class:`Pipeline` — ordered stage chain with ``replace`` / ``without`` /
  ``insert_after`` composition and artifact-prefix caching via
  :class:`ArtifactStore`.
* :func:`sweep` — multi-scenario fan-out with shared prefix caching and a
  tidy comparison table.

``repro.core.cadflow.run_flow`` remains as a thin, deprecated wrapper.

CLI: ``PYTHONPATH=src python -m repro.flow {run,sweep} ...``
"""

from .artifacts import Artifacts, ArtifactStore, StoreStats
from .config import KNOWN_ALGOS, FlowConfig
from .pipeline import Pipeline, execute
from .report import FlowReport, report_from
from .stages import (DEFAULT_STAGE_NAMES, STAGE_REGISTRY, ClusterStage,
                     ConstraintsStage, FloorplanStage, FunctionStage,
                     HwLoopStage, PowerStage, RuntimeCalibrationStage, Stage,
                     StaticVoltageStage, TimingStage, cluster_slack,
                     default_stages, get_stage, register_stage)
from .sweep import (HWLOOP_COLUMNS, ROW_COLUMNS, SweepResult, expand_grid,
                    sweep)


def run(cfg: "FlowConfig | None" = None, *, pipeline: "Pipeline | None" = None,
        store: "ArtifactStore | None" = None, **overrides) -> FlowReport:
    """Execute the flow for ``cfg`` (or keyword overrides of the default
    config) and return the flat :class:`FlowReport`."""
    if cfg is None:
        cfg = FlowConfig(**overrides)
    elif overrides:
        cfg = cfg.replace(**overrides)
    art = execute(cfg, pipeline=pipeline, store=store)
    return report_from(art, cfg)


__all__ = [
    "Artifacts", "ArtifactStore", "StoreStats", "FlowConfig", "KNOWN_ALGOS",
    "Pipeline", "execute", "FlowReport", "report_from", "Stage",
    "FunctionStage", "TimingStage", "ClusterStage", "FloorplanStage",
    "StaticVoltageStage", "RuntimeCalibrationStage", "PowerStage",
    "ConstraintsStage", "HwLoopStage", "STAGE_REGISTRY",
    "DEFAULT_STAGE_NAMES", "default_stages", "get_stage", "register_stage",
    "cluster_slack", "sweep", "SweepResult", "expand_grid", "ROW_COLUMNS",
    "HWLOOP_COLUMNS", "run",
]
