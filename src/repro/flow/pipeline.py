"""Composable stage pipeline with artifact-prefix caching.

A :class:`Pipeline` is an ordered chain of :class:`~repro.flow.stages.Stage`
objects.  ``run(config)`` threads an :class:`~repro.flow.artifacts.Artifacts`
value through the chain; with an :class:`~repro.flow.artifacts.ArtifactStore`
attached, every stage's output delta is cached under ``(stage name,
fingerprint of all config fields any stage so far depends on)`` — so two
configs that differ only in a *later* stage's fields (say, the clustering
algorithm) share the expensive timing prefix instead of recomputing it.
"""

from __future__ import annotations

from typing import Any, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .artifacts import Artifacts, ArtifactStore
from .config import FlowConfig
from .stages import Stage, default_stages


def _content_digest(value: Any) -> Any:
    """Hashable, value-exact digest of a stage input artifact.

    Arrays key on their raw bytes (exact — no hash collisions to reason
    about; the flow's cacheable inputs are small slack/label vectors).
    Returns ``None`` for values that cannot be digested, which disables
    content keying for that stage run.
    """
    if isinstance(value, np.ndarray):
        return (value.dtype.str, value.shape, value.tobytes())
    if isinstance(value, (int, float, str, bool, bytes, type(None))):
        return (type(value).__name__, value)
    return None


class Pipeline:
    """An ordered, editable chain of flow stages.

    ``content_cache`` (default on) lets stages that declare
    ``content_cache = True`` key their cached output on the *values* of their
    required artifacts instead of the accumulated upstream config
    fingerprint.  The cluster stage is the motivating case: min-slack vectors
    are identical across technology nodes (the synthesized timing structure
    is tech-independent), so one clustering per algorithm serves every tech
    of a sweep.  Pass ``content_cache=False`` to reproduce the purely
    prefix-keyed behaviour (the perf baseline of the ``flow`` benchmark).
    """

    def __init__(self, stages: Optional[Sequence[Stage]] = None, *,
                 content_cache: bool = True):
        self.stages: List[Stage] = list(default_stages() if stages is None
                                        else stages)
        self.content_cache = content_cache
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stage names: {names}")

    # -- composition ---------------------------------------------------------

    def _index(self, name: str) -> int:
        for i, s in enumerate(self.stages):
            if s.name == name:
                return i
        raise KeyError(f"no stage named {name!r}; have "
                       f"{[s.name for s in self.stages]}")

    def replace(self, name: str, stage: Stage) -> "Pipeline":
        """New pipeline with the named stage swapped for ``stage``."""
        out = list(self.stages)
        out[self._index(name)] = stage
        return Pipeline(out, content_cache=self.content_cache)

    def without(self, *names: str) -> "Pipeline":
        """New pipeline with the named stage(s) removed (skipped)."""
        drop = set(names)
        for n in drop:
            self._index(n)                      # raise on unknown names
        return Pipeline([s for s in self.stages if s.name not in drop],
                        content_cache=self.content_cache)

    def insert_after(self, name: str, stage: Stage) -> "Pipeline":
        out = list(self.stages)
        out.insert(self._index(name) + 1, stage)
        return Pipeline(out, content_cache=self.content_cache)

    def insert_before(self, name: str, stage: Stage) -> "Pipeline":
        out = list(self.stages)
        out.insert(self._index(name), stage)
        return Pipeline(out, content_cache=self.content_cache)

    def __repr__(self) -> str:
        return f"Pipeline({[s.name for s in self.stages]})"

    # -- validation ----------------------------------------------------------

    def check(self, initial: Iterable[str] = ()) -> None:
        """Verify every stage's ``requires`` is satisfied by earlier stages
        (or by artifacts provided up front).  Raises ``ValueError`` early
        instead of failing mid-run."""
        have = set(initial)
        for s in self.stages:
            missing = set(s.requires) - have
            if missing:
                raise ValueError(
                    f"stage {s.name!r} requires {sorted(missing)} but only "
                    f"{sorted(have)} are available; reorder or provide them")
            have |= set(s.provides)

    # -- execution -----------------------------------------------------------

    def run(self, cfg: Optional[FlowConfig] = None, *,
            store: Optional[ArtifactStore] = None,
            initial: Optional[Artifacts] = None,
            upto: Optional[str] = None) -> Artifacts:
        """Execute the chain on ``cfg`` and return the final artifacts.

        ``store``   — cross-run cache; unchanged stage prefixes short-circuit.
                      A cached entry is only reused when the *whole upstream
                      stage chain* (implementations + relevant config fields)
                      matches; replacing or inserting a stage invalidates it
                      and everything downstream.
        ``initial`` — artifacts provided up front (stages may consume them).
                      Non-empty initial artifacts disable the store for this
                      run: their contents are not part of the cache key, so
                      reusing cached outputs would be unsound.
        ``upto``    — stop after the named stage (inclusive), e.g. run just
                      the timing+clustering prefix.
        """
        cfg = FlowConfig() if cfg is None else cfg
        art = Artifacts() if initial is None else initial
        self.check(initial=art.keys())

        stop = len(self.stages) if upto is None else self._index(upto) + 1
        use_store = (store is not None and hasattr(cfg, "fingerprint")
                     and len(art) == 0)
        upstream_keys: Tuple[str, ...] = ()
        chain: Tuple[str, ...] = ()
        for stage in self.stages[:stop]:
            upstream_keys = tuple(dict.fromkeys(upstream_keys
                                                + tuple(stage.config_keys)))
            chain = chain + (stage.cache_token(),)
            if use_store:
                key = self._store_key(stage, art, cfg, chain, upstream_keys)
                delta = store.get(key)
                if delta is None:
                    new = stage.run(art, cfg)
                    delta = new.delta_from(art)
                    store.put(key, delta)
                art = art.merged(delta)
            else:
                art = stage.run(art, cfg)
        return art

    def _store_key(self, stage: Stage, art: Artifacts, cfg: FlowConfig,
                   chain: Tuple[str, ...], upstream_keys: Tuple[str, ...]):
        """Cache key for one stage execution.

        Default: prefix keying — the upstream implementation chain plus the
        fingerprint of every config field any stage so far depends on.
        Content keying (stage.content_cache, pipeline content_cache on, and
        all required artifacts digestible): the stage's own implementation +
        config fields + the exact *values* of its inputs, so runs reaching
        identical inputs through different upstream configs share work.
        """
        if self.content_cache and getattr(stage, "content_cache", False):
            digests = tuple(_content_digest(art[r]) for r in stage.requires
                            if r in art)
            if len(digests) == len(stage.requires) and \
                    all(d is not None for d in digests):
                return (stage.name,
                        ("content", stage.cache_token(),
                         cfg.fingerprint(tuple(stage.config_keys)),
                         tuple(zip(stage.requires, digests))))
        return (stage.name, (chain, cfg.fingerprint(upstream_keys)))


def execute(cfg: Optional[FlowConfig] = None, *,
            pipeline: Optional[Pipeline] = None,
            store: Optional[ArtifactStore] = None) -> Artifacts:
    """One-call convenience: run ``cfg`` through ``pipeline`` (default: the
    canonical Fig. 9 chain) and return every artifact."""
    return (pipeline or Pipeline()).run(cfg, store=store)
