"""Composable stage pipeline with artifact-prefix caching.

A :class:`Pipeline` is an ordered chain of :class:`~repro.flow.stages.Stage`
objects.  ``run(config)`` threads an :class:`~repro.flow.artifacts.Artifacts`
value through the chain; with an :class:`~repro.flow.artifacts.ArtifactStore`
attached, every stage's output delta is cached under ``(stage name,
fingerprint of all config fields any stage so far depends on)`` — so two
configs that differ only in a *later* stage's fields (say, the clustering
algorithm) share the expensive timing prefix instead of recomputing it.
"""

from __future__ import annotations

from typing import Any, Iterable, List, Optional, Sequence, Tuple

from .artifacts import Artifacts, ArtifactStore
from .config import FlowConfig
from .stages import Stage, default_stages


class Pipeline:
    """An ordered, editable chain of flow stages."""

    def __init__(self, stages: Optional[Sequence[Stage]] = None):
        self.stages: List[Stage] = list(default_stages() if stages is None
                                        else stages)
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stage names: {names}")

    # -- composition ---------------------------------------------------------

    def _index(self, name: str) -> int:
        for i, s in enumerate(self.stages):
            if s.name == name:
                return i
        raise KeyError(f"no stage named {name!r}; have "
                       f"{[s.name for s in self.stages]}")

    def replace(self, name: str, stage: Stage) -> "Pipeline":
        """New pipeline with the named stage swapped for ``stage``."""
        out = list(self.stages)
        out[self._index(name)] = stage
        return Pipeline(out)

    def without(self, *names: str) -> "Pipeline":
        """New pipeline with the named stage(s) removed (skipped)."""
        drop = set(names)
        for n in drop:
            self._index(n)                      # raise on unknown names
        return Pipeline([s for s in self.stages if s.name not in drop])

    def insert_after(self, name: str, stage: Stage) -> "Pipeline":
        out = list(self.stages)
        out.insert(self._index(name) + 1, stage)
        return Pipeline(out)

    def insert_before(self, name: str, stage: Stage) -> "Pipeline":
        out = list(self.stages)
        out.insert(self._index(name), stage)
        return Pipeline(out)

    def __repr__(self) -> str:
        return f"Pipeline({[s.name for s in self.stages]})"

    # -- validation ----------------------------------------------------------

    def check(self, initial: Iterable[str] = ()) -> None:
        """Verify every stage's ``requires`` is satisfied by earlier stages
        (or by artifacts provided up front).  Raises ``ValueError`` early
        instead of failing mid-run."""
        have = set(initial)
        for s in self.stages:
            missing = set(s.requires) - have
            if missing:
                raise ValueError(
                    f"stage {s.name!r} requires {sorted(missing)} but only "
                    f"{sorted(have)} are available; reorder or provide them")
            have |= set(s.provides)

    # -- execution -----------------------------------------------------------

    def run(self, cfg: Optional[FlowConfig] = None, *,
            store: Optional[ArtifactStore] = None,
            initial: Optional[Artifacts] = None,
            upto: Optional[str] = None) -> Artifacts:
        """Execute the chain on ``cfg`` and return the final artifacts.

        ``store``   — cross-run cache; unchanged stage prefixes short-circuit.
                      A cached entry is only reused when the *whole upstream
                      stage chain* (implementations + relevant config fields)
                      matches; replacing or inserting a stage invalidates it
                      and everything downstream.
        ``initial`` — artifacts provided up front (stages may consume them).
                      Non-empty initial artifacts disable the store for this
                      run: their contents are not part of the cache key, so
                      reusing cached outputs would be unsound.
        ``upto``    — stop after the named stage (inclusive), e.g. run just
                      the timing+clustering prefix.
        """
        cfg = FlowConfig() if cfg is None else cfg
        art = Artifacts() if initial is None else initial
        self.check(initial=art.keys())

        stop = len(self.stages) if upto is None else self._index(upto) + 1
        use_store = (store is not None and hasattr(cfg, "fingerprint")
                     and len(art) == 0)
        upstream_keys: Tuple[str, ...] = ()
        chain: Tuple[str, ...] = ()
        for stage in self.stages[:stop]:
            upstream_keys = tuple(dict.fromkeys(upstream_keys
                                                + tuple(stage.config_keys)))
            chain = chain + (stage.cache_token(),)
            if use_store:
                key = (stage.name, (chain, cfg.fingerprint(upstream_keys)))
                delta = store.get(key)
                if delta is None:
                    new = stage.run(art, cfg)
                    delta = new.delta_from(art)
                    store.put(key, delta)
                art = art.merged(delta)
            else:
                art = stage.run(art, cfg)
        return art


def execute(cfg: Optional[FlowConfig] = None, *,
            pipeline: Optional[Pipeline] = None,
            store: Optional[ArtifactStore] = None) -> Artifacts:
    """One-call convenience: run ``cfg`` through ``pipeline`` (default: the
    canonical Fig. 9 chain) and return every artifact."""
    return (pipeline or Pipeline()).run(cfg, store=store)
