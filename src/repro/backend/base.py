"""The `repro.backend` execution protocol: one matmul contract over every
fidelity level of the voltage-scaled array.

The repo grew four divergent matmul execution paths — compiled Pallas
kernels, the `kernels/ref.py` oracles, `core.SystolicSim`, and
`hwloop.EmulatedAccelerator` — each with its own calling convention, so the
DNN stack could only reach the voltage-scaled array through hwloop's
bolt-on probe traffic.  :class:`MatmulBackend` unifies them:

    out, telemetry = backend.matmul(a, b, precision="f32", count_flags=True)

with a string-keyed registry (``get_backend("emulated")``) and a
context-manager / ``set_default`` scoping API, so the *same* model code runs
its GEMMs on the ideal compiled path, the jnp oracles, the cycle-level
simulator, or the fault-injecting emulated accelerator — selectable per
serve engine, per flow stage, or per ``with use_backend(...)`` block.

Contract highlights (the parity tests in ``tests/backend`` pin these down):

* ``precision=None`` (native) keeps the inputs' promoted dtype;
  ``precision="f32"`` computes/returns float32; ``precision="int8"``
  quantizes both operands through the **shared** host quantizer below, runs
  the exact integer product on the backend, and dequantizes in shared
  float32 code — so the int8 path is bit-identical across backends by
  construction.
* At nominal rails every backend computes the exact product: ``ideal``,
  ``reference``, ``simulated`` and nominal-rail ``emulated`` are
  bit-identical on reduction-order-independent inputs, and telemetry shows
  zero flags / replays / silent failures.
* :func:`matmul` (the model-facing router) is trace-safe: the ideal backend
  lowers to a plain XLA dot; every other backend crosses to the host via
  ``jax.pure_callback`` and accumulates its telemetry there, so jitted
  decode steps can run all their GEMMs on the emulated array.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import os
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.serialize import to_plain


def ensure_host_callback_capacity() -> bool:
    """Single-core deadlock guard for the ``pure_callback`` serving path.

    On hosts where ``os.cpu_count() == 1`` XLA's CPU client gets a
    one-thread execution pool; a host callback then runs ON that thread, and
    any wait it performs on a jax array (``pure_callback_impl`` re-wraps the
    operands with ``device_put``, so even ``np.asarray`` on an argument
    waits) can starve against the enclosing computation — the jit'd decode
    step and the backend callback deadlock each other.  Forcing two virtual
    host devices gives the client a second thread and removes the race.

    Must run before jax creates its CPU client (importing jax is fine).
    Returns True when the flag was injected.  No-op on multi-core hosts or
    when the flag is already present.
    """
    if (os.cpu_count() or 1) != 1:
        return False
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" in flags:
        return False
    os.environ["XLA_FLAGS"] = \
        (flags + " --xla_force_host_platform_device_count=2").strip()
    return True

#: Precision tiers of the protocol.  ``None`` means "native" (keep the
#: inputs' promoted dtype).
PRECISIONS: Tuple[Optional[str], ...] = (None, "f32", "int8")


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BackendTelemetry:
    """Observables of one (or an accumulation of) backend matmul call(s).

    ``flags`` counts partitions whose Razor flag fired (summed over calls);
    ``partition_flags`` is the per-partition OR across the accumulated calls
    (``None`` for backends without a partition notion).  ``energy_j`` is the
    emulated accelerator's ledger delta (0.0 elsewhere).
    """

    calls: int = 0
    macs: int = 0
    flags: int = 0
    replays: int = 0
    silent: int = 0
    energy_j: float = 0.0
    rel_error: float = 0.0          # max over the accumulated calls
    partition_flags: Optional[List[bool]] = None
    # ABFT guard counters (repro.resilience.GuardedBackend; zero elsewhere)
    guard_checks: int = 0           # verifications run
    guard_detected: int = 0         # calls whose first verification failed
    guard_corrected: int = 0        # single-element locate-and-correct wins
    guard_retries: int = 0          # bounded re-executions
    guard_heals: int = 0            # rail heals (watchdog / nominal fallback)
    guard_uncorrected: int = 0      # mismatches surviving the ladder (fail_open)

    def merge(self, other: "BackendTelemetry") -> None:
        self.calls += other.calls
        self.macs += other.macs
        self.flags += other.flags
        self.replays += other.replays
        self.silent += other.silent
        self.energy_j += other.energy_j
        self.guard_checks += other.guard_checks
        self.guard_detected += other.guard_detected
        self.guard_corrected += other.guard_corrected
        self.guard_retries += other.guard_retries
        self.guard_heals += other.guard_heals
        self.guard_uncorrected += other.guard_uncorrected
        self.rel_error = max(self.rel_error, other.rel_error)
        if other.partition_flags is not None:
            if self.partition_flags is None:
                self.partition_flags = [bool(f) for f in other.partition_flags]
            else:
                self.partition_flags = [
                    bool(a or b) for a, b in
                    zip(self.partition_flags, other.partition_flags)]

    def to_dict(self) -> Dict[str, Any]:
        """Plain-JSON snapshot via the one shared telemetry serializer
        (``repro.obs.to_plain``) — field order pinned by the dataclass
        declaration, numpy scalars coerced to python types."""
        return to_plain(self)


# ---------------------------------------------------------------------------
# Shared int8 path (host-side, one definition for every backend)
# ---------------------------------------------------------------------------


def quantize_sym_i8(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-row int8 quantization, float32 throughout.

    Mirrors ``kernels.ref.quantize_sym_i8`` but runs on the host so all four
    backends share one bit-exact quantizer (the int8 parity guarantee).
    """
    xf = np.asarray(x, dtype=np.float32)
    amax = np.max(np.abs(xf), axis=-1, keepdims=True)
    scale = (np.maximum(amax, np.float32(1e-12)) / np.float32(127.0)) \
        .astype(np.float32)
    q = np.clip(np.round(xf / scale), -127, 127).astype(np.int8)
    return q, scale


def _out_dtype(a_dtype, b_dtype, precision: Optional[str]):
    if precision == "f32":
        return np.dtype(np.float32)
    res = jnp.result_type(a_dtype, b_dtype)
    if not jnp.issubdtype(res, jnp.floating):
        return np.dtype(np.float32)      # exact accumulation of int inputs
    return np.dtype(res)


# ---------------------------------------------------------------------------
# Protocol
# ---------------------------------------------------------------------------


class MatmulBackend:
    """Base class of the execution-backend protocol.

    Subclasses implement :meth:`_execute` — the exact-semantics host matmul
    (plus whatever fault injection their fidelity level models) — and the
    base class supplies the precision pipeline, telemetry accumulation and
    the traced-routing entry point.
    """

    name: str = "backend"
    #: The ideal backend routes as a native XLA dot (zero overhead); every
    #: other backend crosses to the host per GEMM.
    is_ideal: bool = False
    #: True only for repro.resilience.GuardedBackend — the serve engine uses
    #: it to surface per-step ABFT guard telemetry without importing the
    #: resilience package.
    is_guarded: bool = False

    def __init__(self) -> None:
        self.total = BackendTelemetry()
        self._pending = BackendTelemetry()
        self._obs = None            # ObsBus, when a serve engine attaches
        self._obs_cb_hist = None    # pure_callback round-trip histogram

    def attach_obs(self, bus) -> None:
        """Attach a ``repro.obs.ObsBus``: every host :meth:`matmul` entry
        (the body of the ``pure_callback`` round-trip) is timed into a
        ``backend_callback_seconds{backend=...}`` histogram.  The serve
        engine attaches only its *outermost* backend, so wrapped inner
        backends (``GuardedBackend.inner``) are never double-counted."""
        self._obs = bus
        self._obs_cb_hist = bus.registry.histogram(
            "backend_callback_seconds",
            "host-side service time of one backend GEMM callback (s)",
            labels=("backend",)).labels(backend=self.name)

    # -- subclass hook --------------------------------------------------------

    def _execute(self, a: np.ndarray, b: np.ndarray
                 ) -> Tuple[np.ndarray, BackendTelemetry]:
        """Exact-product (M, K) @ (K, N) on this backend's machinery.

        Receives host arrays; returns the (possibly fault-injected) product
        in the backend's working precision plus single-call telemetry."""
        raise NotImplementedError

    # -- the protocol ---------------------------------------------------------

    def matmul(self, a, b, *, precision: Optional[str] = None,
               count_flags: bool = True
               ) -> Tuple[np.ndarray, BackendTelemetry]:
        """Execute ``a @ b`` at the given precision tier.

        Host-side entry point (concrete arrays); traced callers go through
        :func:`matmul` / :meth:`traced_matmul`.  Telemetry is returned AND
        accumulated on the backend (``pop_telemetry`` drains it)."""
        a_np, b_np = np.asarray(a), np.asarray(b)
        if a_np.ndim != 2 or b_np.ndim != 2 or a_np.shape[1] != b_np.shape[0]:
            raise ValueError(
                f"matmul expects (M, K) @ (K, N); got {a_np.shape} @ "
                f"{b_np.shape}")
        if precision not in PRECISIONS:
            raise ValueError(f"unknown precision {precision!r}; "
                             f"known: {PRECISIONS}")
        out_dtype = _out_dtype(a_np.dtype, b_np.dtype, precision)
        t0 = self._obs.clock() if self._obs is not None else None
        if precision == "int8":
            qa, sa = quantize_sym_i8(a_np)
            qb, sb = quantize_sym_i8(b_np.T)          # per-column scales of b
            prod, tel = self._execute(qa.astype(np.float32),
                                      qb.T.astype(np.float32))
            # shared float32 dequant: bit-identical across backends given the
            # exact integer product each backend guarantees
            out = (np.asarray(prod, dtype=np.float32) * sa * sb.T) \
                .astype(np.float32)
        else:
            raw, tel = self._execute(a_np, b_np)
            out = np.asarray(raw).astype(out_dtype)
        if not count_flags:
            tel = dataclasses.replace(tel, flags=0, partition_flags=None)
        self._record(tel)
        if t0 is not None:
            self._obs_cb_hist.observe(self._obs.clock() - t0)
        return out, tel

    # -- traced routing -------------------------------------------------------

    def traced_matmul(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """``a @ b`` routed through this backend from (possibly) traced code.

        Crosses to the host with ``jax.pure_callback`` — the result feeds the
        model graph, so the callback (and its telemetry side effects) runs
        exactly when the computation does, including inside ``lax.scan`` over
        layers and under ``jax.jit``.

        Differentiable with **ideal-path gradients** (a custom VJP): the
        forward product carries this backend's fault injection while the
        backward pass uses exact XLA dots — the standard straight-through
        treatment for training through injected hardware faults (pure
        callbacks define no JVP of their own).
        """
        out_dtype = _out_dtype(a.dtype, b.dtype, None)
        m, n = a.shape[0], b.shape[1]

        def host(a_h, b_h):
            out, _ = self.matmul(a_h, b_h)
            return np.asarray(out, dtype=out_dtype)

        @jax.custom_vjp
        def routed(a, b):
            return jax.pure_callback(
                host, jax.ShapeDtypeStruct((m, n), out_dtype), a, b)

        def routed_fwd(a, b):
            return routed(a, b), (a, b)

        def routed_bwd(res, g):
            a, b = res
            return ((g @ b.T).astype(a.dtype), (a.T @ g).astype(b.dtype))

        routed.defvjp(routed_fwd, routed_bwd)
        return routed(a, b)

    # -- telemetry ------------------------------------------------------------

    def _record(self, tel: BackendTelemetry) -> None:
        self.total.merge(tel)
        self._pending.merge(tel)

    def pop_telemetry(self) -> BackendTelemetry:
        """Drain the telemetry accumulated since the last pop (the serve
        engine's per-decode-step payload); totals keep everything."""
        out, self._pending = self._pending, BackendTelemetry()
        return out

    def add_tokens(self, n: int) -> None:
        """Attribute ``n`` served tokens to this backend's energy accounting
        (a no-op unless the backend owns an :class:`EnergyLedger`)."""

    def summary(self) -> Dict[str, Any]:
        """Plain-JSON lifetime telemetry (EngineStats' backend payload)."""
        return {"backend": self.name, **self.total.to_dict()}

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., MatmulBackend]] = {}


def register_backend(name: str, factory: Callable[..., MatmulBackend]
                     ) -> Callable[..., MatmulBackend]:
    """Make a backend constructible by name via :func:`get_backend`."""
    _REGISTRY[name] = factory
    return factory


def available_backends() -> List[str]:
    return sorted(_REGISTRY)


def get_backend(spec: Any, **kw: Any) -> MatmulBackend:
    """Resolve a backend: an instance passes through; a registered name is
    constructed fresh with ``**kw`` forwarded to its factory."""
    if isinstance(spec, MatmulBackend):
        if kw:
            raise ValueError("keyword options only apply when constructing "
                             "a backend by name")
        return spec
    try:
        factory = _REGISTRY[spec]
    except (KeyError, TypeError):
        raise KeyError(f"unknown backend {spec!r}; known: "
                       f"{available_backends()}") from None
    return factory(**kw)


# ---------------------------------------------------------------------------
# Scoping: default + context manager
# ---------------------------------------------------------------------------

_DEFAULT: Optional[MatmulBackend] = None      # lazily resolved to "ideal"
_STACK: List[MatmulBackend] = []


def current_backend() -> MatmulBackend:
    """The backend model GEMMs route through right now."""
    if _STACK:
        return _STACK[-1]
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = get_backend("ideal")
    return _DEFAULT


def set_default(spec: Any, **kw: Any) -> MatmulBackend:
    """Install the process-wide default backend (outside any
    ``use_backend`` scope).  Returns the resolved instance."""
    global _DEFAULT
    _DEFAULT = get_backend(spec, **kw)
    return _DEFAULT


@contextlib.contextmanager
def use_backend(spec: Any, **kw: Any):
    """Scope the active backend: every :func:`matmul` (and hence every model
    GEMM traced) inside the block routes through it.

    The binding happens at TRACE time: a ``jax.jit`` cache entry keeps the
    backend that was active when it was traced, so entering this scope does
    not re-route shapes a jitted function already compiled under another
    backend.  Hold one jit wrapper per backend (``ServeEngine`` constructs
    its own per instance) or trace inside the scope."""
    be = get_backend(spec, **kw)
    _STACK.append(be)
    try:
        yield be
    finally:
        _STACK.pop()


# ---------------------------------------------------------------------------
# Model-facing router
# ---------------------------------------------------------------------------


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Dense GEMM through the active backend.  ``a``: (..., K); ``b``: (K, N).

    On the ideal backend this IS ``a @ b`` (bit-for-bit the established
    model semantics, jit/grad/shard-transparent); any other backend receives
    the flattened (M, K) problem via its host callback.
    """
    be = current_backend()
    if be.is_ideal:
        return a @ b
    lead = a.shape[:-1]
    out = be.traced_matmul(a.reshape((-1, a.shape[-1])), b)
    return out.reshape(lead + (b.shape[-1],))


def largest_common_block(m: int, n: int,
                         prefs: Tuple[int, ...] = (128, 64, 32, 16, 8, 4, 2, 1)
                         ) -> int:
    """Largest preferred tile edge dividing both axes (reference backend's
    flag-grid block)."""
    g = math.gcd(m, n)
    for b in prefs:
        if g % b == 0:
            return b
    return 1
