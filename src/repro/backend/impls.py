"""The four first-class execution backends.

| name        | machinery                          | fidelity                   |
|-------------|------------------------------------|----------------------------|
| `ideal`     | XLA dot (the Pallas/`ops.py` path) | exact, fastest             |
| `reference` | `kernels/ref.py` jnp oracles       | exact, kernel-semantics    |
| `simulated` | `core.SystolicSim`                 | cycle-level Razor faults   |
| `emulated`  | `hwloop.EmulatedAccelerator`       | faults + replay + energy   |

`simulated`/`emulated` tile arbitrary ``(M, K) @ (K, N)`` problems onto
their ``n x n`` array exactly like the accelerator would (K into resident
row tiles, N into column tiles); at nominal rails both degenerate to the
exact tiled product, which is what makes the backend parity matrix
(``tests/backend/test_parity.py``) bit-identical.
"""

from __future__ import annotations

from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from ..core.partition import quadrant_floorplan
from ..core.razor import RazorConfig
from ..core.systolic import SystolicSim
from ..core.timing import TECH_NODES, TimingModel
from ..kernels import ref as kref
from .base import (BackendTelemetry, MatmulBackend, largest_common_block,
                   register_backend)


class IdealBackend(MatmulBackend):
    """The production compiled path: a plain XLA dot (what the Pallas
    `ops.py` wrappers lower to off-CPU).  The router never even crosses to
    the host for this backend — ``matmul()`` stays ``a @ b``."""

    name = "ideal"
    is_ideal = True

    def _execute(self, a, b):
        out = np.asarray(jnp.matmul(jnp.asarray(a), jnp.asarray(b)))
        m, k = a.shape
        tel = BackendTelemetry(calls=1, macs=m * k * b.shape[1])
        return out, tel


class ReferenceBackend(MatmulBackend):
    """The `kernels/ref.py` oracle semantics: the systolic-MAC oracle with a
    uniformly nominal voltage map, so no tile ever trips the corruption
    model and the product is the exact f32 matmul the kernels are tested
    against."""

    name = "reference"

    def _execute(self, a, b):
        m, k = a.shape
        n = b.shape[1]
        block = largest_common_block(m, n)
        grid = (m // block, n // block)
        v_map = jnp.ones(grid, jnp.float32)              # nominal rails
        v_safe = jnp.zeros(grid, jnp.float32)            # every tile safe
        c, fail = kref.systolic_mac(jnp.asarray(a, jnp.float32),
                                    jnp.asarray(b, jnp.float32),
                                    v_map, v_safe, block=block)
        flags = int(np.asarray(fail).sum())
        tel = BackendTelemetry(calls=1, macs=m * k * n, flags=flags)
        return np.asarray(c), tel


class SimulatedBackend(MatmulBackend):
    """`core.SystolicSim` under real traffic: cycle-level Razor
    classification with stale-register silent failures, tiled onto the
    simulator's ``n x n`` array.

    Partial tiles are zero-padded to the array edge; padded MACs still get
    classified (they exist on the die), but their rank-1 terms are zero so
    the product is unaffected and only real MACs are counted in ``macs``.
    """

    name = "simulated"

    def __init__(self, sim: SystolicSim):
        super().__init__()
        self.sim = sim

    @classmethod
    def nominal(cls, array_n: int = 8, tech: str = "vtr-22nm",
                clock_ns: float = 10.0, seed: int = 2021,
                **sim_kw: Any) -> "SimulatedBackend":
        """A fault-free operating point: quadrant floorplan with every rail
        at the tech node's nominal voltage."""
        node = TECH_NODES[tech]
        tm = TimingModel(n=array_n, clock_ns=clock_ns, tech=node, seed=seed)
        fp = quadrant_floorplan(array_n).with_voltages([node.v_nom] * 4)
        return cls(SystolicSim(tm, fp, RazorConfig(clock_ns=clock_ns),
                               **sim_kw))

    def _execute(self, a, b):
        n = self.sim.timing.n
        a = np.asarray(a, dtype=np.float64)
        b = np.asarray(b, dtype=np.float64)
        m, k = a.shape
        n_dim = b.shape[1]
        out = np.zeros((m, n_dim), dtype=np.float64)
        n_part = self.sim._n_part
        part_flags = np.zeros(n_part, dtype=bool)
        replays = silent = macs = 0
        rel_error = 0.0
        for ki in range(0, k, n):
            a_blk = a[:, ki:ki + n]
            kb = a_blk.shape[1]
            if kb < n:
                a_blk = np.pad(a_blk, ((0, 0), (0, n - kb)))
            for nj in range(0, n_dim, n):
                w_blk = b[ki:ki + kb, nj:nj + n]
                nb = w_blk.shape[1]
                w_pad = np.zeros((n, n), dtype=np.float64)
                w_pad[:kb, :nb] = w_blk
                c_blk, stats = self.sim.matmul(a_blk, w_pad)
                out[:, nj:nj + nb] += c_blk[:, :nb]
                part_flags |= stats.partition_fail
                replays += stats.replay_cycles
                silent += int(stats.silent.sum())
                macs += m * kb * nb
                rel_error = max(rel_error, stats.rel_error)
        tel = BackendTelemetry(
            calls=1, macs=macs, flags=int(part_flags.sum()), replays=replays,
            silent=silent, rel_error=rel_error,
            partition_flags=[bool(f) for f in part_flags])
        return out, tel


class EmulatedBackend(MatmulBackend):
    """`hwloop.EmulatedAccelerator` as a production execution target: every
    GEMM runs on the voltage-scaled array with data-dependent Razor fault
    injection, DETECTED replay costs, pluggable SILENT corruption, and the
    :class:`~repro.hwloop.energy.EnergyLedger` pricing every MAC.

    ``backend.accel.rails`` stays live — the hwloop watchdog adapter (or an
    undervolting experiment) can move rails between serve steps.
    """

    name = "emulated"

    def __init__(self, accel):
        super().__init__()
        self.accel = accel

    @classmethod
    def nominal(cls, array_n: int = 8, tech: str = "vtr-22nm",
                clock_ns: float = 10.0, seed: int = 2021,
                **accel_kw: Any) -> "EmulatedBackend":
        """Fault-free operating point (quadrant floorplan, nominal rails) —
        the zero-flag end of the parity matrix, ledger still live."""
        from ..hwloop.device import EmulatedAccelerator
        node = TECH_NODES[tech]
        tm = TimingModel(n=array_n, clock_ns=clock_ns, tech=node, seed=seed)
        fp = quadrant_floorplan(array_n).with_voltages([node.v_nom] * 4)
        return cls(EmulatedAccelerator(tm, fp,
                                       razor=RazorConfig(clock_ns=clock_ns),
                                       **accel_kw))

    @classmethod
    def from_flow(cls, report, cfg, *, rails: Optional[np.ndarray] = None,
                  **accel_kw: Any) -> "EmulatedBackend":
        """The CAD flow's calibrated operating point: the `FlowReport`'s
        floorplan and runtime rails (the actual voltage-scaled serving
        target)."""
        from ..hwloop.device import EmulatedAccelerator
        return cls(EmulatedAccelerator.from_flow(report, cfg, rails=rails,
                                                 **accel_kw))

    @property
    def ledger(self):
        return self.accel.ledger

    def add_tokens(self, n: int) -> None:
        self.accel.ledger.add_tokens(n)

    def _execute(self, a, b):
        j_before = self.accel.ledger.total_j
        c, mtel = self.accel.matmul(a, b)
        tel = BackendTelemetry(
            calls=1, macs=int(mtel.macs_p.sum()),
            flags=int(mtel.partition_flags.sum()),
            replays=int(mtel.replay_cycles),
            silent=int(mtel.silent_p.sum()),
            energy_j=float(self.accel.ledger.total_j - j_before),
            rel_error=float(mtel.rel_error),
            partition_flags=[bool(f) for f in mtel.partition_flags])
        return c, tel

    def summary(self):
        out = super().summary()
        out["rails_v"] = [float(v) for v in self.accel.rails]
        out["corruption"] = self.accel.corruption
        led = self.accel.ledger.summary()
        # the ledger counts the DEVICE's lifetime (a shared accel also sees
        # hwloop probe traffic); keep the backend-routed "macs" authoritative
        led["device_macs"] = led.pop("macs")
        out.update(led)
        return out


register_backend("ideal", IdealBackend)
register_backend("reference", ReferenceBackend)
register_backend("simulated", SimulatedBackend.nominal)
register_backend("emulated", EmulatedBackend.nominal)
