"""repro.backend — one execution-backend protocol over ideal / reference /
simulated / emulated voltage-scaled arrays.

Quickstart::

    from repro import backend

    be = backend.get_backend("emulated")          # nominal-rail array
    out, tel = be.matmul(a, b)                    # telemetry per call

    with backend.use_backend(be):                 # scope model GEMMs
        logits, state = api.decode_step(params, state, tokens)
    print(be.summary()["energy_per_token_j"])

The serve engine threads this end to end: ``ServeEngine(cfg, params,
backend="emulated")`` (or ``launch.serve --backend emulated``) runs every
decode GEMM on the fault-injecting :class:`EmulatedBackend` and surfaces
per-step flag/replay/energy telemetry in ``EngineStats``.
"""

from .base import (PRECISIONS, BackendTelemetry, MatmulBackend,
                   available_backends, current_backend,
                   ensure_host_callback_capacity, get_backend, matmul,
                   quantize_sym_i8, register_backend, set_default,
                   use_backend)
from .impls import (EmulatedBackend, IdealBackend, ReferenceBackend,
                    SimulatedBackend)

__all__ = [
    "PRECISIONS", "BackendTelemetry", "MatmulBackend", "available_backends",
    "current_backend", "ensure_host_callback_capacity", "get_backend",
    "matmul", "quantize_sym_i8",
    "register_backend", "set_default", "use_backend",
    "IdealBackend", "ReferenceBackend", "SimulatedBackend", "EmulatedBackend",
]
