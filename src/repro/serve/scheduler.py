"""Slot scheduler for continuous batching.

Pure-python admission/eviction bookkeeping, kept model-free so the policy is
unit-testable without touching jax: a fixed number of decode slots, a pending
queue, and a slot -> request map.  The engine asks ``admit()`` for newly
filled slots each iteration and ``evict()``s a slot the moment its request
finishes — a new request then rides the very next decode step while the
other slots keep decoding (no head-of-line blocking).

Two admission policies:

``"fifo"`` (default)
    The original first-in-first-out queue, bit-compatible with the seed
    behaviour: no priorities, no deadlines, unbounded queue unless
    ``max_pending`` is set.

``"priority"``
    Production admission for the ``repro.server`` frontend: requests carry a
    ``Priority`` tier and an optional TTFT SLO (``deadline_s``, seconds from
    submission).  Admission picks the highest tier first, tightest deadline
    within a tier (earliest-deadline-first), FIFO as the final tiebreak.
    Requests whose deadline has already expired while queued are *shed*
    (dropped with telemetry, never silently), and a bounded queue
    (``max_pending``) sheds the lowest-priority victim — or rejects the
    newcomer — when full, which is the backpressure signal the HTTP layer
    turns into 503s.
"""

from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple


class Priority(enum.IntEnum):
    """Request priority tier: higher value wins admission."""
    LOW = 0
    NORMAL = 1
    HIGH = 2


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    frames: Optional[Any] = None     # encdec only: (1, t_enc, d) frame embeds
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False          # cut short (budget / max_len), NOT completed
    # serving QoS (priority admission policy only; FIFO ignores both)
    priority: Priority = Priority.NORMAL
    deadline_s: Optional[float] = None   # TTFT SLO, seconds from submission
    shed: bool = False               # dropped by the scheduler, never decoded
    shed_reason: Optional[str] = None    # "queue_full" | "deadline"
    cancelled: bool = False          # caller abandoned it (disconnect/timeout);
    #                                  the engine reaps it at the next step
    # telemetry (clock readings, filled in by the engine)
    submit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    # streaming hooks (set by the repro.server frontend; the engine calls
    # on_token per emitted token and on_finish exactly once per terminal
    # state — completed, truncated, or shed)
    on_token: Optional[Callable[["Request", int], None]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    on_finish: Optional[Callable[["Request"], None]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    _finish_fired: bool = \
        dataclasses.field(default=False, repr=False, compare=False)

    def fire_finish(self) -> bool:
        """Invoke ``on_finish`` exactly once, no matter how many terminal
        paths (shed, drain truncation, engine failure, normal completion)
        reach this request.  Returns True on the first (real) firing."""
        if self._finish_fired:
            return False
        self._finish_fired = True
        if self.on_finish is not None:
            self.on_finish(self)
        return True

    @property
    def ttft_s(self) -> Optional[float]:
        if self.submit_t is None or self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def deadline_t(self) -> Optional[float]:
        """Absolute first-token deadline (clock units), once submitted."""
        if self.deadline_s is None or self.submit_t is None:
            return None
        return self.submit_t + self.deadline_s

    def deadline_met(self) -> Optional[bool]:
        """Whether the first token arrived within the SLO (None: no SLO)."""
        if self.deadline_s is None:
            return None
        ttft = self.ttft_s
        return ttft is not None and ttft <= self.deadline_s

    @property
    def status(self) -> str:
        if self.shed:
            return "shed"
        if self.cancelled:
            return "cancelled"
        if self.truncated:
            return "truncated"
        if self.done:
            return "completed"
        return "pending" if not self.out_tokens else "running"


class SlotScheduler:
    """Admission of requests into a fixed set of decode slots.

    The default configuration (``policy="fifo"``, ``max_pending=None``) is
    bit-compatible with the original FIFO scheduler.
    """

    POLICIES = ("fifo", "priority")

    def __init__(self, slots: int, policy: str = "fifo",
                 max_pending: Optional[int] = None,
                 clock: Optional[Callable[[], float]] = None,
                 obs=None):
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"expected one of {self.POLICIES}")
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.slots = slots
        self.policy = policy
        self.max_pending = max_pending
        self.clock = clock
        self.obs = obs   # optional repro.obs.ObsBus (shed trace events)
        self.pending: Deque[Request] = collections.deque()
        self.active: Dict[int, Request] = {}
        self.shed_requests: List[Request] = []

    # ---- queue side ----------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Queue a request; returns False when it was shed instead.

        With a bounded queue, a full queue sheds the lowest-priority /
        latest-queued victim when the newcomer outranks it, otherwise the
        newcomer itself — strict backpressure either way.
        """
        if self.max_pending is not None \
                and len(self.pending) >= self.max_pending:
            victim_i = min(range(len(self.pending)),
                           key=lambda i: (self.pending[i].priority, -i))
            victim = self.pending[victim_i]
            if self.policy == "priority" and req.priority > victim.priority:
                del self.pending[victim_i]
                self._shed(victim, "queue_full")
            else:
                self._shed(req, "queue_full")
                return False
        self.pending.append(req)
        return True

    @property
    def n_pending(self) -> int:
        return len(self.pending)

    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def n_shed(self) -> int:
        return len(self.shed_requests)

    def drained(self) -> bool:
        return not self.pending and not self.active

    # ---- shedding ------------------------------------------------------------

    def _shed(self, req: Request, reason: str) -> None:
        req.shed = req.done = True
        req.shed_reason = reason
        if self.clock is not None:
            req.finish_t = self.clock()
        self.shed_requests.append(req)
        if self.obs is not None:
            self.obs.event("request_shed", uid=req.uid, reason=reason,
                           priority=getattr(req.priority, "name",
                                            str(req.priority)),
                           queue_depth=len(self.pending))
        req.fire_finish()

    def expire_deadlines(self) -> List[Request]:
        """Shed queued requests whose TTFT deadline has already passed
        (priority policy with a clock only; FIFO never sheds)."""
        if self.policy != "priority" or self.clock is None:
            return []
        now = self.clock()
        expired = [r for r in self.pending
                   if r.deadline_t is not None and now > r.deadline_t]
        if expired:
            self.pending = collections.deque(
                r for r in self.pending if not (r.deadline_t is not None
                                                and now > r.deadline_t))
            for r in expired:
                self._shed(r, "deadline")
        return expired

    # ---- slot side -----------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i in range(self.slots) if i not in self.active]

    def _pop_next(self) -> Request:
        if self.policy == "fifo":
            return self.pending.popleft()
        # highest tier first; earliest absolute deadline within a tier
        # (requests without an SLO sort last); FIFO as the final tiebreak
        best = min(range(len(self.pending)),
                   key=lambda i: (-self.pending[i].priority,
                                  self.pending[i].deadline_t
                                  if self.pending[i].deadline_t is not None
                                  else float("inf"),
                                  i))
        req = self.pending[best]
        del self.pending[best]
        return req

    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots from the pending queue; returns the new
        (slot, request) assignments.  FIFO order under the default policy;
        priority/EDF order (after shedding expired deadlines) under
        ``policy="priority"``."""
        self.expire_deadlines()
        out: List[Tuple[int, Request]] = []
        for slot in self.free_slots():
            if not self.pending:
                break
            req = self._pop_next()
            self.active[slot] = req
            out.append((slot, req))
        return out

    def evict(self, slot: int) -> Request:
        if slot not in self.active:
            raise KeyError(f"slot {slot} is not active")
        return self.active.pop(slot)
