"""Slot scheduler for continuous batching.

Pure-python admission/eviction bookkeeping, kept model-free so the policy is
unit-testable without touching jax: a fixed number of decode slots, a FIFO
pending queue, and a slot -> request map.  The engine asks ``admit()`` for
newly filled slots each iteration and ``evict()``s a slot the moment its
request finishes — a new request then rides the very next decode step while
the other slots keep decoding (no head-of-line blocking).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    frames: Optional[Any] = None     # encdec only: (1, t_enc, d) frame embeds
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False          # cut short (budget / max_len), NOT completed
    # telemetry (wall-clock, filled in by the engine)
    submit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None

    @property
    def ttft_s(self) -> Optional[float]:
        if self.submit_t is None or self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t


class SlotScheduler:
    """FIFO admission of requests into a fixed set of decode slots."""

    def __init__(self, slots: int):
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        self.slots = slots
        self.pending: Deque[Request] = collections.deque()
        self.active: Dict[int, Request] = {}

    # ---- queue side ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    @property
    def n_pending(self) -> int:
        return len(self.pending)

    @property
    def n_active(self) -> int:
        return len(self.active)

    def drained(self) -> bool:
        return not self.pending and not self.active

    # ---- slot side -----------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i in range(self.slots) if i not in self.active]

    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots from the pending queue (FIFO); returns the new
        (slot, request) assignments."""
        out: List[Tuple[int, Request]] = []
        for slot in self.free_slots():
            if not self.pending:
                break
            req = self.pending.popleft()
            self.active[slot] = req
            out.append((slot, req))
        return out

    def evict(self, slot: int) -> Request:
        if slot not in self.active:
            raise KeyError(f"slot {slot} is not active")
        return self.active.pop(slot)
