"""Batched serving engine: wave-scheduled static batching.

Requests queue up; the scheduler forms waves of up to ``slots`` requests,
left-pads prompts to a common length with BOS (a *valid* model input — no
masking surgery needed, so the engine is correct for every family including
SSM/hybrid states), absorbs the prompt teacher-forced, then decodes greedily
until every request in the wave completes.

Continuous (per-slot) batching with per-request cache indices is the
production extension; the wave engine is the correct, testable core and is
what the decode_32k dry-run cells lower.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from ..models import model_api

Pytree = Any

BOS = 2


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    decode_steps: int = 0
    waves: int = 0
    completed: int = 0
    tokens_generated: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Pytree, slots: int = 4,
                 max_len: int = 128):
        self.cfg = cfg
        self.api = model_api(cfg)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.queue: List[Request] = []
        self.stats = EngineStats()
        self._shape = ShapeConfig("serve", max_len, slots, "decode")
        self._step = jax.jit(self.api.decode_step)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fresh_state(self) -> Pytree:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.api.decode_state_specs(self._shape),
                            is_leaf=lambda x: hasattr(x, "struct"))

    def run_until_drained(self, max_steps: int = 10_000) -> EngineStats:
        budget = max_steps
        while self.queue and budget > 0:
            wave = [self.queue.pop(0) for _ in range(min(self.slots,
                                                         len(self.queue)))]
            budget -= self._run_wave(wave, budget)
        return self.stats

    def _run_wave(self, wave: List[Request], budget: int) -> int:
        self.stats.waves += 1
        n = len(wave)
        plen = max(max(len(r.prompt) for r in wave), 1)
        toks = np.full((self.slots, plen), BOS, np.int32)
        for i, r in enumerate(wave):
            if r.prompt:
                toks[i, plen - len(r.prompt):] = r.prompt   # BOS-prefix pad
        state = self._fresh_state()
        steps = 0

        # absorb prompt (teacher-forced): feed tokens 0..plen-2
        logits = None
        for t in range(plen):
            logits, state = self._step(self.params, state,
                                       jnp.asarray(toks[:, t:t + 1]))
            self.stats.decode_steps += 1
            steps += 1

        # decode
        cur = np.array([int(np.argmax(np.asarray(logits)[i]))
                        for i in range(self.slots)], np.int32)
        max_new = max(r.max_new_tokens for r in wave)
        for _ in range(min(max_new, self.max_len - plen - 1, budget - steps)):
            for i, r in enumerate(wave):
                if not r.done:
                    r.out_tokens.append(int(cur[i]))
                    self.stats.tokens_generated += 1
                    if len(r.out_tokens) >= r.max_new_tokens:
                        r.done = True
                        self.stats.completed += 1
            if all(r.done for r in wave):
                break
            logits, state = self._step(self.params, state,
                                       jnp.asarray(cur[:, None]))
            self.stats.decode_steps += 1
            steps += 1
            cur = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
        for r in wave:
            if not r.done:
                r.done = True
                self.stats.completed += 1
        return steps
