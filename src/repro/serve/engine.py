"""Batched serving engines.

``ServeEngine`` is the production path: **continuous (per-slot) batching**.
A ``SlotScheduler`` admits a request into any free decode slot mid-flight;
its prompt is absorbed in one batched ``api.prefill`` call (SSM/hybrid
families, whose state is O(1), absorb token-by-token at batch 1) and the
resulting batch-1 state is scattered into the live batch with
``api.slot_update`` — no other slot recomputes anything.  Each model step
then decodes one token for every occupied slot; a finished request's slot is
refilled on the very next iteration.  Mixed prompt/output lengths therefore
never head-of-line block: per-request outputs are bit-identical to a
``slots=1`` reference decode while total model steps drop strictly below the
wave engine's on mixed workloads.

``WaveServeEngine`` is the legacy wave-scheduled static batcher, kept as the
benchmark baseline: it forms waves of up to ``slots`` requests, left-pads
prompts to a common length with BOS and decodes until the *whole wave*
finishes — the head-of-line blocking the continuous engine removes.

Both engines share ``EngineStats`` telemetry: prefill vs decode model calls,
per-request TTFT, per-slot occupancy, and honest completion accounting —
requests cut short by the step budget or ``max_len`` are reported as
``truncated`` (never ``completed``), and requests still queued when the
budget runs out are ``unserved``.

``ServeEngine(backend=...)`` selects the ``repro.backend`` execution target
for ALL model GEMMs: ``backend="emulated"`` serves every decode matmul on
the fault-injecting voltage-scaled array, with per-step per-partition Razor
flags (``backend_step_flags``) and the backend's lifetime flag/replay/energy
summary (``backend_telemetry``) in ``EngineStats``.

Both engines read wall-clock time through an injectable ``clock`` callable
(default ``time.monotonic``): every latency stamp — ``Request.submit_t`` /
``first_token_t`` / ``finish_t`` and therefore ``ttft_s`` — comes from it,
so tests and the ``repro.server`` traffic harness swap in a virtual clock
and get bit-deterministic latency telemetry.

``ServeEngine(policy="priority", max_pending=N)`` forwards QoS admission to
the ``SlotScheduler``: priority tiers, TTFT-deadline shedding, and
bounded-queue backpressure (``submit()`` then returns False for a shed
request, and ``EngineStats.shed`` counts every drop).  The default
(``policy="fifo"``, unbounded) is bit-compatible with the seed engine.

Every engine owns a ``repro.obs.ObsBus`` sharing its clock:
``EngineStats`` scalar counters are registry-backed views (one source of
truth behind ``GET /metrics``), request lifecycle events
(submit/admit/prefill/decode-step/guard/finish) flow through the tracer
into the flight-recorder ring, and per-step backend telemetry lands as
flag/replay/energy counters + rate gauges.  Pass ``obs=ObsBus(
enabled=False)`` to disable tracing while keeping the stats registry.
"""

from __future__ import annotations

import collections
import time
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from ..models import model_api
from ..obs import ObsBus, to_plain
from .scheduler import Request, SlotScheduler

Pytree = Any

BOS = 2


# scalar EngineStats fields and the registry counters that back them
# (field -> (metric name, help)); declaration order pins to_dict()'s
# legacy key order
_STAT_COUNTERS = (
    ("prefill_steps", "serve_prefill_steps_total",
     "model calls spent absorbing prompts"),
    ("decode_steps", "serve_decode_steps_total",
     "batched one-token decode calls"),
    ("waves", "serve_waves_total", "wave-engine waves formed"),
    ("admitted", "serve_requests_admitted_total",
     "requests admitted into a decode slot"),
    ("completed", "serve_requests_completed_total",
     "requests served their full max_new_tokens"),
    ("truncated", "serve_requests_truncated_total",
     "requests cut short by budget or max_len"),
    ("unserved", "serve_requests_unserved_total",
     "requests still queued at drain"),
    ("shed", "serve_requests_shed_total",
     "requests dropped by admission (bounded queue / deadline)"),
    ("cancelled", "serve_requests_cancelled_total",
     "requests abandoned by the caller (disconnect/timeout)"),
    ("tokens_generated", "serve_tokens_generated_total",
     "tokens emitted to callers"),
)


class EngineStats:
    """Engine telemetry, now a *view* over an ``ObsBus`` registry.

    Scalar counters (``prefill_steps`` .. ``tokens_generated``) are
    properties backed by registry counters — ``stats.completed += 1``
    and a ``GET /metrics`` scrape read the same cell, so there is one
    source of truth and nothing to double-count.  Aggregate fields
    (per-slot occupancy lists, TTFT samples, hwloop/backend summaries)
    stay plain attributes.  ``to_dict()`` is bit-compatible with the
    pre-bus dataclass serialization (same keys, same order, same
    values).
    """

    def __init__(self, slot_busy_steps: Optional[List[int]] = None,
                 backend: Optional[str] = None, obs=None) -> None:
        self.obs = obs if obs is not None else ObsBus()
        reg = self.obs.registry
        self._counters = {
            field: reg.counter(metric, help)
            for field, metric, help in _STAT_COUNTERS}
        self._ttft_hist = reg.histogram(
            "serve_ttft_seconds", "submit to first emitted token (s)")
        self.slot_busy_steps: List[int] = list(slot_busy_steps or [])
        self.ttft_s: List[float] = []
        # hardware-in-the-loop emulation telemetry (continuous engine with
        # a repro.hwloop session attached; empty/None otherwise): per
        # decode step the per-partition Razor flags, plus the session's
        # final summary (flag rates, rails, recalibrations, energy/token)
        self.hwloop_step_flags: List[List[bool]] = []
        self.hwloop: Optional[Dict[str, Any]] = None
        # execution-backend telemetry (continuous engine with a non-ideal
        # repro.backend attached): the backend's name, per-decode-step
        # per-partition Razor flags from the REAL model GEMMs, and the
        # backend's lifetime summary (flags, replays, energy/token)
        self.backend: Optional[str] = backend
        self.backend_step_flags: List[List[bool]] = []
        self.backend_telemetry: Optional[Dict[str, Any]] = None
        # ABFT guard events (GuardedBackend only): one entry per decode
        # step on which the guard did anything — {"step": decode step
        # index, plus the non-zero guard_* counters of that step's GEMMs}
        self.guard_step_events: List[Dict[str, int]] = []
        # closed-loop rail autoscaler summary (continuous engine with a
        # repro.railscale.Autoscaler attached; None otherwise): policy,
        # final ladder level/rails, transition + heal-preemption counts
        self.railscale: Optional[Dict[str, Any]] = None

    def record_ttft(self, ttft: float) -> None:
        """One TTFT sample: keeps the raw list (bit-compatible to_dict)
        and feeds the latency histogram behind ``/metrics``."""
        self.ttft_s.append(ttft)
        self._ttft_hist.observe(ttft)

    @property
    def model_steps(self) -> int:
        """Total model invocations — the cost both engines are compared on."""
        return self.prefill_steps + self.decode_steps

    def occupancy(self) -> List[float]:
        """Per-slot fraction of decode steps spent on a live request."""
        d = max(self.decode_steps, 1)
        return [b / d for b in self.slot_busy_steps]

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {field: getattr(self, field)
                               for field, _, _ in _STAT_COUNTERS}
        out.update(
            slot_busy_steps=self.slot_busy_steps,
            ttft_s=self.ttft_s,
            hwloop_step_flags=self.hwloop_step_flags,
            hwloop=self.hwloop,
            backend=self.backend,
            backend_step_flags=self.backend_step_flags,
            backend_telemetry=self.backend_telemetry,
            guard_step_events=self.guard_step_events,
            railscale=self.railscale,
            model_steps=self.model_steps,
            occupancy=self.occupancy(),
            ttft_mean_s=(sum(self.ttft_s) / len(self.ttft_s)
                         if self.ttft_s else None),
        )
        return to_plain(out)


def _counter_property(field: str) -> property:
    def fget(self) -> int:
        return int(self._counters[field].value())

    def fset(self, value) -> None:
        self._counters[field].set(float(value))

    return property(fget, fset)


for _field, _metric, _help in _STAT_COUNTERS:
    setattr(EngineStats, _field, _counter_property(_field))
del _field, _metric, _help


class ServeEngine:
    """Continuous-batching engine over a fixed number of decode slots."""

    def __init__(self, cfg: ModelConfig, params: Pytree, slots: int = 4,
                 max_len: int = 128, hwloop=None, backend=None,
                 clock: Callable[[], float] = time.monotonic,
                 policy: str = "fifo", max_pending: Optional[int] = None,
                 obs: Optional[ObsBus] = None, autoscaler=None):
        self.cfg = cfg
        self._clock = clock
        # one ObsBus per engine (never process-global: virtual-time runs
        # must replay bit-identically), sharing the engine clock so
        # latency histograms are deterministic under the load harness
        self.obs = obs if obs is not None else ObsBus(clock=clock)
        # execution backend for ALL model GEMMs (a repro.backend name or
        # instance): "emulated" serves every decode matmul on the
        # fault-injecting voltage-scaled array with flag/energy telemetry
        if backend is not None:
            from ..backend import get_backend
            backend = get_backend(backend)
        self.backend = backend
        self._track_backend = backend is not None and not backend.is_ideal
        self.api = model_api(cfg, backend=backend)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        # optional repro.hwloop.HwLoopSession (duck-typed to avoid importing
        # the hwloop package here).  Legacy mode (no emulated backend): each
        # decode step's emitted tokens drive one probe-traffic accelerator
        # step.  With an emulated backend the session becomes a THIN ADAPTER:
        # no probe traffic — the backend's real per-step GEMM flags feed its
        # CalibrationWatchdog, and rail heals land on the serving device.
        self.hwloop = hwloop
        self._hwloop_adapter = (hwloop is not None
                                and hasattr(backend, "accel"))
        if self._hwloop_adapter:
            self.hwloop.attach_accelerator(backend.accel)
            if backend.is_guarded:
                # the guard's escalation ladder heals rails THROUGH the
                # watchdog rather than jumping straight to nominal
                backend.attach_session(hwloop)
        self.scheduler = SlotScheduler(slots, policy=policy,
                                       max_pending=max_pending, clock=clock,
                                       obs=self.obs)
        self.stats = EngineStats(
            slot_busy_steps=[0] * slots,
            backend=backend.name if backend is not None else None,
            obs=self.obs)
        reg = self.obs.registry
        self._g_queue_depth = reg.gauge(
            "serve_queue_depth", "requests waiting for a decode slot")
        self._g_active = reg.gauge(
            "serve_active_slots", "slots serving a live request")
        reg.gauge("serve_slots", "configured decode slots").set(slots)
        self._h_queue_wait = reg.histogram(
            "serve_queue_wait_seconds", "submit to slot admission (s)")
        if backend is not None and hasattr(backend, "attach_obs"):
            backend.attach_obs(self.obs)   # callback latency + guard events
        if hwloop is not None and hasattr(hwloop, "attach_obs"):
            hwloop.attach_obs(self.obs)    # recalibrations + rail gauges
        if self._track_backend:
            self._c_gemms = reg.counter(
                "backend_gemm_calls_total", "backend matmul invocations")
            self._c_macs = reg.counter(
                "backend_macs_total", "multiply-accumulates executed")
            self._c_flags = reg.counter(
                "backend_flags_total", "Razor DETECTED flags raised")
            self._c_replays = reg.counter(
                "backend_replays_total", "partition-cycle replays")
            self._c_energy = reg.counter(
                "backend_energy_joules_total", "emulated array energy (J)")
            self._g_flag_rate = reg.gauge(
                "serve_flag_rate",
                "lifetime flags per partition-step observation")
            self._g_replay_rate = reg.gauge(
                "serve_replay_rate", "lifetime replays per GEMM call")
            self._g_energy_per_token = reg.gauge(
                "serve_energy_per_token_joules",
                "lifetime backend energy / tokens generated (J)")
            self._c_guard = reg.counter(
                "guard_events_total",
                "ABFT guard escalation events by kind", labels=("kind",))
            self._flag_slots = 0   # partition-step observations seen
        # optional repro.railscale.Autoscaler (duck-typed): closed-loop
        # energy-aware rail control.  Attached last so it sees the fully
        # wired ObsBus/hwloop; ticked once per decode step AFTER that
        # step's telemetry (queue gauges, backend counters, hwloop
        # flags/heals) has been published — its decisions read only the
        # registry, so virtual-time runs stay bit-deterministic.
        self.autoscaler = autoscaler
        if autoscaler is not None:
            autoscaler.attach(self)
        self._shape = ShapeConfig("serve", max_len, slots, "decode")
        self._sub_shape = ShapeConfig("serve", max_len, 1, "decode")
        self._state = self.api.make_decode_state(self._shape)
        self._cur = np.full((slots,), BOS, np.int32)   # next token per slot
        self._step = jax.jit(self.api.decode_step)
        self._inject = jax.jit(
            lambda state, slot, sub: self.api.slot_update(
                self._shape, state, slot, sub))
        # dense/moe/vlm/encdec absorb the whole prompt in ONE prefill call
        # (jit recompiles per distinct prompt length); SSM/hybrid state is
        # O(1) so the prompt is absorbed by decode steps at batch 1.
        self._has_prefill = cfg.family in ("dense", "moe", "vlm", "encdec")
        if self._has_prefill:
            self._prefill = jax.jit(self.api.prefill,
                                    static_argnames=("max_len",))

    # ---- intake --------------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Queue a request.  Returns False when the scheduler shed it on
        admission (bounded queue under the priority policy) — the request
        never decodes and ``EngineStats.shed`` counts it."""
        req.submit_t = self._clock()
        accepted = self.scheduler.submit(req)
        self.stats.shed = self.scheduler.n_shed
        self._g_queue_depth.set(self.scheduler.n_pending)
        self.obs.event("request_submitted", uid=req.uid,
                       priority=getattr(req.priority, "name",
                                        str(req.priority)),
                       accepted=accepted,
                       queue_depth=self.scheduler.n_pending)
        return accepted

    # for callers poking at the backlog (launchers, tests)
    @property
    def queue(self):
        return self.scheduler.pending

    # ---- prompt absorption ---------------------------------------------------

    def _absorb(self, req: Request):
        """Absorb one request's prompt at batch 1.

        Returns (last-position logits (1, V), batch-1 decode state, model
        calls spent)."""
        prompt = req.prompt if req.prompt else [BOS]
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None, :])
        if self._has_prefill:
            batch: Dict[str, jax.Array] = {"tokens": toks}
            if self.cfg.family == "encdec":
                t_enc = self.max_len // self.cfg.enc_frames_ratio
                batch["frames"] = (
                    jnp.asarray(req.frames, jnp.bfloat16)
                    if req.frames is not None else
                    jnp.zeros((1, t_enc, self.cfg.d_model), jnp.bfloat16))
            logits, sub = self._prefill(self.params, batch,
                                        max_len=self.max_len)
            return logits, sub, 1
        sub = self.api.make_decode_state(self._sub_shape)
        logits = None
        for t in range(toks.shape[1]):
            logits, sub = self._step(self.params, sub, toks[:, t:t + 1])
        return logits, sub, toks.shape[1]

    # ---- engine loop ---------------------------------------------------------

    def _emit(self, slot: int, req: Request, tok: int) -> None:
        req.out_tokens.append(tok)
        if req.first_token_t is None:
            req.first_token_t = self._clock()
            if req.submit_t is not None:
                self.stats.record_ttft(req.first_token_t - req.submit_t)
        self._cur[slot] = tok
        self.stats.tokens_generated += 1
        if req.on_token is not None:
            req.on_token(req, tok)

    def _finished(self, req: Request) -> None:
        """Terminal-state bookkeeping shared by every finish site.

        ``fire_finish`` is idempotent, so a request that reaches several
        terminal paths (e.g. cancelled by the client while the drain loop
        truncates it) still delivers ``on_finish`` exactly once."""
        req.finish_t = self._clock()
        self.obs.event("request_finished", uid=req.uid, status=req.status,
                       n_tokens=len(req.out_tokens))
        req.fire_finish()

    def _reap_cancelled(self) -> None:
        """Release slots (and queue positions) of requests their caller
        abandoned — client disconnect / request timeout.  A cancelled request
        is terminal but neither completed nor truncated."""
        for slot, req in list(self.scheduler.active.items()):
            if req.cancelled and not req.done:
                req.done = True
                self.stats.cancelled += 1
                self.scheduler.evict(slot)
                self._cur[slot] = BOS
                self._finished(req)
        if any(r.cancelled for r in self.scheduler.pending):
            keep: List[Request] = []
            for req in self.scheduler.pending:
                if req.cancelled and not req.done:
                    req.done = True
                    self.stats.cancelled += 1
                    self._finished(req)
                else:
                    keep.append(req)
            self.scheduler.pending = collections.deque(keep)

    def _maybe_finish(self, slot: int, req: Request) -> None:
        # generating n tokens writes n-1 of them into the cache (positions
        # plen .. plen+n-2), so n <= max_len - plen keeps a safety margin
        cap = self.max_len - max(len(req.prompt), 1)
        if len(req.out_tokens) >= req.max_new_tokens:
            req.done = True
            self.stats.completed += 1
            self.scheduler.evict(slot)
            self._cur[slot] = BOS          # idle slots are fed BOS
            self._finished(req)
        elif len(req.out_tokens) >= cap:
            req.done = req.truncated = True
            self.stats.truncated += 1
            self.scheduler.evict(slot)
            self._cur[slot] = BOS
            self._finished(req)

    def _admit(self, budget: int) -> int:
        """Fill free slots until the queue, the slots, or the budget run out.
        Absorption is atomic per request, so the budget can overshoot by at
        most one prompt's absorption cost.  Returns model calls used."""
        used = 0
        while used < budget:
            admissions = self.scheduler.admit()
            if not admissions:
                break
            deferred = []
            for slot, req in admissions:
                if used >= budget:
                    deferred.append((slot, req))
                    continue
                if len(req.prompt) >= self.max_len:
                    # cannot absorb at all: report, never serve garbage
                    req.done = req.truncated = True
                    self.stats.truncated += 1
                    self.scheduler.evict(slot)
                    self._finished(req)
                    continue
                wait_s = (self._clock() - req.submit_t
                          if req.submit_t is not None else 0.0)
                self._h_queue_wait.observe(wait_s)
                self.obs.event("request_admitted", uid=req.uid, slot=slot,
                               queue_wait_s=wait_s)
                with self.obs.span("prefill", uid=req.uid, slot=slot,
                                   prompt_len=len(req.prompt)):
                    logits, sub, n = self._absorb(req)
                used += n
                self.stats.prefill_steps += n
                self.stats.admitted += 1
                self._state = self._inject(self._state, jnp.int32(slot), sub)
                self._emit(slot, req, int(np.asarray(logits)[0].argmax()))
                self._maybe_finish(slot, req)   # max_new_tokens == 1
            if deferred:
                # out of budget mid-batch: hand the slots back and restore
                # the requests to the FRONT of the queue in FIFO order
                for slot, req in reversed(deferred):
                    self.scheduler.evict(slot)
                    self.scheduler.pending.appendleft(req)
                break
        return used

    def _publish_backend_step(self, tel, step_flags: List[bool]) -> None:
        """Fold one decode step's backend telemetry into the registry:
        cumulative counters plus the derived rate/energy gauges the
        autoscaler (ROADMAP item 3) reads as control inputs."""
        self._c_gemms.inc(max(float(tel.calls), 0.0))
        self._c_macs.inc(max(float(tel.macs), 0.0))
        self._c_flags.inc(max(float(tel.flags), 0.0))
        self._c_replays.inc(max(float(tel.replays), 0.0))
        self._c_energy.inc(max(float(tel.energy_j), 0.0))
        self._flag_slots += len(step_flags)
        if self._flag_slots:
            self._g_flag_rate.set(
                self._c_flags.value() / self._flag_slots)
        calls = self._c_gemms.value()
        if calls:
            self._g_replay_rate.set(self._c_replays.value() / calls)
        tokens = self.stats.tokens_generated
        if tokens:
            self._g_energy_per_token.set(
                self._c_energy.value() / tokens)

    def step(self, budget: int = 2 ** 31) -> int:
        """One engine iteration: admit into free slots, then one batched
        decode step.  Idle slots are fed BOS and skipped in argmax/token
        bookkeeping.  Returns model calls used."""
        self._reap_cancelled()
        used = self._admit(budget)
        self.stats.shed = self.scheduler.n_shed
        self._reap_cancelled()
        if not self.scheduler.active or used >= budget:
            return used
        if self._track_backend:
            # prefill GEMM telemetry stays in the backend totals but must not
            # pollute the next decode step's flag vector
            self.backend.pop_telemetry()
        span = self.obs.span("decode_step", step=self.stats.decode_steps,
                             active=len(self.scheduler.active))
        logits, self._state = self._step(self.params, self._state,
                                         jnp.asarray(self._cur[:, None]))
        self.stats.decode_steps += 1
        used += 1
        lg = np.asarray(logits)
        step_tokens: List[int] = []
        for slot, req in list(self.scheduler.active.items()):
            self.stats.slot_busy_steps[slot] += 1
            tok = int(lg[slot].argmax())
            self._emit(slot, req, tok)
            step_tokens.append(tok)
            self._maybe_finish(slot, req)
        step_flags: Optional[List[bool]] = None
        if self._track_backend:
            tel = self.backend.pop_telemetry()   # this decode step's GEMMs
            step_flags = [bool(f) for f in (tel.partition_flags or [])]
            self.stats.backend_step_flags.append(step_flags)
            self.backend.add_tokens(len(step_tokens))
            self._publish_backend_step(tel, step_flags)
            if self.backend.is_guarded:
                ev = {k: int(getattr(tel, k)) for k in (
                    "guard_detected", "guard_corrected", "guard_retries",
                    "guard_heals", "guard_uncorrected")
                    if getattr(tel, k)}
                if ev:
                    self.stats.guard_step_events.append(
                        {"step": self.stats.decode_steps - 1, **ev})
                    self.obs.event("guard_step",
                                   step=self.stats.decode_steps - 1, **ev)
                    for k, v in ev.items():
                        self._c_guard.inc(v, kind=k[len("guard_"):])
        span.set(tokens=len(step_tokens),
                 flags=sum(step_flags) if step_flags else 0)
        span.end()
        self._g_queue_depth.set(self.scheduler.n_pending)
        self._g_active.set(len(self.scheduler.active))
        if self.hwloop is not None and step_tokens:
            if self._hwloop_adapter:
                # thin adapter: real GEMM flags -> watchdog -> rail heal
                self.hwloop.observe_flags(step_flags or [])
                self.stats.hwloop_step_flags.append(step_flags or [])
            else:
                tel = self.hwloop.step(step_tokens, n_tokens=len(step_tokens))
                self.stats.hwloop_step_flags.append(
                    [bool(f) for f in np.asarray(tel.flags)])
        if self.autoscaler is not None:
            self.autoscaler.on_decode_step()
        return used

    def run_until_drained(self, max_steps: int = 10_000) -> EngineStats:
        budget = max_steps
        while not self.scheduler.drained() and budget > 0:
            used = self.step(budget)
            if used == 0:        # no admissible work fit in the budget
                break
            budget -= used
        # honest accounting on exhaustion: in-flight requests are truncated,
        # queued ones unserved — neither is "completed"
        for slot in list(self.scheduler.active):
            req = self.scheduler.evict(slot)
            req.done = req.truncated = True
            self.stats.truncated += 1
            self._finished(req)
        self.stats.unserved = self.scheduler.n_pending
        self.stats.shed = self.scheduler.n_shed
        if self.hwloop is not None:
            self.stats.hwloop = self.hwloop.summary()
        if self._track_backend:
            self.stats.backend_telemetry = self.backend.summary()
        if self.autoscaler is not None:
            self.stats.railscale = self.autoscaler.summary()
        return self.stats


class WaveServeEngine:
    """Legacy wave-scheduled static batching (benchmark baseline).

    Forms waves of up to ``slots`` requests, left-pads prompts to a common
    length with BOS (a *valid* model input — no masking surgery needed, so
    the engine is correct for every family including SSM/hybrid states),
    absorbs the prompt teacher-forced, then decodes greedily until every
    request in the wave completes — the head-of-line blocking that
    ``ServeEngine`` removes.
    """

    def __init__(self, cfg: ModelConfig, params: Pytree, slots: int = 4,
                 max_len: int = 128,
                 clock: Callable[[], float] = time.monotonic,
                 obs: Optional[ObsBus] = None):
        self.cfg = cfg
        self._clock = clock
        self.obs = obs if obs is not None else ObsBus(clock=clock)
        self.api = model_api(cfg)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.queue: Deque[Request] = collections.deque()   # O(1) pops
        self.stats = EngineStats(slot_busy_steps=[0] * slots, obs=self.obs)
        self._shape = ShapeConfig("serve", max_len, slots, "decode")
        self._step = jax.jit(self.api.decode_step)

    def submit(self, req: Request) -> None:
        req.submit_t = self._clock()
        self.queue.append(req)

    def run_until_drained(self, max_steps: int = 10_000) -> EngineStats:
        budget = max_steps
        while self.queue and budget > 0:
            wave = [self.queue.popleft()
                    for _ in range(min(self.slots, len(self.queue)))]
            budget -= self._run_wave(wave, budget)
        self.stats.unserved = len(self.queue)
        return self.stats

    def _run_wave(self, wave: List[Request], budget: int) -> int:
        self.stats.waves += 1
        n = len(wave)
        plen = max(max(len(r.prompt) for r in wave), 1)
        toks = np.full((self.slots, plen), BOS, np.int32)
        for i, r in enumerate(wave):
            if r.prompt:
                toks[i, plen - len(r.prompt):] = r.prompt   # BOS-prefix pad
        state = self.api.make_decode_state(self._shape)
        steps = 0

        # absorb prompt (teacher-forced): feed all plen prompt positions; the
        # logits from the last feed predict each request's first new token
        logits = None
        for t in range(plen):
            logits, state = self._step(self.params, state,
                                       jnp.asarray(toks[:, t:t + 1]))
            self.stats.prefill_steps += 1
            steps += 1

        cur = np.full((self.slots,), BOS, np.int32)
        lg = np.asarray(logits)
        for i in range(n):                     # idle rows skip argmax
            cur[i] = lg[i].argmax()
        max_new = max(r.max_new_tokens for r in wave)
        self.stats.admitted += n
        for _ in range(min(max_new, self.max_len - plen - 1,
                           max(budget - steps, 0))):
            for i, r in enumerate(wave):
                if not r.done:
                    r.out_tokens.append(int(cur[i]))
                    if r.first_token_t is None:
                        r.first_token_t = self._clock()
                        if r.submit_t is not None:
                            self.stats.record_ttft(
                                r.first_token_t - r.submit_t)
                    self.stats.tokens_generated += 1
                    if len(r.out_tokens) >= r.max_new_tokens:
                        r.done = True
                        r.finish_t = self._clock()
                        self.stats.completed += 1
                        r.fire_finish()
            if all(r.done for r in wave):
                break
            logits, state = self._step(self.params, state,
                                       jnp.asarray(cur[:, None]))
            self.stats.decode_steps += 1
            steps += 1
            for i, r in enumerate(wave):
                if not r.done:
                    self.stats.slot_busy_steps[i] += 1
            lg = np.asarray(logits)
            for i in range(n):                 # idle rows skip argmax
                cur[i] = lg[i].argmax()
        for r in wave:
            if not r.done:
                # ran out of budget or cache length: this request did NOT
                # receive its max_new_tokens — report it truncated
                r.done = r.truncated = True
                r.finish_t = self._clock()
                self.stats.truncated += 1
                r.fire_finish()
        return steps
