"""Batched serving engines: continuous per-slot batching (``ServeEngine``)
plus the legacy wave-scheduled baseline (``WaveServeEngine``)."""
from .engine import BOS, EngineStats, ServeEngine, WaveServeEngine
from .scheduler import Request, SlotScheduler
