"""Batched serving engine (continuous batching, fixed decode slots)."""
from .engine import EngineStats, Request, ServeEngine
