"""Batched serving engines: continuous per-slot batching (``ServeEngine``)
plus the legacy wave-scheduled baseline (``WaveServeEngine``).

``Request``/``Priority`` are the public request surface — import them from
here, not from ``serve.scheduler`` internals.
"""
from .engine import BOS, EngineStats, ServeEngine, WaveServeEngine
from .scheduler import Priority, Request, SlotScheduler

__all__ = ["BOS", "EngineStats", "Priority", "Request", "ServeEngine",
           "SlotScheduler", "WaveServeEngine"]
