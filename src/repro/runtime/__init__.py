"""Fault tolerance: heartbeats, straggler detection, elastic remapping, and
the repro.flow voltage-recalibration watchdog."""
from .monitor import (CalibrationWatchdog, ElasticPlan, HeartbeatMonitor,
                      HostState, StragglerReport, plan_elastic_remap)
