"""Fault tolerance: heartbeats, straggler detection, elastic remapping."""
from .monitor import (ElasticPlan, HeartbeatMonitor, HostState,
                      StragglerReport, plan_elastic_remap)
