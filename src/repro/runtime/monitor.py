"""Fault tolerance for 1000+-node runs: heartbeats, straggler detection and
elastic remapping (DESIGN.md Sec. 7).  On this CPU container the hosts are
simulated; the *logic* (what production agents would execute) is real and
fully tested with injected failures.

Control flow at scale:
  * every host heartbeats each step; the monitor marks a host dead after
    ``timeout_steps`` silent steps;
  * per-step durations feed a robust z-score; persistent outliers are flagged
    as stragglers (candidates for preemptive replacement);
  * on failure, ``ElasticPlan`` recomputes the largest usable mesh from the
    survivors, remaps data shards, and the trainer restores the last
    checkpoint (the deterministic data pipeline replays exactly);
  * :class:`CalibrationWatchdog` extends the same pattern to the paper's
    voltage islands: persistent Razor fail flags on a partition in
    production trigger a re-run of the :mod:`repro.flow` runtime-calibration
    stage (with cached upstream artifacts) to re-tune the rails.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat_step: int = -1
    durations: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True


@dataclasses.dataclass
class StragglerReport:
    host_id: int
    z_score: float
    median_s: float
    host_s: float


class HeartbeatMonitor:
    def __init__(self, num_hosts: int, timeout_steps: int = 3,
                 straggler_z: float = 3.0, straggler_patience: int = 3,
                 window: int = 16):
        self.hosts = {h: HostState(h) for h in range(num_hosts)}
        self.timeout_steps = timeout_steps
        self.straggler_z = straggler_z
        self.straggler_patience = straggler_patience
        self.window = window
        self._flag_counts: Dict[int, int] = {}

    def beat(self, host_id: int, step: int, duration_s: float) -> None:
        h = self.hosts[host_id]
        h.last_beat_step = step
        h.durations.append(duration_s)
        if len(h.durations) > self.window:
            h.durations.pop(0)

    def check_dead(self, step: int) -> List[int]:
        """Hosts that missed ``timeout_steps`` consecutive heartbeats."""
        dead = []
        for h in self.hosts.values():
            if h.alive and step - h.last_beat_step > self.timeout_steps:
                h.alive = False
                dead.append(h.host_id)
        return dead

    def stragglers(self) -> List[StragglerReport]:
        """Hosts whose recent step time is a persistent robust outlier."""
        live = [h for h in self.hosts.values() if h.alive and h.durations]
        if len(live) < 3:
            return []
        recents = {h.host_id: sum(h.durations[-4:]) / len(h.durations[-4:])
                   for h in live}
        vals = sorted(recents.values())
        med = vals[len(vals) // 2]
        mad = sorted(abs(v - med) for v in vals)[len(vals) // 2] or 1e-9
        out = []
        for hid, v in recents.items():
            z = 0.6745 * (v - med) / mad
            if z > self.straggler_z:
                self._flag_counts[hid] = self._flag_counts.get(hid, 0) + 1
                if self._flag_counts[hid] >= self.straggler_patience:
                    out.append(StragglerReport(hid, z, med, v))
            else:
                self._flag_counts[hid] = 0
        return out

    def alive_hosts(self) -> List[int]:
        return [h.host_id for h in self.hosts.values() if h.alive]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Result of an elastic remap: the new mesh shape and shard assignment."""

    data_parallel: int                  # new size of the data axis
    model_parallel: int                 # unchanged (TP groups must be whole)
    host_to_shard: Dict[int, int]
    dropped_hosts: Tuple[int, ...]

    @property
    def world(self) -> int:
        return self.data_parallel * self.model_parallel


def plan_elastic_remap(alive: Sequence[int], model_parallel: int,
                       hosts_per_dp_group: int = 1) -> ElasticPlan:
    """Largest data-parallel width that the surviving hosts can populate.

    TP groups are atomic (a dead host kills its whole model-parallel group);
    the data axis shrinks to the number of complete surviving groups.  At
    least one complete group must survive.
    """
    groups: Dict[int, List[int]] = {}
    for h in alive:
        groups.setdefault(h // hosts_per_dp_group, []).append(h)
    complete = [g for g, members in sorted(groups.items())
                if len(members) == hosts_per_dp_group]
    if not complete:
        raise RuntimeError("no complete model-parallel group survives")
    dp = len(complete)
    mapping = {}
    for shard, g in enumerate(complete):
        for h in sorted(groups[g]):
            mapping[h] = shard
    dropped = tuple(h for h in alive if h not in mapping)
    return ElasticPlan(data_parallel=dp, model_parallel=model_parallel,
                       host_to_shard=mapping, dropped_hosts=dropped)


# ---------------------------------------------------------------------------
# Voltage-island calibration watchdog (repro.flow integration)
# ---------------------------------------------------------------------------


class CalibrationWatchdog:
    """Heartbeat-style guard for the flow's runtime voltage scheme.

    In production the calibrated rails from the
    ``runtime_calibration`` stage can drift out of date (temperature,
    ageing, workload shift).  This watchdog consumes per-partition Razor
    fail flags each serving step — the same signal Algorithm 2 uses — and,
    when a partition fails ``patience`` consecutive steps (or its initial
    calibration never converged), re-runs the calibration stage through
    :mod:`repro.flow` with a bumped trial seed.  The shared artifact store
    means only calibration + downstream stages re-execute; the timing /
    clustering / floorplan prefix is reused from cache.
    """

    def __init__(self, config, patience: int = 3, store=None,
                 max_unconverged_retries: int = 3):
        from ..flow import ArtifactStore
        self.config = config
        self.patience = patience
        self.max_unconverged_retries = max_unconverged_retries
        self.store = store if store is not None else ArtifactStore()
        self.recalibrations = 0
        self._unconverged_retries = 0
        self.report = self._run(seed_bump=0)
        self._streak = np.zeros(self.report.n_partitions, dtype=np.int64)

    def _run(self, seed_bump: int):
        from ..flow import run
        cfg = self.config
        if seed_bump:
            # re-roll only the Razor trials: the timing/clustering prefix
            # stays cache-valid because ``seed`` itself is untouched
            cfg = cfg.replace(
                calibration_seed=cfg.resolved_calibration_seed() + seed_bump)
        return run(cfg, store=self.store)

    @property
    def runtime_v(self) -> np.ndarray:
        return np.asarray(self.report.runtime_v)

    def needs_recalibration(self) -> np.ndarray:
        """(P,) bool: partitions whose initial calibration never converged."""
        conv = self.report.calibration_converged
        if conv is None:
            return np.zeros(self.report.n_partitions, dtype=bool)
        return ~np.asarray(conv, dtype=bool)

    def observe(self, partition_fail_flags: Sequence[bool]):
        """Feed one serving step's per-partition Razor flags.

        Returns the fresh ``FlowReport`` when a recalibration was triggered
        (persistent failures or an unconverged initial calibration), else
        ``None`` — mirroring ``HeartbeatMonitor.check_dead``'s "act only on
        persistent signals" contract.
        """
        flags = np.asarray(partition_fail_flags, dtype=bool)
        if flags.shape != self._streak.shape:
            raise ValueError(
                f"expected {self._streak.shape[0]} partition flags, "
                f"got {flags.shape}")
        self._streak = np.where(flags, self._streak + 1, 0)
        persistent_fail = bool((self._streak >= self.patience).any())
        # an unconverged initial calibration warrants a bounded number of
        # re-rolls — not one per serving step, or a config that can never
        # converge would pay a full calibration every observe()
        retry_unconverged = (self.needs_recalibration().any()
                             and self._unconverged_retries
                             < self.max_unconverged_retries)
        if not (persistent_fail or retry_unconverged):
            return None
        if not persistent_fail:
            self._unconverged_retries += 1
        self.recalibrations += 1
        self.report = self._run(seed_bump=self.recalibrations)
        self._streak = np.zeros(self.report.n_partitions, dtype=np.int64)
        return self.report
