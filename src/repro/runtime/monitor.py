"""Fault tolerance for 1000+-node runs: heartbeats, straggler detection and
elastic remapping (DESIGN.md Sec. 7).  On this CPU container the hosts are
simulated; the *logic* (what production agents would execute) is real and
fully tested with injected failures.

Control flow at scale:
  * every host heartbeats each step; the monitor marks a host dead after
    ``timeout_steps`` silent steps;
  * per-step durations feed a robust z-score; persistent outliers are flagged
    as stragglers (candidates for preemptive replacement);
  * on failure, ``ElasticPlan`` recomputes the largest usable mesh from the
    survivors, remaps data shards, and the trainer restores the last
    checkpoint (the deterministic data pipeline replays exactly).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat_step: int = -1
    durations: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True


@dataclasses.dataclass
class StragglerReport:
    host_id: int
    z_score: float
    median_s: float
    host_s: float


class HeartbeatMonitor:
    def __init__(self, num_hosts: int, timeout_steps: int = 3,
                 straggler_z: float = 3.0, straggler_patience: int = 3,
                 window: int = 16):
        self.hosts = {h: HostState(h) for h in range(num_hosts)}
        self.timeout_steps = timeout_steps
        self.straggler_z = straggler_z
        self.straggler_patience = straggler_patience
        self.window = window
        self._flag_counts: Dict[int, int] = {}

    def beat(self, host_id: int, step: int, duration_s: float) -> None:
        h = self.hosts[host_id]
        h.last_beat_step = step
        h.durations.append(duration_s)
        if len(h.durations) > self.window:
            h.durations.pop(0)

    def check_dead(self, step: int) -> List[int]:
        """Hosts that missed ``timeout_steps`` consecutive heartbeats."""
        dead = []
        for h in self.hosts.values():
            if h.alive and step - h.last_beat_step > self.timeout_steps:
                h.alive = False
                dead.append(h.host_id)
        return dead

    def stragglers(self) -> List[StragglerReport]:
        """Hosts whose recent step time is a persistent robust outlier."""
        live = [h for h in self.hosts.values() if h.alive and h.durations]
        if len(live) < 3:
            return []
        recents = {h.host_id: sum(h.durations[-4:]) / len(h.durations[-4:])
                   for h in live}
        vals = sorted(recents.values())
        med = vals[len(vals) // 2]
        mad = sorted(abs(v - med) for v in vals)[len(vals) // 2] or 1e-9
        out = []
        for hid, v in recents.items():
            z = 0.6745 * (v - med) / mad
            if z > self.straggler_z:
                self._flag_counts[hid] = self._flag_counts.get(hid, 0) + 1
                if self._flag_counts[hid] >= self.straggler_patience:
                    out.append(StragglerReport(hid, z, med, v))
            else:
                self._flag_counts[hid] = 0
        return out

    def alive_hosts(self) -> List[int]:
        return [h.host_id for h in self.hosts.values() if h.alive]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Result of an elastic remap: the new mesh shape and shard assignment."""

    data_parallel: int                  # new size of the data axis
    model_parallel: int                 # unchanged (TP groups must be whole)
    host_to_shard: Dict[int, int]
    dropped_hosts: Tuple[int, ...]

    @property
    def world(self) -> int:
        return self.data_parallel * self.model_parallel


def plan_elastic_remap(alive: Sequence[int], model_parallel: int,
                       hosts_per_dp_group: int = 1) -> ElasticPlan:
    """Largest data-parallel width that the surviving hosts can populate.

    TP groups are atomic (a dead host kills its whole model-parallel group);
    the data axis shrinks to the number of complete surviving groups.  At
    least one complete group must survive.
    """
    groups: Dict[int, List[int]] = {}
    for h in alive:
        groups.setdefault(h // hosts_per_dp_group, []).append(h)
    complete = [g for g, members in sorted(groups.items())
                if len(members) == hosts_per_dp_group]
    if not complete:
        raise RuntimeError("no complete model-parallel group survives")
    dp = len(complete)
    mapping = {}
    for shard, g in enumerate(complete):
        for h in sorted(groups[g]):
            mapping[h] = shard
    dropped = tuple(h for h in alive if h not in mapping)
    return ElasticPlan(data_parallel=dp, model_parallel=model_parallel,
                       host_to_shard=mapping, dropped_hosts=dropped)
