"""Training loop: step builder + data pipeline + checkpointing + fault
tolerance, usable from CPU smoke scale to the production mesh.

The loop is deliberately restart-oriented: all state lives in
(params, opt_state, step); the data pipeline is stateless in `step`; a crash
at any point resumes bit-identically from the last checkpoint (tested).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import optim
from ..checkpoint.manager import CheckpointManager
from ..configs.base import ModelConfig, ShapeConfig
from ..data.pipeline import DataConfig, PrefetchLoader, SyntheticDataset
from ..models import model_api
from ..models.shardlib import Rules, replicated_rules, use_rules
from ..runtime.monitor import HeartbeatMonitor

Pytree = Any


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    async_checkpoint: bool = True
    seed: int = 0


@dataclasses.dataclass
class TrainResult:
    losses: List[float]
    steps_done: int
    final_params: Pytree
    final_opt_state: Pytree
    wall_s: float


def make_train_step(api, cfg: ModelConfig, opt_cfg: optim.AdamWConfig,
                    rules: Optional[Rules] = None, donate: bool = True):
    rules = rules or replicated_rules()

    def train_step(params, opt_state, batch):
        with use_rules(rules):
            loss, grads = jax.value_and_grad(api.loss)(params, batch)
            params, opt_state = optim.apply_updates(params, opt_state, grads,
                                                    opt_cfg)
        return params, opt_state, loss

    return jax.jit(train_step, donate_argnums=(0, 1) if donate else ())


def train(cfg: ModelConfig, shape: ShapeConfig,
          train_cfg: Optional[TrainConfig] = None,
          opt_cfg: Optional[optim.AdamWConfig] = None,
          rules: Optional[Rules] = None,
          monitor: Optional[HeartbeatMonitor] = None,
          resume: bool = False) -> TrainResult:
    train_cfg = train_cfg or TrainConfig()
    opt_cfg = opt_cfg or optim.AdamWConfig(total_steps=train_cfg.steps)
    api = model_api(cfg)

    params = api.init_params(jax.random.PRNGKey(train_cfg.seed))
    opt_state = optim.init_state(params, opt_cfg)
    start_step = 0

    ckpt = None
    if train_cfg.checkpoint_dir:
        ckpt = CheckpointManager(train_cfg.checkpoint_dir)
        if resume and ckpt.latest_step() is not None:
            state = ckpt.restore({"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            params = jax.tree.map(jnp.asarray, params)
            opt_state = jax.tree.map(jnp.asarray, opt_state)
            start_step = ckpt.latest_step()

    data_cfg = DataConfig(
        vocab_size=cfg.padded_vocab, seq_len=shape.seq_len,
        global_batch=shape.global_batch, seed=train_cfg.seed,
        mean_doc_len=max(shape.seq_len // 8, 8),   # learnable unigram signal
        frontend=cfg.frontend, frontend_tokens=cfg.frontend_tokens,
        d_model=cfg.d_model, enc_frames_ratio=cfg.enc_frames_ratio)
    dataset = SyntheticDataset(data_cfg)
    loader = PrefetchLoader(dataset, start_step=start_step)

    step_fn = make_train_step(api, cfg, opt_cfg, rules)

    losses: List[float] = []
    t0 = time.time()
    step = start_step
    try:
        for step in range(start_step, train_cfg.steps):
            batch_np = next(loader)
            batch = {k: jnp.asarray(v) for k, v in batch_np.data.items()}
            if cfg.frontend == "vision":
                # trim text to leave room for the patch prefix
                p = min(cfg.frontend_tokens, shape.seq_len // 2)
                batch["patch_embeds"] = batch["patch_embeds"][:, :p].astype(
                    jnp.bfloat16)
                batch["tokens"] = batch["tokens"][:, :shape.seq_len - p]
                batch["labels"] = batch["labels"][:, :shape.seq_len - p]
            t_step = time.time()
            params, opt_state, loss = step_fn(params, opt_state, batch)
            loss_f = float(loss)
            losses.append(loss_f)
            if monitor is not None:
                monitor.beat(0, step, time.time() - t_step)
            if not np.isfinite(loss_f):
                raise FloatingPointError(f"loss diverged at step {step}")
            if train_cfg.log_every and step % train_cfg.log_every == 0:
                print(f"step {step:5d} loss {loss_f:.4f} "
                      f"({time.time() - t_step:.2f}s)")
            if (ckpt and train_cfg.checkpoint_every
                    and (step + 1) % train_cfg.checkpoint_every == 0):
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          blocking=not train_cfg.async_checkpoint)
    finally:
        loader.close()
        if ckpt:
            ckpt.wait()

    return TrainResult(losses=losses, steps_done=step + 1 - start_step,
                       final_params=params, final_opt_state=opt_state,
                       wall_s=time.time() - t0)
