"""Training loop substrate."""
from .trainer import TrainConfig, TrainResult, make_train_step, train
