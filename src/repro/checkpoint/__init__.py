"""Sharded, async, elastically-reshardable checkpoints."""
from .manager import CheckpointManager
