"""Sharded checkpointing with async writes, atomic publication and elastic
resharding (DESIGN.md Sec. 7).

Layout:  <dir>/step_<n>/manifest.json + shard_<host>.npz
The manifest records the pytree structure, per-leaf global shape/dtype and
the writing mesh, so a restore may target a *different* mesh/host count —
leaves are reassembled from shards and re-split for the new topology.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

Pytree = Any

_SEP = "/"

# npz cannot serialize ml_dtypes (bfloat16, fp8): store raw bit views and
# reinterpret on restore using the manifest's logical dtype.
_BITCAST = {"bfloat16": (np.uint16, ml_dtypes.bfloat16),
            "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
            "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2)}


def _encode(arr: np.ndarray) -> np.ndarray:
    name = str(arr.dtype)
    if name in _BITCAST:
        return arr.view(_BITCAST[name][0])
    return arr


def _decode(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    if logical_dtype in _BITCAST:
        return arr.view(_BITCAST[logical_dtype][1])
    return arr.astype(logical_dtype)


def _flatten_with_names(tree: Pytree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path)
        out.append((name, leaf))
    return out


def _unflatten_like(template: Pytree, named: Dict[str, np.ndarray]) -> Pytree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path)
        arr = named[name]
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Host-sharded npz checkpoints.

    ``num_hosts``/``host_id`` simulate the multi-host layout on CPU: each
    host writes the rows of every leaf's leading axis it owns (leaves whose
    leading dim doesn't divide are written whole by host 0).
    """

    def __init__(self, directory: str | Path, host_id: int = 0,
                 num_hosts: int = 1, keep: int = 3):
        self.dir = Path(directory)
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.keep = keep
        self.dir.mkdir(parents=True, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # -- helpers -----------------------------------------------------------------

    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def _owned_slice(self, arr: np.ndarray, host: int) -> np.ndarray:
        n = arr.shape[0] if arr.ndim else 0
        if arr.ndim == 0 or n % self.num_hosts:
            return arr if host == 0 else arr[:0] if arr.ndim else arr
        per = n // self.num_hosts
        return arr[host * per:(host + 1) * per]

    # -- save --------------------------------------------------------------------

    def save(self, step: int, tree: Pytree, blocking: bool = True) -> Path:
        named = [(k, np.asarray(v)) for k, v in _flatten_with_names(tree)]
        tmp = self.dir / f".tmp_step_{step:08d}_{self.host_id}"
        final = self._step_dir(step)

        def _write() -> None:
            tmp.mkdir(parents=True, exist_ok=True)
            shard = {k: _encode(self._owned_slice(v, self.host_id))
                     for k, v in named}
            np.savez(tmp / f"shard_{self.host_id}.npz", **shard)
            if self.host_id == 0:
                manifest = {
                    "step": step,
                    "num_hosts": self.num_hosts,
                    "leaves": {k: {"shape": list(v.shape),
                                   "dtype": str(v.dtype)} for k, v in named},
                }
                (tmp / "manifest.json").write_text(json.dumps(manifest))
            # atomic publication: rename once the shard is complete
            final.mkdir(parents=True, exist_ok=True)
            for f in tmp.iterdir():
                os.replace(f, final / f.name)
            shutil.rmtree(tmp, ignore_errors=True)
            self._gc()

        if blocking:
            _write()
        else:
            self.wait()
            self._async_thread = threading.Thread(target=_write, daemon=True)
            self._async_thread.start()
        return final

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -----------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Pytree, step: Optional[int] = None) -> Pytree:
        """Reassemble the full tree from however many shards were written
        (elastic: the reading topology is independent of the writing one)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        shards = [np.load(d / f"shard_{h}.npz")
                  for h in range(manifest["num_hosts"])]
        named: Dict[str, np.ndarray] = {}
        for key, meta in manifest["leaves"].items():
            parts = [s[key] for s in shards]
            parts = [p for p in parts if p.size or p.ndim == 0]
            if len(parts) == 1 or parts[0].ndim == 0:
                arr = parts[0]
            else:
                arr = np.concatenate(parts, axis=0)
            arr = _decode(arr, meta["dtype"])
            expect = tuple(meta["shape"])
            if arr.shape != expect:
                raise ValueError(f"{key}: restored {arr.shape} != {expect}")
            named[key] = arr
        return _unflatten_like(template, named)
