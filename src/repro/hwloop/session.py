"""Online hardware-in-the-loop session: emulate, observe, recalibrate.

:class:`HwLoopSession` is the piece that makes the paper's claim *operational*
inside the serving stack: per decode step it runs data-dependent probe
traffic through the :class:`~repro.hwloop.device.EmulatedAccelerator`,
feeds the observed per-partition Razor flags into the
:class:`~repro.runtime.monitor.CalibrationWatchdog`, and — when flags
persist past the watchdog's patience — re-runs the cached
``runtime_calibration`` stage of :mod:`repro.flow` mid-serve (the shared
:class:`~repro.flow.artifacts.ArtifactStore` keeps the
timing/cluster/floorplan prefix as cache hits) and swaps the fresh rails
onto the live device.  Lowering a rail below its safe point therefore
raises that partition's DETECTED rate for a few steps and then heals.

The session also owns token attribution for the energy ledger, so
``energy_per_token_j`` is meaningful to the serve engine's telemetry.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from ..flow.config import FlowConfig
from ..runtime.monitor import CalibrationWatchdog
from .device import EmulatedAccelerator, MatmulTelemetry


@dataclasses.dataclass
class StepTelemetry:
    """What one ``step()`` observed — the serve engine's per-step payload."""

    flags: np.ndarray               # (P,) bool DETECTED flags this step
    detected_p: np.ndarray          # (P,) DETECTED counts
    silent_p: np.ndarray            # (P,) SILENT counts (oracle-only view)
    rel_error: float
    recalibrated: bool              # the watchdog re-ran Algorithm 2


class HwLoopSession:
    """Voltage-aware emulation loop bound to one CAD-flow operating point.

    ``flow_config``  — the operating point; the session's watchdog runs the
    full Fig. 9 flow once up front (cached in ``store``).
    ``probe_rows``   — streamed activation rows per probe matmul.
    ``rail_margin``  — guard band added on top of the calibrated rails (both
    at init and after every recalibration); 0 runs exactly at the
    Algorithm-2 rails, which sit at the edge of the clean region by
    construction.
    """

    def __init__(self, flow_config: FlowConfig, *,
                 corruption: str = "stale",
                 patience: int = 3,
                 store=None,
                 probe_rows: int = 16,
                 rail_margin: float = 0.0,
                 leak_frac: float = 0.05,
                 seed: int = 0):
        self.config = flow_config
        self.rail_margin = float(rail_margin)
        self.watchdog = CalibrationWatchdog(flow_config, patience=patience,
                                            store=store)
        self.accel = EmulatedAccelerator.from_flow(
            self.watchdog.report, flow_config, corruption=corruption,
            leak_frac=leak_frac, seed=seed)
        self.accel.set_rails(self._guarded(self.watchdog.runtime_v))
        self.probe_rows = int(probe_rows)
        self._seed = int(seed)
        self.steps = 0
        self.recalibrations = 0
        self.flag_history: List[np.ndarray] = []
        self._obs = None   # ObsBus, when a serve engine attaches

    def _guarded(self, rails: np.ndarray) -> np.ndarray:
        return np.asarray(rails, dtype=np.float64) + self.rail_margin

    # -- experiment knobs -----------------------------------------------------

    @property
    def n_partitions(self) -> int:
        return self.accel.n_partitions

    @property
    def rails(self) -> np.ndarray:
        return self.accel.rails

    @property
    def rail_envelope(self) -> tuple:
        """``(floor_v, ceil_v)``: the tech node's physical rail band —
        threshold voltage up to the top of the paper's scaling range.
        Wider than the *calibrated* clean region on purpose: undervolt
        experiments (and the railscale policies probing toward NTC) may
        dip below the safe point — that is what the watchdog heals — but
        never below V_th into electrically meaningless territory."""
        node = self.config.node
        return float(node.v_th), float(max(node.v_nom, node.v_min))

    def set_partition_voltage(self, partition: int, v: float) -> None:
        """Lower (or raise) one rail live — the undervolting experiment.  A
        rail below the partition's safe point raises its DETECTED rate and,
        after the watchdog's patience, triggers a mid-serve recalibration
        that restores safe rails.

        Hardened: non-finite voltages are rejected, the write is clamped
        to the tech node's :attr:`rail_envelope`, and the
        ``hwloop_rail_volts`` gauge republishes immediately so a manual
        rail write can never leave the exported telemetry stale."""
        v = float(v)
        if not np.isfinite(v):
            raise ValueError(f"non-finite rail voltage {v!r} for partition "
                             f"{partition}")
        if not 0 <= int(partition) < self.n_partitions:
            raise IndexError(f"partition {partition} out of range "
                             f"[0, {self.n_partitions})")
        lo, hi = self.rail_envelope
        self.accel.set_partition_voltage(int(partition), min(max(v, lo), hi))
        self._publish_rails()

    # -- backend adapter -------------------------------------------------------

    def attach_accelerator(self, accel) -> None:
        """Bind the session to an external device — the serve engine's
        ``EmulatedBackend`` accelerator.  The session then stops generating
        probe traffic and instead acts as the watchdog adapter: real GEMM
        flags arrive via :meth:`observe_flags` and rail heals land on the
        live serving device (whose ledger also owns the energy accounting).

        A *foreign* device (not the session's own accel) gets the session's
        guarded calibrated rails applied — ``from_flow`` devices carry raw
        Algorithm-2 rails, which sit at the edge of the clean region and
        would trip spurious flags without the ``rail_margin`` band.
        Re-attaching the session's own accel is a no-op, so deliberate rail
        experiments (undervolting) survive engine reconstruction."""
        if accel is self.accel:
            return
        if accel.n_partitions != self.n_partitions:
            raise ValueError(
                f"attached device has {accel.n_partitions} partitions; the "
                f"session calibrated {self.n_partitions}")
        self.accel = accel
        accel.set_rails(self._guarded(np.asarray(self.watchdog.runtime_v)))

    def attach_obs(self, bus) -> None:
        """Attach a ``repro.obs.ObsBus``: recalibrations count into
        ``hwloop_recalibrations_total``, live rail voltages export as
        ``hwloop_rail_volts{partition=...}`` gauges, and every rail heal
        emits a ``rail_heal`` trace event into the flight recorder."""
        self._obs = bus
        self._c_recal = bus.registry.counter(
            "hwloop_recalibrations_total",
            "watchdog-triggered mid-serve rail recalibrations")
        self._g_rails = bus.registry.gauge(
            "hwloop_rail_volts", "live per-partition rail voltage (V)",
            labels=("partition",))
        self._publish_rails()

    def _publish_rails(self) -> None:
        if self._obs is None:
            return
        for p, v in enumerate(np.asarray(self.rails, dtype=np.float64)):
            self._g_rails.set(float(v), partition=str(p))

    def observe_flags(self, flags, n_tokens: int = 0) -> bool:
        """Feed one serving step's observed per-partition Razor flags into
        the watchdog; returns True when a recalibration fired (fresh rails
        are already swapped onto the attached device).  ``n_tokens`` > 0
        additionally attributes tokens to the device's energy ledger (the
        probe path does this; the backend adapter attributes its own)."""
        flags = np.asarray(flags, dtype=bool)
        if flags.shape != (self.n_partitions,):
            raise ValueError(f"expected {self.n_partitions} partition flags, "
                             f"got shape {flags.shape}")
        if n_tokens:
            self.accel.ledger.add_tokens(n_tokens)
        self.flag_history.append(flags)
        report = self.watchdog.observe(flags)
        recalibrated = report is not None
        if recalibrated:
            self.recalibrations += 1
            self.accel.set_rails(self._guarded(np.asarray(report.runtime_v)))
            if self._obs is not None:
                self._c_recal.inc()
                self._publish_rails()
                self._obs.event(
                    "rail_heal", step=self.steps,
                    rails_v=[float(v) for v in np.asarray(self.rails)])
        self.steps += 1
        return recalibrated

    # -- the loop --------------------------------------------------------------

    def step(self, tokens: Sequence[int],
             n_tokens: Optional[int] = None) -> StepTelemetry:
        """Emulate one serving step's accelerator traffic.

        ``tokens`` are the token ids the model emitted this step; the probe
        activations are derived from them deterministically, so the
        switching-activity term (and hence the failure probability at NTC)
        is data-dependent, as in the paper.  ``n_tokens`` (default
        ``len(tokens)``) is attributed to the energy ledger.
        """
        toks = np.atleast_1d(np.asarray(tokens, dtype=np.int64))
        n_tokens = len(toks) if n_tokens is None else int(n_tokens)
        n = self.accel.timing.n
        rng = np.random.default_rng(
            (self._seed * 1_000_003 + self.steps * 7919
             + int(toks.sum() % (2 ** 31))) & 0x7FFFFFFF)
        a = rng.normal(size=(self.probe_rows, n))
        w = rng.normal(size=(n, n))
        _, tel = self.accel.matmul(a, w)
        flags = np.asarray(tel.partition_flags, dtype=bool)
        recalibrated = self.observe_flags(flags, n_tokens=n_tokens)
        return StepTelemetry(flags=flags, detected_p=tel.detected_p,
                             silent_p=tel.silent_p, rel_error=tel.rel_error,
                             recalibrated=recalibrated)

    # -- telemetry -------------------------------------------------------------

    def flag_rate(self) -> np.ndarray:
        """(P,) fraction of steps on which each partition's flag fired."""
        if not self.flag_history:
            return np.zeros(self.n_partitions)
        return np.mean(np.asarray(self.flag_history, dtype=np.float64), axis=0)

    def summary(self) -> Dict[str, Any]:
        """Plain-JSON telemetry: flag rates, rails, recalibrations, energy."""
        return {
            "steps": self.steps,
            "flag_rate": self.flag_rate().tolist(),
            "recalibrations": self.recalibrations,
            "watchdog_recalibrations": self.watchdog.recalibrations,
            "rails_v": self.rails.tolist(),
            "rail_margin_v": self.rail_margin,
            "corruption": self.accel.corruption,
            **self.accel.ledger.summary(),
        }
