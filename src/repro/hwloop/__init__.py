"""repro.hwloop — voltage-aware fault-injection & energy-accounting emulation.

The missing loop between the CAD flow and real inference: a
:class:`FlowReport`'s calibrated voltage islands become an
:class:`EmulatedAccelerator` that executes matmuls with data-dependent
Razor fault injection and a cycle/energy ledger; :class:`HwLoopSession`
runs it online under the serve engine, feeding observed flag rates back
into the flow's ``runtime_calibration`` stage (via
:class:`~repro.runtime.monitor.CalibrationWatchdog`) so rails re-tune
mid-serve.

Quickstart::

    from repro.flow import FlowConfig
    from repro.hwloop import HwLoopSession

    session = HwLoopSession(FlowConfig(array_n=8, tech="vtr-22nm",
                                       max_trials=8))
    tel = session.step(tokens=[17, 42])        # one serving step's traffic
    print(session.summary()["energy_per_token_j"])

Pipeline integration: the ``hwloop`` stage (``repro.flow``'s registry) adds
voltage→(energy/token, replay-rate, accuracy-proxy) artifacts to any flow
run; :func:`hwloop_pipeline` returns the default chain with it inserted, so
``sweep(..., pipeline=hwloop_pipeline())`` produces Pareto tables across
tech nodes.
"""

from .device import EmulatedAccelerator, MatmulTelemetry, quantized_activity
from .energy import EnergyLedger
from .inject import (CORRUPTION_MODELS, bit_flip, get_corruption,
                     register_corruption, stale_psum, te_drop)
from .session import HwLoopSession, StepTelemetry


def hwloop_pipeline(**pipeline_kw):
    """The canonical Fig. 9 stage chain with the ``hwloop`` emulation stage
    inserted after ``power`` — ready for :func:`repro.flow.sweep`."""
    from ..flow import Pipeline, get_stage
    return Pipeline(**pipeline_kw).insert_after("power", get_stage("hwloop"))


__all__ = [
    "EmulatedAccelerator", "MatmulTelemetry", "quantized_activity",
    "EnergyLedger", "CORRUPTION_MODELS", "register_corruption",
    "get_corruption", "stale_psum", "te_drop", "bit_flip",
    "HwLoopSession", "StepTelemetry", "hwloop_pipeline",
]
